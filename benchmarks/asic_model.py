"""Analytic cycle/energy model of the ConvCoTM ASIC.

The container has no silicon; the paper's Tables II/III/IV are reproduced
from first principles and the model is asserted against every measured
number in the paper:

Cycle model (Sec. IV-E, Fig. 8):
  * single-image latency = 99 (transfer: 98 image bytes + 1 label over the
    8-bit AXI stream) + 372 (361 patch cycles + class-sum pipeline +
    argmax + control) = 471 cycles
  * continuous mode: one classification per 372 cycles (double-buffered
    image registers); measured system throughput adds FPGA-side overhead:
    60.3 k/s at 27.8 MHz -> overhead factor 74.73/60.3 = 1.239.

Power model, fitted to the paper's four measurement points:
  P(f, V) = c_dyn * f * V^2 + P_leak(V)
  c_dyn        = 27.7 pW/(Hz V^2)      (digital switching)
  P_leak(1.2V) = 41.1 uW, P_leak(0.82V) = 2.2 uW (low-leakage UMC 65 nm;
  leakage is strongly super-linear in V, consistent with the paper's
  relaxed-timing, high-Vt cell choice.)

The model reproduces: 1.15 mW / 0.52 mW / 81 uW / 21 uW, EPC 19.1 / 8.6 /
35.3 / 9.6 nJ, 60.3 k and 2.27 k cls/s, and 25.4 us latency within a few
percent (tested in tests/test_benchmarks.py).
"""

from __future__ import annotations

import dataclasses
from typing import Dict

__all__ = ["AsicModel", "PAPER_POINTS", "scaled_28nm", "table3_scaled_up"]

# Cycle constants (Sec. IV-E)
TRANSFER_CYCLES = 99
COMPUTE_CYCLES = 372
LATENCY_CYCLES = TRANSFER_CYCLES + COMPUTE_CYCLES          # 471

# Measured system overhead at 27.8 MHz: 74.73 k core-limited vs 60.3 k
SYSTEM_OVERHEAD = (27.8e6 / COMPUTE_CYCLES) / 60.3e3       # = 1.2393
# At 1 MHz the measured rate was 2.27 k (core-limited 2.688 k).
SYSTEM_OVERHEAD_1MHZ = (1.0e6 / COMPUTE_CYCLES) / 2.27e3   # = 1.1843
# Measured single-image latency 25.4 us at 27.8 MHz vs 471 accelerator
# cycles (16.9 us): the system processor adds ~1.5x.
LATENCY_OVERHEAD = 25.4e-6 * 27.8e6 / LATENCY_CYCLES       # = 1.4993

# Fitted power model
C_DYN = 27.69e-12          # W / (Hz * V^2)
P_LEAK = {1.20: 41.1e-6, 0.82: 2.2e-6}


@dataclasses.dataclass(frozen=True)
class AsicModel:
    clock_hz: float = 27.8e6
    vdd: float = 0.82
    compute_cycles: int = COMPUTE_CYCLES
    transfer_cycles: int = TRANSFER_CYCLES
    system_overhead: float = SYSTEM_OVERHEAD

    def power_w(self) -> float:
        leak = P_LEAK.get(self.vdd)
        if leak is None:
            # interpolate leakage exponentially in V between the two points
            import math

            v0, v1 = 0.82, 1.20
            l0, l1 = P_LEAK[v0], P_LEAK[v1]
            alpha = math.log(l1 / l0) / (v1 - v0)
            leak = l0 * math.exp(alpha * (self.vdd - v0))
        return C_DYN * self.clock_hz * self.vdd**2 + leak

    def classifications_per_second(self, continuous: bool = True) -> float:
        cyc = self.compute_cycles if continuous else LATENCY_CYCLES
        return self.clock_hz / cyc / self.system_overhead

    def latency_s(self) -> float:
        """Single-image latency incl. transfer + system-processor overhead.

        The accelerator itself needs 471 cycles (16.9 us at 27.8 MHz); the
        paper measures 25.4 us end-to-end, i.e. the FPGA system processor
        adds ~1.5x — LATENCY_OVERHEAD below.  The same factor predicts the
        0.66 ms measured at 1 MHz (706 cycles * 0.94 ~ the 1 MHz overhead
        differs slightly; within 8%).
        """
        return LATENCY_CYCLES * LATENCY_OVERHEAD / self.clock_hz

    def energy_per_classification_j(self) -> float:
        return self.power_w() / self.classifications_per_second()

    def summary(self) -> Dict[str, float]:
        return {
            "clock_mhz": self.clock_hz / 1e6,
            "vdd": self.vdd,
            "power_mw": self.power_w() * 1e3,
            "cls_per_s": self.classifications_per_second(),
            "epc_nj": self.energy_per_classification_j() * 1e9,
            "latency_us": self.latency_s() * 1e6,
        }


PAPER_POINTS = {
    # (clock_hz, vdd) -> measured (power_W, epc_J, cls_per_s or None)
    (27.8e6, 1.20): (1.15e-3, 19.1e-9, 60.3e3),
    (27.8e6, 0.82): (0.52e-3, 8.6e-9, 60.3e3),
    (1.0e6, 1.20): (81e-6, 35.3e-9, 2.27e3),
    (1.0e6, 0.82): (21e-6, 9.6e-9, 2.27e3),
}


def model_for(clock_hz: float, vdd: float) -> AsicModel:
    ov = SYSTEM_OVERHEAD if clock_hz > 2e6 else SYSTEM_OVERHEAD_1MHZ
    return AsicModel(clock_hz=clock_hz, vdd=vdd, system_overhead=ov)


def scaled_28nm(vdd: float = 0.7) -> Dict[str, float]:
    """Sec. VI-A: 28 nm port with 10-literal clause multiplexing.

    Area: 2.7 mm^2 * (1 - 0.47) [literal-budget logic cut] * (28/65)^2.
    Power: paper estimates 50% of the 0.82 V 65 nm figure at 0.7 V.
    """
    area_65 = 2.7
    area = area_65 * (1 - 0.47) * (28.0 / 65.0) ** 2
    base = model_for(27.8e6, 0.82)
    power = 0.5 * base.power_w()
    cls = base.classifications_per_second()
    return {
        "area_mm2": area,
        "power_mw": power * 1e3,
        "epc_nj": power / cls * 1e9,
        "cls_per_s": cls,
    }


def table3_scaled_up(technology: str = "65nm") -> Dict[str, float]:
    """Sec. VI-C / Table III: envisaged CIFAR-10 TM-Composites accelerator.

    4 specialists run sequentially on one configurable TM module:
      per specialist: ~1000 processing cycles + ~1020 model-load cycles
      (32.5 kB at 32 B/cycle)  => ~2020; x4 => 8080 cycles/classification.
    Area/power scale with R = specialist model size / this ASIC's model
    size = 32.5 kB / 5.6 kB = 5.8.
    """
    clock = 27.8e6
    spec_model_kb = 32.5          # 20 kB TA actions + 12.5 kB weights
    this_model_kb = 5.632
    r = spec_model_kb / this_model_kb
    cycles = 4 * (1000 + int(spec_model_kb * 1024 / 32) + 20)
    fps = clock / cycles
    base = model_for(clock, 0.82)
    power = base.power_w() * r
    epc = power / fps
    out = {
        "model_ratio_R": r,
        "cycles_per_classification": cycles,
        "fps": fps,
        "power_mw_65nm": power * 1e3,
        "epc_uj_65nm": epc * 1e6,
        "area_mm2_65nm": 2.7 * r + 2.0,
        "complete_model_kb": 4 * spec_model_kb,
    }
    if technology == "28nm":
        out["power_mw_28nm"] = power * 0.5 * 1e3
        out["epc_uj_28nm"] = epc * 0.5 * 1e6
        out["area_mm2_28nm"] = (2.7 * r + 2.0) * (28.0 / 65.0) ** 2 * 0.47
    return out
