"""Measured JAX ConvCoTM inference benchmarks (CPU wall-clock).

These time the algorithmic twin, not the chip: useful for comparing the
evaluation paths (dense / bitpacked / matmul / packed-serving) and for the
CSRF tile-skip statistics the paper reports (~50% clause-output toggling
reduction; we report the fraction of patch tiles the kernel may skip)."""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.convcotm import COTM_CONFIGS
from repro.core import infer, infer_packed, init_model
from repro.core.cotm import init_boundary_model
from repro.core.patches import extract_patch_features, make_literals, pack_bits

__all__ = ["bench_inference_paths", "csrf_skip_stats"]


def _timeit(fn, *args, iters=5) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def bench_inference_paths(batch: int = 64) -> List[Dict]:
    cfg0 = COTM_CONFIGS["convcotm-mnist"]
    key = jax.random.PRNGKey(0)
    model = init_boundary_model(key, cfg0)
    imgs = (jax.random.uniform(key, (batch, 28, 28)) > 0.6).astype(jnp.uint8)
    from repro.serve import available_paths

    rows = []
    for path in available_paths():
        cfg = dataclasses.replace(cfg0, eval_path=path)
        us = _timeit(lambda m, x: infer(m, x, cfg)[0], model, imgs)
        rows.append(
            {
                "name": f"convcotm_infer_{path}",
                "us_per_call": round(us, 1),
                "derived": f"{batch / us * 1e6:.0f} img/s (batch {batch})",
            }
        )
    # Serving fast path: literals packed ahead of time.
    feats = extract_patch_features(imgs, cfg0.patch)
    lp = pack_bits(make_literals(feats))
    us = _timeit(lambda m, x: infer_packed(m, x, cfg0)[0], model, lp)
    rows.append(
        {
            "name": "convcotm_infer_packed",
            "us_per_call": round(us, 1),
            "derived": f"{batch / us * 1e6:.0f} img/s (packed literals)",
        }
    )
    return rows


def csrf_skip_stats(batch: int = 64, block_p: int = 64) -> Dict:
    """Fraction of (image, patch-chunk) tiles the CSRF block-skip saves.

    A tile can be skipped once every clause in the block has fired — the
    TPU analogue of the paper's 'clause already 1 -> stop evaluating'
    feedback (which cut combinational toggling ~50% in the ASIC)."""
    # A briefly TRAINED model (clause fire statistics on random includes
    # are degenerate — real models fire because patterns were learned).
    import dataclasses as _dc

    from repro.core import update_batch
    from repro.data import booleanize_split, synthetic_glyphs

    cfg = _dc.replace(COTM_CONFIGS["convcotm-mnist"], n_clauses=64, T=60, s=3.0)
    key = jax.random.PRNGKey(1)
    model = init_model(key, cfg)
    tx, ty, _, _ = synthetic_glyphs(n_train=1000, n_test=10, seed=0)
    tx = jnp.asarray(booleanize_split(tx))
    ty = jnp.asarray(ty.astype(np.int32))
    for _ in range(6):
        for i in range(0, 1000, 100):
            key, k = jax.random.split(key)
            model = update_batch(k, model, tx[i:i+100], ty[i:i+100], cfg)
    imgs = tx[:batch]
    feats = extract_patch_features(imgs, cfg.patch)
    lits = make_literals(feats)
    from repro.core.clauses import clause_nonempty, patch_clause_outputs

    cp = np.asarray(patch_clause_outputs(lits, model.include))      # [B,P,C]
    ne = np.asarray(clause_nonempty(model.include))
    cp = cp & ne[None, None]
    b, p, c = cp.shape
    fired_cum = np.cumsum(cp, axis=1) > 0                            # OR register
    n_chunks = (p + block_p - 1) // block_p
    skippable = 0
    for i in range(1, n_chunks):
        start = i * block_p
        all_fired = fired_cum[:, start - 1, :].all(axis=1)           # [B]
        skippable += all_fired.sum()
    total = b * (n_chunks - 1)
    # Per-clause toggling proxy: fraction of patch evaluations after the
    # clause has latched (the work CSRF eliminates clause-wise).
    idx_first = np.argmax(cp, axis=1)                                # [B,C]
    ever = cp.any(axis=1)
    saved = np.where(ever, p - 1 - idx_first, 0).sum()
    evals = b * p * c
    return {
        "tile_skip_fraction": float(skippable) / max(total, 1),
        "clausewise_eval_saving": float(saved) / evals,
        "fired_fraction": float(ever.mean()),
    }
