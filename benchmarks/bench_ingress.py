"""Ingress benchmark: host pipeline vs the device-resident fused graph.

Measures the cost of getting raw pixels into the clause datapath, the
stage the ASIC gets "for free" (booleanized pixels stream straight into
the clause pool, Sec. IV-C) and the stage that dominated the serving
stack before the device-resident ingress:

  * **host**   — ``data.pipeline.preprocess_for_serving``: booleanize
    (jnp -> np), patch/literals/pack (np -> jnp -> np), literals back on
    the host.  At least three host<->device round trips per request.
  * **device** — ``core.ingress.device_ingress``: the same stages fused
    into one jitted dispatch; one H2D copy of raw uint8 in.
  * **e2e**    — the serving engine's full raw->predictions step
    (``classify``), device vs host ingress modes, isolating how much of
    request latency the ingress split explains.

Rows carry machine-readable ``fields`` (consumed by
``benchmarks/run.py --emit-json`` -> ``BENCH_ingress.json``) on top of
the repo's ``name,us_per_call,derived`` CSV contract.  Numbers land in
EXPERIMENTS.md §Ingress.

Run:  PYTHONPATH=src python -m benchmarks.bench_ingress [--quick] [--tiny]
"""

from __future__ import annotations

import argparse
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["bench_ingress", "tiny_config"]


def tiny_config():
    """A CI-smoke geometry: small clause pool, 7x7 patches."""
    from repro.core.cotm import CoTMConfig
    from repro.core.patches import PatchSpec

    return CoTMConfig(
        n_clauses=32,
        n_classes=10,
        patch=PatchSpec(image_x=11, image_y=11, window_x=5, window_y=5),
    )


def _paper_config():
    from repro.configs.convcotm import COTM_CONFIGS

    return COTM_CONFIGS["convcotm-mnist"]


def _time(fn, n_iter: int) -> float:
    """Median-of-runs microseconds per call (fn must block internally)."""
    ts = []
    for _ in range(n_iter):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def bench_ingress(
    methods=("threshold", "adaptive", "none"),
    buckets=(1, 64),
    n_iter: int = 10,
    tiny: bool = False,
    path: str = "fused",
) -> List[Dict]:
    """One row per (method, bucket): host vs device ingress microseconds,
    plus end-to-end engine rows (device vs host raw classify)."""
    from repro.core.cotm import init_boundary_model
    from repro.core.ingress import IngressSpec, device_ingress
    from repro.data.pipeline import preprocess_for_serving
    from repro.serve import ServingEngine, get_path

    cfg = tiny_config() if tiny else _paper_config()
    spec = cfg.patch
    packed = get_path(path).input_form == "packed"
    rng = np.random.default_rng(0)
    rows: List[Dict] = []

    for method in methods:
        ispec = IngressSpec(patch=spec, method=method, packed=packed)
        for b in buckets:
            raw = rng.integers(0, 256, (b, spec.image_y, spec.image_x))
            raw = (raw > 128).astype(np.uint8) if method == "none" else raw.astype(np.uint8)

            def host():
                preprocess_for_serving(raw, spec, method=method, packed=packed)

            def device():
                jax.block_until_ready(device_ingress(ispec, jnp.asarray(raw)))

            host()      # trace/compile warmup
            device()
            host_us = _time(host, n_iter)
            dev_us = _time(device, n_iter)
            rows.append(
                {
                    "name": f"ingress_{method}_b{b}",
                    "us_per_call": round(dev_us, 1),
                    "derived": (
                        f"device {dev_us:,.0f} us vs host {host_us:,.0f} us "
                        f"({host_us / dev_us:.1f}x) | "
                        f"{b / dev_us * 1e6:,.0f} img/s device ingress"
                    ),
                    "fields": {
                        "kind": "ingress",
                        "method": method,
                        "bucket": b,
                        "host_us": host_us,
                        "device_us": dev_us,
                        "speedup": host_us / dev_us,
                    },
                }
            )

    # End to end: the engine's raw path, device vs host ingress modes.
    engine = ServingEngine(max_batch=max(buckets))
    model = init_boundary_model(jax.random.PRNGKey(0), cfg)
    engine.register("m", model, cfg, booleanize_method="threshold", path=path)
    engine.warmup("m", buckets=buckets)
    for b in buckets:
        raw = rng.integers(0, 256, (b, spec.image_y, spec.image_x)).astype(np.uint8)
        for mode in ("device", "host"):
            engine.classify("m", raw, ingress=mode)   # warm ingress caches
            us = _time(
                lambda m=mode: engine.classify("m", raw, ingress=m), n_iter
            )
            st = engine.stats("m")
            rows.append(
                {
                    "name": f"classify_raw_{mode}_{path}_b{b}",
                    "us_per_call": round(us, 1),
                    "derived": (
                        f"{b / us * 1e6:,.0f} cls/s end-to-end raw ({mode} "
                        f"ingress) | split so far: ingress "
                        f"{st.mean_ingress_us:,.0f} us / device "
                        f"{st.mean_device_us:,.0f} us per request"
                    ),
                    "fields": {
                        "kind": "classify_raw",
                        "ingress": mode,
                        "path": path,
                        "bucket": b,
                        "us_per_request": us,
                        "cls_per_s": b / us * 1e6,
                    },
                }
            )
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer methods/reps")
    ap.add_argument("--tiny", action="store_true", help="CI-smoke geometry")
    ap.add_argument("--path", default="fused")
    args = ap.parse_args()
    kw = dict(tiny=args.tiny, path=args.path)
    if args.quick:
        kw.update(methods=("threshold",), buckets=(1, 8), n_iter=3)
    print("name,us_per_call,derived")
    for r in bench_ingress(**kw):
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")


if __name__ == "__main__":
    main()
