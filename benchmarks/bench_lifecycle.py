"""Hot-swap pause benchmark: what a swap storm costs live traffic.

The lifecycle contract (ARCHITECTURE.md §Lifecycle) is *zero-downtime*:
a swap never drops or fails a request.  What it may do is add latency —
the engine lock serializes the install against microbatch dispatch, and
the candidate pays its per-version sparsity analysis before the flip.
This benchmark measures that pause directly:

  * **baseline** — open-loop Poisson load (single raw images through the
    device-resident ingress), no lifecycle events: p50/p99 latency;
  * **swap storm** — the identical load while hot swaps + a rollback
    land mid-stream (weight-variant candidates, the shape a retrained
    model actually has): p50/p99 again.  The p99 delta is the headline
    "swap pause" number (EXPERIMENTS.md §Lifecycle);
  * **install costs** — wall time of ``engine.swap`` (freeze + sparsity
    analysis + stamp + flip) and ``engine.rollback`` (an O(1) pointer
    flip) off the serving path, plus the jit cache growth across the
    storm (0 once the pow2 sparsity bin is warm — the
    compiles-only-the-delta contract, tests/test_lifecycle.py).

Run:  PYTHONPATH=src python -m benchmarks.bench_lifecycle [--tiny] [--quick]
"""

from __future__ import annotations

import argparse
import asyncio
import time
from typing import Dict, List

import jax
import numpy as np

__all__ = ["bench_lifecycle"]


def _setup(max_batch: int, tiny: bool):
    from repro.core.cotm import CoTMModel, init_boundary_model
    from repro.serve import ServingEngine

    if tiny:
        from benchmarks.bench_ingress import tiny_config

        cfg = tiny_config()
    else:
        from repro.configs.convcotm import COTM_CONFIGS

        cfg = COTM_CONFIGS["convcotm-mnist"]
    base = init_boundary_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    w = np.asarray(base.weights)
    variants = [
        CoTMModel(
            ta_state=base.ta_state,
            weights=jax.numpy.asarray(
                w + rng.integers(-3, 4, w.shape).astype(w.dtype)
            ),
        )
        for _ in range(8)
    ]
    engine = ServingEngine(max_batch=max_batch)
    engine.register("m", base, cfg, booleanize_method="threshold")
    engine.warmup("m", forms=("raw",))
    # Warm the pow2-binned sparsity shape a swapped-in image carries, so
    # the storm measures the install pause, not one-time compiles.
    engine.swap("m", variants[0], cfg)
    engine.warmup("m", forms=("raw",))
    side = cfg.patch.image_y
    imgs = rng.integers(0, 256, (64, side, side)).astype(np.uint8)
    pool = [imgs[i : i + 1] for i in range(len(imgs))]
    return engine, cfg, variants, pool


async def _run(
    engine, cfg, pool, *, rate: float, n_requests: int, seed: int,
    swaps=None,
) -> Dict:
    """One open-loop run; ``swaps`` (model list) land evenly spaced
    through the stream via the service's off-loop swap, ending with one
    rollback.  Returns latency stats + per-event install times."""
    from repro.serve import ServiceConfig, ServingService
    from repro.serve.loadgen import poisson_open_loop

    service = ServingService(engine, ServiceConfig(max_delay_us=200.0))
    await service.start()
    rng = np.random.default_rng(seed)
    pick = rng.integers(0, len(pool), n_requests)
    load = asyncio.create_task(
        poisson_open_loop(
            service, "m", [pool[i] for i in pick], rate, seed=seed
        )
    )
    swap_s: List[float] = []
    rollback_s = 0.0
    if swaps:
        gap = n_requests / rate / (len(swaps) + 2)
        for candidate in swaps:
            await asyncio.sleep(gap)
            t0 = time.perf_counter()
            await service.swap("m", candidate, cfg)
            swap_s.append(time.perf_counter() - t0)
        await asyncio.sleep(gap)
        t0 = time.perf_counter()
        await service.rollback("m")
        rollback_s = time.perf_counter() - t0
    admitted, rejected = await load
    await asyncio.gather(*(f for _, f in admitted))
    await service.stop(drain=True)
    st = service.stats("m")
    return {
        "p50_us": st.p50_latency_us,
        "p99_us": st.p99_latency_us,
        "completed": st.completed,
        "rejected": rejected,
        "swap_ms": [s * 1e3 for s in swap_s],
        "rollback_ms": rollback_s * 1e3,
    }


def bench_lifecycle(
    rate: float = 2000.0,
    n_requests: int = 400,
    n_swaps: int = 4,
    max_batch: int = 256,
    tiny: bool = False,
) -> List[Dict]:
    import repro.serve.engine as engine_mod
    from tools.recompile_guard import RecompileGuard

    engine, cfg, variants, pool = _setup(max_batch, tiny=tiny)
    base_r = asyncio.run(
        _run(engine, cfg, pool, rate=rate, n_requests=n_requests, seed=2)
    )
    guard = RecompileGuard(
        engine_mod.classify_step, (engine_mod, "_raw_step_jit"),
        allow=10**9,   # measuring, not asserting — tests own the assert
    )
    with guard:
        storm_r = asyncio.run(
            _run(
                engine, cfg, pool, rate=rate, n_requests=n_requests, seed=2,
                swaps=variants[1 : 1 + n_swaps],
            )
        )
    compiles = sum(d.grew for d in guard.deltas if d.grew > 0)
    added_p99 = storm_r["p99_us"] - base_r["p99_us"]
    swap_ms = storm_r["swap_ms"]
    rows = [
        {
            "name": "lifecycle_baseline",
            "us_per_call": round(base_r["p50_us"], 1),
            "derived": (
                f"no lifecycle events | p50 {base_r['p50_us']:,.0f} us "
                f"p99 {base_r['p99_us']:,.0f} us | "
                f"{base_r['completed']} completed, "
                f"{base_r['rejected']} rejected"
            ),
            "fields": {"kind": "lifecycle", **base_r, "rate": rate},
        },
        {
            "name": f"lifecycle_swap_storm_x{n_swaps}",
            "us_per_call": round(storm_r["p50_us"], 1),
            "derived": (
                f"{n_swaps} swaps + 1 rollback mid-stream | p50 "
                f"{storm_r['p50_us']:,.0f} us p99 {storm_r['p99_us']:,.0f} us "
                f"(added p99 {added_p99:+,.0f} us) | swap install "
                f"{np.mean(swap_ms):,.1f} ms mean, rollback "
                f"{storm_r['rollback_ms']:,.2f} ms | {compiles} compiles | "
                f"{storm_r['completed']} completed, "
                f"{storm_r['rejected']} rejected"
            ),
            "fields": {
                "kind": "lifecycle", **storm_r, "rate": rate,
                "added_p99_us": added_p99, "compiles": compiles,
            },
        },
    ]
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer requests")
    ap.add_argument("--tiny", action="store_true", help="CI-smoke geometry")
    ap.add_argument("--rate", type=float, default=2000.0)
    args = ap.parse_args()
    kw = dict(tiny=args.tiny, rate=args.rate)
    if args.quick:
        kw.update(n_requests=150, n_swaps=3)
    print("name,us_per_call,derived")
    for r in bench_lifecycle(**kw):
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")


if __name__ == "__main__":
    main()
