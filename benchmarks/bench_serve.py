"""Serving-engine throughput benchmark vs. the paper's ASIC figures.

Measures end-to-end classifications/s of the batched ``repro.serve``
engine at the paper's exact model scale (128 clauses, 361 patches, 272
literals), across several power-of-two batch buckets, and compares
against the chip's 60.3k classifications/s and 25.4 us single-image
latency (Table II, 27.8 MHz point).

Two raw-request ingress modes are measured:

  * ``device`` (default) — the fused raw->predictions graph: one jitted
    step per bucket, single H2D copy (``core.ingress``);
  * ``host`` — the legacy per-request host pipeline (booleanize ->
    patch -> pack on the host, three round trips), kept as the baseline.

Rows carry machine-readable ``fields`` for ``benchmarks/run.py
--emit-json`` (-> ``BENCH_serve.json``); per-request latency is split
into ingress vs device components (EXPERIMENTS.md §Ingress).  Every
``serve_engine`` row also carries the analytic roofline columns from
``roofline.analysis.tm_path_roofline`` — the v5e ceiling for the path
that actually ran (``resolved_path``: the autotuned winner, or a sparse
path's dense fallback) and the achieved fraction against it
(EXPERIMENTS.md §Sparsity).

``bench_serve`` sweeps one or more eval paths (``paths=``, CLI
``--paths fused,fused_sparse``); ``--autotune`` registers under the
per-bucket autotuner so rows report the tuned winner per (form, bucket).

``bench_sparsity_sweep`` measures the sparse-vs-dense crossover: for a
range of active-clause fractions (empty clauses forced by zeroing TA
rows — no include => empty, Sec. IV-D) it times each dense path against
its sparse twin and reports the device-side speedup per fraction
(EXPERIMENTS.md §Sparsity).

``bench_serve_mesh`` adds per-device-count rows (the ``serve_mesh``
kind): the same raw-pixel workload served by a :class:`ServeMesh`-backed
engine at 1/2/8 data shards — each row records the devices the batch was
actually spread over (EXPERIMENTS.md §Serve/mesh).  Run it with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` on CPU;
``benchmarks/run.py --emit-json`` does so via a subprocess so the main
harness stays single-device.

Runs on CPU with the ``ref`` kernel backend (the non-TPU default).

Run:  PYTHONPATH=src python -m benchmarks.bench_serve [--quick] [--tiny]
          [--paths fused,fused_sparse] [--autotune] [--sparsity]
      XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python -m benchmarks.bench_serve --mesh [--tiny]
"""

from __future__ import annotations

import argparse
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

PAPER_RATE = 60_300        # classifications/s @ 27.8 MHz
PAPER_LATENCY_US = 25.4    # single-image latency incl. system overhead

__all__ = ["bench_serve", "bench_serve_mesh", "bench_sparsity_sweep"]


def _config(tiny: bool):
    if tiny:
        from benchmarks.bench_ingress import tiny_config

        return tiny_config()
    from repro.configs.convcotm import COTM_CONFIGS

    return COTM_CONFIGS["convcotm-mnist"]


def _engine(
    path: str,
    max_batch: int,
    tiny: bool = False,
    mesh=None,
    *,
    autotune: bool = False,
    model=None,
):
    from repro.core.cotm import init_boundary_model
    from repro.serve import ServingEngine

    cfg = _config(tiny)
    if model is None:
        model = init_boundary_model(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(max_batch=max_batch, mesh=mesh, autotune=autotune)
    engine.register("mnist", model, cfg, booleanize_method="threshold", path=path)
    return engine, cfg


def _roofline_fields(engine, cfg, form: str, bucket: int) -> Dict:
    """The analytic-ceiling columns for the path a (form, bucket)
    dispatch actually evaluates (tuned winner / fallback-resolved)."""
    from repro.roofline.analysis import tm_path_roofline

    resolved, params = engine.resolved_path("mnist", form, bucket)
    sp = engine.servable("mnist").sparsity
    rl = tm_path_roofline(
        cfg,
        resolved,
        engine.bucket_for(bucket),
        n_active=None if sp is None else sp.n_active,
    )
    return {
        "resolved_path": resolved,
        "tuned_params": [list(kv) for kv in params],
        "roofline_bound": rl["bound"],
        "roofline_ceiling_cls_per_s": rl["ceiling_cls_per_s"],
    }


def bench_serve(
    buckets=(1, 8, 64, 256),
    n_requests: int = 10,
    path: str = "fused",
    ingress_modes=("device", "host"),
    tiny: bool = False,
    paths: Optional[Sequence[str]] = None,
    autotune: bool = False,
) -> List[Dict]:
    """One CSV row per (path, ingress mode, batch bucket): us/request +
    classifications/s + the ingress/device latency split + the roofline
    ceiling/fraction for the path that actually ran."""
    rows = []
    for p in paths if paths is not None else (path,):
        rows += _bench_serve_one(
            p, buckets, n_requests, ingress_modes, tiny, autotune
        )
    return rows


def _bench_serve_one(
    path: str, buckets, n_requests, ingress_modes, tiny, autotune
) -> List[Dict]:
    engine, cfg = _engine(path, max_batch=max(buckets), tiny=tiny, autotune=autotune)
    if autotune:
        # Tune every measured bucket (not just min/max) so each row's
        # resolved_path is that bucket's winner, then warm the winners.
        engine.autotune("mnist", buckets=buckets)
    engine.warmup("mnist", buckets=buckets)
    rng = np.random.default_rng(0)
    side = cfg.patch.image_y
    rows = []
    for mode in ingress_modes:
        form = "raw" if mode == "device" else "literals"
        for bucket in buckets:
            imgs = rng.integers(0, 256, (bucket, side, side)).astype(np.uint8)
            # One untimed request: warms the host-side trace caches for
            # this shape; the jitted classify step itself was compiled by
            # engine.warmup above.
            engine.classify("mnist", imgs, ingress=mode)
            t = t_in = t_dev = 0.0
            for _ in range(n_requests):
                res = engine.classify("mnist", imgs, ingress=mode)
                t += res.latency_s
                t_in += res.ingress_s
                t_dev += res.device_s
            n = n_requests * bucket
            rate = n / t
            us = t / n_requests * 1e6
            rl = _roofline_fields(engine, cfg, form, bucket)
            rl["roofline_fraction"] = (
                rate / rl["roofline_ceiling_cls_per_s"]
                if rl["roofline_ceiling_cls_per_s"] > 0
                else 0.0
            )
            rows.append(
                {
                    "name": f"serve_engine_{path}_{mode}_b{bucket}",
                    "us_per_call": round(us, 1),
                    "derived": (
                        f"{rate:,.0f} class/s = {rate / PAPER_RATE:.3f}x ASIC "
                        f"({PAPER_RATE}/s); per-image {us / bucket:.1f} us "
                        f"vs chip {PAPER_LATENCY_US} us | split ingress "
                        f"{t_in / n_requests * 1e6:,.0f} us / device "
                        f"{t_dev / n_requests * 1e6:,.0f} us | "
                        f"ran {rl['resolved_path']} at "
                        f"{rl['roofline_fraction']:.1e} of "
                        f"{rl['roofline_bound']}-bound ceiling"
                    ),
                    "fields": {
                        "kind": "serve_engine",
                        "path": path,
                        "ingress": mode,
                        "bucket": bucket,
                        "us_per_request": us,
                        "cls_per_s": rate,
                        "x_asic": rate / PAPER_RATE,
                        "ingress_us": t_in / n_requests * 1e6,
                        "device_us": t_dev / n_requests * 1e6,
                        "autotuned": autotune,
                        **rl,
                    },
                }
            )
    st = engine.stats("mnist")
    rows.append(
        {
            "name": f"serve_engine_{path}_compiles",
            "us_per_call": 0,
            "derived": (
                f"{len(st.compiled_buckets)} bucket compiles for "
                f"{st.requests} requests (bounded-recompile contract)"
                + (
                    f"; autotune {st.autotune.get('total_s', 0):.1f}s over "
                    f"{len(st.autotune.get('plan', []))} plan entries"
                    if st.autotune
                    else ""
                )
            ),
            "fields": {
                "kind": "compiles",
                "path": path,
                "compiled_buckets": list(st.compiled_buckets),
                "requests": st.requests,
                **(
                    {
                        "autotune_total_s": st.autotune.get("total_s"),
                        "autotune_plan": st.autotune.get("plan"),
                    }
                    if st.autotune
                    else {}
                ),
            },
        }
    )
    return rows


def _model_with_active_fraction(cfg, fraction: float, key: int = 0):
    """A boundary-initialised model whose trailing clauses are forced
    empty: zeroed TA rows sit below TA_HALF, so every literal is
    excluded and the clause can never fire (the Sec. IV-D empty-clause
    rule) — ``analyze_sparsity`` then drops them from the active set."""
    import dataclasses

    import jax.numpy as jnp

    from repro.core.cotm import TA_HALF, init_boundary_model

    model = init_boundary_model(jax.random.PRNGKey(key), cfg)
    n_clauses = model.ta_state.shape[0]
    n_active = int(round(n_clauses * fraction))
    ta = np.asarray(model.ta_state).copy()
    ta[n_active:] = 0
    if n_active:                      # keep survivors provably non-empty
        ta[:n_active, 0] = np.maximum(ta[:n_active, 0], TA_HALF)
    return dataclasses.replace(model, ta_state=jnp.asarray(ta)), n_active


def bench_sparsity_sweep(
    active_fractions=(0.0625, 0.25, 0.5, 1.0),
    pairs=(
        ("bitpacked", "sparse"),
        ("matmul", "matmul_sparse"),
        ("fused", "fused_sparse"),
    ),
    bucket: int = 64,
    n_requests: int = 5,
    tiny: bool = False,
) -> List[Dict]:
    """Sparse-vs-dense crossover: per active-clause fraction, time each
    dense path against its sparse twin on the same model and report the
    device-side speedup.  The crossover point (where the sparse win
    exceeds its gather overhead) is what the autotuner discovers
    empirically per (bucket, geometry)."""
    cfg = _config(tiny)
    side = cfg.patch.image_y
    rng = np.random.default_rng(0)
    rows = []
    for fraction in active_fractions:
        model, n_active = _model_with_active_fraction(cfg, fraction)
        imgs = rng.integers(0, 256, (bucket, side, side)).astype(np.uint8)
        dense_dev_us: Dict[str, float] = {}
        for dense_name, sparse_name in pairs:
            for p in (dense_name, sparse_name):
                engine, _ = _engine(p, max_batch=bucket, tiny=tiny, model=model)
                engine.warmup("mnist", buckets=(bucket,), forms=("raw",))
                engine.classify("mnist", imgs)      # host-cache warmup
                t = t_dev = 0.0
                for _ in range(n_requests):
                    res = engine.classify("mnist", imgs)
                    t += res.latency_s
                    t_dev += res.device_s
                rate = n_requests * bucket / t
                dev_us = t_dev / n_requests * 1e6
                if p == dense_name:
                    dense_dev_us[dense_name] = dev_us
                speedup = (
                    dense_dev_us[dense_name] / dev_us if p == sparse_name else 1.0
                )
                rl = _roofline_fields(engine, cfg, "raw", bucket)
                rows.append(
                    {
                        "name": f"sparsity_{p}_a{fraction:g}_b{bucket}",
                        "us_per_call": round(dev_us, 1),
                        "derived": (
                            f"{n_active} active clauses ({fraction:.0%}): "
                            f"{rate:,.0f} class/s, device {dev_us:,.0f} us"
                            + (
                                f" = {speedup:.2f}x vs {dense_name}"
                                if p == sparse_name
                                else ""
                            )
                        ),
                        "fields": {
                            "kind": "sparsity_sweep",
                            "path": p,
                            "dense_twin": dense_name,
                            "active_fraction": fraction,
                            "n_active": n_active,
                            "bucket": bucket,
                            "cls_per_s": rate,
                            "device_us": dev_us,
                            "speedup_vs_dense": speedup,
                            **rl,
                        },
                    }
                )
    return rows


def bench_serve_mesh(
    device_counts=(1, 2, 8),
    buckets=(8, 64),
    n_requests: int = 5,
    path: str = "fused",
    tiny: bool = False,
) -> List[Dict]:
    """Per-device-count serving rows: the raw-pixel path on a data-
    parallel :class:`ServeMesh` at each device count (skipping counts the
    process does not have; set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` on CPU).

    Each row's ``fields`` carry ``devices`` (mesh size),
    ``devices_used`` (devices the dispatched batch actually spread over
    — asserted == mesh size by the multidevice CI job's tests) and
    ``per_device_bucket`` alongside the usual throughput numbers.
    """
    from repro.serve import make_serve_mesh

    rows = []
    avail = jax.device_count()
    rng = np.random.default_rng(0)
    for nd in device_counts:
        if nd > avail:
            continue
        smesh = make_serve_mesh(nd, 1)
        engine, cfg = _engine(path, max_batch=max(buckets), tiny=tiny, mesh=smesh)
        side = cfg.patch.image_y
        engine.warmup("mnist", buckets=[b for b in buckets if b >= nd], forms=("raw",))
        for bucket in buckets:
            if bucket < nd:
                continue  # smaller than one image per shard
            imgs = rng.integers(0, 256, (bucket, side, side)).astype(np.uint8)
            devices_used = len(
                {s.device for s in smesh.place_batch(imgs).addressable_shards}
            )
            engine.classify("mnist", imgs)   # untimed host-cache warmup
            t = 0.0
            for _ in range(n_requests):
                t += engine.classify("mnist", imgs).latency_s
            rate = n_requests * bucket / t
            us = t / n_requests * 1e6
            rows.append(
                {
                    "name": f"serve_mesh_{path}_d{nd}_b{bucket}",
                    "us_per_call": round(us, 1),
                    "derived": (
                        f"{rate:,.0f} class/s on {nd} device(s) "
                        f"({bucket // nd}/device of bucket {bucket}) = "
                        f"{rate / PAPER_RATE:.3f}x ASIC; batch spread over "
                        f"{devices_used} devices"
                    ),
                    "fields": {
                        "kind": "serve_mesh",
                        "path": path,
                        "devices": nd,
                        "devices_used": devices_used,
                        "bucket": bucket,
                        "per_device_bucket": bucket // nd,
                        "us_per_request": us,
                        "cls_per_s": rate,
                        "x_asic": rate / PAPER_RATE,
                    },
                }
            )
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="two buckets, fewer reps")
    ap.add_argument("--tiny", action="store_true", help="CI-smoke geometry")
    ap.add_argument("--path", default="fused")
    ap.add_argument("--paths", default=None,
                    help="comma-separated eval paths to sweep (overrides --path)")
    ap.add_argument("--autotune", action="store_true",
                    help="register under the per-bucket autotuner; rows "
                         "report the tuned winner per (form, bucket)")
    ap.add_argument("--sparsity", action="store_true",
                    help="sparse-vs-dense crossover sweep over active-"
                         "clause fractions instead of the bucket sweep")
    ap.add_argument("--mesh", action="store_true",
                    help="per-device-count ServeMesh rows instead of the "
                         "single-device sweep (wants 8 virtual devices)")
    args = ap.parse_args()
    buckets = (8, 64) if args.quick else (1, 8, 64, 256)
    reps = 3 if args.quick else 10
    print("name,us_per_call,derived")
    if args.mesh:
        for r in bench_serve_mesh(
            n_requests=reps, path=args.path, tiny=args.tiny
        ):
            print(f"{r['name']},{r['us_per_call']},{r['derived']}")
        return
    if args.sparsity:
        for r in bench_sparsity_sweep(
            bucket=8 if args.quick or args.tiny else 64,
            n_requests=reps,
            tiny=args.tiny,
        ):
            print(f"{r['name']},{r['us_per_call']},{r['derived']}")
        return
    for r in bench_serve(
        buckets=buckets,
        n_requests=reps,
        path=args.path,
        paths=args.paths.split(",") if args.paths else None,
        ingress_modes=("device", "host"),
        tiny=args.tiny,
        autotune=args.autotune,
    ):
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")


if __name__ == "__main__":
    main()
