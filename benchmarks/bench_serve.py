"""Serving-engine throughput benchmark vs. the paper's ASIC figures.

Measures end-to-end classifications/s of the batched ``repro.serve``
engine at the paper's exact model scale (128 clauses, 361 patches, 272
literals), across several power-of-two batch buckets, and compares
against the chip's 60.3k classifications/s and 25.4 us single-image
latency (Table II, 27.8 MHz point).

Two raw-request ingress modes are measured:

  * ``device`` (default) — the fused raw->predictions graph: one jitted
    step per bucket, single H2D copy (``core.ingress``);
  * ``host`` — the legacy per-request host pipeline (booleanize ->
    patch -> pack on the host, three round trips), kept as the baseline.

Rows carry machine-readable ``fields`` for ``benchmarks/run.py
--emit-json`` (-> ``BENCH_serve.json``); per-request latency is split
into ingress vs device components (EXPERIMENTS.md §Ingress).

``bench_serve_mesh`` adds per-device-count rows (the ``serve_mesh``
kind): the same raw-pixel workload served by a :class:`ServeMesh`-backed
engine at 1/2/8 data shards — each row records the devices the batch was
actually spread over (EXPERIMENTS.md §Serve/mesh).  Run it with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` on CPU;
``benchmarks/run.py --emit-json`` does so via a subprocess so the main
harness stays single-device.

Runs on CPU with the ``ref`` kernel backend (the non-TPU default).

Run:  PYTHONPATH=src python -m benchmarks.bench_serve [--quick] [--tiny]
      XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python -m benchmarks.bench_serve --mesh [--tiny]
"""

from __future__ import annotations

import argparse
from typing import Dict, List

import jax
import numpy as np

PAPER_RATE = 60_300        # classifications/s @ 27.8 MHz
PAPER_LATENCY_US = 25.4    # single-image latency incl. system overhead

__all__ = ["bench_serve", "bench_serve_mesh"]


def _engine(path: str, max_batch: int, tiny: bool = False, mesh=None):
    from repro.core.cotm import init_boundary_model
    from repro.serve import ServingEngine

    if tiny:
        from benchmarks.bench_ingress import tiny_config

        cfg = tiny_config()
    else:
        from repro.configs.convcotm import COTM_CONFIGS

        cfg = COTM_CONFIGS["convcotm-mnist"]
    model = init_boundary_model(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(max_batch=max_batch, mesh=mesh)
    engine.register("mnist", model, cfg, booleanize_method="threshold", path=path)
    return engine, cfg


def bench_serve(
    buckets=(1, 8, 64, 256),
    n_requests: int = 10,
    path: str = "fused",
    ingress_modes=("device", "host"),
    tiny: bool = False,
) -> List[Dict]:
    """One CSV row per (ingress mode, batch bucket): us/request +
    classifications/s + the ingress/device latency split."""
    engine, cfg = _engine(path, max_batch=max(buckets), tiny=tiny)
    engine.warmup("mnist", buckets=buckets)
    rng = np.random.default_rng(0)
    side = cfg.patch.image_y
    rows = []
    for mode in ingress_modes:
        for bucket in buckets:
            imgs = rng.integers(0, 256, (bucket, side, side)).astype(np.uint8)
            # One untimed request: warms the host-side trace caches for
            # this shape; the jitted classify step itself was compiled by
            # engine.warmup above.
            engine.classify("mnist", imgs, ingress=mode)
            t = t_in = t_dev = 0.0
            for _ in range(n_requests):
                res = engine.classify("mnist", imgs, ingress=mode)
                t += res.latency_s
                t_in += res.ingress_s
                t_dev += res.device_s
            n = n_requests * bucket
            rate = n / t
            us = t / n_requests * 1e6
            rows.append(
                {
                    "name": f"serve_engine_{path}_{mode}_b{bucket}",
                    "us_per_call": round(us, 1),
                    "derived": (
                        f"{rate:,.0f} class/s = {rate / PAPER_RATE:.3f}x ASIC "
                        f"({PAPER_RATE}/s); per-image {us / bucket:.1f} us "
                        f"vs chip {PAPER_LATENCY_US} us | split ingress "
                        f"{t_in / n_requests * 1e6:,.0f} us / device "
                        f"{t_dev / n_requests * 1e6:,.0f} us"
                    ),
                    "fields": {
                        "kind": "serve_engine",
                        "path": path,
                        "ingress": mode,
                        "bucket": bucket,
                        "us_per_request": us,
                        "cls_per_s": rate,
                        "x_asic": rate / PAPER_RATE,
                        "ingress_us": t_in / n_requests * 1e6,
                        "device_us": t_dev / n_requests * 1e6,
                    },
                }
            )
    st = engine.stats("mnist")
    rows.append(
        {
            "name": f"serve_engine_{path}_compiles",
            "us_per_call": 0,
            "derived": (
                f"{len(st.compiled_buckets)} bucket compiles for "
                f"{st.requests} requests (bounded-recompile contract)"
            ),
            "fields": {
                "kind": "compiles",
                "path": path,
                "compiled_buckets": list(st.compiled_buckets),
                "requests": st.requests,
            },
        }
    )
    return rows


def bench_serve_mesh(
    device_counts=(1, 2, 8),
    buckets=(8, 64),
    n_requests: int = 5,
    path: str = "fused",
    tiny: bool = False,
) -> List[Dict]:
    """Per-device-count serving rows: the raw-pixel path on a data-
    parallel :class:`ServeMesh` at each device count (skipping counts the
    process does not have; set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` on CPU).

    Each row's ``fields`` carry ``devices`` (mesh size),
    ``devices_used`` (devices the dispatched batch actually spread over
    — asserted == mesh size by the multidevice CI job's tests) and
    ``per_device_bucket`` alongside the usual throughput numbers.
    """
    from repro.serve import make_serve_mesh

    rows = []
    avail = jax.device_count()
    rng = np.random.default_rng(0)
    for nd in device_counts:
        if nd > avail:
            continue
        smesh = make_serve_mesh(nd, 1)
        engine, cfg = _engine(path, max_batch=max(buckets), tiny=tiny, mesh=smesh)
        side = cfg.patch.image_y
        engine.warmup("mnist", buckets=[b for b in buckets if b >= nd], forms=("raw",))
        for bucket in buckets:
            if bucket < nd:
                continue  # smaller than one image per shard
            imgs = rng.integers(0, 256, (bucket, side, side)).astype(np.uint8)
            devices_used = len(
                {s.device for s in smesh.place_batch(imgs).addressable_shards}
            )
            engine.classify("mnist", imgs)   # untimed host-cache warmup
            t = 0.0
            for _ in range(n_requests):
                t += engine.classify("mnist", imgs).latency_s
            rate = n_requests * bucket / t
            us = t / n_requests * 1e6
            rows.append(
                {
                    "name": f"serve_mesh_{path}_d{nd}_b{bucket}",
                    "us_per_call": round(us, 1),
                    "derived": (
                        f"{rate:,.0f} class/s on {nd} device(s) "
                        f"({bucket // nd}/device of bucket {bucket}) = "
                        f"{rate / PAPER_RATE:.3f}x ASIC; batch spread over "
                        f"{devices_used} devices"
                    ),
                    "fields": {
                        "kind": "serve_mesh",
                        "path": path,
                        "devices": nd,
                        "devices_used": devices_used,
                        "bucket": bucket,
                        "per_device_bucket": bucket // nd,
                        "us_per_request": us,
                        "cls_per_s": rate,
                        "x_asic": rate / PAPER_RATE,
                    },
                }
            )
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="two buckets, fewer reps")
    ap.add_argument("--tiny", action="store_true", help="CI-smoke geometry")
    ap.add_argument("--path", default="fused")
    ap.add_argument("--mesh", action="store_true",
                    help="per-device-count ServeMesh rows instead of the "
                         "single-device sweep (wants 8 virtual devices)")
    args = ap.parse_args()
    buckets = (8, 64) if args.quick else (1, 8, 64, 256)
    reps = 3 if args.quick else 10
    print("name,us_per_call,derived")
    if args.mesh:
        for r in bench_serve_mesh(
            n_requests=reps, path=args.path, tiny=args.tiny
        ):
            print(f"{r['name']},{r['us_per_call']},{r['derived']}")
        return
    for r in bench_serve(
        buckets=buckets, n_requests=reps, path=args.path, tiny=args.tiny
    ):
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")


if __name__ == "__main__":
    main()
