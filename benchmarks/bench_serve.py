"""Serving-engine throughput benchmark vs. the paper's ASIC figures.

Measures end-to-end classifications/s of the batched ``repro.serve``
engine (host booleanize -> patch -> pack -> bucket -> jitted classify)
at the paper's exact model scale (128 clauses, 361 patches, 272
literals), across several power-of-two batch buckets, and compares
against the chip's 60.3k classifications/s and 25.4 us single-image
latency (Table II, 27.8 MHz point).

Runs on CPU with the ``ref`` kernel backend (the non-TPU default).

Run:  PYTHONPATH=src python -m benchmarks.bench_serve [--quick]
"""

from __future__ import annotations

import argparse
from typing import Dict, List

import jax
import numpy as np

PAPER_RATE = 60_300        # classifications/s @ 27.8 MHz
PAPER_LATENCY_US = 25.4    # single-image latency incl. system overhead

__all__ = ["bench_serve"]


def _engine(path: str, max_batch: int):
    from repro.configs.convcotm import COTM_CONFIGS
    from repro.core.cotm import init_boundary_model
    from repro.serve import ServingEngine

    cfg = COTM_CONFIGS["convcotm-mnist"]
    model = init_boundary_model(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(max_batch=max_batch)
    engine.register("mnist", model, cfg, booleanize_method="threshold", path=path)
    return engine


def bench_serve(
    buckets=(1, 8, 64, 256), n_requests: int = 10, path: str = "fused"
) -> List[Dict]:
    """One CSV row per batch bucket: us/request + classifications/s."""
    engine = _engine(path, max_batch=max(buckets))
    engine.warmup("mnist", buckets=buckets)
    rng = np.random.default_rng(0)
    rows = []
    for bucket in buckets:
        imgs = rng.integers(0, 256, (bucket, 28, 28)).astype(np.uint8)
        # One untimed request: warms the host-side ingress (booleanize /
        # patch / pack trace caches) for this shape; the jitted classify
        # step itself was compiled by engine.warmup above.
        engine.classify("mnist", imgs)
        t, n = 0.0, 0
        for _ in range(n_requests):
            res = engine.classify("mnist", imgs)
            t += res.latency_s
            n += bucket
        rate = n / t
        us = t / n_requests * 1e6
        rows.append(
            {
                "name": f"serve_engine_{path}_b{bucket}",
                "us_per_call": round(us, 1),
                "derived": (
                    f"{rate:,.0f} class/s = {rate / PAPER_RATE:.2f}x ASIC "
                    f"({PAPER_RATE}/s); per-image {us / bucket:.1f} us "
                    f"vs chip {PAPER_LATENCY_US} us"
                ),
            }
        )
    st = engine.stats("mnist")
    rows.append(
        {
            "name": f"serve_engine_{path}_compiles",
            "us_per_call": 0,
            "derived": (
                f"{len(st.compiled_buckets)} bucket compiles for "
                f"{st.requests} requests (bounded-recompile contract)"
            ),
        }
    )
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="two buckets, fewer reps")
    ap.add_argument("--path", default="fused")
    args = ap.parse_args()
    buckets = (8, 64) if args.quick else (1, 8, 64, 256)
    reps = 3 if args.quick else 10
    print("name,us_per_call,derived")
    for r in bench_serve(buckets=buckets, n_requests=reps, path=args.path):
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")


if __name__ == "__main__":
    main()
