"""Async ServingService benchmark: open-loop Poisson load vs the ASIC.

Drives the asyncio service (queue -> latency-aware microbatch -> pow2
bucket -> jitted classify) with an open-loop Poisson arrival process of
single-image requests — arrivals follow a precomputed exponential
schedule and never wait for earlier results, which is how independent
users actually load a service (closed-loop generators hide queueing
collapse).  Three sweeps, reported as CSV rows:

  * arrival-rate sweep at a fixed ``max_delay_us`` over a preprocessed
    request pool: throughput, p50/p99 latency and batch occupancy as
    offered load approaches and exceeds capacity, compared against the
    chip's 60.3k classifications/s and 25.4 us single-image latency
    (Table II) — isolates the service spine from any ingress;
  * ``max_delay_us`` sweep at a fixed rate: the latency/occupancy
    tradeoff of the coalescing deadline (0 = pure latency mode);
  * **raw-pixel sweep**: the same open-loop load submitted as raw uint8
    images, through the device-resident ingress (raw pixels enqueue with
    a shape check; booleanize/patch/pack fuse into the microbatch's
    classify graph) vs the legacy per-request host ingress — the
    before/after of the device-resident ingress (EXPERIMENTS.md
    §Ingress; the ISSUE-4 acceptance criterion);
  * **robustness sweep** (ARCHITECTURE.md §Faults): deadline-checked vs
    unchecked load (the healthy-path cost of the request-lifetime
    machinery — shed scans, expiry bookkeeping; acceptance is < 5%
    throughput overhead), and the tuned path vs its one-step
    ``degraded_fallback`` (what a tripped circuit breaker costs while
    the primary path is out).

Rows carry machine-readable ``fields`` for ``benchmarks/run.py
--emit-json``.  Numbers land in EXPERIMENTS.md §Serve / §Ingress /
§Faults.

Run:  PYTHONPATH=src python -m benchmarks.bench_service [--quick]
"""

from __future__ import annotations

import argparse
import asyncio
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

PAPER_RATE = 60_300        # classifications/s @ 27.8 MHz
PAPER_LATENCY_US = 25.4    # single-image latency incl. system overhead

__all__ = ["bench_service", "run_load"]


def _setup(path: str, max_batch: int, tiny: bool = False):
    from repro.core.cotm import init_boundary_model
    from repro.serve import ServingEngine, get_path

    if tiny:
        from benchmarks.bench_ingress import tiny_config

        cfg = tiny_config()
    else:
        from repro.configs.convcotm import COTM_CONFIGS

        cfg = COTM_CONFIGS["convcotm-mnist"]
    model = init_boundary_model(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(max_batch=max_batch)
    engine.register("mnist", model, cfg, booleanize_method="threshold", path=path)
    engine.warmup("mnist")

    # Request pools, reused across sweeps: raw single images and their
    # preprocessed literal form.
    from repro.data.pipeline import preprocess_for_serving

    rng = np.random.default_rng(0)
    side = cfg.patch.image_y
    imgs = rng.integers(0, 256, (64, side, side)).astype(np.uint8)
    pre = preprocess_for_serving(
        imgs, cfg.patch, method="threshold",
        packed=get_path(path).input_form == "packed",
    )
    raw_pool = [imgs[i : i + 1] for i in range(len(imgs))]
    pre_pool = [pre[i : i + 1] for i in range(len(pre))]
    return engine, raw_pool, pre_pool


async def run_load(
    engine, pool, *, rate: float, n_requests: int, max_delay_us: float,
    high_water: int = 4096, seed: int = 0,
    preprocessed: bool = True, host_ingress: bool = False,
    deadline_s: Optional[float] = None,
) -> Dict:
    """One open-loop Poisson run; returns the stats row.

    ``deadline_s`` rides on every request: the service then runs the
    full request-lifetime machinery (expiry scans, shed-before-dispatch)
    even when the deadline is generous enough that nothing expires —
    which is exactly what the deadline-overhead rows measure.
    """
    from repro.serve import ServiceConfig, ServingService
    from repro.serve.loadgen import poisson_open_loop

    service = ServingService(
        engine, ServiceConfig(max_delay_us=max_delay_us, high_water=high_water)
    )
    await service.start()
    rng = np.random.default_rng(seed)
    pick = rng.integers(0, len(pool), n_requests)

    loop = asyncio.get_running_loop()
    t0 = loop.time()
    admitted, rejected = await poisson_open_loop(
        service, "mnist", [pool[i] for i in pick], rate,
        seed=seed, preprocessed=preprocessed, host_ingress=host_ingress,
        deadline_s=deadline_s,
    )
    # With a deadline set, shed requests resolve with ServiceExpired —
    # still a resolution, so gather with exceptions captured.
    await asyncio.gather(
        *(f for _, f in admitted), return_exceptions=True
    )
    await service.stop(drain=True)
    wall = loop.time() - t0

    st = service.stats("mnist")
    return {
        "offered_per_s": n_requests / wall,
        "achieved_per_s": st.completed / wall,
        "rejected": rejected,
        "expired": st.expired,
        "p50_us": st.p50_latency_us,
        "p99_us": st.p99_latency_us,
        "mean_occupancy": st.mean_occupancy,
        "batches": st.batches,
        "ingress_us_per_image": st.ingress_us_per_image,
        "device_us_per_image": st.device_us_per_image,
    }


def _row(name: str, r: Dict, derived: str, **fields) -> Dict:
    return {
        "name": name,
        "us_per_call": round(r["p50_us"], 1),
        "derived": derived,
        "fields": {
            "achieved_per_s": r["achieved_per_s"],
            "offered_per_s": r["offered_per_s"],
            "p50_us": r["p50_us"],
            "p99_us": r["p99_us"],
            "mean_occupancy": r["mean_occupancy"],
            "rejected": r["rejected"],
            "expired": r.get("expired", 0),
            "ingress_us_per_image": r["ingress_us_per_image"],
            "device_us_per_image": r["device_us_per_image"],
            **fields,
        },
    }


def bench_service(
    rates: Sequence[float] = (500.0, 2000.0, 8000.0),
    delays_us: Sequence[float] = (0.0, 200.0, 2000.0),
    raw_rates: Sequence[float] = (2000.0,),
    fixed_rate: float = 2000.0,
    n_requests: int = 400,
    path: str = "fused",
    max_batch: int = 256,
    tiny: bool = False,
) -> List[Dict]:
    """CSV rows: one per arrival rate, one per coalescing deadline, and
    one per (raw ingress mode, rate)."""
    engine, raw_pool, pre_pool = _setup(path, max_batch, tiny=tiny)
    rows = []
    for rate in rates:
        r = asyncio.run(
            run_load(engine, pre_pool, rate=rate, n_requests=n_requests,
                     max_delay_us=200.0)
        )
        rows.append(_row(
            f"service_{path}_rate{int(rate)}", r,
            (
                f"offered {r['offered_per_s']:,.0f}/s achieved "
                f"{r['achieved_per_s']:,.0f}/s "
                f"({r['achieved_per_s'] / PAPER_RATE:.3f}x ASIC) | "
                f"p50 {r['p50_us']:,.0f} us p99 {r['p99_us']:,.0f} us "
                f"(chip {PAPER_LATENCY_US} us) | occupancy "
                f"{r['mean_occupancy']:.2f} | rejected {r['rejected']}"
            ),
            kind="rate_sweep", rate=rate, path=path,
        ))
    for delay in delays_us:
        r = asyncio.run(
            run_load(engine, pre_pool, rate=fixed_rate, n_requests=n_requests,
                     max_delay_us=delay)
        )
        rows.append(_row(
            f"service_{path}_delay{int(delay)}us", r,
            (
                f"rate {fixed_rate:,.0f}/s | p50 {r['p50_us']:,.0f} us "
                f"p99 {r['p99_us']:,.0f} us | occupancy "
                f"{r['mean_occupancy']:.2f} over {r['batches']} batches"
            ),
            kind="delay_sweep", delay_us=delay, path=path,
        ))
    # Raw-pixel path: device-resident ingress vs the per-request host
    # pipeline, same open-loop load.  The ISSUE-4 acceptance comparison.
    for rate in raw_rates:
        raw_rows = {}
        for mode, host in (("device", False), ("host", True)):
            r = asyncio.run(
                run_load(engine, raw_pool, rate=rate, n_requests=n_requests,
                         max_delay_us=200.0,
                         preprocessed=False, host_ingress=host)
            )
            raw_rows[mode] = r
            rows.append(_row(
                f"service_{path}_raw_{mode}_rate{int(rate)}", r,
                (
                    f"RAW pixels, {mode} ingress | offered "
                    f"{r['offered_per_s']:,.0f}/s achieved "
                    f"{r['achieved_per_s']:,.0f}/s "
                    f"({r['achieved_per_s'] / PAPER_RATE:.3f}x ASIC) | "
                    f"p50 {r['p50_us']:,.0f} us p99 {r['p99_us']:,.0f} us | "
                    f"split ingress {r['ingress_us_per_image']:,.0f} / device "
                    f"{r['device_us_per_image']:,.0f} us/img"
                ),
                kind="raw_ingress", ingress=mode, rate=rate, path=path,
            ))
        speedup = (
            raw_rows["device"]["achieved_per_s"]
            / raw_rows["host"]["achieved_per_s"]
            if raw_rows["host"]["achieved_per_s"]
            else float("inf")
        )
        rows.append({
            "name": f"service_{path}_raw_speedup_rate{int(rate)}",
            "us_per_call": 0,
            "derived": (
                f"device-resident ingress {speedup:.1f}x host-ingress "
                f"baseline on the raw-pixel path"
            ),
            "fields": {"kind": "raw_speedup", "rate": rate, "speedup": speedup},
        })
    # Robustness rows (ARCHITECTURE.md §Faults).  First the price of the
    # request-lifetime machinery on a healthy service: identical load
    # with no deadline vs a generous one (nothing expires; the service
    # still runs every expiry scan).  Acceptance: < 5% throughput loss.
    r_unchecked = asyncio.run(
        run_load(engine, pre_pool, rate=fixed_rate, n_requests=n_requests,
                 max_delay_us=200.0)
    )
    r_checked = asyncio.run(
        run_load(engine, pre_pool, rate=fixed_rate, n_requests=n_requests,
                 max_delay_us=200.0, deadline_s=30.0)
    )
    overhead_pct = (
        100.0 * (1.0 - r_checked["achieved_per_s"]
                 / r_unchecked["achieved_per_s"])
        if r_unchecked["achieved_per_s"] else 0.0
    )
    for mode, r in (("unchecked", r_unchecked), ("checked", r_checked)):
        rows.append(_row(
            f"service_{path}_deadline_{mode}", r,
            (
                f"deadline {mode} | achieved {r['achieved_per_s']:,.0f}/s | "
                f"p50 {r['p50_us']:,.0f} us p99 {r['p99_us']:,.0f} us | "
                f"expired {r['expired']}"
            ),
            kind="deadline_overhead", mode=mode, path=path,
        ))
    rows.append({
        "name": f"service_{path}_deadline_overhead",
        "us_per_call": 0,
        "derived": (
            f"deadline-checked vs unchecked: {overhead_pct:+.1f}% "
            f"throughput overhead (acceptance < 5%)"
        ),
        "fields": {"kind": "deadline_overhead_pct", "path": path,
                   "overhead_pct": overhead_pct},
    })
    # Then the degraded mode: one circuit-breaker step down the fallback
    # chain (tuned plan dropped, ingress rebuilt for the fallback's input
    # form) vs the tuned path under the same raw-pixel load — raw pixels
    # because preprocessed pools are form-coupled to the path they were
    # packed for, while degradation's ingress rebuild makes raw
    # submissions path-agnostic (that IS the degraded contract).
    r_tuned_raw = asyncio.run(
        run_load(engine, raw_pool, rate=fixed_rate, n_requests=n_requests,
                 max_delay_us=200.0, preprocessed=False)
    )
    fallback = engine.degrade_path("mnist")
    if fallback is not None:
        engine.warmup("mnist")
        r_deg = asyncio.run(
            run_load(engine, raw_pool, rate=fixed_rate, n_requests=n_requests,
                     max_delay_us=200.0, preprocessed=False)
        )
        ratio = (
            r_deg["achieved_per_s"] / r_tuned_raw["achieved_per_s"]
            if r_tuned_raw["achieved_per_s"] else 0.0
        )
        rows.append(_row(
            f"service_{path}_degraded_{fallback}", r_deg,
            (
                f"degraded {path} -> {fallback} | achieved "
                f"{r_deg['achieved_per_s']:,.0f}/s "
                f"({ratio:.2f}x tuned {path}) | p50 {r_deg['p50_us']:,.0f} us "
                f"p99 {r_deg['p99_us']:,.0f} us"
            ),
            kind="degraded_path", path=path, fallback=fallback,
            vs_tuned_ratio=ratio,
        ))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer rates/requests")
    ap.add_argument("--tiny", action="store_true", help="CI-smoke geometry")
    ap.add_argument("--path", default="fused")
    args = ap.parse_args()
    kw = dict(tiny=args.tiny)
    if args.quick:
        kw.update(rates=(500.0, 2000.0), delays_us=(0.0, 200.0),
                  raw_rates=(2000.0,), n_requests=150)
    print("name,us_per_call,derived")
    for r in bench_service(path=args.path, **kw):
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")


if __name__ == "__main__":
    main()
