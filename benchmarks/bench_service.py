"""Async ServingService benchmark: open-loop Poisson load vs the ASIC.

Drives the asyncio service (queue -> latency-aware microbatch -> pow2
bucket -> jitted classify) with an open-loop Poisson arrival process of
single-image requests — arrivals follow a precomputed exponential
schedule and never wait for earlier results, which is how independent
users actually load a service (closed-loop generators hide queueing
collapse).  Two sweeps, reported as CSV rows:

  * arrival-rate sweep at a fixed ``max_delay_us``: throughput,
    p50/p99 latency and batch occupancy as offered load approaches and
    exceeds capacity, compared against the chip's 60.3k
    classifications/s and 25.4 us single-image latency (Table II);
  * ``max_delay_us`` sweep at a fixed rate: the latency/occupancy
    tradeoff of the coalescing deadline (0 = pure latency mode).

Requests are preprocessed once into the eval path's literal form and
submitted with ``preprocessed=True`` so the sweep isolates the service
spine (scheduler + bucketed datapath) from the host-side booleanize/
patch ingress — ``benchmarks/bench_serve.py`` measures that ingress.
Numbers land in EXPERIMENTS.md §Serve.

Run:  PYTHONPATH=src python -m benchmarks.bench_service [--quick]
"""

from __future__ import annotations

import argparse
import asyncio
from typing import Dict, List, Sequence

import jax
import numpy as np

PAPER_RATE = 60_300        # classifications/s @ 27.8 MHz
PAPER_LATENCY_US = 25.4    # single-image latency incl. system overhead

__all__ = ["bench_service", "run_load"]


def _setup(path: str, max_batch: int):
    from repro.configs.convcotm import COTM_CONFIGS
    from repro.core.cotm import init_boundary_model
    from repro.serve import ServingEngine, get_path

    cfg = COTM_CONFIGS["convcotm-mnist"]
    model = init_boundary_model(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(max_batch=max_batch)
    engine.register("mnist", model, cfg, booleanize_method="threshold", path=path)
    engine.warmup("mnist")

    # One preprocessed single-image request pool, reused across sweeps.
    from repro.data.pipeline import preprocess_for_serving

    rng = np.random.default_rng(0)
    imgs = rng.integers(0, 256, (64, 28, 28)).astype(np.uint8)
    pool = preprocess_for_serving(
        imgs, cfg.patch, method="threshold",
        packed=get_path(path).input_form == "packed",
    )
    return engine, [pool[i : i + 1] for i in range(len(pool))]


async def run_load(
    engine, pool, *, rate: float, n_requests: int, max_delay_us: float,
    high_water: int = 4096, seed: int = 0,
) -> Dict:
    """One open-loop Poisson run; returns the stats row."""
    from repro.serve import ServiceConfig, ServingService
    from repro.serve.loadgen import poisson_open_loop

    service = ServingService(
        engine, ServiceConfig(max_delay_us=max_delay_us, high_water=high_water)
    )
    await service.start()
    rng = np.random.default_rng(seed)
    pick = rng.integers(0, len(pool), n_requests)

    loop = asyncio.get_running_loop()
    t0 = loop.time()
    admitted, rejected = await poisson_open_loop(
        service, "mnist", [pool[i] for i in pick], rate,
        seed=seed, preprocessed=True,
    )
    await asyncio.gather(*(f for _, f in admitted))
    await service.stop(drain=True)
    wall = loop.time() - t0

    st = service.stats("mnist")
    return {
        "offered_per_s": n_requests / wall,
        "achieved_per_s": st.completed / wall,
        "rejected": rejected,
        "p50_us": st.p50_latency_us,
        "p99_us": st.p99_latency_us,
        "mean_occupancy": st.mean_occupancy,
        "batches": st.batches,
    }


def bench_service(
    rates: Sequence[float] = (500.0, 2000.0, 8000.0),
    delays_us: Sequence[float] = (0.0, 200.0, 2000.0),
    fixed_rate: float = 2000.0,
    n_requests: int = 400,
    path: str = "fused",
    max_batch: int = 256,
) -> List[Dict]:
    """CSV rows: one per arrival rate, then one per coalescing deadline."""
    engine, pool = _setup(path, max_batch)
    rows = []
    for rate in rates:
        r = asyncio.run(
            run_load(engine, pool, rate=rate, n_requests=n_requests,
                     max_delay_us=200.0)
        )
        rows.append(
            {
                "name": f"service_{path}_rate{int(rate)}",
                "us_per_call": round(r["p50_us"], 1),
                "derived": (
                    f"offered {r['offered_per_s']:,.0f}/s achieved "
                    f"{r['achieved_per_s']:,.0f}/s "
                    f"({r['achieved_per_s'] / PAPER_RATE:.3f}x ASIC) | "
                    f"p50 {r['p50_us']:,.0f} us p99 {r['p99_us']:,.0f} us "
                    f"(chip {PAPER_LATENCY_US} us) | occupancy "
                    f"{r['mean_occupancy']:.2f} | rejected {r['rejected']}"
                ),
            }
        )
    for delay in delays_us:
        r = asyncio.run(
            run_load(engine, pool, rate=fixed_rate, n_requests=n_requests,
                     max_delay_us=delay)
        )
        rows.append(
            {
                "name": f"service_{path}_delay{int(delay)}us",
                "us_per_call": round(r["p50_us"], 1),
                "derived": (
                    f"rate {fixed_rate:,.0f}/s | p50 {r['p50_us']:,.0f} us "
                    f"p99 {r['p99_us']:,.0f} us | occupancy "
                    f"{r['mean_occupancy']:.2f} over {r['batches']} batches"
                ),
            }
        )
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer rates/requests")
    ap.add_argument("--path", default="fused")
    args = ap.parse_args()
    kw = {}
    if args.quick:
        kw = dict(rates=(500.0, 2000.0), delays_us=(0.0, 200.0), n_requests=150)
    print("name,us_per_call,derived")
    for r in bench_service(path=args.path, **kw):
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")


if __name__ == "__main__":
    main()
