"""ConvCoTM training throughput (the FPGA in [12] reports 40 k samples/s;
the paper estimates 22.2 k/s for an ASIC at 27.8 MHz — here we measure the
JAX twin on CPU for completeness).

Two comparisons at paper geometry (28x28, 128 clauses):

  * dense-vs-matmul training eval — ``update_batch`` with
    ``config.train_eval='dense'`` (the reference ``[P, C, 2o]`` boolean
    broadcast, ~12.6M intermediate elements per image) against
    ``'matmul'`` (the MXU violation-count fast path, bit-identical);
  * engine-vs-naive epoch loops — a hand-written per-batch python loop
    (literal extraction per step, one dispatch per batch) against
    ``TrainerEngine`` (literals frozen once, one jitted ``lax.scan`` per
    epoch with donated model buffers).

Run:  PYTHONPATH=src python -m benchmarks.bench_train
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CoTMConfig, init_model, update_batch

__all__ = ["bench_tm_train", "bench_train_eval_paths", "bench_epoch_loops"]


def _paper_cfg(train_eval: str) -> CoTMConfig:
    return CoTMConfig(
        n_clauses=128, n_classes=10, T=500, s=10.0, train_eval=train_eval
    )


def bench_train_eval_paths(batch: int = 64, iters: int = 3) -> List[Dict]:
    """update_batch samples/s, dense-broadcast vs matmul training eval."""
    key = jax.random.PRNGKey(0)
    imgs = (jax.random.uniform(key, (batch, 28, 28)) > 0.6).astype(jnp.uint8)
    labels = jax.random.randint(key, (batch,), 0, 10)
    out, rate = [], {}
    for train_eval in ("dense", "matmul"):
        cfg = _paper_cfg(train_eval)
        model = init_model(key, cfg)
        model = update_batch(key, model, imgs, labels, cfg)  # compile
        jax.block_until_ready(model.ta_state)
        t0 = time.perf_counter()
        for _ in range(iters):
            model = update_batch(key, model, imgs, labels, cfg)
        jax.block_until_ready(model.ta_state)
        us = (time.perf_counter() - t0) / iters * 1e6
        rate[train_eval] = batch / us * 1e6
        out.append(
            {
                "name": f"convcotm_train_step_{train_eval}_batch{batch}",
                "us_per_call": round(us, 1),
                "derived": f"{rate[train_eval]:.0f} samples/s (paper-scale model)",
            }
        )
    out.append(
        {
            "name": "convcotm_train_eval_speedup",
            "us_per_call": 0,
            "derived": f"matmul {rate['matmul'] / rate['dense']:.1f}x over "
            f"dense broadcast",
        }
    )
    return out


def bench_epoch_loops(
    n: int = 1024, batch: int = 64, epochs: int = 2
) -> List[Dict]:
    """Full-epoch samples/s: naive per-batch python loop vs TrainerEngine.

    Both use the matmul training eval; the comparison isolates the engine
    mechanics (literals frozen once + one jitted scan per epoch + donated
    buffers) from the clause-eval speedup measured above.  The first
    engine epoch (compile) is excluded from both timings.
    """
    from repro.data import PipelineState, batches
    from repro.train.tm_engine import TrainerEngine

    cfg = _paper_cfg("matmul")
    key = jax.random.PRNGKey(0)
    rng = np.random.default_rng(0)
    imgs = (rng.random((n, 28, 28)) > 0.6).astype(np.uint8)
    labels = rng.integers(0, 10, n).astype(np.int32)

    # --- naive loop: re-extract + dispatch per batch ----------------------
    model = init_model(key, cfg)
    state = PipelineState(seed=0)
    k = key
    # warm the compile outside the timed region
    model = update_batch(k, model, jnp.asarray(imgs[:batch]), jnp.asarray(labels[:batch]), cfg)
    jax.block_until_ready(model.ta_state)
    t0 = time.perf_counter()
    for _ in range(epochs):
        for xb, yb, state in batches(imgs, labels, batch, state):
            k, kk = jax.random.split(k)
            model = update_batch(kk, model, jnp.asarray(xb), jnp.asarray(yb), cfg)
    jax.block_until_ready(model.ta_state)
    naive_s = time.perf_counter() - t0

    # --- engine: frozen literals + jitted scan per epoch ------------------
    engine = TrainerEngine(cfg, batch_size=batch)
    ds = engine.prepare(imgs, labels, booleanize_method="none")
    model = engine.init_model(key)
    key, model, st, _ = engine.fit(key, model, ds, epochs=1)  # compile epoch
    t0 = time.perf_counter()
    key, model, st, _ = engine.fit(key, model, ds, epochs=epochs, state=st)
    jax.block_until_ready(model.ta_state)
    engine_s = time.perf_counter() - t0

    total = epochs * (n // batch) * batch
    return [
        {
            "name": f"convcotm_epoch_naive_n{n}",
            "us_per_call": round(naive_s / epochs * 1e6, 1),
            "derived": f"{total / naive_s:.0f} samples/s (per-batch dispatch)",
        },
        {
            "name": f"convcotm_epoch_engine_n{n}",
            "us_per_call": round(engine_s / epochs * 1e6, 1),
            "derived": f"{total / engine_s:.0f} samples/s "
            f"({naive_s / engine_s:.1f}x over naive loop)",
        },
    ]


def bench_tm_train(batch: int = 64, iters: int = 3) -> List[Dict]:
    """The full training benchmark suite (run.py entry point)."""
    return bench_train_eval_paths(batch, iters) + bench_epoch_loops(batch=batch)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for r in bench_tm_train():
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")
