"""ConvCoTM training throughput (the FPGA in [12] reports 40 k samples/s;
the paper estimates 22.2 k/s for an ASIC at 27.8 MHz — here we measure the
JAX twin on CPU for completeness)."""

from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.core import CoTMConfig, init_model, update_batch
from repro.core.patches import PatchSpec

__all__ = ["bench_tm_train"]


def bench_tm_train(batch: int = 64, iters: int = 3) -> List[Dict]:
    cfg = CoTMConfig(n_clauses=128, n_classes=10, T=500, s=10.0)
    key = jax.random.PRNGKey(0)
    model = init_model(key, cfg)
    imgs = (jax.random.uniform(key, (batch, 28, 28)) > 0.6).astype(jnp.uint8)
    labels = jax.random.randint(key, (batch,), 0, 10)
    model = update_batch(key, model, imgs, labels, cfg)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        model = update_batch(key, model, imgs, labels, cfg)
    jax.block_until_ready(model.ta_state)
    us = (time.perf_counter() - t0) / iters * 1e6
    return [
        {
            "name": "convcotm_train_step_batch64",
            "us_per_call": round(us, 1),
            "derived": f"{batch / us * 1e6:.0f} samples/s (paper-scale model)",
        }
    ]
