import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: re-lowers the three chosen (arch x shape) pairs
with each optimization applied, extracts HLO collective evidence, and
recomputes the analytic roofline terms.

Pairs (selection rationale in EXPERIMENTS.md §Perf):
  1. mistral-nemo-12b x train_4k   — largest collective term (TP ARs)
  2. xlstm-350m      x train_4k    — worst roofline fraction (TP overhead
                                     on a 350M model)
  3. phi3.5-moe-42b  x decode_32k  — most collective-bound decode (FSDP
                                     regather + EP a2a)
(The paper-technique pair — the ConvCoTM kernel itself — is hillclimbed in
benchmarks/bench_inference.py + kernels/, reported alongside.)

Run: PYTHONPATH=src python -m benchmarks.perf_hillclimb [--pair N]
Writes experiments/perf/<name>.json.
"""

import argparse
import dataclasses
import json
import time


from repro.configs import SHAPES, get_config
from repro.launch.dryrun import lower_cell
from repro.roofline.analysis import HBM_BW, ICI_BW, PEAK_FLOPS
from repro.roofline.flops import (
    collective_bytes_estimate,
    flops_estimate,
    hbm_bytes_estimate,
)

OUT = "experiments/perf"


def terms_for(cfg, shape_name, *, k, profile, parallel_block, gather_hoisted,
              chips=256, dp=16, tp=16, pods=1):
    shape = SHAPES[shape_name]
    fpc = flops_estimate(cfg, shape) / chips
    bpc = hbm_bytes_estimate(cfg, shape, chips, k)
    coll = collective_bytes_estimate(
        cfg, shape, dp=dp, tp=tp, pods=pods, microbatches=k, profile=profile,
        parallel_block=parallel_block, gather_hoisted=gather_hoisted,
    )
    c, m, x = fpc / PEAK_FLOPS, bpc / HBM_BW, coll["total"] / ICI_BW
    step = max(c, m, x)
    return {
        "compute_s": c, "memory_s": m, "collective_s": x,
        "collective_breakdown": coll,
        "dominant": ["compute", "memory", "collective"][[c, m, x].index(step)],
        "roofline_fraction": c / step if step else 0.0,
    }


def run_pair_1():
    """mistral-nemo-12b x train_4k: parallel_block + hoisted gathers."""
    arch, shape = "mistral-nemo-12b", "train_4k"
    base_cfg = get_config(arch)
    k = 8
    results = {"pair": f"{arch} x {shape}", "iterations": []}

    # Iteration 0: baseline (already dry-run; recompute terms + HLO counts).
    res = lower_cell(arch, shape, False, profile="tp")
    counts = None  # use saved hlo? lower again to count:
    results["iterations"].append(
        {
            "name": "baseline (sequential block, per-microbatch gathers)",
            "analytic": terms_for(base_cfg, shape, k=k, profile="tp",
                                  parallel_block=False, gather_hoisted=False),
            "hlo_collectives_loop_once": res["roofline"]["collectives"],
        }
    )

    # Iteration 1: PaLM parallel block (code change, re-lowered).
    pb_cfg = dataclasses.replace(base_cfg, use_parallel_block=True)
    res1 = lower_cell(arch, shape, False, cfg_override=pb_cfg, profile="tp")
    results["iterations"].append(
        {
            "name": "parallel attn+mlp block (1 AR/layer)",
            "analytic": terms_for(pb_cfg, shape, k=k, profile="tp",
                                  parallel_block=True, gather_hoisted=False),
            "hlo_collectives_loop_once": res1["roofline"]["collectives"],
        }
    )

    # Iteration 2: + loop-invariant weight-gather hoisting (XLA LICM;
    # modeled — the gather count drop is visible in the while-body counts).
    results["iterations"].append(
        {
            "name": "+ hoisted fwd param all-gather (1/step instead of 1/microbatch)",
            "analytic": terms_for(pb_cfg, shape, k=k, profile="tp",
                                  parallel_block=True, gather_hoisted=True),
        }
    )
    # Iteration 3: halve grad accumulation — remat-saved inputs are
    # 671 MB/layer at 16 seq/chip; k=4 keeps them at 6.7 GB/step while
    # halving the per-microbatch FSDP gathers + reduce-scatters.
    results["iterations"].append(
        {
            "name": "+ microbatches 8->4 (26 GB -> 6.7 GB saved acts, half the FSDP traffic)",
            "analytic": terms_for(pb_cfg, shape, k=4, profile="tp",
                                  parallel_block=True, gather_hoisted=True),
        }
    )
    # Iteration 4 (multi-pod): on the (2,16,16) mesh the inter-pod fp32
    # gradient all-reduce rides the slowest links; int8 + error-feedback
    # compression (tested in tests/test_distributed.py + the real train
    # step in tests/test_multidevice.py) cuts it 4x.
    t_fp32 = terms_for(pb_cfg, shape, k=4, profile="tp", parallel_block=True,
                       gather_hoisted=True, chips=512, pods=2)
    results["iterations"].append(
        {"name": "(2-pod mesh) fp32 inter-pod grad all-reduce", "analytic": t_fp32}
    )
    import repro.roofline.flops as F

    coll = F.collective_bytes_estimate(
        pb_cfg, SHAPES[shape], dp=16, tp=16, pods=2, microbatches=4,
        profile="tp", parallel_block=True, gather_hoisted=True, pod_int8=True,
    )
    t_int8 = terms_for(pb_cfg, shape, k=4, profile="tp", parallel_block=True,
                       gather_hoisted=True, chips=512, pods=2)
    t_int8["collective_s"] = coll["total"] / ICI_BW
    t_int8["collective_breakdown"] = coll
    step_s = max(t_int8["compute_s"], t_int8["memory_s"], t_int8["collective_s"])
    t_int8["roofline_fraction"] = t_int8["compute_s"] / step_s
    results["iterations"].append(
        {"name": "(2-pod mesh) + int8+EF pod gradient compression", "analytic": t_int8}
    )
    return results


def run_pair_2():
    """xlstm-350m x train_4k: kill TP entirely (dp profile)."""
    arch, shape = "xlstm-350m", "train_4k"
    cfg = get_config(arch)
    k = 8
    results = {"pair": f"{arch} x {shape}", "iterations": []}
    res_tp = lower_cell(arch, shape, False, profile="tp")
    results["iterations"].append(
        {
            "name": "baseline (tp profile: 16-way TP on a 350M model)",
            "analytic": terms_for(cfg, shape, k=k, profile="tp",
                                  parallel_block=False, gather_hoisted=False),
            "hlo_collectives_loop_once": res_tp["roofline"]["collectives"],
        }
    )
    res_dp = lower_cell(arch, shape, False, profile="dp")
    results["iterations"].append(
        {
            "name": "dp profile (no TP; params ZeRO over 256 chips)",
            "analytic": terms_for(cfg, shape, k=k, profile="dp",
                                  parallel_block=False, gather_hoisted=False),
            "hlo_collectives_loop_once": res_dp["roofline"]["collectives"],
        }
    )
    results["iterations"].append(
        {
            "name": "+ hoisted fwd gather",
            "analytic": terms_for(cfg, shape, k=k, profile="dp",
                                  parallel_block=False, gather_hoisted=True),
        }
    )
    # 350M activations are tiny (~134 MB/layer of remat-saved inputs at 16
    # seqs/chip): grad accumulation buys nothing and costs k x the
    # per-microbatch gathers + reduce-scatters.  k=1 lowers & compiles.
    results["iterations"].append(
        {
            "name": "+ microbatches=1 (activations fit; single gather+RS)",
            "analytic": terms_for(cfg, shape, k=1, profile="dp",
                                  parallel_block=False, gather_hoisted=False),
        }
    )
    return results


def run_pair_3():
    """phi3.5-moe decode_32k: decode-resident weights (serve_tp)."""
    arch, shape = "phi3.5-moe-42b-a6.6b", "decode_32k"
    cfg = get_config(arch)
    results = {"pair": f"{arch} x {shape}", "iterations": []}
    res_b = lower_cell(arch, shape, False, profile="tp")
    results["iterations"].append(
        {
            "name": "baseline (train-style sharding at decode: fsdp regather)",
            "analytic": terms_for(cfg, shape, k=1, profile="tp",
                                  parallel_block=False, gather_hoisted=False),
            "hlo_collectives_loop_once": res_b["roofline"]["collectives"],
        }
    )
    res_s = lower_cell(arch, shape, False, profile="serve_tp")
    results["iterations"].append(
        {
            "name": "decode-resident weights (serve_tp profile)",
            "analytic": terms_for(cfg, shape, k=1, profile="serve_tp",
                                  parallel_block=False, gather_hoisted=False),
            "hlo_collectives_loop_once": res_s["roofline"]["collectives"],
        }
    )
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", type=int, default=0, help="0 = all")
    args = ap.parse_args()
    os.makedirs(OUT, exist_ok=True)
    pairs = {1: run_pair_1, 2: run_pair_2, 3: run_pair_3}
    todo = [args.pair] if args.pair else [1, 2, 3]
    for n in todo:
        t0 = time.time()
        res = pairs[n]()
        path = os.path.join(OUT, f"pair{n}.json")
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        print(f"pair {n}: {res['pair']} ({time.time()-t0:.0f}s)")
        for it in res["iterations"]:
            a = it["analytic"]
            print(
                f"  {it['name'][:60]:60s} c={a['compute_s']:.3f} "
                f"m={a['memory_s']:.3f} x={a['collective_s']:.3f} "
                f"dom={a['dominant']} frac={a['roofline_fraction']:.2f}"
            )


if __name__ == "__main__":
    main()
