"""Roofline table generator: merges the dry-run JSONs (compile artifacts)
with the analytic flops/bytes/collective models into the EXPERIMENTS.md
§Roofline table."""

from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

from repro.configs import ARCHS, SHAPES, applicable_shapes, get_config
from repro.roofline.analysis import HBM_BW, ICI_BW, PEAK_FLOPS
from repro.roofline.flops import (
    collective_bytes_estimate,
    flops_estimate,
    hbm_bytes_estimate,
)

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")

__all__ = ["roofline_rows", "render_markdown"]


def _cell_json(arch: str, shape: str, mesh: str) -> Optional[Dict]:
    path = os.path.join(DRYRUN_DIR, f"{arch}__{shape}__{mesh}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def roofline_rows(mesh: str = "16x16") -> List[Dict]:
    chips = 512 if mesh == "2x16x16" else 256
    pods = 2 if mesh == "2x16x16" else 1
    dp, tp = 16, 16
    rows = []
    for arch in sorted(ARCHS):
        cfg = get_config(arch)
        for shape_name in applicable_shapes(cfg):
            shape = SHAPES[shape_name]
            cell = _cell_json(arch, shape_name, mesh)
            k = cell.get("microbatches", 1) if cell else 1
            gflops = flops_estimate(cfg, shape)
            fpc = gflops / chips
            bytes_pc = hbm_bytes_estimate(cfg, shape, chips, k)
            coll = collective_bytes_estimate(
                cfg, shape, dp=dp, tp=tp, pods=pods, microbatches=k
            )
            compute_s = fpc / PEAK_FLOPS
            memory_s = bytes_pc / HBM_BW
            collective_s = coll["total"] / ICI_BW
            step = max(compute_s, memory_s, collective_s)
            dom = ["compute", "memory", "collective"][
                [compute_s, memory_s, collective_s].index(step)
            ]
            n = cfg.param_count()
            na = cfg.active_param_count()
            tokens = shape.global_batch * (
                shape.seq_len if shape.kind != "decode" else 1
            )
            mf = (6.0 if shape.kind == "train" else 2.0) * na * tokens
            row = {
                "arch": arch,
                "shape": shape_name,
                "mesh": mesh,
                "kind": shape.kind,
                "params_b": round(n / 1e9, 2),
                "compute_s": compute_s,
                "memory_s": memory_s,
                "collective_s": collective_s,
                "dominant": dom,
                "roofline_fraction": compute_s / step if step else 0.0,
                "model_flops": mf,
                "useful_ratio": mf / gflops if gflops else None,
                "hlo_flops_per_chip_loop_once": (
                    cell["roofline"]["flops_per_chip"] if cell else None
                ),
                "hlo_wire_bytes_loop_once": (
                    cell["roofline"]["wire_bytes_per_chip"] if cell else None
                ),
                "compiled": cell is not None,
                "compile_s": cell["compile_s"] if cell else None,
                "microbatches": k,
            }
            rows.append(row)
    return rows


def render_markdown(rows: List[Dict]) -> str:
    hdr = (
        "| arch | shape | params(B) | compute(s) | memory(s) | collective(s) "
        "| dominant | roofline frac | useful(6ND/exec) | compiled |\n"
        "|---|---|---|---|---|---|---|---|---|---|\n"
    )
    body = []
    for r in rows:
        body.append(
            f"| {r['arch']} | {r['shape']} | {r['params_b']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['roofline_fraction']:.2f} "
            f"| {r['useful_ratio']:.2f} "
            f"| {'Y' if r['compiled'] else 'n/a'} |"
        )
    return hdr + "\n".join(body) + "\n"
