"""Benchmark harness entry point: one function per paper table + the JAX
measured benchmarks + the roofline table.  Prints ``name,us_per_call,
derived`` CSV rows per the repo contract, then the table reproductions.

``--emit-json DIR`` instead runs the serving/ingress regression harness
and writes machine-readable ``BENCH_serve.json`` and
``BENCH_ingress.json`` (cls/s per path and bucket, ingress vs device
latency split) so the perf trajectory is comparable across PRs; CI
smoke-runs it at ``--tiny`` geometry and uploads the artifact.

Run:  PYTHONPATH=src python -m benchmarks.run [--quick]
      PYTHONPATH=src python -m benchmarks.run --emit-json bench_out [--tiny]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

_MESH_ROWS_MARK = "MESH_ROWS_JSON="


def _mesh_rows(*, tiny: bool) -> list:
    """Per-device-count ``serve_mesh`` rows for BENCH_serve.json.

    The virtual device count must be set before jax initializes, so the
    sweep runs in a subprocess with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the parent
    harness stays on its own device set) and ships its rows back as one
    JSON line.
    """
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        env["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8"
        ).strip()
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    buckets = (8,) if tiny else (8, 64)
    reps = 3 if tiny else 10
    code = (
        "import json\n"
        "from benchmarks.bench_serve import bench_serve_mesh\n"
        f"rows = bench_serve_mesh(device_counts=(1, 2, 8), "
        f"buckets={buckets!r}, n_requests={reps}, tiny={tiny!r})\n"
        f"print({_MESH_ROWS_MARK!r} + json.dumps(rows))\n"
    )
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=1800,
    )
    if r.returncode != 0:
        raise RuntimeError(
            f"mesh benchmark subprocess failed:\n{r.stderr[-3000:]}"
        )
    for line in r.stdout.splitlines():
        if line.startswith(_MESH_ROWS_MARK):
            return json.loads(line[len(_MESH_ROWS_MARK):])
    raise RuntimeError("mesh benchmark subprocess produced no rows line")


def _csv(rows):
    for r in rows:
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")


def _json_payload(rows, *, tiny: bool) -> dict:
    """The cross-PR regression schema: stable row names + typed fields."""
    import jax

    return {
        "schema": 1,
        "generated_at": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "backend": jax.default_backend(),
        "geometry": "tiny" if tiny else "paper",
        "rows": [
            {
                "name": r["name"],
                "us_per_call": r["us_per_call"],
                "derived": r["derived"],
                **({"fields": r["fields"]} if "fields" in r else {}),
            }
            for r in rows
        ],
    }


def emit_json(out_dir: str, *, tiny: bool) -> None:
    """Write BENCH_serve.json + BENCH_ingress.json to ``out_dir``."""
    from benchmarks.bench_ingress import bench_ingress
    from benchmarks.bench_serve import bench_serve, bench_sparsity_sweep
    from benchmarks.bench_service import bench_service

    os.makedirs(out_dir, exist_ok=True)
    buckets = (1, 8) if tiny else (1, 8, 64)
    # Tiny calls cost microseconds — the run time is all compiles — so
    # high rep counts are free and keep the trajectory gate's numbers
    # out of single-timer-tick noise.
    reps = 20 if tiny else 10

    # The registered fused path and its sparse twin, side by side, so
    # the JSON shows the per-bucket sparse win (or loss) every PR.
    serve_rows = bench_serve(
        buckets=buckets, n_requests=reps, tiny=tiny,
        paths=("fused", "fused_sparse"),
    )
    serve_rows += bench_sparsity_sweep(
        active_fractions=(0.25, 1.0) if tiny else (0.0625, 0.25, 0.5, 1.0),
        pairs=(("fused", "fused_sparse"),),
        bucket=max(buckets),
        n_requests=reps,
        tiny=tiny,
    )
    # Per-device-count sharded-serving rows (8 virtual CPU devices in a
    # subprocess — device count is fixed at jax init).
    serve_rows += _mesh_rows(tiny=tiny)
    serve_rows += bench_service(
        rates=(500.0,) if tiny else (500.0, 2000.0),
        delays_us=(200.0,),
        raw_rates=(1000.0,) if tiny else (2000.0,),
        n_requests=60 if tiny else 300,
        tiny=tiny,
    )
    with open(os.path.join(out_dir, "BENCH_serve.json"), "w") as f:
        json.dump(_json_payload(serve_rows, tiny=tiny), f, indent=2)

    ingress_rows = bench_ingress(
        methods=("threshold",) if tiny else ("threshold", "adaptive", "none"),
        buckets=buckets,
        n_iter=reps,
        tiny=tiny,
    )
    with open(os.path.join(out_dir, "BENCH_ingress.json"), "w") as f:
        json.dump(_json_payload(ingress_rows, tiny=tiny), f, indent=2)

    # Trajectory artifact: the committed cross-PR rows plus an
    # uncommitted "current" row distilled from this run's serve sweep,
    # so the artifact shows this run against history at a glance.  The
    # committed file itself is only updated via
    # ``benchmarks/trajectory.py --update`` (see its docstring).
    from benchmarks import trajectory as traj

    current = {
        "pr": "current (uncommitted)",
        "generated_at": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "backend": __import__("jax").default_backend(),
        "geometries": {
            "tiny" if tiny else "paper": {
                "best_cls_per_s": traj.distill_serve_rows(serve_rows)
            }
        },
    }
    with open(os.path.join(out_dir, "BENCH_trajectory.json"), "w") as f:
        json.dump(
            traj.upsert_row(traj.load_trajectory(), current),
            f, indent=2, sort_keys=True,
        )
    for name in ("BENCH_serve.json", "BENCH_ingress.json",
                 "BENCH_trajectory.json"):
        print(f"wrote {os.path.join(out_dir, name)}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="skip wall-clock benches")
    ap.add_argument(
        "--emit-json", metavar="DIR", default=None,
        help="write BENCH_serve.json/BENCH_ingress.json to DIR and exit",
    )
    ap.add_argument(
        "--tiny", action="store_true",
        help="CI-smoke geometry for --emit-json (small clause pool/patches)",
    )
    args = ap.parse_args()

    if args.emit_json:
        emit_json(args.emit_json, tiny=args.tiny)
        return

    print("name,us_per_call,derived")

    # --- measured JAX benchmarks -----------------------------------------
    if not args.quick:
        from benchmarks.bench_inference import bench_inference_paths, csrf_skip_stats

        _csv(bench_inference_paths())
        stats = csrf_skip_stats()
        print(
            f"csrf_skip_stats,0,"
            f"tile_skip={stats['tile_skip_fraction']:.2f} "
            f"clausewise_saving={stats['clausewise_eval_saving']:.2f} "
            f"fired={stats['fired_fraction']:.2f}"
        )
        from benchmarks.bench_train import bench_tm_train

        _csv(bench_tm_train())

        from benchmarks.bench_serve import bench_serve

        _csv(bench_serve(buckets=(8, 64), n_requests=5))

    # --- Table II: ASIC characteristics (analytic model vs paper) --------
    from benchmarks.tables import (
        table2_rows,
        table3_rows,
        table4_rows,
        table5_rows,
        table6_rows,
    )

    print("\n== Table II: ConvCoTM ASIC characteristics (model vs paper) ==")
    for r in table2_rows():
        print(
            f"  {r['clock_mhz']:5.1f} MHz {r['vdd']:.2f} V | "
            f"P {r['power_mw_model']:7.3f} / {r['power_mw_paper']:7.3f} mW | "
            f"EPC {r['epc_nj_model']:6.2f} / {r['epc_nj_paper']:6.2f} nJ | "
            f"rate {r['rate_model']:8.0f} / {r['rate_paper']:8.0f} /s"
        )
        print(f"    (model vs paper; latency model {r['latency_us_model']} us)")

    print("\n== Table III: envisaged CIFAR-10 TM-Composites scale-up ==")
    for r in table3_rows():
        print(f"  {r['parameter']:32s} model={r['model']} paper={r['paper']}")

    print("\n== Table IV: MNIST ULP accelerator comparison ==")
    for r in table4_rows():
        print(
            f"  {r['design']:45s} {r['type']:18s} acc={r['mnist_acc_pct']}% "
            f"rate={r['cls_per_s']} EPC={r['epc_nj']} nJ"
        )

    print("\n== Table V: CIFAR-10 ULP accelerator comparison ==")
    for r in table5_rows():
        acc = f"{r['cifar10_acc_pct']}%" if r["cifar10_acc_pct"] else "n/a"
        fps = r["fps"] if r["fps"] else "n/a"
        epc = f"{r['epc_uj']} uJ" if r["epc_uj"] else "n/a"
        print(f"  {r['design']:48s} {r['algorithm']:10s} acc={acc} rate={fps} EPC={epc}")

    print("\n== Table VI: TM hardware overview ==")
    for r in table6_rows():
        epc = f"{r['epc_j']*1e9:.1f} nJ" if r["epc_j"] else "n/a"
        rate = f"{r['cls_per_s']:,}" if r["cls_per_s"] else "n/a"
        print(f"  {r['design']:45s} {r['algorithm']:10s} {r['operation']:12s} "
              f"rate={rate} EPC={epc}")

    # --- Roofline table (from dry-run artifacts + analytic models) -------
    try:
        from benchmarks.roofline_table import render_markdown, roofline_rows

        rows = roofline_rows("16x16")
        compiled = sum(1 for r in rows if r["compiled"])
        print(f"\n== Roofline (16x16, {compiled}/{len(rows)} cells compiled) ==")
        for r in rows:
            print(
                f"  {r['arch']:24s} {r['shape']:12s} dom={r['dominant']:10s} "
                f"frac={r['roofline_fraction']:.2f} "
                f"c={r['compute_s']:.2e} m={r['memory_s']:.2e} "
                f"x={r['collective_s']:.2e}"
            )
    except Exception as e:  # dry-run artifacts absent
        print(f"\n(roofline table unavailable: {e})", file=sys.stderr)


if __name__ == "__main__":
    main()
