"""Reproductions of the paper's tables.

table2: ASIC characteristics/performance (model vs paper measurements)
table3: envisaged CIFAR-10 TM-Composites scale-up
table4: MNIST ULP-accelerator comparison (paper's cited numbers + ours)
table6: TM-hardware overview (cited numbers + this reproduction)
"""

from __future__ import annotations

from typing import Dict, List

from benchmarks.asic_model import (
    PAPER_POINTS,
    model_for,
    scaled_28nm,
    table3_scaled_up,
)

__all__ = ["table2_rows", "table3_rows", "table4_rows", "table6_rows"]


def table2_rows() -> List[Dict]:
    """Model vs paper for every (clock, vdd) measurement point."""
    rows = []
    for (f, v), (p_meas, epc_meas, rate_meas) in PAPER_POINTS.items():
        m = model_for(f, v)
        s = m.summary()
        rows.append(
            {
                "clock_mhz": f / 1e6,
                "vdd": v,
                "power_mw_model": round(s["power_mw"], 3),
                "power_mw_paper": p_meas * 1e3,
                "epc_nj_model": round(s["epc_nj"], 2),
                "epc_nj_paper": epc_meas * 1e9,
                "rate_model": round(s["cls_per_s"], 0),
                "rate_paper": rate_meas,
                "latency_us_model": round(s["latency_us"], 1),
            }
        )
    return rows


def table3_rows() -> List[Dict]:
    t65 = table3_scaled_up("65nm")
    t28 = table3_scaled_up("28nm")
    return [
        {
            "parameter": "classification rate (FPS)",
            "model": round(t65["fps"], 0),
            "paper": 3440,
        },
        {
            "parameter": "EPC 65nm (uJ)",
            "model": round(t65["epc_uj_65nm"], 2),
            "paper": 0.9,
        },
        {
            "parameter": "power 65nm (mW)",
            "model": round(t65["power_mw_65nm"], 2),
            "paper": 3.0,
        },
        {
            "parameter": "EPC 28nm (uJ)",
            "model": round(t28["epc_uj_28nm"], 2),
            "paper": 0.45,
        },
        {
            "parameter": "complete model size (kB)",
            "model": t65["complete_model_kb"],
            "paper": 130,
        },
        {
            "parameter": "area 65nm (mm2)",
            "model": round(t65["area_mm2_65nm"], 1),
            "paper": 17.7,
        },
    ]


# Cited comparison points (Table IV of the paper).
_TABLE4_CITED = [
    ("This work (65nm, 0.82V, 27.8MHz)", "ConvCoTM digital", 97.42, 60_300, 8.6),
    ("Zhao TCAS-I'25 [20] (28nm)", "CNN analog-IMC", 97.9, 3_508, 3.32),
    ("Yejun TCAS-II'23 [21] (65nm, 0.7V)", "SNN mixed-signal", 95.35, 40_000, 12.92),
    ("Yang JSSC'23 [9] (40nm)", "TNN charge-IMC", 97.1, 549, 180.0),
]


def table4_rows() -> List[Dict]:
    est = scaled_28nm()
    rows = [
        {
            "design": name,
            "type": kind,
            "mnist_acc_pct": acc,
            "cls_per_s": rate,
            "epc_nj": epc,
        }
        for name, kind, acc, rate, epc in _TABLE4_CITED
    ]
    rows.insert(
        1,
        {
            "design": "This work scaled to 28nm (est., Sec. VI-A)",
            "type": "ConvCoTM digital",
            "mnist_acc_pct": 97.42,
            "cls_per_s": round(est["cls_per_s"], 0),
            "epc_nj": round(est["epc_nj"], 1),
        },
    )
    return rows


# Cited comparison points (Table V of the paper: CIFAR-10 accelerators).
_TABLE5_CITED = [
    ("Envisaged ConvCoTM composites (65nm, Sec. VI-C)", "ConvCoTM", 79.0, 3440, 0.9),
    ("Envisaged ConvCoTM composites (28nm)", "ConvCoTM", 79.0, 3440, 0.45),
    ("Mauro TCAS-I'20 [6] (22nm SoC)", "BNN", None, 15.4, 43.8),
    ("Knag JSSC'21 [7] (10nm)", "BNN", 86.0, None, None),
    ("Bankman TCAS-I'20 [5] (28nm IMC)", "BNN", 86.0, 237, 3.8),
    ("Park TCAS-I'25 [26] (65nm time-domain IMC)", "SNN VGG-16", 91.13, None, None),
    ("Yoshioka JSSC'25 [27] (65nm analog CIM)", "CNN/ViT", 91.7, None, None),
]


def table5_rows() -> List[Dict]:
    """CIFAR-10 comparison; 'ours' rows come from the Table III model."""
    t65 = table3_scaled_up("65nm")
    rows = []
    for name, algo, acc, fps, epc_uj in _TABLE5_CITED:
        rows.append(
            {
                "design": name,
                "algorithm": algo,
                "cifar10_acc_pct": acc,
                "fps": fps,
                "epc_uj": epc_uj,
            }
        )
    # overwrite the envisaged-65nm row with the model's own numbers
    rows[0]["fps"] = round(t65["fps"], 0)
    rows[0]["epc_uj"] = round(t65["epc_uj_65nm"], 2)
    return rows


_TABLE6_CITED = [
    ("This work (ASIC 65nm)", "ConvCoTM", "inference", 60_300, 8.6e-9),
    ("Wheeldon Phil.Trans.A'20 [11] (ASIC 65nm)", "vanilla TM", "train+infer", None, None),
    ("Mao TCAS-I'25 [31] (FPGA)", "TM/CoTM", "train+infer", 22_400, 73.6e-6),
    ("Tunheim TCAS-I'25 [12] (FPGA)", "ConvCoTM", "train+infer", 134_000, 13.3e-6),
    ("Tunheim MICPRO'23 [28] (FPGA)", "CTM", "train+infer", 4_400_000, 0.6e-6),
    ("Ghazal ISLPED'23 [35] (ReRAM IMC, sim)", "vanilla TM", "inference", None, 13.9e-9),
]


def table6_rows() -> List[Dict]:
    return [
        {
            "design": n,
            "algorithm": a,
            "operation": op,
            "cls_per_s": r,
            "epc_j": e,
        }
        for n, a, op, r, e in _TABLE6_CITED
    ]
