"""Committed cross-PR perf trajectory: ``benchmarks/BENCH_trajectory.json``.

``BENCH_serve.json`` is a CI artifact — it shows this PR's numbers but
vanishes with the workflow run, so nothing in the repo history says
whether a hot path got faster or slower.  This module distills each
serving sweep into one **trajectory row per PR** — best classifications/s
per (path, bucket) at tiny and paper geometry — appended to a committed
JSON file, which gives every future PR a baseline to beat and the CI
gate (``tools/check_bench_trajectory.py``) a row to compare against:
a fresh tiny-geometry measurement regressing >15% against the last
committed row fails the build (ROADMAP item 5).

Schema (``benchmarks/BENCH_trajectory.json``)::

    {"schema": 1,
     "rows": [{"pr": "PR6", "generated_at": ..., "backend": "cpu",
               "geometries": {
                 "tiny":  {"best_cls_per_s": {"fused|b8": 46256.0, ...}},
                 "paper": {"best_cls_per_s": {...}}}}]}

Keys are ``"{path}|b{bucket}"``; the value is the best measured cls/s
over the swept ingress modes (device ingress in practice).  Rows are
keyed by PR label — re-measuring the same PR replaces its row instead
of appending a duplicate, so the file stays one-row-per-PR.

Update the committed file (run from the repo root)::

    PYTHONPATH=src python -m benchmarks.trajectory --update --pr PR6

Gate it (CI does this after ``run.py --emit-json --tiny``)::

    python tools/check_bench_trajectory.py --bench bench_out/BENCH_serve.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Optional, Sequence

#: Paths distilled into the trajectory: each dense path and its sparse
#: twin, so the committed history shows the sparse-vs-dense gap per PR.
TRAJECTORY_PATHS = (
    "bitpacked",
    "sparse",
    "matmul",
    "matmul_sparse",
    "fused",
    "fused_sparse",
)

TRAJECTORY_FILE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                               "BENCH_trajectory.json")


def distill_serve_rows(rows: Sequence[Dict]) -> Dict[str, float]:
    """Best cls/s per ``"{path}|b{bucket}"`` from ``serve_engine`` rows
    (dicts with a ``fields`` mapping, as produced by ``bench_serve`` and
    stored in ``BENCH_serve.json``).

    Malformed rows (missing path/bucket/cls_per_s, non-numeric
    throughput, non-dict shapes) are skipped with a warning rather than
    crashing the CI gate: one corrupt artifact row must not turn the
    perf gate into a hard error unrelated to performance.
    """
    best: Dict[str, float] = {}
    skipped = 0
    for r in rows:
        f = r.get("fields", {}) if isinstance(r, dict) else None
        if not isinstance(f, dict) or f.get("kind") != "serve_engine":
            continue
        try:
            key = f"{f['path']}|b{f['bucket']}"
            cls_per_s = float(f["cls_per_s"])
        except (KeyError, TypeError, ValueError):
            skipped += 1
            continue
        best[key] = max(best.get(key, 0.0), cls_per_s)
    if skipped:
        print(
            f"trajectory: skipped {skipped} malformed serve_engine row(s)",
            file=sys.stderr,
        )
    return best


def load_trajectory(path: str = TRAJECTORY_FILE) -> Dict:
    if not os.path.exists(path):
        return {"schema": 1, "rows": []}
    with open(path) as f:
        return json.load(f)


def save_trajectory(traj: Dict, path: str = TRAJECTORY_FILE) -> None:
    with open(path, "w") as f:
        json.dump(traj, f, indent=2, sort_keys=True)
        f.write("\n")


def upsert_row(traj: Dict, row: Dict) -> Dict:
    """Replace the row with the same PR label, else append — the file
    stays one row per PR no matter how often a PR re-measures."""
    rows = [r for r in traj.get("rows", []) if r.get("pr") != row["pr"]]
    rows.append(row)
    return {**traj, "schema": 1, "rows": rows}


def previous_row(traj: Dict, *, before_pr: Optional[str] = None) -> Optional[Dict]:
    """The most recent committed row (optionally skipping ``before_pr``'s
    own row, so a PR gates against its predecessor, not itself)."""
    rows = [r for r in traj.get("rows", []) if r.get("pr") != before_pr]
    return rows[-1] if rows else None


def compare(
    prev_best: Dict[str, float],
    cur_best: Dict[str, float],
    threshold: float = 0.15,
) -> List[Dict]:
    """Per shared key: current vs previous cls/s.  ``regressed`` marks
    keys whose throughput dropped by more than ``threshold``."""
    out = []
    for key in sorted(set(prev_best) & set(cur_best)):
        prev, cur = prev_best[key], cur_best[key]
        drop = (prev - cur) / prev if prev > 0 else 0.0
        out.append(
            {
                "key": key,
                "prev_cls_per_s": prev,
                "cur_cls_per_s": cur,
                "drop": drop,
                "regressed": drop > threshold,
            }
        )
    return out


def median_drop(results: Sequence[Dict]) -> float:
    """The fleet-wide regression signal the CI gate acts on: the median
    throughput drop across shared keys.  Single-key jitter at tiny
    geometry on a shared CPU runner reaches 20-40% between identical
    runs, so any-key gating would flap; a *code* regression shifts many
    keys at once, which the median catches and noise does not."""
    drops = sorted(r["drop"] for r in results)
    n = len(drops)
    if n == 0:
        return 0.0
    mid = n // 2
    return drops[mid] if n % 2 else (drops[mid - 1] + drops[mid]) / 2.0


def measure_row(
    pr: str,
    *,
    geometries: Sequence[str] = ("tiny", "paper"),
    paths: Sequence[str] = TRAJECTORY_PATHS,
    n_requests: Optional[int] = None,
) -> Dict:
    """Measure one trajectory row: the device-ingress bucket sweep over
    ``paths`` at each geometry, distilled to best cls/s per key.  Tiny
    geometry defaults to 20 requests per point (calls are microseconds;
    low rep counts put the gate's baseline inside timer noise), paper
    geometry to 5."""
    import jax

    from benchmarks.bench_serve import bench_serve

    geoms = {}
    for geom in geometries:
        tiny = geom == "tiny"
        rows = bench_serve(
            buckets=(1, 8) if tiny else (1, 64),
            n_requests=n_requests or (20 if tiny else 5),
            paths=paths,
            ingress_modes=("device",),
            tiny=tiny,
        )
        geoms[geom] = {"best_cls_per_s": distill_serve_rows(rows)}
    return {
        "pr": pr,
        "generated_at": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "backend": jax.default_backend(),
        "geometries": geoms,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--update", action="store_true",
                    help="measure and upsert this PR's row in the "
                         "committed trajectory file")
    ap.add_argument("--pr", default=None, help="PR label for the row")
    ap.add_argument("--file", default=TRAJECTORY_FILE)
    ap.add_argument("--tiny-only", action="store_true",
                    help="measure only the tiny geometry (CI smoke)")
    args = ap.parse_args()
    traj = load_trajectory(args.file)
    if not args.update:
        print(json.dumps(traj, indent=2, sort_keys=True))
        return
    if not args.pr:
        ap.error("--update requires --pr")
    row = measure_row(
        args.pr,
        geometries=("tiny",) if args.tiny_only else ("tiny", "paper"),
    )
    save_trajectory(upsert_row(traj, row), args.file)
    print(f"wrote {args.file} ({len(load_trajectory(args.file)['rows'])} rows)")


if __name__ == "__main__":
    main()
