# Root conftest: pytest inserts this file's directory (the repo root) on
# sys.path, which is what lets tests import the repo tooling packages
# (`import tools.tmlint`, `import tools.recompile_guard`) without an
# install step.  Source imports still come from src/ via PYTHONPATH=src
# (the tier-1 command in ROADMAP.md).
