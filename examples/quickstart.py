"""Quickstart: train a ConvCoTM on the 2-D noisy-XOR task (CTM paper [13])
and deploy it through the ASIC register-image flow.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    CoTMConfig,
    PatchSpec,
    accuracy,
    infer,
    init_model,
    pack_model,
    unpack_model,
    update_batch,
)
from repro.data import booleanize_split, noisy_xor_2d


def main():
    # 1. Data: 4x4 Boolean images, class = XOR pattern identity.
    tx, ty, vx, vy = noisy_xor_2d(n_train=2000, n_test=500, seed=0)
    tx, vx = booleanize_split(tx), booleanize_split(vx)

    # 2. A small ConvCoTM: 2x2 convolution window over the 4x4 image.
    cfg = CoTMConfig(
        n_clauses=20,
        n_classes=2,
        patch=PatchSpec(image_x=4, image_y=4, window_x=2, window_y=2),
        T=20,
        s=3.0,
    )
    key = jax.random.PRNGKey(42)
    model = init_model(key, cfg)

    txj = jnp.asarray(tx)
    tyj = jnp.asarray(ty.astype(np.int32))
    vxj = jnp.asarray(vx)
    vyj = jnp.asarray(vy.astype(np.int32))

    # 3. Train (coalesced TM updates, batch-parallel).
    for epoch in range(10):
        for i in range(0, len(tx), 100):
            key, k = jax.random.split(key)
            model = update_batch(k, model, txj[i : i + 100], tyj[i : i + 100], cfg)
        acc = float(accuracy(model, vxj, vyj, cfg))
        print(f"epoch {epoch}: test accuracy {acc:.3f}")

    # 4. Deploy: pack to the chip's register image and back (Sec. IV-B).
    blob = pack_model(model, cfg)
    print(f"register image: {len(blob)} bytes")
    deployed = unpack_model(blob, cfg)
    pred, sums = infer(deployed, vxj[:8], cfg)
    print("predictions:", np.asarray(pred), " labels:", vy[:8])


if __name__ == "__main__":
    main()
