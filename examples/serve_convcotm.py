"""Full ServableModel lifecycle: train -> checkpoint -> freeze -> register
-> serve batched requests.

Trains a small ConvCoTM on the offline MNIST stand-in, saves it through
the repro.checkpoint layer, then restores it into the batched serving
engine and streams mixed-size request batches through the power-of-two
buckets — the software analogue of loading the chip's register image and
running continuous classification (Sec. IV-B/C).

Run:  PYTHONPATH=src python examples/serve_convcotm.py
"""

import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import save_pytree
from repro.configs.convcotm import COTM_CONFIGS
from repro.core import init_model, update_batch
from repro.data import booleanize_split, get_dataset
from repro.serve import ServingEngine


def main():
    cfg = dataclasses.replace(
        COTM_CONFIGS["convcotm-mnist"], n_clauses=64, T=100, s=5.0,
        eval_path="fused",
    )
    tx, ty, vx, vy, source = get_dataset("mnist", n_train=1500, n_test=400)
    print(f"dataset source: {source}")

    # 1. Train.
    txb = jnp.asarray(booleanize_split(tx, "threshold"))
    tyj = jnp.asarray(ty.astype(np.int32))
    key = jax.random.PRNGKey(0)
    model = init_model(key, cfg)
    for epoch in range(4):
        for i in range(0, len(tx), 100):
            key, k = jax.random.split(key)
            model = update_batch(k, model, txb[i : i + 100], tyj[i : i + 100], cfg)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        # 2. Checkpoint the trained model (the deployable artifact).
        save_pytree(model, ckpt_dir, step=4)

        # 3. Restore into the engine: freeze happens once, at registration.
        engine = ServingEngine(max_batch=128)
        engine.load_checkpoint(
            "mnist", ckpt_dir, cfg, booleanize_method="threshold"
        )

        # 4. Serve a mixed-size request stream.
        rng = np.random.default_rng(1)
        correct = total = 0
        for _ in range(24):
            n = int(rng.integers(1, 129))
            idx = rng.integers(0, len(vx), n)
            res = engine.classify("mnist", vx[idx])
            correct += int((res.predictions == vy[idx].astype(np.int64)).sum())
            total += n
        st = engine.stats("mnist")
        print(
            f"served {st.images} images in {st.requests} requests: "
            f"{st.classifications_per_s:,.0f} classifications/s, "
            f"accuracy {correct / total:.3f}"
        )
        print(
            f"buckets compiled: {sorted(st.compiled_buckets)} "
            f"(hits {dict(sorted(st.bucket_hits.items()))})"
        )


if __name__ == "__main__":
    main()
