"""Serve a small LM with batched requests through the decode cache path.

Demonstrates the serving substrate the decode_32k/long_500k dry-run cells
lower: prefill -> ring/recurrent caches -> batched sampling with latched
EOS (the monotone-saturation early exit).

Run:  PYTHONPATH=src python examples/serve_lm.py [arch]
      (arch defaults to xlstm-350m; any of `repro.configs.list_archs()`)
"""

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs, reduced_config
from repro.launch import specs as S
from repro.launch.serve import generate
from repro.models.base import init_params, param_count


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "xlstm-350m"
    cfg = reduced_config(get_config(arch))
    print(f"arch={arch} (reduced: {cfg.n_layers}L d={cfg.d_model}) "
          f"params={param_count(S.model_decls(cfg))/1e3:.0f}k")

    key = jax.random.PRNGKey(0)
    params = init_params(S.model_decls(cfg), key)
    rng = np.random.default_rng(0)

    batch, plen, gen = 4, 16, 24
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, plen)), jnp.int32)
    fe = None
    if cfg.is_encoder_decoder or cfg.modality == "vision":
        fe = jnp.asarray(rng.standard_normal((batch, 16, cfg.d_model)), cfg.dtype)

    t0 = time.time()
    out = generate(
        cfg, params, prompts, gen, temperature=0.8, frontend_embeds=fe, seed=1
    )
    dt = time.time() - t0
    print(f"served {batch} requests x {gen} tokens in {dt:.1f}s "
          f"({batch * gen / dt:.1f} tok/s on CPU)")
    print("sampled token ids (first 2 requests):")
    print(np.asarray(out)[:2])


if __name__ == "__main__":
    main()
