"""Async serving-service lifecycle: register -> start -> submit concurrently
-> observe stats -> graceful drain.

Two tenants (an MNIST-style model with fixed-threshold booleanization and
an FMNIST-style model with adaptive Gaussian booleanization) share one
ServingService.  Concurrent submitters fire mixed-size requests at both;
the microbatcher coalesces them into pow2 buckets under the 200 us
deadline, round-robin keeps the tenants fair, and the run ends with a
graceful drain — every in-queue request is answered before shutdown.
Also demonstrates backpressure: a burst past the high-water mark is
rejected with a retry-after hint instead of queueing unboundedly.

Run:  PYTHONPATH=src python examples/serve_service.py
"""

import asyncio
import dataclasses

import jax
import numpy as np

from repro.configs.convcotm import COTM_CONFIGS
from repro.core.cotm import init_boundary_model
from repro.data import get_dataset
from repro.serve import (
    ServiceConfig,
    ServiceOverloaded,
    ServingEngine,
    ServingService,
)


async def submitter(service, name, images, n_requests, max_n, seed):
    """One tenant's request stream: mixed-size batches, back to back."""
    rng = np.random.default_rng(seed)
    ok, res = 0, None
    for _ in range(n_requests):
        n = int(rng.integers(1, max_n + 1))
        idx = rng.integers(0, len(images), n)
        try:
            res = await service.submit(name, images[idx])
            ok += 1
        except ServiceOverloaded as e:
            await asyncio.sleep(e.retry_after_s)
            continue
        await asyncio.sleep(0)     # hand the loop to the other tenant
    return ok, res


async def main():
    cfg = dataclasses.replace(
        COTM_CONFIGS["convcotm-mnist"], n_clauses=64, eval_path="fused"
    )
    _, _, vx, _, source = get_dataset("mnist", n_test=512)
    print(f"dataset source: {source}")

    # 1. Register two tenants (independent models, booleanizers, stats).
    engine = ServingEngine(max_batch=32)
    for i, (name, method) in enumerate(
        [("mnist", "threshold"), ("fmnist", "adaptive")]
    ):
        model = init_boundary_model(jax.random.PRNGKey(i), cfg)
        engine.register(name, model, cfg, booleanize_method=method)
        engine.warmup(name)

    # 2. Start the service: bounded queue, 200 us coalescing deadline.
    service = ServingService(
        engine, ServiceConfig(max_delay_us=200.0, high_water=256)
    )
    await service.start()

    # 3. Two concurrent tenants submit mixed-size requests.
    totals = await asyncio.gather(
        submitter(service, "mnist", vx, 20, 24, seed=1),
        submitter(service, "fmnist", vx, 20, 24, seed=2),
    )
    for name, (ok, res) in zip(("mnist", "fmnist"), totals):
        last = (
            f"last rode a bucket-{res.bucket} microbatch of "
            f"{res.batch_requests} request(s)" if res else "all rejected"
        )
        print(f"{name}: {ok} requests served; {last}")

    # 4. Backpressure: a burst past high_water is rejected, not queued.
    burst = [vx[:16] for _ in range(64)]
    admitted = rejected = 0
    hint = 0.0
    futures = []
    for b in burst:
        try:
            futures.append(service.submit_nowait("mnist", b))
            admitted += 1
        except ServiceOverloaded as e:
            rejected += 1
            hint = e.retry_after_s
    await asyncio.gather(*futures)
    print(f"burst of {len(burst)}: admitted {admitted}, rejected {rejected} "
          f"(retry-after hint {hint * 1e3:.1f} ms)")

    # 5. Snapshot stats, then drain gracefully.
    for name in engine.models():
        st = service.stats(name)
        print(
            f"{name}: {st.completed} requests / {st.images} images in "
            f"{st.batches} microbatches | occupancy {st.mean_occupancy:.2f} | "
            f"p50 {st.p50_latency_us:,.0f} us p99 {st.p99_latency_us:,.0f} us | "
            f"split ingress {st.ingress_us_per_image:,.0f} / device "
            f"{st.device_us_per_image:,.0f} us/img (raw pixels ride the "
            f"fused device-ingress graph)"
        )
    await service.stop(drain=True)
    print("drained and stopped.")


if __name__ == "__main__":
    asyncio.run(main())
