"""End-to-end driver: train the paper's exact accelerator configuration
(128 clauses, 10x10 window, 10 classes, 28x28 images) on the offline
MNIST stand-in (or real MNIST if mounted under $REPRO_DATA_DIR), with the
double-buffered pipeline and checkpointed cursor — the ASIC's continuous
classification mode, end to end.

Run:  PYTHONPATH=src python examples/train_convcotm_glyphs.py [epochs]
"""

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.convcotm import BOOLEANIZE_METHOD, COTM_CONFIGS
from repro.core import accuracy, init_model, pack_model, update_batch
from repro.data import (
    DoubleBufferedLoader,
    PipelineState,
    batches,
    booleanize_split,
    get_dataset,
)


def main():
    epochs = int(sys.argv[1]) if len(sys.argv) > 1 else 5
    cfg = COTM_CONFIGS["convcotm-mnist"]
    tx, ty, vx, vy, source = get_dataset("mnist", n_train=4000, n_test=800)
    print(f"dataset source: {source} ({len(tx)} train / {len(vx)} test)")
    method = BOOLEANIZE_METHOD["convcotm-mnist"]
    tx = booleanize_split(tx, method)
    vx = booleanize_split(vx, method)

    key = jax.random.PRNGKey(0)
    model = init_model(key, cfg)
    vxj = jnp.asarray(vx)
    vyj = jnp.asarray(vy.astype(np.int32))

    state = PipelineState(seed=1)
    for epoch in range(epochs):
        t0 = time.time()
        n = 0
        # Double-buffered loader: batch k+1 is in flight while k trains
        # (the ASIC's second image register, Sec. IV-C).
        loader = DoubleBufferedLoader(batches(tx, ty.astype(np.int32), 100, state))
        for xb, yb, cursor in loader:
            key, k = jax.random.split(key)
            model = update_batch(k, model, xb, yb, cfg)
            n += xb.shape[0]
        state = PipelineState(epoch=epoch + 1, step=0, seed=1)
        acc = float(accuracy(model, vxj, vyj, cfg))
        dt = time.time() - t0
        print(
            f"epoch {epoch}: acc {acc:.4f}  ({n/dt:.0f} samples/s, "
            f"{dt:.1f}s)"
        )

    blob = pack_model(model, cfg)
    print(f"final model -> register image of {len(blob)} bytes "
          f"(chip expects 5632)")


if __name__ == "__main__":
    main()
