"""End-to-end driver: train the paper's exact accelerator configuration
(128 clauses, 10x10 window, 10 classes, 28x28 images) on the offline
MNIST stand-in (or real MNIST if mounted under $REPRO_DATA_DIR) through
the batch-parallel TrainerEngine — dataset booleanized and lowered to
literals exactly once (device-resident, the ASIC's image registers), each
epoch a single jitted lax.scan with donated model buffers, cursor
checkpointable via PipelineState.

Run:  PYTHONPATH=src python examples/train_convcotm_glyphs.py [epochs]
"""

import sys

import jax

from repro.configs.convcotm import BOOLEANIZE_METHOD, COTM_CONFIGS
from repro.core import pack_model
from repro.data import get_dataset
from repro.train.tm_engine import TrainerEngine


def main():
    epochs = int(sys.argv[1]) if len(sys.argv) > 1 else 5
    cfg = COTM_CONFIGS["convcotm-mnist"]
    tx, ty, vx, vy, source = get_dataset("mnist", n_train=4000, n_test=800)
    print(f"dataset source: {source} ({len(tx)} train / {len(vx)} test)")
    method = BOOLEANIZE_METHOD["convcotm-mnist"]

    engine = TrainerEngine(cfg, batch_size=100)
    # The shared ingress (booleanize -> patches -> literals) runs once per
    # split; epochs gather device-resident literals instead of re-extracting
    # patch features from raw pixels every pass.
    train_ds = engine.prepare(tx, ty, booleanize_method=method)
    eval_ds = engine.prepare(vx, vy, booleanize_method=method)

    key = jax.random.PRNGKey(0)
    model = engine.init_model(key)
    key, model, state, reports = engine.fit(
        key, model, train_ds, epochs=epochs, eval_ds=eval_ds, log=print
    )

    blob = pack_model(model, cfg)
    print(f"final model -> register image of {len(blob)} bytes "
          f"(chip expects 5632)")


if __name__ == "__main__":
    main()
