"""Train a ~100M-parameter LM for a few hundred steps — the end-to-end
training driver (deliverable b): data pipeline -> sharded train step with
microbatched grad accumulation -> checkpointing -> straggler policy.

Run:  PYTHONPATH=src python examples/train_lm.py [steps]
(steps defaults to 200; ~100M params; synthetic token stream since the
container is offline. Loss must decrease — asserted at the end.)
"""

import dataclasses
import sys

import jax.numpy as jnp

from repro.configs import TrainConfig, get_config
from repro.launch import specs as S
from repro.launch.train import run_training, synthetic_lm_batch
from repro.models.base import param_count
from repro.sharding.partition import single_device_mesh


def main():
    steps = int(sys.argv[1]) if len(sys.argv) > 1 else 200
    # ~100M dense config from the h2o-danube family (shrunk depth/width).
    # (An xLSTM variant also runs — see repro.launch.train --arch
    # xlstm-350m --reduced — but stacked exponential-gated recurrences at
    # this depth/seq need LR tuning beyond an example's scope.)
    cfg = dataclasses.replace(
        get_config("h2o-danube-1.8b"),
        n_layers=10,
        d_model=768,
        n_heads=12,
        n_kv_heads=4,
        head_dim=64,
        d_ff=4096,
        vocab_size=2048,
        sliding_window=None,
    )
    n = param_count(S.model_decls(cfg))
    print(f"training {n/1e6:.0f}M-param dense LM for {steps} steps")
    tcfg = TrainConfig(
        learning_rate=1e-3,
        grad_clip=50.0,
        total_steps=steps,
        warmup_steps=max(steps // 10, 1),
        microbatches=2,
        checkpoint_every=max(steps // 2, 1),
    )
    import shutil

    ckpt_dir = "/tmp/repro_lm_ckpt"
    shutil.rmtree(ckpt_dir, ignore_errors=True)   # fresh run (resume demo: rerun without this)
    metrics = run_training(
        cfg, tcfg, single_device_mesh(),
        batch=8, seq=128, steps=steps, ckpt_dir=ckpt_dir,
        log_every=max(steps // 10, 1),
    )
    final = metrics["loss"]
    first = metrics["first_loss"]
    # Convergence on the synthetic successor stream is ~0.005 nats/step at
    # this scale; require proportional measured progress (per-batch losses
    # are noisy, so compare to the first measured step, not ln V).
    required = min(0.8, 0.003 * steps)
    print(f"loss {first:.3f} -> {final:.3f} (required drop {required:.2f})")
    assert final < first - required, "loss did not decrease"


if __name__ == "__main__":
    main()
