"""Sharded checkpointing with atomic commit, async save, and elastic
restore (chip-count changes between save and restore are fine).

Layout:  <dir>/step_<n>/
           manifest.json        {step, leaves: {name: {shape, dtype}}}
           <leaf-name>.npy      full (unsharded) array per leaf
           COMMITTED            sentinel written last (atomic rename of the
                                staging dir makes the whole step atomic)

Arrays are gathered to host before writing — correct for single-process
runs and for multi-controller runs whose arrays are fully addressable.  On
a real multi-host pod each process would write only its addressable shards
(per-shard files keyed by shard index); the manifest format already
carries shapes/dtypes so that extension is additive.  Restore device_puts
every leaf with the sharding for the *current* mesh, which is how elastic
rescaling works: a checkpoint from 512 chips restores cleanly onto 256 or
1024 because shardings are re-derived, not stored.

Pipeline state (epoch/step cursors, RNG) rides in the manifest's
``extra`` dict so a restarted job resumes mid-epoch.

Servable checkpoints (ARCHITECTURE.md §Lifecycle) ride the same format:
:func:`save_servable` stores the frozen register image's arrays as the
pytree and its lifecycle identity — the ``ServableVersion`` stamp and the
``TunedPlan`` JSON — in ``extra``, so :func:`restore_servable` returns a
model that re-registers (or hot-swaps) with its provenance intact.
Legacy / malformed manifests (pre-version checkpoints) synthesize a v0
stamp instead of crashing restore.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = [
    "Checkpointer",
    "save_pytree",
    "restore_pytree",
    "latest_step",
    "save_servable",
    "restore_servable",
]


def _flatten_with_names(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
             for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def save_pytree(tree: Any, directory: str, step: int, extra: Optional[Dict] = None) -> str:
    """Synchronous atomic save. Returns the committed directory."""
    names, leaves, _ = _flatten_with_names(tree)
    final = os.path.join(directory, f"step_{step:08d}")
    staging = final + ".tmp"
    if os.path.exists(staging):
        shutil.rmtree(staging)
    os.makedirs(staging, exist_ok=True)

    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    for name, leaf in zip(names, leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = name.replace("/", "__") + ".npy"
        dtype_name = str(arr.dtype)
        if dtype_name == "bfloat16":
            # numpy can't natively (de)serialize ml_dtypes.bfloat16 —
            # store the raw uint16 payload and record the logical dtype.
            np.save(os.path.join(staging, fname), arr.view(np.uint16))
        else:
            np.save(os.path.join(staging, fname), arr)
        manifest["leaves"][name] = {
            "file": fname, "shape": list(arr.shape), "dtype": dtype_name
        }
    with open(os.path.join(staging, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    open(os.path.join(staging, "COMMITTED"), "w").close()
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(staging, final)
    return final


def _committed_steps(directory: str) -> list:
    """``(step, dirname)`` pairs of committed checkpoints, ascending.

    Malformed ``step_*`` entries (non-numeric suffix — a stray
    ``step_backup`` dir, editor droppings) are skipped instead of
    crashing ``int()``: a junk directory must never take down restore or
    garbage collection.
    """
    out = []
    for d in os.listdir(directory):
        if not d.startswith("step_") or d.endswith(".tmp"):
            continue
        try:
            step = int(d[5:])
        except ValueError:
            continue
        if os.path.exists(os.path.join(directory, d, "COMMITTED")):
            out.append((step, d))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = _committed_steps(directory)
    return steps[-1][0] if steps else None


def restore_pytree(
    template: Any,
    directory: str,
    step: Optional[int] = None,
    shardings: Optional[Any] = None,
) -> Tuple[Any, int, Dict]:
    """Restore into the structure of ``template``.

    ``shardings`` (same structure, NamedSharding leaves) re-shards onto the
    *current* mesh — the elastic-restore path.  Returns (tree, step, extra).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    names, leaves, treedef = _flatten_with_names(template)
    shard_leaves = (
        jax.tree.leaves(shardings, is_leaf=lambda x: hasattr(x, "spec"))
        if shardings is not None
        else [None] * len(leaves)
    )
    out = []
    for name, tmpl, shd in zip(names, leaves, shard_leaves):
        meta = manifest["leaves"].get(name)
        if meta is None:
            raise KeyError(f"checkpoint at step {step} missing leaf {name}")
        arr = np.load(os.path.join(d, meta["file"]))
        if meta.get("dtype") == "bfloat16":
            import ml_dtypes

            arr = arr.view(ml_dtypes.bfloat16)
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(
                f"leaf {name}: checkpoint shape {arr.shape} != template {tmpl.shape}"
            )
        arr = arr.astype(tmpl.dtype)
        out.append(jax.device_put(arr, shd) if shd is not None else jax.device_put(arr))
    return jax.tree.unflatten(treedef, out), step, manifest.get("extra", {})


def save_servable(servable: Any, directory: str, step: int) -> str:
    """Checkpoint a frozen :class:`~repro.serve.servable.ServableModel`.

    The register-image arrays are the pytree; the lifecycle identity —
    the :class:`~repro.serve.servable.ServableVersion` stamp and the
    ``TunedPlan`` JSON — rides in the manifest ``extra``.  The sparsity
    analysis is NOT stored: it is derived (and version-specific — Gorji
    et al.'s indexing argument), so restore re-analyzes.
    """
    tree = {
        "include": servable.include,
        "include_packed": servable.include_packed,
        "nonempty": servable.nonempty,
        "weights": servable.weights,
    }
    extra: Dict[str, Any] = {}
    if servable.version is not None:
        extra["servable_version"] = servable.version.as_dict()
    if servable.tuned is not None:
        extra["tuned_plan"] = servable.tuned.to_json()
    return save_pytree(tree, directory, step, extra)


def restore_servable(
    config: Any, directory: str, step: Optional[int] = None
) -> Tuple[Any, int]:
    """Restore a :func:`save_servable` checkpoint as a stamp-carrying
    :class:`~repro.serve.servable.ServableModel`.

    Returns ``(servable, step)``.  The restored model carries its
    :class:`ServableVersion` and ``TunedPlan`` (digest intact) back from
    the manifest; legacy or malformed manifests synthesize the v0 stamp
    (``ServableVersion.from_dict``) so pre-version checkpoints load.
    ``sparsity`` is left ``None`` — the serving engine re-analyzes at
    register/swap.
    """
    from repro.serve.autotune import TunedPlan
    from repro.serve.servable import ServableModel, ServableVersion

    spec = config.patch
    template = {
        "include": np.zeros((config.n_clauses, config.n_literals), np.uint8),
        "include_packed": np.zeros((config.n_clauses, spec.n_words), np.uint32),
        "nonempty": np.zeros((config.n_clauses,), bool),
        "weights": np.zeros((config.n_classes, config.n_clauses), np.int8),
    }
    tree, step, extra = restore_pytree(template, directory, step)
    extra = extra or {}
    tuned = None
    if extra.get("tuned_plan"):
        try:
            tuned = TunedPlan.from_json(extra["tuned_plan"])
        except (ValueError, KeyError, TypeError):
            tuned = None        # malformed plan: restore the model anyway
    servable = ServableModel(
        include=tree["include"],
        include_packed=tree["include_packed"],
        nonempty=tree["nonempty"],
        weights=tree["weights"],
        config=config,
        tuned=tuned,
        version=ServableVersion.from_dict(extra.get("servable_version")),
    )
    return servable, step


class Checkpointer:
    """Async checkpointer: save() returns immediately; the previous save is
    joined first (at most one in flight — double-commit protection).  Keeps
    the newest ``keep`` checkpoints.

    A failure on the save thread (disk full, permissions, serialization)
    is captured and re-raised on the **next** ``wait()`` or ``save()`` —
    an async save must never vanish silently, or a later restart would
    resume from an older step while the caller believed this one
    committed."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    def wait(self):
        """Join the in-flight save; raise if it (or a previous one) failed.

        The captured exception is re-raised exactly once — a caller that
        handles it can keep using the checkpointer."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            error, self._error = self._error, None
            raise error

    def save(self, tree: Any, step: int, extra: Optional[Dict] = None):
        self.wait()   # joins the previous save and re-raises its failure
        # device_get on the caller thread (arrays may be donated afterwards).
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            # Only this thread writes _error, and wait() joins before
            # reading it — no lock needed with one save in flight.
            try:
                save_pytree(host_tree, self.directory, step, extra)
                self._gc()
            except BaseException as e:  # noqa: BLE001 — surfaced on wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self):
        for _, d in _committed_steps(self.directory)[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, d))

    def restore(self, template: Any, step: Optional[int] = None, shardings=None):
        self.wait()
        return restore_pytree(template, self.directory, step, shardings)
