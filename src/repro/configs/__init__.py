"""Config registry: ``--arch <id>`` for the 10 assigned archs + the
paper's own ConvCoTM configurations."""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.archs import ARCHS
from repro.configs.base import (
    SHAPES,
    ModelConfig,
    ShapeConfig,
    TrainConfig,
    applicable_shapes,
)

__all__ = [
    "ARCHS",
    "SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "TrainConfig",
    "applicable_shapes",
    "get_config",
    "list_archs",
    "reduced_config",
]


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs():
    return sorted(ARCHS)


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests (per the brief: small
    layers/width, few experts, tiny vocab — same code paths)."""
    pattern = cfg.block_pattern
    n_layers = (2 * len(pattern) + 1) if pattern else 3  # cycles + tail coverage
    changes: Dict = dict(
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=512,
        rglru_lru_width=64 if cfg.rglru_lru_width else 0,
    )
    if cfg.is_moe:
        changes.update(
            n_experts=8, n_experts_per_token=2,
            d_ff_shared=64 if cfg.n_shared_experts else 0,
            router_group_size=64,
        )
    if cfg.sliding_window:
        changes["sliding_window"] = 16
    if cfg.local_window:
        changes["local_window"] = 16
    if cfg.is_encoder_decoder:
        changes["n_encoder_layers"] = 2
    if cfg.mrope_sections:
        changes["mrope_sections"] = (2, 3, 3)
    return dataclasses.replace(cfg, **changes)
