"""The 10 assigned architecture configs (exact dims from the brief).

This module is the single source of truth: ``--arch <id>`` selection
resolves through the ``ARCHS`` dict via ``repro.configs.get_config``.
"""

from __future__ import annotations

from repro.configs.base import ModelConfig

__all__ = ["ARCHS"]

# xLSTM-350M: sLSTM + mLSTM blocks, d_ff=0 -> capacity inside blocks
# (proj_factor).  7:1 mLSTM:sLSTM ratio (paper's xLSTM[7:1]); 24 layers =
# 3 cycles of 8.
XLSTM_350M = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=256,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    proj_factor=2.0,
    tie_embeddings=True,
    supports_long_context=True,   # recurrent state: O(1) decode
    sharding_profile="dp",        # 350M params: TP is pure overhead (§Perf)
)

# RecurrentGemma-2B: RG-LRU + local attention, 1 attn per 2 recurrent.
RECURRENTGEMMA_2B = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    block_pattern=("rglru", "rglru", "attn"),
    local_window=2048,
    rglru_lru_width=2560,
    conv_width=4,
    logit_softcap=30.0,
    tie_embeddings=True,
    supports_long_context=True,   # windowed attn + recurrent state
)

MISTRAL_NEMO_12B = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    head_dim=128,
    rope_theta=1_000_000.0,       # 128k context
    supports_long_context=False,  # pure full attention -> long_500k skipped
)

H2O_DANUBE_1_8B = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6912,
    vocab_size=32000,
    head_dim=80,
    sliding_window=4096,          # llama+mistral mix with SWA
    supports_long_context=True,   # windowed KV cache is O(window)
)

H2O_DANUBE_3_4B = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    head_dim=120,
    sliding_window=4096,
    supports_long_context=True,
)

CODEQWEN15_7B = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,                # MHA
    d_ff=13440,
    vocab_size=92416,
    head_dim=128,
    rope_theta=1_000_000.0,
    supports_long_context=False,
)

QWEN2_MOE_A27B = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,                    # routed expert hidden
    vocab_size=151936,
    head_dim=128,
    n_experts=60,
    n_experts_per_token=4,
    n_shared_experts=4,           # one fused shared expert of 4x1408
    d_ff_shared=5632,
    supports_long_context=False,
)

PHI35_MOE_42B = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    head_dim=128,
    n_experts=16,
    n_experts_per_token=2,
    supports_long_context=False,
)

SEAMLESS_M4T_LARGE_V2 = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,                  # decoder layers
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    head_dim=64,
    is_encoder_decoder=True,
    modality="audio",
    supports_long_context=False,
)

QWEN2_VL_7B = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    head_dim=128,
    mrope_sections=(16, 24, 24),  # pairs per (t, h, w); sums to hd/2
    rope_theta=1_000_000.0,
    modality="vision",
    supports_long_context=False,
)

ARCHS = {
    c.name: c
    for c in [
        XLSTM_350M,
        RECURRENTGEMMA_2B,
        MISTRAL_NEMO_12B,
        H2O_DANUBE_1_8B,
        H2O_DANUBE_3_4B,
        CODEQWEN15_7B,
        QWEN2_MOE_A27B,
        PHI35_MOE_42B,
        SEAMLESS_M4T_LARGE_V2,
        QWEN2_VL_7B,
    ]
}
