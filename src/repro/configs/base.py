"""Config dataclasses: model architecture, input shapes, run settings."""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax.numpy as jnp

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "applicable_shapes", "TrainConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description (one per assigned arch)."""

    name: str
    family: str                       # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads

    # --- attention variants ---
    sliding_window: Optional[int] = None      # SWA (h2o-danube)
    local_window: Optional[int] = None        # local attention (recurrentgemma)
    rope_theta: float = 10_000.0
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE
    logit_softcap: Optional[float] = None     # recurrentgemma final softcap

    # --- MoE ---
    n_experts: int = 0
    n_experts_per_token: int = 0
    n_shared_experts: int = 0
    d_ff_shared: int = 0              # shared-expert hidden size
    router_group_size: int = 512      # dispatch group (tokens)
    capacity_factor: float = 1.25

    # --- recurrent families ---
    block_pattern: Optional[Tuple[str, ...]] = None  # cycled: attn|mlstm|slstm|rglru
    proj_factor: float = 2.0          # xLSTM mLSTM up-projection
    conv_width: int = 4               # RG-LRU temporal conv width
    rglru_lru_width: int = 0          # 0 -> d_model

    # --- encoder-decoder / frontends ---
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    modality: Optional[str] = None    # 'audio' | 'vision' | None
    frontend_fraction: float = 0.25   # fraction of seq taken by stub frontend embeds

    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # Perf knobs (EXPERIMENTS.md §Perf): sharding profile for train/prefill
    # ('tp' default, 'dp' for small archs); parallel attention+MLP blocks
    # (PaLM-style) halve the per-layer TP all-reduce count.
    sharding_profile: str = "tp"
    use_parallel_block: bool = False
    dtype: Any = jnp.bfloat16
    supports_long_context: bool = False  # sub-quadratic decode path exists

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def pattern_for_layer(self, i: int) -> str:
        if self.block_pattern is None:
            return "attn"
        return self.block_pattern[i % len(self.block_pattern)]

    def param_count(self) -> int:
        """Analytic parameter count (matmul + embedding params)."""
        d, hd = self.d_model, self.head_dim
        att = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        mlp = 3 * d * self.d_ff
        if self.is_moe:
            mlp = self.n_experts * 3 * d * self.d_ff + (
                3 * d * self.d_ff_shared if self.n_shared_experts else 0
            ) + d * self.n_experts
        per_layer = 0
        n_attn = n_rec = 0
        for i in range(self.n_layers):
            kind = self.pattern_for_layer(i)
            if kind == "attn":
                per_layer += att + mlp
                n_attn += 1
            elif kind == "rglru":
                w = self.rglru_lru_width or d
                per_layer += 2 * d * w + w * d + self.conv_width * w + 2 * w + mlp
                n_rec += 1
            elif kind == "mlstm":
                up = int(d * self.proj_factor)
                per_layer += 2 * d * up + 3 * up * up // max(self.n_heads, 1) + up * d
            elif kind == "slstm":
                per_layer += 4 * d * d + mlp if self.d_ff else 4 * d * d + 2 * d * d
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.is_encoder_decoder:
            per_layer += self.n_encoder_layers * (att + mlp + att)  # enc + cross-attn
        return per_layer + emb

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed-active experts)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        inactive = (self.n_experts - self.n_experts_per_token) * 3 * d * self.d_ff * self.n_layers
        return total - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                          # 'train' | 'prefill' | 'decode'

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> Tuple[str, ...]:
    """Which of the four assigned shapes run for this arch.

    long_500k needs a sub-quadratic decode path (SSM/hybrid/SWA); pure
    full-attention archs skip it (documented in ARCHITECTURE.md
    §Substrate). Everything else runs everywhere.
    """
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long_context:
        names.append("long_500k")
    return tuple(names)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Run settings for the training driver."""

    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1_000
    microbatches: int = 1              # gradient accumulation
    remat: str = "full"                # 'none' | 'full'
    grad_compression: bool = False     # int8 + error feedback on pod axis
    checkpoint_every: int = 200
    seed: int = 0
