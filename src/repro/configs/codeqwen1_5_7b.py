"""--arch codeqwen1.5-7b (see configs/archs.py for the full definition)."""

from repro.configs.archs import CODEQWEN15_7B as CONFIG

__all__ = ["CONFIG"]
