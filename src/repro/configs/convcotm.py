"""The paper's own accelerator configurations (``--arch convcotm-*``).

These are CoTMConfig (not ModelConfig) instances: the ConvCoTM is the
paper's architecture and runs through the same launcher / benchmark
harness as the LM archs, but with its own model/inference code
(repro.core).  Values follow Sec. III-D / IV:

  * 28x28 booleanized images, 10x10 window, stride 1 -> 361 patches,
    272 literals; 128 clauses; 10 classes; int8 weights.
  * MNIST uses threshold-75 booleanization, FMNIST/KMNIST adaptive
    Gaussian (handled by the data pipeline, method recorded here).
  * Training hyper-parameters (T, s) follow the TMU ConvCoTM defaults the
    paper's models were trained with.
  * cifar10-composites is the envisaged Table III scale-up: 4 TM
    Specialists, 1000 clauses, literal budget 16.
"""

from __future__ import annotations

import dataclasses

from repro.core.composites import CompositeConfig
from repro.core.cotm import CoTMConfig
from repro.core.patches import PatchSpec

__all__ = ["COTM_CONFIGS", "BOOLEANIZE_METHOD", "CIFAR10_COMPOSITES"]

_PAPER_PATCH = PatchSpec(
    image_x=28, image_y=28, window_x=10, window_y=10, stride_x=1, stride_y=1,
    channels=1, therm_bits=1,
)

CONVCOTM_MNIST = CoTMConfig(n_clauses=128, n_classes=10, patch=_PAPER_PATCH, T=500, s=10.0)
CONVCOTM_FMNIST = dataclasses.replace(CONVCOTM_MNIST)
CONVCOTM_KMNIST = dataclasses.replace(CONVCOTM_MNIST)

BOOLEANIZE_METHOD = {
    "convcotm-mnist": "threshold",
    "convcotm-fmnist": "adaptive",
    "convcotm-kmnist": "adaptive",
}

COTM_CONFIGS = {
    "convcotm-mnist": CONVCOTM_MNIST,
    "convcotm-fmnist": CONVCOTM_FMNIST,
    "convcotm-kmnist": CONVCOTM_KMNIST,
}

# --- Table III: envisaged CIFAR-10 TM-Composites accelerator -------------
# Four specialists; window sizes / booleanizations per Table III.  1000
# clauses each, literal budget 16, 10-bit weights (we keep int8 clamp: the
# JAX model is the algorithmic twin, the ASIC model handles energy).

def _spec(window: int, therm_bits: int) -> PatchSpec:
    return PatchSpec(
        image_x=32, image_y=32, window_x=window, window_y=window,
        stride_x=1, stride_y=1, channels=3, therm_bits=therm_bits,
    )

_SPECIALISTS = (
    CoTMConfig(n_clauses=1000, n_classes=10, patch=_spec(4, 4), T=1500, s=10.0,
               max_included_literals=16),
    CoTMConfig(n_clauses=1000, n_classes=10, patch=_spec(3, 3), T=1500, s=10.0,
               max_included_literals=16),
    CoTMConfig(n_clauses=1000, n_classes=10, patch=_spec(32, 1), T=1500, s=10.0,
               max_included_literals=16),   # whole-image (HOG-specialist stand-in)
    CoTMConfig(n_clauses=1000, n_classes=10, patch=_spec(10, 1), T=1500, s=10.0,
               max_included_literals=16),   # 10x10 adaptive-thresholding specialist
)

CIFAR10_COMPOSITES = CompositeConfig(specialists=_SPECIALISTS)
