"""--arch h2o-danube-1.8b (see configs/archs.py for the full definition)."""

from repro.configs.archs import H2O_DANUBE_1_8B as CONFIG

__all__ = ["CONFIG"]
