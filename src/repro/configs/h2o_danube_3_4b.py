"""--arch h2o-danube-3-4b (see configs/archs.py for the full definition)."""

from repro.configs.archs import H2O_DANUBE_3_4B as CONFIG

__all__ = ["CONFIG"]
