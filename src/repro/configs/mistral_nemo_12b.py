"""--arch mistral-nemo-12b (see configs/archs.py for the full definition)."""

from repro.configs.archs import MISTRAL_NEMO_12B as CONFIG

__all__ = ["CONFIG"]
