"""--arch phi3.5-moe-42b-a6.6b (see configs/archs.py for the full definition)."""

from repro.configs.archs import PHI35_MOE_42B as CONFIG

__all__ = ["CONFIG"]
