"""--arch qwen2-moe-a2.7b (see configs/archs.py for the full definition)."""

from repro.configs.archs import QWEN2_MOE_A27B as CONFIG

__all__ = ["CONFIG"]
