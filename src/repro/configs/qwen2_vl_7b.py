"""--arch qwen2-vl-7b (see configs/archs.py for the full definition)."""

from repro.configs.archs import QWEN2_VL_7B as CONFIG

__all__ = ["CONFIG"]
