"""--arch recurrentgemma-2b (see configs/archs.py for the full definition)."""

from repro.configs.archs import RECURRENTGEMMA_2B as CONFIG

__all__ = ["CONFIG"]
