"""--arch seamless-m4t-large-v2 (see configs/archs.py for the full definition)."""

from repro.configs.archs import SEAMLESS_M4T_LARGE_V2 as CONFIG

__all__ = ["CONFIG"]
