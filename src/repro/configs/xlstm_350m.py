"""--arch xlstm-350m (see configs/archs.py for the full definition)."""

from repro.configs.archs import XLSTM_350M as CONFIG

__all__ = ["CONFIG"]
