"""ConvCoTM core: the paper's contribution as composable JAX modules."""

from repro.core.booleanize import (
    adaptive_gaussian_booleanize,
    booleanize,
    thermometer_encode,
    threshold_booleanize,
)
from repro.core.clauses import (
    argmax_predict,
    class_sums,
    clause_nonempty,
    eval_clauses_bitpacked,
    eval_clauses_dense,
    eval_clauses_matmul,
    patch_clause_outputs,
    patch_clause_outputs_matmul,
)
from repro.core.composites import CompositeConfig, CompositeModel, composite_infer
from repro.core.cotm import CoTMConfig, CoTMModel, infer, infer_packed, init_model
from repro.core.ingress import (
    IngressSpec,
    apply_booleanize,
    apply_ingress,
    device_ingress,
    raw_trailing_shape,
)
from repro.core.model_io import model_size_bytes, pack_model, unpack_model
from repro.core.patches import (
    PatchSpec,
    extract_patch_features,
    make_literals,
    pack_bits,
    unpack_bits,
)
from repro.core.train import (
    accuracy,
    batch_literals,
    update_batch,
    update_batch_literals,
)

__all__ = [
    "CoTMConfig",
    "CoTMModel",
    "CompositeConfig",
    "CompositeModel",
    "IngressSpec",
    "PatchSpec",
    "accuracy",
    "adaptive_gaussian_booleanize",
    "apply_booleanize",
    "apply_ingress",
    "argmax_predict",
    "batch_literals",
    "booleanize",
    "class_sums",
    "clause_nonempty",
    "composite_infer",
    "device_ingress",
    "eval_clauses_bitpacked",
    "eval_clauses_dense",
    "eval_clauses_matmul",
    "extract_patch_features",
    "infer",
    "infer_packed",
    "init_model",
    "make_literals",
    "model_size_bytes",
    "pack_bits",
    "pack_model",
    "patch_clause_outputs",
    "patch_clause_outputs_matmul",
    "raw_trailing_shape",
    "thermometer_encode",
    "threshold_booleanize",
    "unpack_bits",
    "unpack_model",
    "update_batch",
    "update_batch_literals",
]
