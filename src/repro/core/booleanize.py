"""Booleanization of images for Tsetlin machines.

The paper (Sec. III-D) uses:
  * MNIST:   fixed threshold — pixel > 75 -> 1 else 0 (U = 1 bit/pixel).
  * FMNIST / KMNIST: adaptive Gaussian thresholding (per-pixel local mean
    with a Gaussian window, as in the CTM paper [13] / OpenCV
    ``adaptiveThreshold``).
  * Thermometer encoding (U bits/pixel) is supported for the scaled-up
    TM-Composites configuration (Table III uses 3- and 4-bit color
    thermometers on CIFAR-10).

All functions are pure jnp and jit-compatible; batch axes lead.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "threshold_booleanize",
    "gaussian_kernel1d",
    "adaptive_gaussian_booleanize",
    "thermometer_encode",
    "thermometer_thresholds",
    "booleanize",
]


def threshold_booleanize(images: jax.Array, threshold: int = 75) -> jax.Array:
    """Fixed-threshold booleanization (paper's MNIST setting).

    Args:
      images: uint8/float array ``[..., H, W]`` (or with channel dim).
      threshold: pixels strictly greater than this become 1.

    Returns:
      uint8 array of 0/1, same shape.
    """
    return (images > threshold).astype(jnp.uint8)


def gaussian_kernel1d(size: int, sigma: Optional[float] = None) -> np.ndarray:
    """1-D Gaussian window matching OpenCV's ``getGaussianKernel`` default.

    OpenCV default sigma for a given ksize: 0.3*((ksize-1)*0.5 - 1) + 0.8.
    """
    if sigma is None or sigma <= 0:
        sigma = 0.3 * ((size - 1) * 0.5 - 1) + 0.8
    x = np.arange(size, dtype=np.float64) - (size - 1) / 2.0
    k = np.exp(-(x**2) / (2.0 * sigma**2))
    return (k / k.sum()).astype(np.float32)


@functools.partial(jax.jit, static_argnames=("block_size",))
def adaptive_gaussian_booleanize(
    images: jax.Array,
    block_size: int = 11,
    c: float = 2.0,
) -> jax.Array:
    """Adaptive Gaussian thresholding (paper's FMNIST/KMNIST setting).

    pixel -> 1 iff pixel > gaussian_local_mean(pixel) - c, computed with a
    separable ``block_size`` Gaussian window and edge replication, which is
    what ``cv2.adaptiveThreshold(..., ADAPTIVE_THRESH_GAUSSIAN_C,
    THRESH_BINARY, block_size, c)`` does.

    Args:
      images: ``[..., H, W]`` uint8/float.
      block_size: odd window size.
      c: constant subtracted from the local mean.
    """
    if block_size % 2 != 1:
        raise ValueError(f"block_size must be odd, got {block_size}")
    x = images.astype(jnp.float32)
    batch_shape = x.shape[:-2]
    h, w = x.shape[-2:]
    x2 = x.reshape((-1, h, w))

    k = jnp.asarray(gaussian_kernel1d(block_size))
    pad = block_size // 2

    # Separable convolution with edge replication.
    xp = jnp.pad(x2, ((0, 0), (pad, pad), (0, 0)), mode="edge")
    # Convolve rows (axis 1).
    xr = jax.vmap(
        lambda img: jax.vmap(
            lambda col: jnp.convolve(col, k, mode="valid"), in_axes=1, out_axes=1
        )(img)
    )(xp)
    xp2 = jnp.pad(xr, ((0, 0), (0, 0), (pad, pad)), mode="edge")
    local_mean = jax.vmap(
        lambda img: jax.vmap(lambda row: jnp.convolve(row, k, mode="valid"))(img)
    )(xp2)

    out = (x2 > (local_mean - c)).astype(jnp.uint8)
    return out.reshape(batch_shape + (h, w))


def thermometer_thresholds(levels: int, lo: float = 0.0, hi: float = 255.0) -> np.ndarray:
    """Evenly spaced interior thresholds for a ``levels``-bit thermometer."""
    return np.linspace(lo, hi, levels + 2)[1:-1].astype(np.float32)


@functools.partial(jax.jit, static_argnames=("levels",))
def thermometer_encode(
    images: jax.Array, levels: int, lo: float = 0.0, hi: float = 255.0
) -> jax.Array:
    """Thermometer encoding with ``levels`` bits per value.

    Output shape: ``images.shape + (levels,)`` with bit u set iff
    value > threshold_u; monotone by construction (Buckman et al. [38]).
    For ``levels == 1`` this is a single mid-range threshold.
    """
    th = jnp.asarray(thermometer_thresholds(levels, lo, hi))
    x = images.astype(jnp.float32)[..., None]
    return (x > th).astype(jnp.uint8)


def booleanize(
    images: jax.Array,
    method: str = "threshold",
    threshold: int = 75,
    block_size: int = 11,
    c: float = 2.0,
    levels: int = 1,
) -> jax.Array:
    """Dataset-appropriate booleanization dispatch.

    ``method``: 'threshold' (MNIST), 'adaptive' (alias
    'adaptive_gaussian'; FMNIST/KMNIST), 'thermometer' (multi-bit,
    scaled-up configs).
    Returns ``[..., H, W]`` for U=1 methods, ``[..., H, W, U]`` for
    thermometer with levels > 1.
    """
    if method == "threshold":
        return threshold_booleanize(images, threshold)
    if method in ("adaptive", "adaptive_gaussian"):
        return adaptive_gaussian_booleanize(images, block_size, c)
    if method == "thermometer":
        out = thermometer_encode(images, levels)
        if levels == 1:
            out = out[..., 0]
        return out
    raise ValueError(f"unknown booleanization method: {method}")
