"""Clause evaluation for the (convolutional) coalesced Tsetlin machine.

A clause j (Eq. 2) is the AND of the literals whose trained TA action is
*include*.  For convolution (Eq. 6) a clause fires for an image iff it fires
for at least one patch (the ASIC's sequential-OR register).

Three functionally identical evaluation paths are provided:

  * ``eval_clauses_dense``     — reference semantics on 0/1 uint8 literals.
  * ``eval_clauses_bitpacked`` — uint32 bitwise path (VPU-friendly); the
    Pallas kernel in ``repro.kernels.clause_eval`` implements exactly this
    with VMEM tiling + the CSRF block-skip.
  * ``eval_clauses_matmul``    — MXU formulation: a clause fires on a patch
    iff ``popcount(include & ~literals) == 0``, i.e. iff
    ``(1 - literals) @ includeᵀ == 0`` — one bf16 matmul with fp32
    accumulation (counts ≤ 2o = 272 are exact in fp32).

The *empty clause* rule (paper Sec. IV-D): a clause with zero includes
outputs 0 during inference (the ASIC's ``Empty`` signal forces c_j^b low).
Note all three paths implement this via the ``nonempty`` mask.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.patches import pack_bits

__all__ = [
    "clause_nonempty",
    "eval_clauses_dense",
    "eval_clauses_bitpacked",
    "eval_clauses_matmul",
    "patch_clause_outputs",
    "patch_clause_outputs_matmul",
    "class_sums",
    "argmax_predict",
]


def clause_nonempty(include: jax.Array) -> jax.Array:
    """[C, 2o] 0/1 include mask -> [C] bool nonempty flags."""
    return jnp.any(include > 0, axis=-1)


def patch_clause_outputs(
    literals: jax.Array, include: jax.Array, training: bool = False
) -> jax.Array:
    """Per-patch clause outputs c_j^b (before the sequential OR).

    Args:
      literals: uint8 0/1 ``[B, P, 2o]``.
      include:  uint8 0/1 ``[C, 2o]`` TA-action (include) mask.
      training: TM semantics — an *empty* clause outputs 1 during learning
        (so it can receive Type Ia feedback and bootstrap includes) but 0
        during classification (the ASIC's ``Empty`` signal, Sec. IV-D).

    Returns:
      uint8 0/1 ``[B, P, C]``.
    """
    # violation: literal required (include=1) but absent (literal=0).
    viol = (include[None, None] > 0) & (literals[:, :, None, :] == 0)
    fires = ~jnp.any(viol, axis=-1)
    if not training:
        fires &= clause_nonempty(include)[None, None]
    return fires.astype(jnp.uint8)


def eval_clauses_dense(literals: jax.Array, include: jax.Array) -> jax.Array:
    """Sequential-OR clause outputs c_j (Eq. 6). [B, P, 2o] -> [B, C]."""
    return jnp.any(patch_clause_outputs(literals, include) > 0, axis=1).astype(
        jnp.uint8
    )


def eval_clauses_bitpacked(
    lit_packed: jax.Array,
    include_packed: jax.Array,
    nonempty: jax.Array,
) -> jax.Array:
    """Bit-packed clause evaluation.

    Args:
      lit_packed:     uint32 ``[B, P, W]`` packed literals.
      include_packed: uint32 ``[C, W]`` packed include masks.
      nonempty:       bool ``[C]``.

    Returns:
      uint8 0/1 ``[B, C]`` ORed over patches.
    """
    viol = include_packed[None, None] & ~lit_packed[:, :, None, :]
    fires_patch = jnp.all(viol == 0, axis=-1)            # [B, P, C]
    fired = jnp.any(fires_patch, axis=1) & nonempty[None]
    return fired.astype(jnp.uint8)


def patch_clause_outputs_matmul(
    literals: jax.Array,
    include: jax.Array,
    training: bool = False,
    *,
    dtype=jnp.bfloat16,
) -> jax.Array:
    """MXU formulation of :func:`patch_clause_outputs` (bit-identical).

    violations = (1 - literals) @ includeᵀ: a clause fires on a patch iff
    it has zero violations.  Inputs are 0/1 so bf16 operands are exact;
    accumulation is forced to fp32 (counts ≤ 2o stay exact), making the
    boolean outputs identical to the dense-broadcast reference — this is
    the training fast path (one matmul instead of a ``[P, C, 2o]``
    broadcast per sample).

    Args/returns: as :func:`patch_clause_outputs`.
    """
    neg = (1 - literals).astype(dtype)                   # [B, P, 2o]
    inc = include.astype(dtype)                          # [C, 2o]
    viol_counts = jax.lax.dot_general(
        neg,
        inc,
        (((neg.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                    # [B, P, C]
    fires = viol_counts == 0.0
    if not training:
        fires &= clause_nonempty(include)[None, None]
    return fires.astype(jnp.uint8)


def eval_clauses_matmul(
    literals: jax.Array,
    include: jax.Array,
    nonempty: jax.Array | None = None,
    *,
    dtype=jnp.bfloat16,
) -> jax.Array:
    """MXU formulation: violations = (1 - literals) @ includeᵀ.

    A clause fires on a patch iff it has zero violations. Inputs are 0/1 so
    bf16 operands are exact; accumulation is forced to fp32 (counts ≤ 2o).
    """
    fires_patch = patch_clause_outputs_matmul(
        literals, include, training=True, dtype=dtype
    )                                                    # [B, P, C]
    fired = jnp.any(fires_patch > 0, axis=1)
    if nonempty is None:
        nonempty = clause_nonempty(include)
    return (fired & nonempty[None]).astype(jnp.uint8)


def class_sums(fired: jax.Array, weights: jax.Array) -> jax.Array:
    """Eq. (3): v_i = sum_j w_ij * c_j, as an int32 matmul.

    Args:
      fired:   uint8/int ``[B, C]`` clause outputs.
      weights: int ``[m, C]`` signed clause weights (int8 range on the ASIC).

    Returns:
      int32 ``[B, m]`` class sums.
    """
    return jax.lax.dot_general(
        fired.astype(jnp.int8),
        weights.astype(jnp.int8),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def argmax_predict(v: jax.Array) -> jax.Array:
    """Eq. (4) with the ASIC's tie rule (Fig. 6): v1 > v0 selects v1, so
    ties resolve to the lowest class index — which is also jnp.argmax's
    first-occurrence rule."""
    return jnp.argmax(v, axis=-1).astype(jnp.int32)


def pack_include(include: jax.Array, n_words: int | None = None) -> jax.Array:
    """[C, 2o] 0/1 include mask -> uint32 [C, W] packed."""
    return pack_bits(include, n_words)
