"""TM Composites (Granmo [17]) — the paper's envisaged scaled-up design.

Table III sketches a CIFAR-10 accelerator running four *TM Specialists*
sequentially on one configurable TM module: each specialist is a ConvCoTM
with its own booleanization and window geometry; per image the specialists'
class sums are normalized, summed, and argmax'd.

We implement the composite as a first-class model so the scaled-up
configuration can be dry-run, benchmarked (benchmarks/table3_scaledup.py)
and trained end-to-end on small data. Normalization follows [17]:
v_i <- v_i / max_i |v_i| per specialist (scale-free vote merging).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.cotm import CoTMConfig, CoTMModel, infer

__all__ = ["CompositeConfig", "CompositeModel", "composite_infer"]


@dataclasses.dataclass(frozen=True)
class CompositeConfig:
    specialists: Tuple[CoTMConfig, ...]

    @property
    def n_classes(self) -> int:
        return self.specialists[0].n_classes


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CompositeModel:
    members: Tuple[CoTMModel, ...]


def composite_infer(
    model: CompositeModel,
    views: Sequence[jax.Array],
    config: CompositeConfig,
) -> Tuple[jax.Array, jax.Array]:
    """Composite prediction.

    Args:
      views: one booleanized input per specialist (each specialist may use a
        different booleanization/window, so inputs differ per member).

    Returns:
      (predictions [B], composite class sums float32 [B, m]).
    """
    if len(views) != len(config.specialists):
        raise ValueError("one view per specialist required")
    total = None
    for member, view, cfg in zip(model.members, views, config.specialists):
        _, v = infer(member, view, cfg)
        v = v.astype(jnp.float32)
        denom = jnp.maximum(jnp.max(jnp.abs(v), axis=-1, keepdims=True), 1.0)
        vn = v / denom
        total = vn if total is None else total + vn
    return jnp.argmax(total, axis=-1).astype(jnp.int32), total
