"""Coalesced convolutional Tsetlin machine (ConvCoTM) model + inference.

The model is a pytree matching the ASIC's programmable state (Sec. IV-B):

  * ``ta_state``: uint8 ``[C, 2o]`` Tsetlin-automaton counters (2N states,
    N = 128).  The *TA action* (include) is ``state >= N`` — the hardware
    keeps only these action bits in its 34 816 model flops; we keep the full
    counters so the same object trains and serves.
  * ``weights``: int32 ``[m, C]`` signed clause weights, clamped to the
    ASIC's int8 range at all times.

Inference follows Algorithm 1: booleanize -> patches/literals -> parallel
clause evaluation with sequential OR -> class sums -> argmax.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import clauses as cl
from repro.core.patches import PatchSpec, extract_patch_features, make_literals, pack_bits

__all__ = [
    "CoTMConfig",
    "CoTMModel",
    "GeometryBounds",
    "MAX_GEOMETRY",
    "init_model",
    "init_boundary_model",
    "infer",
    "infer_packed",
]

TA_HALF = 128          # N: include iff state >= N (8-bit TA, Fig. 1)
WEIGHT_MAX = 127       # int8 two's-complement clamp (Sec. IV-B)
WEIGHT_MIN = -127


@dataclasses.dataclass(frozen=True)
class GeometryBounds:
    """The maximum model geometry the integer datapath supports.

    These are the bounds the overflow proofs are carried out at:
    ``tools/tmverify`` rule TM404 runs interval analysis over the
    clause-eval / class-sum jaxprs at exactly this envelope and fails if
    any accumulator chain can exceed its dtype at these sizes — so a
    config inside the envelope is served by arithmetic that provably
    cannot overflow, and :class:`CoTMConfig` rejects configs outside it
    rather than serving silently wrong class sums.
    """

    n_clauses: int = 1024      # C   (paper: 128; Table III composites: 1000)
    n_classes: int = 64        # m   (paper: 10)
    n_literals: int = 8192     # 2o  (paper: 272; CIFAR whole-image: 6144)
    n_patches: int = 2048      # P   (paper: 361; CIFAR 3x3 window: 900)
    batch: int = 4096          # B   (engine max_batch default: 256)

    def admits(self, n_clauses: int, n_classes: int, n_literals: int,
               n_patches: int) -> bool:
        return (
            n_clauses <= self.n_clauses
            and n_classes <= self.n_classes
            and n_literals <= self.n_literals
            and n_patches <= self.n_patches
        )


#: The proven envelope (see GeometryBounds).  Growing it requires the
#: TM404 interval proofs to still pass at the new sizes — tier-1 runs
#: ``python -m tools.tmverify`` on every PR, so an envelope bump that
#: breaks an accumulator bound fails CI instead of shipping.
MAX_GEOMETRY = GeometryBounds()


@dataclasses.dataclass(frozen=True)
class CoTMConfig:
    """Static hyper-parameters of a ConvCoTM (paper values as defaults)."""

    n_clauses: int = 128
    n_classes: int = 10
    patch: PatchSpec = dataclasses.field(default_factory=PatchSpec)
    # Training hyper-parameters (TMU-compatible).
    T: int = 500                 # class-sum clip threshold
    s: float = 10.0              # specificity
    boost_true_positive: bool = True
    max_included_literals: Optional[int] = None   # literal budget [42]
    # Any path registered in repro.serve.paths:
    # 'dense' | 'bitpacked' | 'matmul' | 'kernel' | 'fused' | plugins.
    eval_path: str = "matmul"
    # Training-time clause evaluation inside ``core.train.sample_deltas``:
    # 'matmul' (MXU violation-count fast path, bit-identical) | 'dense'
    # (the reference [P, C, 2o] broadcast, kept for equivalence tests and
    # the dense-vs-matmul training benchmark).
    train_eval: str = "matmul"

    def __post_init__(self):
        if not MAX_GEOMETRY.admits(
            self.n_clauses, self.n_classes,
            self.patch.n_literals, self.patch.n_patches,
        ):
            raise ValueError(
                f"geometry (C={self.n_clauses}, m={self.n_classes}, "
                f"2o={self.patch.n_literals}, P={self.patch.n_patches}) "
                f"exceeds the proven overflow-free envelope {MAX_GEOMETRY}; "
                f"grow GeometryBounds only with the tmverify TM404 proofs "
                f"passing at the new sizes"
            )

    @property
    def n_literals(self) -> int:
        return self.patch.n_literals

    @property
    def model_bits(self) -> int:
        """Register-image size: TA actions + 8-bit weights (45 056 for paper)."""
        return self.n_clauses * self.n_literals + self.n_classes * self.n_clauses * 8


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CoTMModel:
    """Trainable/servable ConvCoTM state (pytree)."""

    ta_state: jax.Array          # uint8 [C, 2o]
    weights: jax.Array           # int32 [m, C]

    @property
    def include(self) -> jax.Array:
        """TA action signals: uint8 0/1 [C, 2o]."""
        return (self.ta_state >= TA_HALF).astype(jnp.uint8)


def init_model(key: jax.Array, config: CoTMConfig) -> CoTMModel:
    """TMU-style init: all TAs at N-1 (weakly exclude); weights random ±1."""
    kw = key
    ta = jnp.full((config.n_clauses, config.n_literals), TA_HALF - 1, jnp.uint8)
    signs = jax.random.bernoulli(kw, 0.5, (config.n_classes, config.n_clauses))
    weights = jnp.where(signs, 1, -1).astype(jnp.int32)
    return CoTMModel(ta_state=ta, weights=weights)


def init_boundary_model(
    key: jax.Array, config: CoTMConfig, spread: int = 10
) -> CoTMModel:
    """Untrained model with TA states straddling the include boundary.

    ``init_model`` puts every TA one step below include, so no clause ever
    fires — degenerate for exercising the inference datapath.  Scattering
    states in ``[N - spread, N + spread)`` gives nondegenerate include
    masks (and, with high probability, some empty clauses) without
    training; used by benchmarks, serving demos and tests.
    """
    k_weights, k_ta = jax.random.split(key)
    model = init_model(k_weights, config)
    model.ta_state = jax.random.randint(
        k_ta, model.ta_state.shape, TA_HALF - spread, TA_HALF + spread
    ).astype(jnp.uint8)
    return model


def _literals_for(images: jax.Array, spec: PatchSpec) -> jax.Array:
    feats = extract_patch_features(images, spec)
    return make_literals(feats)


@functools.partial(jax.jit, static_argnames=("config",))
def infer(
    model: CoTMModel, images: jax.Array, config: CoTMConfig
) -> Tuple[jax.Array, jax.Array]:
    """Algorithm 1 for a batch of booleanized images.

    The evaluation path named by ``config.eval_path`` is resolved through
    the ``repro.serve.paths`` registry; the model-side quantities (include
    bits, packed include words, nonempty mask) come from a ``ServableModel``
    frozen inline at trace time.  Long-running callers should freeze once
    and serve through ``repro.serve.engine`` instead.

    Args:
      model: trained model.
      images: uint8 0/1 ``[B, Y, X]`` (or ``[B, Y, X, Z, U]``).

    Returns:
      (predictions int32 ``[B]``, class sums int32 ``[B, m]``).
    """
    from repro.serve import paths as sp
    from repro.serve.servable import freeze

    sm = freeze(model, config)
    path = sp.get_path(config.eval_path)
    lits = _literals_for(images, config.patch)
    if path.input_form == sp.PACKED:
        lits = pack_bits(lits)
    v = sp.run_path(path, sm, lits)
    return cl.argmax_predict(v), v


@functools.partial(jax.jit, static_argnames=("config", "use_kernel"))
def infer_packed(
    model: CoTMModel,
    lit_packed: jax.Array,
    config: CoTMConfig,
    use_kernel: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Inference from pre-packed literals (the serving fast path).

    The data pipeline packs literals once on the host / in an earlier stage;
    this step then touches only 9 uint32 words per patch.  Dispatches to
    ``config.eval_path`` if that path consumes packed literals, else to the
    ``bitpacked`` path; ``use_kernel`` forces the Pallas kernel path.
    """
    from repro.serve import paths as sp
    from repro.serve.servable import freeze

    if use_kernel:
        path = sp.get_path("kernel")
    else:
        path = sp.get_path(config.eval_path)
        if path.input_form != sp.PACKED:
            path = sp.get_path("bitpacked")
    sm = freeze(model, config)
    v = sp.run_path(path, sm, lit_packed)
    return cl.argmax_predict(v), v
