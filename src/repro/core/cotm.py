"""Coalesced convolutional Tsetlin machine (ConvCoTM) model + inference.

The model is a pytree matching the ASIC's programmable state (Sec. IV-B):

  * ``ta_state``: uint8 ``[C, 2o]`` Tsetlin-automaton counters (2N states,
    N = 128).  The *TA action* (include) is ``state >= N`` — the hardware
    keeps only these action bits in its 34 816 model flops; we keep the full
    counters so the same object trains and serves.
  * ``weights``: int32 ``[m, C]`` signed clause weights, clamped to the
    ASIC's int8 range at all times.

Inference follows Algorithm 1: booleanize -> patches/literals -> parallel
clause evaluation with sequential OR -> class sums -> argmax.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import clauses as cl
from repro.core.patches import PatchSpec, extract_patch_features, make_literals, pack_bits

__all__ = ["CoTMConfig", "CoTMModel", "init_model", "infer", "infer_packed"]

TA_HALF = 128          # N: include iff state >= N (8-bit TA, Fig. 1)
WEIGHT_MAX = 127       # int8 two's-complement clamp (Sec. IV-B)
WEIGHT_MIN = -127


@dataclasses.dataclass(frozen=True)
class CoTMConfig:
    """Static hyper-parameters of a ConvCoTM (paper values as defaults)."""

    n_clauses: int = 128
    n_classes: int = 10
    patch: PatchSpec = dataclasses.field(default_factory=PatchSpec)
    # Training hyper-parameters (TMU-compatible).
    T: int = 500                 # class-sum clip threshold
    s: float = 10.0              # specificity
    boost_true_positive: bool = True
    max_included_literals: Optional[int] = None   # literal budget [42]
    eval_path: str = "matmul"    # 'dense' | 'bitpacked' | 'matmul' | 'kernel'

    @property
    def n_literals(self) -> int:
        return self.patch.n_literals

    @property
    def model_bits(self) -> int:
        """Register-image size: TA actions + 8-bit weights (45 056 for paper)."""
        return self.n_clauses * self.n_literals + self.n_classes * self.n_clauses * 8


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CoTMModel:
    """Trainable/servable ConvCoTM state (pytree)."""

    ta_state: jax.Array          # uint8 [C, 2o]
    weights: jax.Array           # int32 [m, C]

    @property
    def include(self) -> jax.Array:
        """TA action signals: uint8 0/1 [C, 2o]."""
        return (self.ta_state >= TA_HALF).astype(jnp.uint8)


def init_model(key: jax.Array, config: CoTMConfig) -> CoTMModel:
    """TMU-style init: all TAs at N-1 (weakly exclude); weights random ±1."""
    kw = key
    ta = jnp.full((config.n_clauses, config.n_literals), TA_HALF - 1, jnp.uint8)
    signs = jax.random.bernoulli(kw, 0.5, (config.n_classes, config.n_clauses))
    weights = jnp.where(signs, 1, -1).astype(jnp.int32)
    return CoTMModel(ta_state=ta, weights=weights)


def _literals_for(images: jax.Array, spec: PatchSpec) -> jax.Array:
    feats = extract_patch_features(images, spec)
    return make_literals(feats)


@functools.partial(jax.jit, static_argnames=("config",))
def infer(
    model: CoTMModel, images: jax.Array, config: CoTMConfig
) -> Tuple[jax.Array, jax.Array]:
    """Algorithm 1 for a batch of booleanized images.

    Args:
      model: trained model.
      images: uint8 0/1 ``[B, Y, X]`` (or ``[B, Y, X, Z, U]``).

    Returns:
      (predictions int32 ``[B]``, class sums int32 ``[B, m]``).
    """
    lits = _literals_for(images, config.patch)
    include = model.include
    nonempty = cl.clause_nonempty(include)
    path = config.eval_path
    if path == "dense":
        fired = cl.eval_clauses_dense(lits, include)
    elif path == "bitpacked":
        lp = pack_bits(lits)
        ip = pack_bits(include)
        fired = cl.eval_clauses_bitpacked(lp, ip, nonempty)
    elif path == "kernel":
        from repro.kernels import ops as kops
        lp = pack_bits(lits)
        ip = pack_bits(include)
        fired = kops.clause_eval(lp, ip, nonempty)
    else:  # matmul (default: MXU-native)
        fired = cl.eval_clauses_matmul(lits, include, nonempty)
    v = cl.class_sums(fired, model.weights)
    return cl.argmax_predict(v), v


@functools.partial(jax.jit, static_argnames=("config", "use_kernel"))
def infer_packed(
    model: CoTMModel,
    lit_packed: jax.Array,
    config: CoTMConfig,
    use_kernel: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Inference from pre-packed literals (the serving fast path).

    The data pipeline packs literals once on the host / in an earlier stage;
    this step then touches only 9 uint32 words per patch.
    """
    include = model.include
    nonempty = cl.clause_nonempty(include)
    ip = pack_bits(include)
    if use_kernel:
        from repro.kernels import ops as kops
        fired = kops.clause_eval(lit_packed, ip, nonempty)
    else:
        fired = cl.eval_clauses_bitpacked(lit_packed, ip, nonempty)
    v = cl.class_sums(fired, model.weights)
    return cl.argmax_predict(v), v
