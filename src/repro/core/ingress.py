"""Device-resident inference ingress: raw pixels -> literals, one graph.

The chip classifies 60.3k images/s because booleanized pixels stream
straight into the clause datapath with no intermediate memory traffic
(paper Sec. IV-C).  The software ingress used to be the opposite: the
host pipeline (``data.pipeline.preprocess_for_serving``) round-tripped
every batch host<->device at least three times (booleanize jnp->np, pack
np->jnp->np, then np->device again in classify).  This module is the
fused replacement: :func:`apply_ingress` composes

    booleanize -> patch extraction -> literals -> (optional) bit pack

as pure jnp, so it traces into the *same* jitted graph as clause
evaluation — one H2D copy of raw ``uint8 [B, H, W]`` in, one D2H copy of
predictions out.  All static decisions (method, geometry, thermometer
levels) live in the hashable :class:`IngressSpec`, which is exactly the
jit static-argument key the serving engine uses for its bounded-
recompile contract.

Bit-identity contract: every stage calls the same functions the host
pipeline calls (``core.booleanize``, ``core.patches``), so device-ingress
results equal ``preprocess_for_serving`` bit for bit — asserted across
all booleanize methods in ``tests/test_ingress.py``.

On TPU the packed route can additionally drop into the Pallas ingress
kernel (``kernels/ingress.py``), which keeps even the dense ``[B, P, 2o]``
literal bits in VMEM and writes only packed uint32 words to HBM.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.booleanize import (
    adaptive_gaussian_booleanize,
    thermometer_encode,
    threshold_booleanize,
)
from repro.core.patches import (
    PatchSpec,
    extract_patch_features,
    make_literals,
    pack_bits,
)

__all__ = [
    "IngressSpec",
    "apply_booleanize",
    "apply_ingress",
    "device_ingress",
    "raw_trailing_shape",
]

#: Method aliases: the paper's FMNIST/KMNIST preprocessing is OpenCV's
#: adaptiveThreshold with a Gaussian window; both spellings resolve to
#: the same code path.
_METHOD_ALIASES = {"adaptive_gaussian": "adaptive"}
_METHODS = ("threshold", "adaptive", "thermometer", "none")


@dataclasses.dataclass(frozen=True)
class IngressSpec:
    """Static description of one raw->literals ingress (hashable: this is
    the jit static-argument key of the fused classify step).

    ``method``: 'threshold' (MNIST), 'adaptive'/'adaptive_gaussian'
    (FMNIST/KMNIST), 'thermometer' (scaled-up configs), 'none' (inputs
    already booleanized).  ``packed`` selects the literal form of the
    target eval path.  ``kernel_backend`` steers the packed route:
    ``None`` auto-picks (Pallas on TPU, plain jnp elsewhere), 'interpret'
    forces the Pallas ingress kernel in interpret mode (tests), 'jnp'
    forces the plain composition.
    """

    patch: PatchSpec
    method: str = "threshold"
    packed: bool = True
    threshold: int = 75
    block_size: int = 11
    c: float = 2.0
    levels: int = 1
    kernel_backend: Optional[str] = None

    def __post_init__(self):
        m = _METHOD_ALIASES.get(self.method, self.method)
        if m not in _METHODS:
            raise ValueError(
                f"unknown booleanization method {self.method!r}; "
                f"expected one of {_METHODS} (or 'adaptive_gaussian')"
            )
        if m == "thermometer" and self.levels != self.patch.therm_bits:
            raise ValueError(
                f"thermometer levels={self.levels} must equal the patch "
                f"spec's therm_bits={self.patch.therm_bits}"
            )

    @property
    def resolved_method(self) -> str:
        return _METHOD_ALIASES.get(self.method, self.method)


def raw_trailing_shape(spec: IngressSpec) -> Tuple[int, ...]:
    """Expected trailing dims of a raw input batch for this ingress.

    Grayscale single-bit specs take ``[B, Y, X]``; multi-channel specs
    append ``Z``; pre-booleanized ('none') thermometer inputs also carry
    their ``U`` axis (the thermometer *method* produces U on device, so
    its raw input does not).
    """
    p = spec.patch
    shape: Tuple[int, ...] = (p.image_y, p.image_x)
    if p.channels > 1:
        shape += (p.channels,)
    if spec.resolved_method == "none" and p.therm_bits > 1:
        shape += (p.therm_bits,)
    return shape


def apply_booleanize(spec: IngressSpec, raw: jax.Array) -> jax.Array:
    """The booleanize stage of the ingress (pure jnp, jit-side)."""
    m = spec.resolved_method
    if m == "none":
        return raw.astype(jnp.uint8)
    if m == "threshold":
        return threshold_booleanize(raw, spec.threshold)
    if m == "adaptive":
        return adaptive_gaussian_booleanize(raw, spec.block_size, spec.c)
    # thermometer: appends the U axis (kept even for levels == 1 here;
    # _with_feature_axes normalizes against the patch spec below).
    out = thermometer_encode(raw, spec.levels)
    if spec.levels == 1:
        out = out[..., 0]
    return out


def _with_feature_axes(bits: jax.Array, patch: PatchSpec) -> jax.Array:
    """Normalize booleanized bits to the ``[B, Y, X, Z, U]`` layout
    ``extract_patch_features`` consumes, using the patch spec to
    disambiguate a trailing channel axis from a trailing thermometer
    axis."""
    if bits.ndim == 5:
        return bits
    if bits.ndim == 3:
        return bits[..., None, None]
    if bits.ndim != 4:
        raise ValueError(f"booleanized input must be 3-5D, got {bits.ndim}D")
    if patch.therm_bits > 1 and patch.channels == 1 and bits.shape[-1] == patch.therm_bits:
        return bits[..., None, :]          # [B, Y, X, U] -> [B, Y, X, 1, U]
    if patch.channels > 1 and patch.therm_bits == 1 and bits.shape[-1] == patch.channels:
        return bits[..., :, None]          # [B, Y, X, Z] -> [B, Y, X, Z, 1]
    raise ValueError(
        f"cannot map trailing dim {bits.shape[-1]} onto (Z={patch.channels}, "
        f"U={patch.therm_bits})"
    )


def apply_ingress(spec: IngressSpec, raw: jax.Array) -> jax.Array:
    """Raw pixels -> literals in ``spec``'s form, composable under jit.

    Returns dense uint8 ``[B, P, 2o]`` or packed uint32 ``[B, P, W]``.
    No ``np.asarray`` anywhere: the patch index tables are trace-time
    constants and every stage stays on device, so calling this inside a
    jitted classify step fuses the whole raw->predictions path into one
    executable.
    """
    bits = _with_feature_axes(apply_booleanize(spec, raw), spec.patch)
    if spec.packed and spec.patch.channels == 1 and spec.patch.therm_bits == 1:
        backend = spec.kernel_backend or (
            "pallas" if jax.default_backend() == "tpu" else "jnp"
        )
        if backend != "jnp":
            from repro.kernels.ops import ingress_pack

            return ingress_pack(bits[..., 0, 0], spec.patch, backend=backend)
    feats = extract_patch_features(bits, spec.patch)
    lits = make_literals(feats)
    if spec.packed:
        return pack_bits(lits, spec.patch.n_words)
    return lits


#: Standalone jitted ingress (raw on host -> literals on device in one
#: dispatch).  The serving engine does NOT call this — it inlines
#: :func:`apply_ingress` into its classify step so literals never leave
#: the graph; this entry point serves the training engine's dataset
#: freezing and the ingress benchmarks.
device_ingress = jax.jit(apply_ingress, static_argnums=(0,))
