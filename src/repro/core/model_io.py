"""Model (de)serialization to the ASIC's register image.

The chip stores (Sec. IV-B):
  * TA action signals: 272 x 128 = 34 816 bits   (4 352 bytes)
  * clause weights:    10 x 128 x 8 bits          (1 280 bytes)
  * total model size:  45 056 bits = 5 632 bytes

Layout written here (and consumed by the load-model AXI stream in the RTL
repo [40]): clause-major TA-action bits, LSB-first within each byte, literal
index ascending; then class-major int8 two's-complement weights. This gives
a bit-exact round trip between the JAX model and the "register image" the
system processor would DMA to the chip — used by the equivalence tests.
"""

from __future__ import annotations

import numpy as np

from repro.core.cotm import CoTMConfig, CoTMModel, TA_HALF

__all__ = ["pack_model", "unpack_model", "model_size_bytes"]


def model_size_bytes(config: CoTMConfig) -> int:
    ta_bits = config.n_clauses * config.n_literals
    if ta_bits % 8:
        ta_bits += 8 - ta_bits % 8
    return ta_bits // 8 + config.n_classes * config.n_clauses


def pack_model(model: CoTMModel, config: CoTMConfig) -> bytes:
    """JAX model -> register image (bytes)."""
    include = np.asarray(model.include, np.uint8)            # [C, 2o]
    c, lits = include.shape
    assert c == config.n_clauses and lits == config.n_literals
    flat = include.reshape(-1)
    pad = (-flat.size) % 8
    if pad:
        flat = np.concatenate([flat, np.zeros(pad, np.uint8)])
    ta_bytes = np.packbits(flat.reshape(-1, 8), axis=1, bitorder="little").reshape(-1)

    w = np.asarray(model.weights, np.int64)
    if w.min() < -128 or w.max() > 127:
        raise ValueError("weights exceed the ASIC's int8 range")
    w_bytes = w.astype(np.int8).reshape(-1).view(np.uint8)
    return ta_bytes.tobytes() + w_bytes.tobytes()


def unpack_model(blob: bytes, config: CoTMConfig) -> CoTMModel:
    """Register image -> inference-only model.

    TA counters are reconstructed at the action boundary (include -> N,
    exclude -> N-1): the chip only keeps action bits, so this is the
    canonical inference-equivalent state.
    """
    import jax.numpy as jnp

    exp = model_size_bytes(config)
    if len(blob) != exp:
        raise ValueError(f"register image is {len(blob)} bytes, expected {exp}")
    ta_bits = config.n_clauses * config.n_literals
    ta_nbytes = (ta_bits + 7) // 8
    ta_raw = np.frombuffer(blob[:ta_nbytes], np.uint8)
    bits = np.unpackbits(ta_raw, bitorder="little")[:ta_bits]
    include = bits.reshape(config.n_clauses, config.n_literals)
    ta_state = np.where(include > 0, TA_HALF, TA_HALF - 1).astype(np.uint8)

    w = (
        np.frombuffer(blob[ta_nbytes:], np.uint8)
        .view(np.int8)
        .reshape(config.n_classes, config.n_clauses)
        .astype(np.int32)
    )
    return CoTMModel(ta_state=jnp.asarray(ta_state), weights=jnp.asarray(w))
