"""Patch generation for the convolutional coalesced Tsetlin machine.

Mirrors the ASIC's patch-generation module (paper Sec. IV-C):

  * a ``Wx × Wy`` window slides over the ``X × Y`` booleanized image with
    strides ``(dx, dy)``; x (column) fastest, then y (row) — patch index
    b = y_pos * Bx + x_pos, exactly the order the shift-register hardware
    produces patches in;
  * per patch, the feature vector is
        [window bits (row-major wy, wx, z, u), y-position thermometer
         (Y - Wy bits), x-position thermometer (X - Wx bits)]
    matching Eq. (5): N_F = Wx*Wy*Z*U + (Y - Wy) + (X - Wx);
  * literals are [features, ~features] (Eq. 1) and are bit-packed LSB-first
    into uint32 words for the clause-evaluation kernels.

Everything here is shape-static and jit-friendly; index tables are numpy
constants baked at trace time.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "PatchSpec",
    "extract_patch_features",
    "make_literals",
    "pack_bits",
    "unpack_bits",
]


@dataclasses.dataclass(frozen=True)
class PatchSpec:
    """Static geometry of the convolution, as in paper Sec. III-C."""

    image_x: int = 28          # X: columns
    image_y: int = 28          # Y: rows
    window_x: int = 10         # Wx
    window_y: int = 10         # Wy
    stride_x: int = 1          # dx
    stride_y: int = 1          # dy
    channels: int = 1          # Z
    therm_bits: int = 1        # U

    @property
    def bx(self) -> int:
        return 1 + (self.image_x - self.window_x) // self.stride_x

    @property
    def by(self) -> int:
        return 1 + (self.image_y - self.window_y) // self.stride_y

    @property
    def n_patches(self) -> int:
        """B = Bx * By (361 for the paper's 28x28 / 10x10 / stride 1)."""
        return self.bx * self.by

    @property
    def n_window_features(self) -> int:
        return self.window_x * self.window_y * self.channels * self.therm_bits

    @property
    def n_pos_y_bits(self) -> int:
        return self.image_y - self.window_y

    @property
    def n_pos_x_bits(self) -> int:
        return self.image_x - self.window_x

    @property
    def n_features(self) -> int:
        """o in Eq. (5); 136 for the paper's configuration."""
        return self.n_window_features + self.n_pos_y_bits + self.n_pos_x_bits

    @property
    def n_literals(self) -> int:
        """2o; 272 for the paper's configuration."""
        return 2 * self.n_features

    @property
    def n_words(self) -> int:
        """uint32 words per packed literal vector (9 for the paper)."""
        return (self.n_literals + 31) // 32

    def validate(self) -> None:
        if (self.image_x - self.window_x) % self.stride_x:
            raise ValueError("window/stride does not tile image in x")
        if (self.image_y - self.window_y) % self.stride_y:
            raise ValueError("window/stride does not tile image in y")


@functools.lru_cache(maxsize=None)
def _index_tables(spec: PatchSpec) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(iy, ix) gather tables [P, Wy*Wx] plus position bits [P, pos_bits]."""
    spec.validate()
    bx, by = spec.bx, spec.by
    xs = np.arange(bx) * spec.stride_x
    ys = np.arange(by) * spec.stride_y
    # Patch order: y outer, x inner (paper's raster order).
    py, px = np.meshgrid(ys, xs, indexing="ij")          # [By, Bx]
    py = py.reshape(-1)                                   # [P]
    px = px.reshape(-1)
    wy, wx = np.meshgrid(
        np.arange(spec.window_y), np.arange(spec.window_x), indexing="ij"
    )
    wy = wy.reshape(-1)                                   # [Wy*Wx]
    wx = wx.reshape(-1)
    iy = py[:, None] + wy[None, :]                        # [P, Wy*Wx]
    ix = px[:, None] + wx[None, :]

    # Thermometer position encoding (paper Table I): position p (0-based)
    # has the lowest p bits set, out of (span) bits; p = span means all set.
    def therm(positions: np.ndarray, nbits: int) -> np.ndarray:
        if nbits == 0:
            return np.zeros((positions.shape[0], 0), np.uint8)
        bit = np.arange(nbits)[None, :]
        return (bit < positions[:, None]).astype(np.uint8)

    pos_y = therm(py // max(spec.stride_y, 1), spec.n_pos_y_bits)
    pos_x = therm(px // max(spec.stride_x, 1), spec.n_pos_x_bits)
    pos = np.concatenate([pos_y, pos_x], axis=1)          # [P, 36] for paper
    return iy, ix, pos


def extract_patch_features(images: jax.Array, spec: PatchSpec) -> jax.Array:
    """Booleanized images -> per-patch feature bits.

    Args:
      images: uint8 0/1 array, ``[B, Y, X]`` (Z=U=1) or ``[B, Y, X, Z, U]``.
      spec: static geometry.

    Returns:
      uint8 ``[B, P, o]`` feature bits in the ASIC's literal order.
    """
    iy, ix, pos = _index_tables(spec)
    if images.ndim == 3:
        images = images[..., None, None]
    if images.shape[-2] != spec.channels or images.shape[-1] != spec.therm_bits:
        raise ValueError(
            f"images trailing dims {images.shape[-2:]} != (Z={spec.channels},"
            f" U={spec.therm_bits})"
        )
    b = images.shape[0]
    # Gather window pixels: [B, P, Wy*Wx, Z, U] -> [B, P, Wy*Wx*Z*U].
    win = images[:, jnp.asarray(iy), jnp.asarray(ix)]
    win = win.reshape(b, spec.n_patches, spec.n_window_features)
    posb = jnp.broadcast_to(
        jnp.asarray(pos)[None], (b, spec.n_patches, pos.shape[1])
    ).astype(jnp.uint8)
    return jnp.concatenate([win, posb], axis=-1)


def make_literals(features: jax.Array) -> jax.Array:
    """[.., o] feature bits -> [.., 2o] literals = [x, ~x] (Eq. 1)."""
    return jnp.concatenate([features, 1 - features], axis=-1).astype(jnp.uint8)


def pack_bits(bits: jax.Array, n_words: int | None = None) -> jax.Array:
    """Pack 0/1 bits along the last axis into uint32, LSB-first.

    ``bits[..., k]`` maps to word ``k // 32`` bit ``k % 32``. Trailing pad
    bits are zero.
    """
    n = bits.shape[-1]
    w = (n + 31) // 32
    if n_words is None:
        n_words = w
    if n_words < w:
        raise ValueError(f"n_words={n_words} too small for {n} bits")
    pad = n_words * 32 - n
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros(bits.shape[:-1] + (pad,), bits.dtype)], axis=-1
        )
    b = bits.astype(jnp.uint32).reshape(bits.shape[:-1] + (n_words, 32))
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(b << shifts, axis=-1, dtype=jnp.uint32)


def unpack_bits(words: jax.Array, n_bits: int) -> jax.Array:
    """Inverse of :func:`pack_bits`; returns uint8 0/1 ``[..., n_bits]``."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(words.shape[:-1] + (words.shape[-1] * 32,))
    return bits[..., :n_bits].astype(jnp.uint8)
