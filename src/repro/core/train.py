"""Coalesced convolutional Tsetlin machine training in pure JAX.

Implements the CoTM update rule (Glimsdal & Granmo [19]) with convolution
(CTM [13]), matching the TMU reference semantics the paper's models were
trained with (Sec. V: "the TMU SW-version of the ConvCoTM was trained to
find suitable models", weights clamped to the int8 range):

Per sample (X, y) with clause outputs c_j (ORed over patches):

  * target class y:  update prob  p_y = (T - clip(v_y)) / 2T
  * one sampled negative class q: p_q = (T + clip(v_q)) / 2T
  * a clause drawn for update w.r.t. class i gets
      - Type I feedback  if w[i,j] has *positive* polarity for the target
        (or negative polarity for the negative class),
      - Type II feedback otherwise,
    and its weight w[i,j] is incremented (target) / decremented (negative)
    when the clause fired.
  * Type I with c=1 (Ia) uses the literals of a *randomly selected patch*
    among the patches where the clause matched (the FPGA in [12] uses
    reservoir sampling; we draw with a Gumbel argmax over matching patches,
    which is exactly uniform). literal=1 -> TA +1 (prob 1 if
    boost_true_positive else (s-1)/s); literal=0 -> TA -1 with prob 1/s.
  * Type I with c=0 (Ib): every TA -1 with prob 1/s.
  * Type II with c=1: literal=0 & action=exclude -> TA +1 (blocks the
    clause on this pattern); c=0: no-op.
  * Optional literal budget (IJCAI'23 [42]): new includes are blocked once
    a clause has ``max_included_literals`` includes.

Two application modes:
  * ``mode='batch'``  — per-sample deltas are computed with vmap and summed
    before a single apply (batch-parallel TM training; the standard
    data-parallel approximation, and the one that shards over pods).
  * ``mode='scan'``   — strict sequential per-sample application (exact
    TMU semantics) via lax.scan; used by equivalence tests on small sizes.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import clauses as cl
from repro.core.cotm import (
    CoTMConfig,
    CoTMModel,
    TA_HALF,
    WEIGHT_MAX,
    WEIGHT_MIN,
)
from repro.core.patches import extract_patch_features, make_literals

__all__ = ["sample_deltas", "update_batch", "accuracy"]


def _select_patch_literals(
    key: jax.Array, lits: jax.Array, cp: jax.Array
) -> jax.Array:
    """Uniformly select, per clause, one patch among those where it fired.

    Args:
      key: PRNG key.
      lits: uint8 ``[P, 2o]`` literals of every patch.
      cp:   uint8 ``[P, C]`` per-patch clause outputs.

    Returns:
      uint8 ``[C, 2o]`` selected literal vector per clause (arbitrary row
      for clauses that never fired — callers must gate on ``fired``).
    """
    g = jax.random.gumbel(key, cp.shape)                 # [P, C]
    score = jnp.where(cp > 0, g, -jnp.inf)
    idx = jnp.argmax(score, axis=0)                      # [C]
    return lits[idx]                                     # [C, 2o]


def sample_deltas(
    key: jax.Array,
    model: CoTMModel,
    images: jax.Array,
    label: jax.Array,
    config: CoTMConfig,
) -> Tuple[jax.Array, jax.Array]:
    """Per-sample TA and weight deltas (not yet applied).

    Args:
      images: one booleanized image ``[Y, X]`` (or ``[Y, X, Z, U]``).
      label:  int scalar.

    Returns:
      (ta_delta int8 ``[C, 2o]``, w_delta int32 ``[m, C]``).
    """
    k_patch, k_neg, k_t, k_q, k_ia1, k_ia0, k_ib = jax.random.split(key, 7)
    feats = extract_patch_features(images[None], config.patch)[0]   # [P, o]
    lits = make_literals(feats)                                      # [P, 2o]
    include = model.include
    # Training semantics: empty clauses output 1 (bootstrap; Sec. IV-D
    # applies the empty->0 rule only to inference).
    cp = cl.patch_clause_outputs(lits[None], include, training=True)[0]  # [P, C]
    fired = jnp.any(cp > 0, axis=0)                                  # [C] bool
    sel = _select_patch_literals(k_patch, lits, cp)                  # [C, 2o]

    v = cl.class_sums(fired[None].astype(jnp.uint8), model.weights)[0]
    v = jnp.clip(v, -config.T, config.T)                             # [m]

    m = config.n_classes
    y = label.astype(jnp.int32)
    # Sample negative class uniformly from the other m-1 classes.
    q = jax.random.randint(k_neg, (), 0, m - 1, jnp.int32)
    q = jnp.where(q >= y, q + 1, q)

    p_t = (config.T - v[y]).astype(jnp.float32) / (2.0 * config.T)
    p_q = (config.T + v[q]).astype(jnp.float32) / (2.0 * config.T)

    c = config.n_clauses
    upd_t = jax.random.bernoulli(k_t, p_t, (c,))                     # [C]
    upd_q = jax.random.bernoulli(k_q, p_q, (c,))

    w_y = model.weights[y]                                           # [C]
    w_q = model.weights[q]
    pos_t = w_y >= 0
    pos_q = w_q >= 0

    type1 = (upd_t & pos_t) | (upd_q & ~pos_q)                       # [C]
    type2 = (upd_t & ~pos_t) | (upd_q & pos_q)

    s = config.s
    lit1 = sel > 0                                                   # [C, 2o]
    # --- Type I ---
    p_inc = 1.0 if config.boost_true_positive else (s - 1.0) / s
    inc_draw = jax.random.bernoulli(k_ia1, p_inc, lit1.shape)
    dec_draw = jax.random.bernoulli(k_ia0, 1.0 / s, lit1.shape)
    dec_draw_ib = jax.random.bernoulli(k_ib, 1.0 / s, lit1.shape)

    fired_b = fired[:, None]
    t1 = type1[:, None]
    # Literal budget [42]: block *new* includes once at budget.
    if config.max_included_literals is not None:
        n_inc = jnp.sum(include, axis=-1, dtype=jnp.int32)[:, None]  # [C,1]
        may_grow = (n_inc < config.max_included_literals) | (include > 0)
    else:
        may_grow = jnp.ones_like(lit1)

    d_ia = jnp.where(
        lit1, inc_draw.astype(jnp.int8) * may_grow.astype(jnp.int8),
        -dec_draw.astype(jnp.int8)
    )
    d_ib = -dec_draw_ib.astype(jnp.int8)
    d_t1 = jnp.where(fired_b, d_ia, d_ib) * t1.astype(jnp.int8)

    # --- Type II --- (c=1 only): 0-literals with action exclude -> +1.
    excl = include == 0
    d_t2 = ((~lit1) & excl & fired_b & type2[:, None]).astype(jnp.int8)
    if config.max_included_literals is not None:
        d_t2 = d_t2 * may_grow.astype(jnp.int8)

    ta_delta = d_t1 + d_t2                                           # [C, 2o]

    # --- Weight updates (clause fired & drawn for update) ---
    dw_y = (upd_t & fired).astype(jnp.int32)                         # +1
    dw_q = -(upd_q & fired).astype(jnp.int32)                        # -1
    w_delta = jnp.zeros((m, c), jnp.int32)
    w_delta = w_delta.at[y].add(dw_y)
    w_delta = w_delta.at[q].add(dw_q)
    return ta_delta, w_delta


def _apply(model: CoTMModel, ta_delta: jax.Array, w_delta: jax.Array) -> CoTMModel:
    ta = jnp.clip(
        model.ta_state.astype(jnp.int32) + ta_delta.astype(jnp.int32), 0, 2 * TA_HALF - 1
    ).astype(jnp.uint8)
    w = jnp.clip(model.weights + w_delta, WEIGHT_MIN, WEIGHT_MAX)
    return CoTMModel(ta_state=ta, weights=w)


@functools.partial(jax.jit, static_argnames=("config", "mode"))
def update_batch(
    key: jax.Array,
    model: CoTMModel,
    images: jax.Array,
    labels: jax.Array,
    config: CoTMConfig,
    mode: str = "batch",
) -> CoTMModel:
    """One training step over a batch of booleanized images."""
    b = images.shape[0]
    keys = jax.random.split(key, b)
    if mode == "batch":
        ta_d, w_d = jax.vmap(
            lambda k, x, y: sample_deltas(k, model, x, y, config)
        )(keys, images, labels)
        return _apply(model, jnp.sum(ta_d.astype(jnp.int32), 0), jnp.sum(w_d, 0))
    if mode == "scan":
        def body(mdl, kxy):
            k, x, y = kxy
            ta_d, w_d = sample_deltas(k, mdl, x, y, config)
            return _apply(mdl, ta_d, w_d), None
        model, _ = jax.lax.scan(body, model, (keys, images, labels))
        return model
    raise ValueError(f"unknown mode: {mode}")


@functools.partial(jax.jit, static_argnames=("config",))
def accuracy(
    model: CoTMModel, images: jax.Array, labels: jax.Array, config: CoTMConfig
) -> jax.Array:
    from repro.core.cotm import infer

    pred, _ = infer(model, images, config)
    return jnp.mean((pred == labels).astype(jnp.float32))
