"""Coalesced convolutional Tsetlin machine training in pure JAX.

Implements the CoTM update rule (Glimsdal & Granmo [19]) with convolution
(CTM [13]), matching the TMU reference semantics the paper's models were
trained with (Sec. V: "the TMU SW-version of the ConvCoTM was trained to
find suitable models", weights clamped to the int8 range):

Per sample (X, y) with clause outputs c_j (ORed over patches):

  * target class y:  update prob  p_y = (T - clip(v_y)) / 2T
  * one sampled negative class q: p_q = (T + clip(v_q)) / 2T
  * a clause drawn for update w.r.t. class i gets
      - Type I feedback  if w[i,j] has *positive* polarity for the target
        (or negative polarity for the negative class),
      - Type II feedback otherwise,
    and its weight w[i,j] is incremented (target) / decremented (negative)
    when the clause fired.
  * Type I with c=1 (Ia) uses the literals of a *randomly selected patch*
    among the patches where the clause matched (the FPGA in [12] uses
    reservoir sampling; we draw with a Gumbel argmax over matching patches,
    which is exactly uniform). literal=1 -> TA +1 (prob 1 if
    boost_true_positive else (s-1)/s); literal=0 -> TA -1 with prob 1/s.
  * Type I with c=0 (Ib): every TA -1 with prob 1/s.
  * Type II with c=1: literal=0 & action=exclude -> TA +1 (blocks the
    clause on this pattern); c=0: no-op.
  * Optional literal budget (IJCAI'23 [42]): new includes are blocked once
    a clause has ``max_included_literals`` includes.

Two clause-evaluation paths feed the update (``config.train_eval``):

  * ``'matmul'`` — the MXU fast path: per-patch violation counts as one
    ``(1 - literals) @ includeᵀ`` matmul (bf16 operands, fp32 accumulation
    — exact for 0/1 inputs), firing iff the count is zero.  Bit-identical
    to the dense path and ~an order of magnitude faster at paper geometry.
  * ``'dense'``  — the reference ``[P, C, 2o]`` boolean broadcast, kept
    for equivalence tests and the dense-vs-matmul training benchmark.

Two application modes:
  * ``mode='batch'``  — per-sample deltas are computed with vmap and summed
    before a single apply (batch-parallel TM training; the standard
    data-parallel approximation, and the one that shards over pods).
  * ``mode='scan'``   — strict sequential per-sample application (exact
    TMU semantics) via lax.scan; used by equivalence tests on small sizes.

``update_batch`` consumes booleanized images; ``update_batch_literals``
is the same step over precomputed literals (for callers that run the
patch/literal extraction once up front).  ``repro.train.tm_engine``'s
TrainerEngine builds full jitted epochs (plus the multi-device delta
psum) on the shared ``_step_literals`` core, so this module stays the
single source of truth for the update semantics.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import clauses as cl
from repro.core.cotm import (
    CoTMConfig,
    CoTMModel,
    TA_HALF,
    WEIGHT_MAX,
    WEIGHT_MIN,
)
from repro.core.patches import extract_patch_features, make_literals

__all__ = [
    "sample_deltas",
    "sample_deltas_literals",
    "update_batch",
    "update_batch_literals",
    "batch_literals",
    "accuracy",
]


def _select_patch_literals(
    key: jax.Array, lits: jax.Array, cp: jax.Array
) -> jax.Array:
    """Uniformly select, per clause, one patch among those where it fired.

    Args:
      key: PRNG key.
      lits: uint8 ``[P, 2o]`` literals of every patch.
      cp:   uint8 ``[P, C]`` per-patch clause outputs.

    Returns:
      uint8 ``[C, 2o]`` selected literal vector per clause (arbitrary row
      for clauses that never fired — callers must gate on ``fired``).
    """
    g = jax.random.gumbel(key, cp.shape)                 # [P, C]
    score = jnp.where(cp > 0, g, -jnp.inf)
    idx = jnp.argmax(score, axis=0)                      # [C]
    return lits[idx]                                     # [C, 2o]


def _train_patch_outputs(
    lits: jax.Array, include: jax.Array, config: CoTMConfig
) -> jax.Array:
    """Per-patch clause outputs ``cp [P, C]`` via ``config.train_eval``.

    Training semantics: empty clauses output 1 (bootstrap; Sec. IV-D
    applies the empty->0 rule only to inference).
    """
    if config.train_eval == "matmul":
        return cl.patch_clause_outputs_matmul(lits[None], include, training=True)[0]
    if config.train_eval == "dense":
        return cl.patch_clause_outputs(lits[None], include, training=True)[0]
    raise ValueError(
        f"unknown train_eval {config.train_eval!r}; expected 'matmul' or 'dense'"
    )


def batch_literals(images: jax.Array, config: CoTMConfig) -> jax.Array:
    """Booleanized images ``[B, Y, X]`` -> dense literals ``[B, P, 2o]``."""
    return make_literals(extract_patch_features(images, config.patch))


def sample_deltas_literals(
    key: jax.Array,
    model: CoTMModel,
    lits: jax.Array,
    label: jax.Array,
    config: CoTMConfig,
) -> Tuple[jax.Array, jax.Array]:
    """Per-sample TA and weight deltas from precomputed literals.

    The literal-level core of :func:`sample_deltas` — the TrainerEngine
    extracts literals once per dataset and calls this directly.

    Args:
      lits:  uint8 ``[P, 2o]`` literals of one sample's patches.
      label: int scalar.

    Returns:
      (ta_delta int8 ``[C, 2o]``, w_delta int32 ``[m, C]``).
    """
    k_patch, k_neg, k_t, k_q, k_ia1, k_ia0, k_ib = jax.random.split(key, 7)
    include = model.include
    cp = _train_patch_outputs(lits, include, config)                 # [P, C]
    fired = jnp.any(cp > 0, axis=0)                                  # [C] bool
    sel = _select_patch_literals(k_patch, lits, cp)                  # [C, 2o]

    v = cl.class_sums(fired[None].astype(jnp.uint8), model.weights)[0]
    v = jnp.clip(v, -config.T, config.T)                             # [m]

    m = config.n_classes
    y = label.astype(jnp.int32)
    # Sample negative class uniformly from the other m-1 classes.
    q = jax.random.randint(k_neg, (), 0, m - 1, jnp.int32)
    q = jnp.where(q >= y, q + 1, q)

    p_t = (config.T - v[y]).astype(jnp.float32) / (2.0 * config.T)
    p_q = (config.T + v[q]).astype(jnp.float32) / (2.0 * config.T)

    c = config.n_clauses
    upd_t = jax.random.bernoulli(k_t, p_t, (c,))                     # [C]
    upd_q = jax.random.bernoulli(k_q, p_q, (c,))

    w_y = model.weights[y]                                           # [C]
    w_q = model.weights[q]
    pos_t = w_y >= 0
    pos_q = w_q >= 0

    type1 = (upd_t & pos_t) | (upd_q & ~pos_q)                       # [C]
    type2 = (upd_t & ~pos_t) | (upd_q & pos_q)

    s = config.s
    lit1 = sel > 0                                                   # [C, 2o]
    # --- Type I ---
    p_inc = 1.0 if config.boost_true_positive else (s - 1.0) / s
    inc_draw = jax.random.bernoulli(k_ia1, p_inc, lit1.shape)
    dec_draw = jax.random.bernoulli(k_ia0, 1.0 / s, lit1.shape)
    dec_draw_ib = jax.random.bernoulli(k_ib, 1.0 / s, lit1.shape)

    fired_b = fired[:, None]
    t1 = type1[:, None]
    # Literal budget [42]: block *new* includes once at budget.
    if config.max_included_literals is not None:
        n_inc = jnp.sum(include, axis=-1, dtype=jnp.int32)[:, None]  # [C,1]
        may_grow = (n_inc < config.max_included_literals) | (include > 0)
    else:
        may_grow = jnp.ones_like(lit1)

    d_ia = jnp.where(
        lit1, inc_draw.astype(jnp.int8) * may_grow.astype(jnp.int8),
        -dec_draw.astype(jnp.int8)
    )
    d_ib = -dec_draw_ib.astype(jnp.int8)
    d_t1 = jnp.where(fired_b, d_ia, d_ib) * t1.astype(jnp.int8)

    # --- Type II --- (c=1 only): 0-literals with action exclude -> +1.
    excl = include == 0
    d_t2 = ((~lit1) & excl & fired_b & type2[:, None]).astype(jnp.int8)
    if config.max_included_literals is not None:
        d_t2 = d_t2 * may_grow.astype(jnp.int8)

    ta_delta = d_t1 + d_t2                                           # [C, 2o]

    # --- Weight updates (clause fired & drawn for update) ---
    dw_y = (upd_t & fired).astype(jnp.int32)                         # +1
    dw_q = -(upd_q & fired).astype(jnp.int32)                        # -1
    w_delta = jnp.zeros((m, c), jnp.int32)
    w_delta = w_delta.at[y].add(dw_y)
    w_delta = w_delta.at[q].add(dw_q)
    return ta_delta, w_delta


def sample_deltas(
    key: jax.Array,
    model: CoTMModel,
    images: jax.Array,
    label: jax.Array,
    config: CoTMConfig,
) -> Tuple[jax.Array, jax.Array]:
    """Per-sample TA and weight deltas (not yet applied).

    Args:
      images: one booleanized image ``[Y, X]`` (or ``[Y, X, Z, U]``).
      label:  int scalar.

    Returns:
      (ta_delta int8 ``[C, 2o]``, w_delta int32 ``[m, C]``).
    """
    feats = extract_patch_features(images[None], config.patch)[0]   # [P, o]
    lits = make_literals(feats)                                      # [P, 2o]
    return sample_deltas_literals(key, model, lits, label, config)


def _apply(model: CoTMModel, ta_delta: jax.Array, w_delta: jax.Array) -> CoTMModel:
    ta = jnp.clip(
        model.ta_state.astype(jnp.int32) + ta_delta.astype(jnp.int32), 0, 2 * TA_HALF - 1
    ).astype(jnp.uint8)
    w = jnp.clip(model.weights + w_delta, WEIGHT_MIN, WEIGHT_MAX)
    return CoTMModel(ta_state=ta, weights=w)


def _step_literals(
    key: jax.Array,
    model: CoTMModel,
    lits: jax.Array,
    labels: jax.Array,
    config: CoTMConfig,
    mode: str,
    mesh=None,
    data_axis: str = "data",
) -> CoTMModel:
    """One training step over pre-extracted literals (not jitted here)."""
    b = lits.shape[0]
    keys = jax.random.split(key, b)
    if mode == "batch":
        ta_d, w_d = jax.vmap(
            lambda k, lit, y: sample_deltas_literals(k, model, lit, y, config)
        )(keys, lits, labels)
        from repro.distributed.collectives import tree_psum_batch

        ta_sum, w_sum = tree_psum_batch(
            (ta_d.astype(jnp.int32), w_d), mesh=mesh, axis=data_axis
        )
        return _apply(model, ta_sum, w_sum)
    if mode == "scan":
        if mesh is not None:
            raise ValueError(
                "mode='scan' is strictly sequential (exact TMU semantics) "
                "and cannot be data-parallel; use mode='batch' with a mesh"
            )

        def body(mdl, kly):
            k, lit, y = kly
            ta_d, w_d = sample_deltas_literals(k, mdl, lit, y, config)
            return _apply(mdl, ta_d, w_d), None

        model, _ = jax.lax.scan(body, model, (keys, lits, labels))
        return model
    raise ValueError(f"unknown mode: {mode}")


@functools.partial(jax.jit, static_argnames=("config", "mode"))
def update_batch_literals(
    key: jax.Array,
    model: CoTMModel,
    lits: jax.Array,
    labels: jax.Array,
    config: CoTMConfig,
    mode: str = "batch",
) -> CoTMModel:
    """One training step over precomputed literals ``[B, P, 2o]``."""
    return _step_literals(key, model, lits, labels, config, mode)


@functools.partial(jax.jit, static_argnames=("config", "mode"))
def update_batch(
    key: jax.Array,
    model: CoTMModel,
    images: jax.Array,
    labels: jax.Array,
    config: CoTMConfig,
    mode: str = "batch",
) -> CoTMModel:
    """One training step over a batch of booleanized images."""
    lits = batch_literals(images, config)
    return _step_literals(key, model, lits, labels, config, mode)


@functools.partial(jax.jit, static_argnames=("config",))
def accuracy(
    model: CoTMModel, images: jax.Array, labels: jax.Array, config: CoTMConfig
) -> jax.Array:
    from repro.core.cotm import infer

    pred, _ = infer(model, images, config)
    return jnp.mean((pred == labels).astype(jnp.float32))
