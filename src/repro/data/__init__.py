from repro.data.datasets import (
    get_dataset,
    load_idx,
    load_mnist_like,
    noisy_xor_2d,
    synthetic_glyphs,
)
from repro.data.pipeline import (
    DoubleBufferedLoader,
    PipelineState,
    batches,
    booleanize_split,
    epoch_permutation,
    literals_host,
    pack_literals_host,
    preprocess_for_serving,
)

__all__ = [
    "DoubleBufferedLoader",
    "PipelineState",
    "batches",
    "booleanize_split",
    "epoch_permutation",
    "get_dataset",
    "literals_host",
    "load_idx",
    "load_mnist_like",
    "noisy_xor_2d",
    "pack_literals_host",
    "preprocess_for_serving",
    "synthetic_glyphs",
]
