from repro.data.datasets import (
    get_dataset,
    load_idx,
    load_mnist_like,
    noisy_xor_2d,
    synthetic_glyphs,
)
from repro.data.pipeline import (
    DoubleBufferedLoader,
    PipelineState,
    batches,
    booleanize_split,
    pack_literals_host,
)

__all__ = [
    "DoubleBufferedLoader",
    "PipelineState",
    "batches",
    "booleanize_split",
    "get_dataset",
    "load_idx",
    "load_mnist_like",
    "noisy_xor_2d",
    "pack_literals_host",
    "synthetic_glyphs",
]
