"""Datasets for the ConvCoTM accelerator reproduction.

Real data: MNIST / FMNIST / KMNIST in IDX format are loaded when present
under ``$REPRO_DATA_DIR`` (default ``/root/data``), laid out as
``<name>/{train,t10k}-{images-idx3,labels-idx1}-ubyte[.gz]``.

Offline fallbacks (this container has no network):
  * ``synthetic_glyphs`` — 10 procedurally drawn 28x28 glyph classes with
    random shift/thickness/noise; visually distinct, so a correct ConvCoTM
    implementation must reach high accuracy on it (used by the integration
    tests as the MNIST stand-in).
  * ``noisy_xor_2d`` — the 2-D noisy XOR task from the CTM paper [13] /
    the FPGA accelerator [28]: 4x4 Boolean images where the class is the
    XOR of two diagonal 2x2 sub-pattern indicators, with label noise.
"""

from __future__ import annotations

import gzip
import os
import struct
from typing import Optional, Tuple

import numpy as np

__all__ = [
    "load_idx",
    "load_mnist_like",
    "synthetic_glyphs",
    "noisy_xor_2d",
    "get_dataset",
]

DATA_DIR = os.environ.get("REPRO_DATA_DIR", "/root/data")


def load_idx(path: str) -> np.ndarray:
    """Read an IDX (u)byte file, gzip-transparent."""
    opener = gzip.open if path.endswith(".gz") else open
    with opener(path, "rb") as f:
        zero, dtype_code, ndim = struct.unpack(">HBB", f.read(4))
        if zero != 0:
            raise ValueError(f"bad IDX magic in {path}")
        shape = struct.unpack(f">{ndim}I", f.read(4 * ndim))
        data = np.frombuffer(f.read(), np.uint8)
    return data.reshape(shape)


def _find(name: str, stem: str) -> Optional[str]:
    for suffix in ("", ".gz"):
        p = os.path.join(DATA_DIR, name, stem + suffix)
        if os.path.exists(p):
            return p
    return None


def load_mnist_like(name: str) -> Optional[Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]]:
    """(train_x, train_y, test_x, test_y) uint8, or None if not on disk."""
    paths = [
        _find(name, "train-images-idx3-ubyte"),
        _find(name, "train-labels-idx1-ubyte"),
        _find(name, "t10k-images-idx3-ubyte"),
        _find(name, "t10k-labels-idx1-ubyte"),
    ]
    if any(p is None for p in paths):
        return None
    tx, ty, vx, vy = (load_idx(p) for p in paths)
    return tx, ty, vx, vy


# ---------------------------------------------------------------------------
# Synthetic glyphs: 10 distinct stroke patterns on a 28x28 canvas.
# ---------------------------------------------------------------------------

def _draw_glyph(cls: int, rng: np.random.Generator) -> np.ndarray:
    img = np.zeros((28, 28), np.float32)
    t = int(rng.integers(2, 4))          # stroke thickness
    a, b = 6, 21                          # bounding box

    def hline(y, x0=a, x1=b):
        img[y : y + t, x0:x1] = 1.0

    def vline(x, y0=a, y1=b):
        img[y0:y1, x : x + t] = 1.0

    def diag(sign):
        for i in range(b - a):
            y = a + i
            x = a + i if sign > 0 else b - 1 - i
            img[y : y + t, x : x + t] = 1.0

    if cls == 0:       # box
        hline(a); hline(b - t); vline(a); vline(b - t)
    elif cls == 1:     # vertical bar
        vline(13)
    elif cls == 2:     # horizontal bar
        hline(13)
    elif cls == 3:     # plus
        vline(13); hline(13)
    elif cls == 4:     # main diagonal
        diag(+1)
    elif cls == 5:     # anti-diagonal
        diag(-1)
    elif cls == 6:     # X
        diag(+1); diag(-1)
    elif cls == 7:     # T
        hline(a); vline(13)
    elif cls == 8:     # L
        vline(a); hline(b - t)
    else:              # U
        vline(a); vline(b - t); hline(b - t)
    return img


def synthetic_glyphs(
    n_train: int = 2000,
    n_test: int = 500,
    seed: int = 0,
    noise: float = 0.02,
    max_shift: int = 3,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Procedural 10-class glyph dataset, uint8 pixel range [0, 255]."""
    rng = np.random.default_rng(seed)

    def make(n):
        xs = np.zeros((n, 28, 28), np.uint8)
        ys = rng.integers(0, 10, n).astype(np.uint8)
        for i in range(n):
            g = _draw_glyph(int(ys[i]), rng)
            dy, dx = rng.integers(-max_shift, max_shift + 1, 2)
            g = np.roll(np.roll(g, dy, axis=0), dx, axis=1)
            flip = rng.random((28, 28)) < noise
            g = np.where(flip, 1.0 - g, g)
            xs[i] = (g * 255).astype(np.uint8)
        return xs, ys

    tx, ty = make(n_train)
    vx, vy = make(n_test)
    return tx, ty, vx, vy


def noisy_xor_2d(
    n_train: int = 4000,
    n_test: int = 1000,
    seed: int = 0,
    label_noise: float = 0.0,
    background_noise: float = 0.08,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """2-D noisy XOR ([13] Sec. 6 / the FPGA accelerator [28]).

    4x4 Boolean images, 2 classes: a 2x2 XOR pattern is placed at a random
    location — the diagonal pattern [[1,0],[0,1]] encodes class 1, the
    anti-diagonal [[0,1],[1,0]] class 0 (the two patterns are the XOR-true /
    XOR-false configurations of a 2-bit pair).  Remaining pixels are sparse
    Bernoulli noise; optional training label noise.  Solvable by a ConvCoTM
    with a 2x2 window (the accelerator in [28] reaches 99.9 %).  Images are
    returned as 0/255 uint8 so the standard booleanization applies.
    """
    rng = np.random.default_rng(seed)

    def make(n, noisy):
        x = (rng.random((n, 4, 4)) < background_noise).astype(np.uint8)
        y = rng.integers(0, 2, n).astype(np.uint8)
        pos = rng.integers(0, 3, (n, 2))
        for i in range(n):
            r, c = pos[i]
            if y[i]:
                pat = np.array([[1, 0], [0, 1]], np.uint8)
            else:
                pat = np.array([[0, 1], [1, 0]], np.uint8)
            x[i, r : r + 2, c : c + 2] = pat
        yl = y.copy()
        if noisy and label_noise > 0:
            flip = rng.random(n) < label_noise
            yl = np.where(flip, 1 - yl, yl)
        return x * 255, yl

    tx, ty = make(n_train, True)
    vx, vy = make(n_test, False)
    return tx, ty, vx, vy


def get_dataset(name: str, **kw):
    """Unified entry: 'mnist' | 'fmnist' | 'kmnist' fall back to glyphs."""
    if name in ("mnist", "fmnist", "kmnist"):
        real = load_mnist_like(name)
        if real is not None:
            return real + ("real",)
        return synthetic_glyphs(**kw) + ("synthetic",)
    if name == "glyphs":
        return synthetic_glyphs(**kw) + ("synthetic",)
    if name == "noisy_xor":
        return noisy_xor_2d(**kw) + ("synthetic",)
    raise ValueError(f"unknown dataset {name}")
