"""Input pipeline: booleanize -> (optionally bit-pack) -> shard -> prefetch.

Mirrors the ASIC's double-buffered image registers (Sec. IV-C): while batch
k is being classified on device, batch k+1 is already being transferred —
``DoubleBufferedLoader`` keeps one device-resident batch in flight.

For the distributed LM substrate the same loader shards the leading batch
axis over the ("pod", "data") mesh axes with ``jax.device_put`` on a
NamedSharding; for the single-host CPU runs it degenerates to one device.
Pipeline state (epoch cursor + RNG) is checkpointable so a restarted job
resumes mid-epoch (see checkpoint/).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.booleanize import booleanize
from repro.core.ingress import _with_feature_axes
from repro.core.patches import PatchSpec, extract_patch_features, make_literals, pack_bits

__all__ = [
    "PipelineState",
    "batches",
    "booleanize_split",
    "DoubleBufferedLoader",
    "epoch_permutation",
    "literals_host",
    "pack_literals_host",
    "preprocess_for_serving",
]


@dataclasses.dataclass
class PipelineState:
    """Checkpointable cursor: (epoch, step-within-epoch, shuffle seed)."""

    epoch: int = 0
    step: int = 0
    seed: int = 0

    def as_dict(self):
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d):
        return cls(**d)


def booleanize_split(
    images: np.ndarray, method: str = "threshold", **kw
) -> np.ndarray:
    """Host-side batch booleanization (uint8 0/1)."""
    return np.asarray(booleanize(jnp.asarray(images), method=method, **kw))


def literals_host(bool_images: np.ndarray, spec: PatchSpec) -> np.ndarray:
    """Host-side dense literals uint8 ``[B, P, 2o]`` (patch + negate).

    Accepts ``[B, Y, X]``, or with trailing channel/thermometer axes
    (normalized to the ``[B, Y, X, Z, U]`` layout against ``spec`` —
    4-D thermometer batches used to be rejected here).
    """
    bits = _with_feature_axes(jnp.asarray(bool_images), spec)
    feats = extract_patch_features(bits, spec)
    return np.asarray(make_literals(feats))


def pack_literals_host(
    bool_images: np.ndarray, spec: PatchSpec
) -> np.ndarray:
    """Precompute packed literals for the serving fast path."""
    bits = _with_feature_axes(jnp.asarray(bool_images), spec)
    feats = extract_patch_features(bits, spec)
    return np.asarray(pack_bits(make_literals(feats), spec.n_words))


def preprocess_for_serving(
    raw_images: np.ndarray,
    spec: PatchSpec,
    method: str = "threshold",
    packed: bool = True,
    **booleanize_kw,
) -> np.ndarray:
    """The HOST-side serving ingress: booleanize -> patch -> literals
    [-> pack], with an np.asarray materialization between stages.

    This is the reference/baseline ingress: serving itself now runs the
    same stages fused inside the engine's jitted raw classify graph
    (``repro.core.ingress.apply_ingress`` — bit-identical, asserted in
    ``tests/test_ingress.py``).  Callers that preprocess once and submit
    ``preprocessed=True`` many times still use this path, as do the
    ingress benchmarks.

    ``method='none'`` skips booleanization (inputs already 0/1).
    ``packed`` selects the literal form the chosen eval path prefers.
    """
    x = np.asarray(raw_images)
    if method != "none":
        x = booleanize_split(x, method, **booleanize_kw)
    x = x.astype(np.uint8)
    if packed:
        return pack_literals_host(x, spec)
    return literals_host(x, spec)


def epoch_permutation(seed: int, epoch: int, n: int) -> np.ndarray:
    """The deterministic shuffle of epoch ``epoch`` under ``seed``.

    Seeds a ``SeedSequence`` with the *pair* ``(seed, epoch)`` so distinct
    pairs get independent streams.  (The old ``default_rng(seed + epoch)``
    collided: (seed=3, epoch=0) and (seed=2, epoch=1) replayed the same
    permutation.)  Shared by :func:`batches` and the
    ``repro.train.tm_engine`` epoch pre-batcher, so both walk the dataset
    in the same order for the same cursor.
    """
    rng = np.random.default_rng(np.random.SeedSequence([seed, epoch]))
    return rng.permutation(n)


def batches(
    x: np.ndarray,
    y: np.ndarray,
    batch_size: int,
    state: Optional[PipelineState] = None,
    drop_remainder: bool = True,
) -> Iterator[Tuple[np.ndarray, np.ndarray, PipelineState]]:
    """Shuffled epoch iterator that resumes from a PipelineState cursor.

    Each yielded ``PipelineState`` is the cursor to resume *after* that
    batch; the state yielded with the final batch rolls over to
    ``(epoch + 1, step=0)``, so resuming from it starts the next epoch
    instead of replaying an exhausted iterator.
    """
    state = state or PipelineState()
    n = x.shape[0]
    n_steps = n // batch_size if drop_remainder else (n + batch_size - 1) // batch_size
    if n_steps and state.step >= n_steps:
        # Cursor exhausted on entry (pre-fix checkpoints, or a larger
        # batch_size than the one it was saved under, leaving fewer
        # steps per epoch): start the next epoch instead of yielding
        # nothing forever.
        state = PipelineState(state.epoch + 1, 0, state.seed)
    perm = epoch_permutation(state.seed, state.epoch, n)
    for step in range(state.step, n_steps):
        idx = perm[step * batch_size : (step + 1) * batch_size]
        if step + 1 == n_steps:
            cursor = PipelineState(state.epoch + 1, 0, state.seed)
        else:
            cursor = PipelineState(state.epoch, step + 1, state.seed)
        yield x[idx], y[idx], cursor


class DoubleBufferedLoader:
    """Keeps the next device batch in flight (the ASIC's second image buffer).

    ``sharding`` may be a NamedSharding over the batch axis for multi-device
    runs; jax.device_put is async so the H2D copy of batch k+1 overlaps the
    compute of batch k.
    """

    def __init__(self, it, sharding: Optional[jax.sharding.Sharding] = None):
        self._it = iter(it)
        self._sharding = sharding
        self._next = None
        self._prime()

    def _put(self, batch):
        if self._sharding is None:
            return jax.device_put(batch)
        return jax.device_put(batch, self._sharding)

    def _prime(self):
        try:
            x, y, st = next(self._it)
            self._next = (self._put(x), self._put(y), st)
        except StopIteration:
            self._next = None

    def __iter__(self):
        return self

    def __next__(self):
        if self._next is None:
            raise StopIteration
        out = self._next
        self._prime()
        return out
