"""Distributed-optimization collectives.

``quantize_int8`` / ``dequantize_int8`` — per-block int8 quantization with
error feedback, used for gradient compression on the slow inter-pod links.

``compressed_grad_sync`` — the gradient compression step of the train
loop: quantize(grad + error_residual) -> (what would cross the pod links)
-> dequantize; the un-transmitted remainder becomes the next step's error
residual.  Under GSPMD the actual pod-axis all-reduce is emitted by XLA
from the batch-sharded loss; compressing the tensor *before* that
reduction bounds inter-pod bytes at 1/4 of fp32 while error feedback keeps
the optimizer trajectory unbiased (standard EF-SGD argument).

``int8_psum_shard_map`` — an explicit manual int8 all-reduce over a named
mesh axis (shard_map), for runtimes where the pod link is driven manually;
unit-tested on a virtual multi-device CPU mesh.
"""

from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

__all__ = [
    "quantize_int8",
    "dequantize_int8",
    "compressed_grad_sync",
    "int8_psum_shard_map",
    "tree_psum_batch",
    "shard_map_compat",
    "psum_tree",
]

BLOCK = 2048


def _shard_map():
    """jax.shard_map (>= 0.6) or the experimental 0.4.x export."""
    if hasattr(jax, "shard_map"):                    # jax >= 0.6
        return functools.partial(jax.shard_map, check_vma=False)
    from jax.experimental.shard_map import shard_map  # jax 0.4.x

    return functools.partial(shard_map, check_rep=False)


def shard_map_compat():
    """The version-compat ``shard_map`` (jax >= 0.6 or the 0.4.x
    experimental export), for callers outside this module that build
    explicit per-shard programs — e.g. the clause-sharded serving step
    (``serve/mesh.py``), whose partial class sums are combined with
    :func:`psum_tree`."""
    return _shard_map()


def psum_tree(tree: Any, axis: str) -> Any:
    """``jax.lax.psum`` every leaf over a named mesh axis.

    Only meaningful inside a ``shard_map``/``pmap`` body.  Integer leaves
    reduce exactly (addition reordering is associative in int32), which is
    what keeps clause-sharded class sums bit-identical to the unsharded
    evaluation."""
    return jax.tree.map(lambda x: jax.lax.psum(x, axis), tree)


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-block symmetric int8 quantization. Returns (q, scales)."""
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros(pad, jnp.float32)])
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, shape, dtype) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def compressed_grad_sync(grads: Any, residual: Any) -> Tuple[Any, Any]:
    """Error-feedback int8 compression of a gradient pytree.

    Returns (dequantized grads, new residual). ``residual`` has the same
    structure as ``grads`` (fp32).
    """

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, s = quantize_int8(gf)
        deq = dequantize_int8(q, s, g.shape, jnp.float32)
        return deq.astype(g.dtype), gf - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (
        jax.tree.unflatten(treedef, [o[0] for o in outs]),
        jax.tree.unflatten(treedef, [o[1] for o in outs]),
    )


def int8_psum_shard_map(x: jax.Array, mesh: Mesh, axis: str = "pod") -> jax.Array:
    """Explicit int8-compressed all-reduce over one mesh axis.

    Each shard quantizes its contribution; the int8 payload is what crosses
    the ``axis`` links; the psum accumulates in int32 and each shard
    rescales with the max of the per-shard scales (conservative shared
    scale, standard for quantized all-reduce).
    """

    def body(xs):
        q, s = quantize_int8(xs)
        s_max = jax.lax.pmax(s, axis)
        # Requantize against the shared scale so the reduction is exact in
        # int32: q' = round(q * s / s_max).
        q2 = jnp.round(q.astype(jnp.float32) * (s / s_max)).astype(jnp.int32)
        tot = jax.lax.psum(q2, axis)
        return dequantize_int8(tot, s_max, xs.shape, xs.dtype)

    other = tuple(a for a in mesh.axis_names if a != axis)
    spec = P(*((None,) * x.ndim))
    return _shard_map()(body, mesh=mesh, in_specs=spec, out_specs=spec)(x)


def tree_psum_batch(tree: Any, mesh: Mesh | None = None, axis: str = "data") -> Any:
    """Sum each leaf of a per-sample pytree over its leading batch axis.

    The TM data-parallel delta reduction: without a mesh this is a plain
    ``jnp.sum(x, axis=0)``; with a mesh the batch axis is sharded over the
    named ``axis``, each device reduces its local shard, and an exact
    integer ``psum`` combines the partial sums — TA/weight deltas are
    small ints, so unlike the LM gradient path no quantization is needed
    and the result is bit-identical to the single-device sum.

    Args:
      tree: pytree of arrays ``[B, ...]`` (cast int8 deltas to int32
        *before* calling, so the reduction cannot overflow).
      mesh: optional mesh whose ``axis`` shards the batch dimension (B
        must divide evenly by the axis size).

    Returns:
      pytree of ``[...]`` sums, replicated across ``axis`` when meshed.
    """
    if mesh is None:
        return jax.tree.map(lambda x: jnp.sum(x, axis=0), tree)

    flat, treedef = jax.tree.flatten(tree)
    in_specs = tuple(P(*((axis,) + (None,) * (x.ndim - 1))) for x in flat)
    out_specs = tuple(P(*((None,) * (x.ndim - 1))) for x in flat)

    def body(*leaves):
        return tuple(jax.lax.psum(jnp.sum(x, axis=0), axis) for x in leaves)

    outs = _shard_map()(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs
    )(*flat)
    return jax.tree.unflatten(treedef, list(outs))
