"""Fault tolerance: straggler detection, restart protocol, elastic rescale.

Everything here is deterministic control logic (unit-tested); the
side-effectful pieces (checkpoint I/O, mesh rebuild) are injected, so the
same policy runs in the CPU tests and on a real cluster agent.

At 1000+ nodes the relevant failure modes are (a) hard node loss — the
run must restart from the last committed checkpoint, possibly on fewer
chips (elastic), (b) stragglers — one slow host stalls every collective,
so per-step deadlines demand intervention long before a hard failure, and
(c) checkpoint corruption — only COMMITTED checkpoints are ever restored
and the newest K are retained (see checkpoint/).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

__all__ = ["StragglerPolicy", "HeartbeatMonitor", "run_with_restarts", "RestartStats"]


@dataclasses.dataclass
class StragglerPolicy:
    """Per-step deadline policy: a step slower than ``factor`` x the rolling
    median is a straggler event; ``tolerance`` consecutive events trigger
    intervention ('reshard' = drop slow hosts and rebuild the mesh).

    Returning 'reshard' **resets the policy**: strikes go back to zero and
    the duration history is cleared, because the intervention changes the
    mesh — the policy re-warms on post-reshard step times instead of
    escalating every subsequent step forever and comparing the new mesh
    against a median polluted by pre-reshard (straggler-inflated)
    durations."""

    factor: float = 3.0
    window: int = 32
    tolerance: int = 3
    _durations: List[float] = dataclasses.field(default_factory=list)
    _strikes: int = 0

    def observe(self, step_seconds: float) -> str:
        """Record one step duration; returns 'ok' | 'straggler' | 'reshard'."""
        hist = self._durations[-self.window:]
        self._durations.append(step_seconds)
        if len(hist) < max(4, self.window // 4):
            return "ok"
        med = sorted(hist)[len(hist) // 2]
        if step_seconds > self.factor * med:
            self._strikes += 1
            if self._strikes >= self.tolerance:
                self._strikes = 0
                self._durations.clear()
                return "reshard"
            return "straggler"
        self._strikes = 0
        return "ok"

    @property
    def median(self) -> Optional[float]:
        if not self._durations:
            return None
        h = sorted(self._durations[-self.window:])
        return h[len(h) // 2]


@dataclasses.dataclass
class HeartbeatMonitor:
    """Tracks per-host heartbeats; hosts silent longer than ``timeout``
    are declared dead (feeds the elastic-restart decision).

    Call :meth:`expect` with the job's host roster at startup: a host
    that NEVER beats is otherwise invisible to ``dead_hosts`` (only
    hosts that beat at least once used to be tracked, so a node that
    died during bring-up was reported healthy forever)."""

    timeout: float = 60.0
    _last: Dict[str, float] = dataclasses.field(default_factory=dict)

    def expect(self, hosts, now: Optional[float] = None):
        """Register the roster: each host's silence clock starts NOW
        (unless it already beat).  Silent-from-birth hosts then age into
        ``dead_hosts`` after ``timeout`` like any other."""
        now = time.monotonic() if now is None else now
        for h in hosts:
            self._last.setdefault(h, now)

    def beat(self, host: str, now: Optional[float] = None):
        self._last[host] = time.monotonic() if now is None else now

    def dead_hosts(self, now: Optional[float] = None) -> List[str]:
        now = time.monotonic() if now is None else now
        return sorted(h for h, t in self._last.items() if now - t > self.timeout)

    def healthy(self, now: Optional[float] = None) -> bool:
        return not self.dead_hosts(now)


@dataclasses.dataclass
class RestartStats:
    restarts: int = 0                 # total over the job (never reset)
    completed_steps: int = 0
    resumed_from: List[int] = dataclasses.field(default_factory=list)


def run_with_restarts(
    step_fn: Callable[[int], None],
    *,
    start_step: int,
    total_steps: int,
    save_fn: Callable[[int], None],
    restore_fn: Callable[[], int],
    checkpoint_every: int,
    max_restarts: int = 3,
    on_restart: Optional[Callable[[Exception], None]] = None,
) -> RestartStats:
    """Checkpoint/restart driver.

    Runs ``step_fn(step)`` for steps [start_step, total_steps); on any
    exception restores via ``restore_fn() -> step`` (which may rebuild the
    mesh with a different chip count — elastic) and resumes.  This is the
    loop structure the launcher uses; tests inject failing step_fns.

    ``max_restarts`` bounds *consecutive* failures without checkpointed
    progress, not failures over the job's lifetime: each successful
    ``save_fn`` after newly completed steps resets the budget, so a
    long-lived run survives unrelated transient failures weeks apart while
    a crash loop (no progress between failures) still gives up after
    ``max_restarts``.  ``stats.restarts`` stays the lifetime total.
    """
    stats = RestartStats()
    step = start_step
    restarts = 0          # consecutive failures since checkpointed progress
    while step < total_steps:
        try:
            step_fn(step)
            stats.completed_steps += 1
            step += 1
            if step % checkpoint_every == 0 or step == total_steps:
                # save_fn only runs right after a successful step, so a
                # completed save IS checkpointed progress: the
                # transient-failure budget renews.
                save_fn(step)
                restarts = 0
        except Exception as e:  # noqa: BLE001 — any failure triggers restart
            # Recovery itself can fail (restore_fn hitting a corrupt or
            # unreachable checkpoint, on_restart's mesh teardown raising).
            # Each recovery failure consumes restart budget like the step
            # failure that triggered it — the loop keeps retrying recovery
            # until it succeeds or the budget runs out, instead of letting
            # a restore-time exception escape with budget unconsumed (and
            # the job's supervisor none the wiser about the attempts).
            err: Optional[Exception] = e
            while err is not None:
                restarts += 1
                stats.restarts += 1
                if restarts > max_restarts:
                    raise err
                try:
                    if on_restart is not None:
                        on_restart(err)
                    step = restore_fn()
                    stats.resumed_from.append(step)
                    err = None
                except Exception as e2:  # noqa: BLE001 — recovery failed too
                    err = e2
    return stats
