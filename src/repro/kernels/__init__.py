# Pallas TPU kernels for the ConvCoTM datapath (clause_eval / class_sum /
# fused_infer / ingress), their pure-jnp oracles (ref.py), the jit'd
# public wrappers with the padding contract (ops.py), shared block/grid
# helpers (shapes.py), and the kernel->oracle registry (registry.py —
# every pallas_call entry point MUST appear there; tools/tmlint TM202
# enforces it).  Import from repro.kernels.ops in serving/training code.
