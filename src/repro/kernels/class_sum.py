"""Pallas TPU kernel: fused class-sum generation (paper Sec. IV-E).

The ASIC computes v_i = sum_j w_ij * c_j with a MUX + 3-stage pipelined
adder tree per class.  The TPU-native equivalent is an int8 x int8 -> int32
matmul on the MXU with the weight matrix VMEM-resident (it is the model's
10 x 128 register file; 1.25 KiB — it never leaves VMEM).

Grid = (image blocks, clause chunks): the clause axis is innermost and the
output tile accumulates partial sums, so clause pools larger than one VMEM
tile (the scaled-up Table III config has 1000 clauses) stream through while
the weight tile for that chunk is fetched once per (chunk, class-block).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.shapes import grid_blocks

__all__ = ["PALLAS_ORACLES", "class_sum_kernel", "class_sum_pallas"]

#: Pallas entry point -> its pure-jnp oracle in kernels/ref.py (aggregated
#: by kernels/registry.py; statically enforced by tools/tmlint TM202).
PALLAS_ORACLES = {"class_sum_pallas": "class_sum_ref"}


def class_sum_kernel(fired_ref, w_ref, out_ref):
    """Refs: fired [Bb, Cc] int32; w [M, Cc] int32; out [Bb, M] int32."""
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    fired = fired_ref[...].astype(jnp.float32)       # 0/1 — exact in f32
    w = w_ref[...].astype(jnp.float32)               # int8-range — exact
    # MXU matmul with fp32 accumulation; |v| <= 127 * C  fits exactly.
    part = jax.lax.dot_general(
        fired, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    out_ref[...] = out_ref[...] + part.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_b", "block_c", "interpret"))
def class_sum_pallas(
    fired: jax.Array,    # uint8/int [B, C]
    weights: jax.Array,  # int [M, C] (int8 value range)
    *,
    block_b: int = 128,
    block_c: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Returns int32 [B, M] class sums; ops.py handles padding."""
    b, c = fired.shape
    m = weights.shape[0]
    grid = (grid_blocks(b, block_b, axis="B"), grid_blocks(c, block_c, axis="C"))
    return pl.pallas_call(
        class_sum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_c), lambda ib, ic: (ib, ic)),
            pl.BlockSpec((m, block_c), lambda ib, ic: (0, ic)),
        ],
        out_specs=pl.BlockSpec((block_b, m), lambda ib, ic: (ib, 0)),
        out_shape=jax.ShapeDtypeStruct((b, m), jnp.int32),
        interpret=interpret,
    )(fired.astype(jnp.int32), weights.astype(jnp.int32))
