"""Pallas TPU kernel: bit-packed ConvCoTM clause evaluation.

This is the accelerator's clause pool (paper Sec. IV-D) re-derived for the
TPU memory hierarchy:

  * Literals arrive bit-packed: uint32 ``[B, P, W]`` — 9 words encode the
    272 literals of a patch (vs 272 bytes dense: an 8.5x cut in HBM traffic
    for the dominant input stream; the dense path is memory-bound).
  * The include masks (the model's TA-action registers) are uint32
    ``[C, W]``.  Their BlockSpec index map ignores the patch-chunk grid
    axis, so the model block stays **resident in VMEM** across all patch
    chunks — the TPU analogue of the ASIC's "model clock stopped, actions
    held in DFFs".
  * Grid = (image blocks, clause blocks, patch chunks); the patch axis is
    innermost so the output tile acts as the sequential-OR register
    (Eq. 6) accumulated in VMEM.
  * **CSRF block-skip** (the paper's clause-switching-reduction feedback,
    adapted): once every clause in the (image x clause) tile has fired,
    remaining patch-chunk iterations skip the whole tile body via
    ``@pl.when`` — monotone OR saturation means no more work can change
    the result.  On the ASIC this cuts combinational toggling ~50 %; here
    it cuts VPU issue slots for the tail chunks.  Disable with
    ``csrf=False`` (the chip has the same enable pin).

Padding contract (enforced by ops.py): patch padding uses all-zero literal
words — any nonempty clause violates on them, and empty clauses are killed
by the ``nonempty`` mask, so zero-padding never changes the OR.  Clause
padding uses zero include masks + nonempty=0; batch padding is sliced off.

Correctness on CPU is established with ``interpret=True`` (tests sweep
shapes/dtypes against ref.py); on real TPU hardware the same call compiles
to Mosaic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.shapes import grid_blocks

__all__ = [
    "PALLAS_ORACLES",
    "clause_eval_kernel",
    "clause_eval_pallas",
    "clause_eval_sparse_kernel",
    "clause_eval_sparse_pallas",
]

#: Pallas entry point -> its pure-jnp oracle in kernels/ref.py (aggregated
#: by kernels/registry.py; statically enforced by tools/tmlint TM202).
PALLAS_ORACLES = {
    "clause_eval_pallas": "clause_eval_ref",
    "clause_eval_sparse_pallas": "clause_eval_sparse_ref",
}


def clause_eval_kernel(lit_ref, inc_ref, nonempty_ref, out_ref, *, csrf: bool):
    """Kernel body for one (image-block, clause-block, patch-chunk) tile.

    Refs:
      lit_ref:      uint32 [Bb, Pc, W]   packed literals
      inc_ref:      uint32 [Cb, W]       packed include masks (VMEM-resident)
      nonempty_ref: int32  [1, Cb]       nonempty flags
      out_ref:      int32  [Bb, Cb]      sequential-OR accumulator
    """
    ip = pl.program_id(2)

    @pl.when(ip == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    def _tile_body():
        lit = lit_ref[...]                      # (Bb, Pc, W) uint32
        inc = inc_ref[...]                      # (Cb, W)     uint32
        # Violation reduction over the word axis as a fori_loop carrying
        # only the [Bb, Pc, Cb] accumulator: viol[b, p, c] = any word
        # with a required-but-absent literal.  (A python
        # `for w in range(n_words)` unroll traced W copies of the body —
        # compile time grew linearly in W past paper geometry — while a
        # single broadcast any() would materialize the full
        # [Bb, Pc, Cb, W] mask in VMEM, ~17 MB at default blocks for
        # W=64.  The loop keeps both trace size and live VMEM flat in W.)
        def word_step(w, viol):
            lw = jax.lax.dynamic_index_in_dim(lit, w, axis=2, keepdims=False)
            iw = jax.lax.dynamic_index_in_dim(inc, w, axis=1, keepdims=False)
            return viol | ((iw[None, None, :] & ~lw[:, :, None]) != 0)

        viol = jax.lax.fori_loop(
            0, lit.shape[2], word_step,
            jnp.zeros(lit.shape[:2] + (inc.shape[0],), jnp.bool_),
        )
        fires = ~viol                           # (Bb, Pc, Cb)
        any_fire = jnp.any(fires, axis=1)       # (Bb, Cb) — OR over patches
        ne = nonempty_ref[0, :] != 0            # (Cb,)
        hit = (any_fire & ne[None, :]).astype(out_ref.dtype)
        out_ref[...] = out_ref[...] | hit       # Eq. (6) accumulator

    if csrf:
        # CSRF: skip the tile once the OR register is saturated.
        not_saturated = jnp.logical_not(jnp.all(out_ref[...] > 0))

        @pl.when(jnp.logical_or(ip == 0, not_saturated))
        def _work():
            _tile_body()
    else:
        _tile_body()


@functools.partial(
    jax.jit,
    static_argnames=("block_b", "block_c", "block_p", "csrf", "interpret"),
)
def clause_eval_pallas(
    lit_packed: jax.Array,      # uint32 [B, P, W]
    include_packed: jax.Array,  # uint32 [C, W]
    nonempty: jax.Array,        # bool/uint8 [C]
    *,
    block_b: int = 8,
    block_c: int = 128,
    block_p: int = 64,
    csrf: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """Pallas clause evaluation; returns uint8 0/1 ``[B, C]``.

    Inputs must already satisfy the padding contract (see ops.py, which
    pads and dispatches); B % block_b == 0 etc. are required here.
    """
    b, p, w = lit_packed.shape
    c = include_packed.shape[0]
    ne = nonempty.astype(jnp.int32).reshape(1, c)

    grid = (
        grid_blocks(b, block_b, axis="B"),
        grid_blocks(c, block_c, axis="C"),
        grid_blocks(p, block_p, axis="P"),
    )
    out = pl.pallas_call(
        functools.partial(clause_eval_kernel, csrf=csrf),
        grid=grid,
        in_specs=[
            # Literals: advance along image and patch axes; full word dim.
            pl.BlockSpec((block_b, block_p, w), lambda ib, ic, ip: (ib, ip, 0)),
            # Model block: pinned across patch chunks (VMEM-resident).
            pl.BlockSpec((block_c, w), lambda ib, ic, ip: (ic, 0)),
            pl.BlockSpec((1, block_c), lambda ib, ic, ip: (0, ic)),
        ],
        out_specs=pl.BlockSpec((block_b, block_c), lambda ib, ic, ip: (ib, ic)),
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.int32),
        interpret=interpret,
    )(lit_packed, include_packed, ne)
    return out.astype(jnp.uint8)


# --- clause-sparsity fast path ---------------------------------------------
#
# The sparse variant evaluates only the ACTIVE clause pool (empty clauses
# pruned at freeze time by serve.servable.analyze_sparsity — the software
# form of the ASIC's ``Empty`` gating, which here removes the rows
# entirely instead of masking them).  The model side is the packed
# EXCLUDE mask: a patch satisfies a clause iff every literal word covers
# it, ``~(lit | exclude) == 0``.  Violations are accumulated as popcount
# word ops (``population_count`` maps to the VPU popcount): the int32
# per-(image, patch, clause) violation COUNT is the quantity the matmul
# formulation computes on the MXU, so the two sparse paths share
# semantics exactly.  There is no ``nonempty`` operand — clause padding
# uses all-ones exclude masks (fires everywhere) and callers slice the
# rows off / give them zero weight columns.


def clause_eval_sparse_kernel(lit_ref, exc_ref, out_ref, *, csrf: bool):
    """Kernel body for one (image-block, clause-block, patch-chunk) tile.

    Refs:
      lit_ref: uint32 [Bb, Pc, W]   packed literals
      exc_ref: uint32 [Cb, W]       packed exclude masks (VMEM-resident)
      out_ref: int32  [Bb, Cb]      sequential-OR accumulator
    """
    ip = pl.program_id(2)

    @pl.when(ip == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    def _tile_body():
        lit = lit_ref[...]                      # (Bb, Pc, W) uint32
        exc = exc_ref[...]                      # (Cb, W)     uint32
        # Popcount violation-count reduction over the word axis; the
        # fori_loop carries only the int32 [Bb, Pc, Cb] count accumulator
        # (same trace/VMEM discipline as clause_eval_kernel's word loop).
        def word_step(w, counts):
            lw = jax.lax.dynamic_index_in_dim(lit, w, axis=2, keepdims=False)
            ew = jax.lax.dynamic_index_in_dim(exc, w, axis=1, keepdims=False)
            miss = ~(lw[:, :, None] | ew[None, None, :])    # required-but-absent
            return counts + jax.lax.population_count(miss).astype(jnp.int32)

        counts = jax.lax.fori_loop(
            0, lit.shape[2], word_step,
            jnp.zeros(lit.shape[:2] + (exc.shape[0],), jnp.int32),
        )
        fires = counts == 0                     # (Bb, Pc, Cb)
        any_fire = jnp.any(fires, axis=1)       # (Bb, Cb) — OR over patches
        out_ref[...] = out_ref[...] | any_fire.astype(out_ref.dtype)

    if csrf:
        # CSRF block-skip: all clauses in the tile saturated -> no-op.
        not_saturated = jnp.logical_not(jnp.all(out_ref[...] > 0))

        @pl.when(jnp.logical_or(ip == 0, not_saturated))
        def _work():
            _tile_body()
    else:
        _tile_body()


@functools.partial(
    jax.jit,
    static_argnames=("block_b", "block_c", "block_p", "csrf", "interpret"),
)
def clause_eval_sparse_pallas(
    lit_packed: jax.Array,      # uint32 [B, P, W]
    exclude_packed: jax.Array,  # uint32 [C_a, W] (pad clauses: all ones)
    *,
    block_b: int = 8,
    block_c: int = 128,
    block_p: int = 64,
    csrf: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """Sparse (active-clause) Pallas evaluation; uint8 0/1 ``[B, C_a]``.

    Padding contract (ops.py): clause rows pad with ALL-ONES exclude
    masks — they fire on every patch (zero violations by construction),
    saturating the CSRF check fastest, and are sliced off / zero-weighted
    by the caller.  Patch padding still uses all-zero literal words: any
    clause with >= 1 include violates on them, and include-free clauses
    cannot exist in the active pool.
    """
    b, p, w = lit_packed.shape
    c = exclude_packed.shape[0]
    grid = (
        grid_blocks(b, block_b, axis="B"),
        grid_blocks(c, block_c, axis="C"),
        grid_blocks(p, block_p, axis="P"),
    )
    out = pl.pallas_call(
        functools.partial(clause_eval_sparse_kernel, csrf=csrf),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_p, w), lambda ib, ic, ip: (ib, ip, 0)),
            pl.BlockSpec((block_c, w), lambda ib, ic, ip: (ic, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_c), lambda ib, ic, ip: (ib, ic)),
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.int32),
        interpret=interpret,
    )(lit_packed, exclude_packed)
    return out.astype(jnp.uint8)
