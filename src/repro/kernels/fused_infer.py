"""Fused ConvCoTM inference kernel: clause evaluation + class sums in one
pallas_call (beyond-paper optimization, EXPERIMENTS.md §Perf/kernel).

The two-kernel pipeline writes the fired vector [B, C] to HBM and reads it
back for the class-sum matmul.  Fused, the OR register lives in a VMEM
scratch for the duration of the patch loop and the weighted reduction
happens in-register on the last patch chunk — exactly the ASIC's datapath,
where clause outputs feed the adder trees without leaving the chip.

Grid = (image blocks, clause chunks, patch chunks); patch axis innermost
(sequential OR), clause chunks accumulate partial class sums into the
[Bb, m] output block (revisited across ic).  CSRF block-skip applies to
the patch loop as in clause_eval.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.shapes import grid_blocks

__all__ = ["PALLAS_ORACLES", "fused_infer_pallas", "fused_infer_sparse_pallas"]

#: Pallas entry point -> its pure-jnp oracle in kernels/ref.py (aggregated
#: by kernels/registry.py; statically enforced by tools/tmlint TM202).
PALLAS_ORACLES = {
    "fused_infer_pallas": "fused_infer_ref",
    "fused_infer_sparse_pallas": "sparse_infer_ref",
}


def _kernel(lit_ref, inc_ref, ne_ref, w_ref, out_ref, or_scratch, *, csrf: bool):
    """Refs:
      lit_ref: uint32 [Bb, Pc, W]; inc_ref: uint32 [Cc, W]
      ne_ref:  int32 [1, Cc];      w_ref: int32 [M, Cc]
      out_ref: int32 [Bb, M]       (class sums, accumulated over ic)
      or_scratch: int32 [Bb, Cc]   (sequential-OR register, VMEM)
    """
    ic = pl.program_id(1)
    ip = pl.program_id(2)
    n_ip = pl.num_programs(2)

    @pl.when(jnp.logical_and(ic == 0, ip == 0))
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(ip == 0)
    def _init_or():
        or_scratch[...] = jnp.zeros_like(or_scratch)

    def _eval_tile():
        lit = lit_ref[...]                              # (Bb, Pc, W)
        inc = inc_ref[...]                              # (Cc, W)
        # Word-axis reduction as a fori_loop carrying the [Bb, Pc, Cc]
        # accumulator (see clause_eval.py: the python unroll bloated the
        # trace linearly in W; a broadcast any() would blow VMEM).
        def word_step(w, viol):
            lw = jax.lax.dynamic_index_in_dim(lit, w, axis=2, keepdims=False)
            iw = jax.lax.dynamic_index_in_dim(inc, w, axis=1, keepdims=False)
            return viol | ((iw[None, None, :] & ~lw[:, :, None]) != 0)

        viol = jax.lax.fori_loop(
            0, lit.shape[2], word_step,
            jnp.zeros(lit.shape[:2] + (inc.shape[0],), jnp.bool_),
        )
        fires = jnp.any(~viol, axis=1)                  # (Bb, Cc)
        ne = ne_ref[0, :] != 0
        or_scratch[...] = or_scratch[...] | (fires & ne[None, :]).astype(
            or_scratch.dtype
        )

    if csrf:
        @pl.when(jnp.logical_or(ip == 0, jnp.logical_not(jnp.all(or_scratch[...] > 0))))
        def _work():
            _eval_tile()
    else:
        _eval_tile()

    @pl.when(ip == n_ip - 1)
    def _class_sums():
        fired = or_scratch[...].astype(jnp.float32)      # (Bb, Cc) 0/1
        w = w_ref[...].astype(jnp.float32)               # (M, Cc)
        part = jax.lax.dot_general(
            fired, w, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        out_ref[...] = out_ref[...] + part.astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("block_b", "block_c", "block_p", "csrf", "interpret"),
)
def fused_infer_pallas(
    lit_packed: jax.Array,      # uint32 [B, P, W]
    include_packed: jax.Array,  # uint32 [C, W]
    nonempty: jax.Array,        # bool/uint8/int [C]
    weights: jax.Array,         # int [M, C]
    *,
    block_b: int = 8,
    block_c: int = 128,
    block_p: int = 64,
    csrf: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """Returns int32 [B, M] class sums. Padding contract as in ops.py."""
    b, p, w = lit_packed.shape
    c = include_packed.shape[0]
    m = weights.shape[0]
    ne = nonempty.astype(jnp.int32).reshape(1, c)
    grid = (
        grid_blocks(b, block_b, axis="B"),
        grid_blocks(c, block_c, axis="C"),
        grid_blocks(p, block_p, axis="P"),
    )
    return pl.pallas_call(
        functools.partial(_kernel, csrf=csrf),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_p, w), lambda ib, ic, ip: (ib, ip, 0)),
            pl.BlockSpec((block_c, w), lambda ib, ic, ip: (ic, 0)),
            pl.BlockSpec((1, block_c), lambda ib, ic, ip: (0, ic)),
            pl.BlockSpec((m, block_c), lambda ib, ic, ip: (0, ic)),
        ],
        out_specs=pl.BlockSpec((block_b, m), lambda ib, ic, ip: (ib, 0)),
        out_shape=jax.ShapeDtypeStruct((b, m), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_b, block_c), jnp.int32)],
        interpret=interpret,
    )(lit_packed, include_packed, ne, weights.astype(jnp.int32))


# --- clause-sparsity fast path ---------------------------------------------


def _sparse_kernel(lit_ref, exc_ref, w_ref, out_ref, or_scratch, *, csrf: bool):
    """Sparse fused kernel: popcount violation counts over the ACTIVE
    clause pool (packed exclude masks, no nonempty operand), sequential-OR
    register in VMEM scratch, in-register class-sum on the last patch
    chunk.  See clause_eval.clause_eval_sparse_kernel for the padding
    contract (pad clauses: all-ones exclude + zero weight columns).

    Refs:
      lit_ref: uint32 [Bb, Pc, W]; exc_ref: uint32 [Cc, W]
      w_ref:   int32 [M, Cc]
      out_ref: int32 [Bb, M]       (class sums, accumulated over ic)
      or_scratch: int32 [Bb, Cc]   (sequential-OR register, VMEM)
    """
    ic = pl.program_id(1)
    ip = pl.program_id(2)
    n_ip = pl.num_programs(2)

    @pl.when(jnp.logical_and(ic == 0, ip == 0))
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(ip == 0)
    def _init_or():
        or_scratch[...] = jnp.zeros_like(or_scratch)

    def _eval_tile():
        lit = lit_ref[...]                              # (Bb, Pc, W)
        exc = exc_ref[...]                              # (Cc, W)

        def word_step(w, counts):
            lw = jax.lax.dynamic_index_in_dim(lit, w, axis=2, keepdims=False)
            ew = jax.lax.dynamic_index_in_dim(exc, w, axis=1, keepdims=False)
            miss = ~(lw[:, :, None] | ew[None, None, :])
            return counts + jax.lax.population_count(miss).astype(jnp.int32)

        counts = jax.lax.fori_loop(
            0, lit.shape[2], word_step,
            jnp.zeros(lit.shape[:2] + (exc.shape[0],), jnp.int32),
        )
        fires = jnp.any(counts == 0, axis=1)            # (Bb, Cc)
        or_scratch[...] = or_scratch[...] | fires.astype(or_scratch.dtype)

    if csrf:
        @pl.when(jnp.logical_or(ip == 0, jnp.logical_not(jnp.all(or_scratch[...] > 0))))
        def _work():
            _eval_tile()
    else:
        _eval_tile()

    @pl.when(ip == n_ip - 1)
    def _class_sums():
        fired = or_scratch[...].astype(jnp.float32)      # (Bb, Cc) 0/1
        w = w_ref[...].astype(jnp.float32)               # (M, Cc)
        part = jax.lax.dot_general(
            fired, w, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        out_ref[...] = out_ref[...] + part.astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("block_b", "block_c", "block_p", "csrf", "interpret"),
)
def fused_infer_sparse_pallas(
    lit_packed: jax.Array,      # uint32 [B, P, W]
    exclude_packed: jax.Array,  # uint32 [C_a, W] (pad clauses: all ones)
    weights_active: jax.Array,  # int [M, C_a]    (pad columns: zero)
    *,
    block_b: int = 8,
    block_c: int = 128,
    block_p: int = 64,
    csrf: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """Returns int32 [B, M] class sums over the active clause pool."""
    b, p, w = lit_packed.shape
    c = exclude_packed.shape[0]
    m = weights_active.shape[0]
    grid = (
        grid_blocks(b, block_b, axis="B"),
        grid_blocks(c, block_c, axis="C"),
        grid_blocks(p, block_p, axis="P"),
    )
    return pl.pallas_call(
        functools.partial(_sparse_kernel, csrf=csrf),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_p, w), lambda ib, ic, ip: (ib, ip, 0)),
            pl.BlockSpec((block_c, w), lambda ib, ic, ip: (ic, 0)),
            pl.BlockSpec((m, block_c), lambda ib, ic, ip: (0, ic)),
        ],
        out_specs=pl.BlockSpec((block_b, m), lambda ib, ic, ip: (ib, 0)),
        out_shape=jax.ShapeDtypeStruct((b, m), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_b, block_c), jnp.int32)],
        interpret=interpret,
    )(lit_packed, exclude_packed, weights_active.astype(jnp.int32))
