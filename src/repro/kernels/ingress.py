"""Pallas TPU kernel: booleanized images -> packed patch literals.

The ingress stage of the fused inference path (ISSUE: the ASIC streams
booleanized pixels straight into the clause datapath, Sec. IV-C).  The
jnp ingress materializes the dense literal tensor ``uint8 [B, P, 2o]``
in HBM between patch extraction and bit packing — 8.5x the bytes of the
packed form, and at paper geometry (361 patches x 272 literals) by far
the largest intermediate of the whole inference pipeline.  This kernel
keeps the dense bits in VMEM for the lifetime of one image block and
writes only the packed ``uint32 [B, P, W]`` words back to HBM, so the
dense literals never exist in device memory at all.

Layout decisions:

  * Grid = (image blocks,) only.  A booleanized image is tiny (28x28
    bytes), and one image block's full patch set — window gather, the
    position thermometer constants, the dense literal bits, and the
    packed output — fits comfortably in VMEM (~800 KB at paper geometry
    for ``block_b=8``), so there is nothing to win from patch chunking
    here; the consumer kernels (clause_eval / fused_infer) chunk the
    patch axis themselves.
  * The window gather is expressed as a static strided-slice per window
    offset (``Wy*Wx`` slices), not a gather: patch (py, px) reads
    ``img[py*dy + wy, px*dx + wx]``, so feature k = wy*Wx + wx of *all*
    patches is one strided view of the image.  Static slices lower on
    Mosaic where gathers would not.
  * The position thermometer bits are per-patch constants (they depend
    only on the geometry), computed by the same
    ``core.patches._index_tables`` the jnp path uses — one source of
    truth for the literal order — and passed as a pinned VMEM-resident
    input (Pallas does not allow kernels to close over array constants).

Correctness on CPU is established with ``interpret=True`` against the
jnp oracle (``ref.ingress_pack_ref``); shape sweeps in
``tests/test_ingress.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.patches import PatchSpec, _index_tables
from repro.kernels.shapes import grid_blocks

__all__ = ["PALLAS_ORACLES", "ingress_pack_kernel", "ingress_pack_pallas"]

#: Pallas entry point -> its pure-jnp oracle in kernels/ref.py (aggregated
#: by kernels/registry.py; statically enforced by tools/tmlint TM202).
PALLAS_ORACLES = {"ingress_pack_pallas": "ingress_pack_ref"}


def ingress_pack_kernel(img_ref, pos_ref, out_ref, *, spec: PatchSpec):
    """Kernel body for one image block.

    Refs:
      img_ref: uint8 [Bb, Y, X]       booleanized image bits
      pos_ref: uint8 [P, max(pos,1)]  position-thermometer bits, pinned
                                      (padded to >= 1 column; the real
                                      width is recovered from ``spec``)
      out_ref: uint32 [Bb, P, W]      packed literal words (LSB-first)
    """
    img = img_ref[...]                              # (Bb, Y, X)
    bb = img.shape[0]
    n_pos = spec.n_pos_y_bits + spec.n_pos_x_bits
    pos = pos_ref[...][:, :n_pos]                   # (P, pos_bits)
    cols = []
    # Feature order: window bits row-major (wy, wx) — matches
    # core.patches._index_tables' meshgrid order exactly.
    for wy in range(spec.window_y):
        ylim = wy + (spec.by - 1) * spec.stride_y + 1
        for wx in range(spec.window_x):
            xlim = wx + (spec.bx - 1) * spec.stride_x + 1
            v = img[:, wy:ylim:spec.stride_y, wx:xlim:spec.stride_x]
            cols.append(v.reshape(bb, spec.n_patches))
    win = jnp.stack(cols, axis=-1)                  # (Bb, P, Wy*Wx)
    posb = jnp.broadcast_to(pos[None], (bb, spec.n_patches, n_pos))
    feats = jnp.concatenate([win, posb], axis=-1)   # (Bb, P, o)
    lits = jnp.concatenate([feats, 1 - feats], axis=-1).astype(jnp.uint32)
    pad = spec.n_words * 32 - spec.n_literals
    if pad:
        lits = jnp.concatenate(
            [lits, jnp.zeros((bb, spec.n_patches, pad), jnp.uint32)], axis=-1
        )
    words = lits.reshape(bb, spec.n_patches, spec.n_words, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    out_ref[...] = jnp.sum(words << shifts, axis=-1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("spec", "block_b", "interpret"))
def ingress_pack_pallas(
    bool_images: jax.Array,     # uint8 0/1 [B, Y, X]
    spec: PatchSpec,
    *,
    block_b: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """Packed literals uint32 ``[B, P, W]``; B % block_b == 0 required
    (ops.py pads and dispatches).  Z = U = 1 geometries only — the
    multi-channel / thermometer layouts take the jnp ingress."""
    if spec.channels != 1 or spec.therm_bits != 1:
        raise ValueError("ingress kernel supports Z=U=1 geometries only")
    b, y, x = bool_images.shape
    if (y, x) != (spec.image_y, spec.image_x):
        raise ValueError(
            f"image dims {(y, x)} != spec ({spec.image_y}, {spec.image_x})"
        )
    _, _, pos = _index_tables(spec)     # the shared position-bit constants
    if pos.shape[1] == 0:               # whole-image window: pad the pos
        pos = jnp.zeros((spec.n_patches, 1), jnp.uint8)   # input to 1 col
    else:
        pos = jnp.asarray(pos, jnp.uint8)
    grid = (grid_blocks(b, block_b, axis="B"),)
    return pl.pallas_call(
        functools.partial(ingress_pack_kernel, spec=spec),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, y, x), lambda ib: (ib, 0, 0)),
            # Position bits: pinned across image blocks (VMEM-resident).
            pl.BlockSpec((spec.n_patches, pos.shape[1]), lambda ib: (0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (block_b, spec.n_patches, spec.n_words), lambda ib: (ib, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, spec.n_patches, spec.n_words), jnp.uint32),
        interpret=interpret,
    )(bool_images, pos)
