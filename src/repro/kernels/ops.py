"""Jit'd public wrappers around the Pallas kernels.

Handles the padding contract, picks block shapes, and falls back to the
pure-jnp reference implementation where Pallas cannot run compiled (this
container is CPU: the default backend is ``ref``; kernels execute with
interpret=True only in tests / explicit ``backend='interpret'`` calls;
on TPU they compile to Mosaic).

Padding safety (proved in tests/test_kernels.py):
  * patches pad with all-zero literal words  -> cannot fire any nonempty
    clause, and empty clauses are masked, so the OR is unchanged;
  * clauses pad with empty include masks + nonempty=0 -> output 0, sliced;
  * batch rows pad with zeros and are sliced off;
  * class-sum pads clauses with fired=0 columns and weight 0 columns.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.class_sum import class_sum_pallas
from repro.kernels.clause_eval import clause_eval_pallas, clause_eval_sparse_pallas
from repro.kernels.shapes import clamp_block as _clamp_block
from repro.kernels.shapes import pad_axis as _pad_axis
from repro.kernels.shapes import pad_axis_ones as _pad_axis_ones
from repro.kernels.shapes import round_up as _round_up

__all__ = [
    "clause_eval",
    "class_sum",
    "fused_infer",
    "fused_infer_from_images",
    "ingress_pack",
    "clause_eval_sparse",
    "fused_infer_sparse",
    "matmul_sparse_infer",
]


def _pick_backend(backend: Optional[str]) -> str:
    """pallas on TPU, the pure-jnp reference elsewhere.

    Pallas interpret mode emulates the kernel grid step-by-step on CPU —
    orders of magnitude slower than the jnp oracle, so it is never a
    default: tests and debuggers opt in with ``backend='interpret'``.
    """
    if backend is not None:
        return backend
    return "pallas" if jax.default_backend() == "tpu" else "ref"


@functools.partial(
    jax.jit, static_argnames=("backend", "block_b", "block_c", "block_p", "csrf")
)
def clause_eval(
    lit_packed: jax.Array,
    include_packed: jax.Array,
    nonempty: jax.Array,
    *,
    backend: Optional[str] = None,
    block_b: int = 8,
    block_c: int = 128,
    block_p: int = 64,
    csrf: bool = True,
) -> jax.Array:
    """Sequential-OR clause outputs uint8 [B, C] from packed inputs.

    backend: 'pallas' (TPU), 'interpret' (Pallas-on-CPU, used by tests),
    'ref' (pure jnp). Default: pallas on TPU, ref everywhere else.
    """
    bk = _pick_backend(backend)
    if bk == "ref":
        return ref.clause_eval_ref(lit_packed, include_packed, nonempty)

    b, p, w = lit_packed.shape
    c = include_packed.shape[0]
    block_b = _clamp_block(block_b, b, 8)
    block_c = _clamp_block(block_c, c, 128)
    block_p = _clamp_block(block_p, p, 8)
    bp = _pad_axis(lit_packed, 0, _round_up(b, block_b))
    bp = _pad_axis(bp, 1, _round_up(p, block_p))
    ip = _pad_axis(include_packed, 0, _round_up(c, block_c))
    ne = _pad_axis(nonempty.astype(jnp.int32), 0, _round_up(c, block_c))
    out = clause_eval_pallas(
        bp,
        ip,
        ne,
        block_b=block_b,
        block_c=block_c,
        block_p=block_p,
        csrf=csrf,
        interpret=(bk == "interpret"),
    )
    return out[:b, :c]


@functools.partial(jax.jit, static_argnames=("spec", "backend", "block_b"))
def ingress_pack(
    bool_images: jax.Array,
    spec,
    *,
    backend: Optional[str] = None,
    block_b: int = 8,
) -> jax.Array:
    """Packed patch literals uint32 [B, P, W] from booleanized images.

    The ingress stage of the fused inference path: on TPU the Pallas
    kernel (kernels/ingress.py) keeps the dense [B, P, 2o] literal bits
    in VMEM and writes only packed words to HBM; the ``ref`` backend is
    the jnp composition (patch gather -> literals -> pack) the rest of
    the repo uses.  Batch padding rows are zero images -> all literal
    words describe a blank patch; callers slice them off.
    """
    bk = _pick_backend(backend)
    if bk == "ref":
        return ref.ingress_pack_ref(bool_images, spec)

    from repro.kernels.ingress import ingress_pack_pallas

    b = bool_images.shape[0]
    block_b = _clamp_block(block_b, b, 8)
    imgs = _pad_axis(bool_images, 0, _round_up(b, block_b))
    out = ingress_pack_pallas(
        imgs, spec, block_b=block_b, interpret=(bk == "interpret")
    )
    return out[:b]


@functools.partial(
    jax.jit,
    static_argnames=("spec", "backend", "block_b", "block_c", "block_p", "csrf"),
)
def fused_infer_from_images(
    bool_images: jax.Array,     # uint8 0/1 [B, Y, X]
    spec,                       # core.patches.PatchSpec
    include_packed: jax.Array,
    nonempty: jax.Array,
    weights: jax.Array,
    *,
    backend: Optional[str] = None,
    block_b: int = 8,
    block_c: int = 128,
    block_p: int = 64,
    csrf: bool = True,
) -> jax.Array:
    """Booleanized images -> class sums with no dense literals in HBM.

    Chains the ingress kernel (dense bits live only in VMEM) into the
    fused clause-eval + class-sum kernel; the only intermediate that
    touches HBM is the packed uint32 [B, P, W] word stream — the same
    discipline as the ASIC datapath, where patch bits feed the clause
    pool without a memory round trip.
    """
    lit_packed = ingress_pack(bool_images, spec, backend=backend, block_b=block_b)
    return fused_infer(
        lit_packed, include_packed, nonempty, weights,
        backend=backend, block_b=block_b, block_c=block_c, block_p=block_p,
        csrf=csrf,
    )


@functools.partial(jax.jit, static_argnames=("backend", "block_b", "block_c"))
def class_sum(
    fired: jax.Array,
    weights: jax.Array,
    *,
    backend: Optional[str] = None,
    block_b: int = 128,
    block_c: int = 128,
) -> jax.Array:
    """int32 [B, M] class sums (Eq. 3)."""
    bk = _pick_backend(backend)
    if bk == "ref":
        return ref.class_sum_ref(fired, weights)
    b, c = fired.shape
    block_b = _clamp_block(block_b, b, 8)
    block_c = _clamp_block(block_c, c, 128)
    fp = _pad_axis(_pad_axis(fired, 0, _round_up(b, block_b)), 1, _round_up(c, block_c))
    wp = _pad_axis(weights, 1, _round_up(c, block_c))
    out = class_sum_pallas(
        fp, wp, block_b=block_b, block_c=block_c, interpret=(bk == "interpret")
    )
    return out[:b]


@functools.partial(
    jax.jit, static_argnames=("backend", "block_b", "block_c", "block_p", "csrf")
)
def fused_infer(
    lit_packed: jax.Array,
    include_packed: jax.Array,
    nonempty: jax.Array,
    weights: jax.Array,
    *,
    backend: Optional[str] = None,
    block_b: int = 8,
    block_c: int = 128,
    block_p: int = 64,
    csrf: bool = True,
) -> jax.Array:
    """Single-kernel clause_eval + class_sum, returns int32 [B, M].

    The fused kernel keeps the sequential-OR register in VMEM scratch and
    reduces it against the weights in-register on the last patch chunk —
    the fired vector never touches HBM (kernels/fused_infer.py)."""
    bk = _pick_backend(backend)
    if bk == "ref":
        return ref.fused_infer_ref(lit_packed, include_packed, nonempty, weights)

    from repro.kernels.fused_infer import fused_infer_pallas

    b, p, w = lit_packed.shape
    c = include_packed.shape[0]
    block_b = _clamp_block(block_b, b, 8)
    block_c = _clamp_block(block_c, c, 128)
    block_p = _clamp_block(block_p, p, 8)
    bp = _pad_axis(lit_packed, 0, _round_up(b, block_b))
    bp = _pad_axis(bp, 1, _round_up(p, block_p))
    ip = _pad_axis(include_packed, 0, _round_up(c, block_c))
    ne = _pad_axis(nonempty.astype(jnp.int32), 0, _round_up(c, block_c))
    wp = _pad_axis(weights, 1, _round_up(c, block_c))
    out = fused_infer_pallas(
        bp, ip, ne, wp,
        block_b=block_b, block_c=block_c, block_p=block_p,
        csrf=csrf, interpret=(bk == "interpret"),
    )
    return out[:b]


# --- clause-sparsity fast path ---------------------------------------------
#
# Active-clause inputs come pre-gathered from
# ``serve.servable.analyze_sparsity`` (empty clauses pruned at freeze
# time).  Sparse padding contract, proved alongside the dense one in
# tests/test_kernels.py / tests/test_sparse.py:
#   * clause rows pad with ALL-ONES exclude masks -> zero violations on
#     every patch, so they fire immediately (saturating CSRF fastest) and
#     are sliced off (clause_eval_sparse) or matched with zero weight
#     columns (fused_infer_sparse);
#   * patch rows pad with all-zero literal words -> every active clause
#     (>= 1 include by construction) violates, OR unchanged;
#   * batch rows pad with zeros and are sliced off.


@functools.partial(
    jax.jit, static_argnames=("backend", "block_b", "block_c", "block_p", "csrf")
)
def clause_eval_sparse(
    lit_packed: jax.Array,
    exclude_packed: jax.Array,
    *,
    backend: Optional[str] = None,
    block_b: int = 8,
    block_c: int = 128,
    block_p: int = 64,
    csrf: bool = True,
) -> jax.Array:
    """Active-clause sequential-OR outputs uint8 [B, C_a] from packed
    literals + packed exclude masks (popcount violation counting)."""
    b, p, w = lit_packed.shape
    c = exclude_packed.shape[0]
    if c == 0:   # fully-empty clause pool: nothing can fire
        return jnp.zeros((b, 0), jnp.uint8)
    bk = _pick_backend(backend)
    if bk == "ref":
        return ref.clause_eval_sparse_ref(lit_packed, exclude_packed)

    block_b = _clamp_block(block_b, b, 8)
    block_c = _clamp_block(block_c, c, 128)
    block_p = _clamp_block(block_p, p, 8)
    bp = _pad_axis(lit_packed, 0, _round_up(b, block_b))
    bp = _pad_axis(bp, 1, _round_up(p, block_p))
    ep = _pad_axis_ones(exclude_packed, 0, _round_up(c, block_c))
    out = clause_eval_sparse_pallas(
        bp,
        ep,
        block_b=block_b,
        block_c=block_c,
        block_p=block_p,
        csrf=csrf,
        interpret=(bk == "interpret"),
    )
    return out[:b, :c]


@functools.partial(
    jax.jit, static_argnames=("backend", "block_b", "block_c", "block_p", "csrf")
)
def fused_infer_sparse(
    lit_packed: jax.Array,
    exclude_packed: jax.Array,
    weights_active: jax.Array,
    *,
    backend: Optional[str] = None,
    block_b: int = 8,
    block_c: int = 128,
    block_p: int = 64,
    csrf: bool = True,
) -> jax.Array:
    """Single-kernel sparse clause-eval + class-sum, int32 [B, M]."""
    b, p, w = lit_packed.shape
    c = exclude_packed.shape[0]
    m = weights_active.shape[0]
    if c == 0:
        return jnp.zeros((b, m), jnp.int32)
    bk = _pick_backend(backend)
    if bk == "ref":
        return ref.sparse_infer_ref(lit_packed, exclude_packed, weights_active)

    from repro.kernels.fused_infer import fused_infer_sparse_pallas

    block_b = _clamp_block(block_b, b, 8)
    block_c = _clamp_block(block_c, c, 128)
    block_p = _clamp_block(block_p, p, 8)
    bp = _pad_axis(lit_packed, 0, _round_up(b, block_b))
    bp = _pad_axis(bp, 1, _round_up(p, block_p))
    ep = _pad_axis_ones(exclude_packed, 0, _round_up(c, block_c))
    wp = _pad_axis(weights_active, 1, _round_up(c, block_c))
    out = fused_infer_sparse_pallas(
        bp, ep, wp,
        block_b=block_b, block_c=block_c, block_p=block_p,
        csrf=csrf, interpret=(bk == "interpret"),
    )
    return out[:b]


@jax.jit
def matmul_sparse_infer(
    literals: jax.Array,        # uint8 0/1 [B, P, 2o] dense literals
    include_active: jax.Array,  # uint8 0/1 [C_a, 2o]
    weights_active: jax.Array,  # int8 [m, C_a]
) -> jax.Array:
    """int8 matmul violation-count path over the active clause pool.

    One int8 x int8 -> int32 dot computes per-(image, patch, clause)
    violation counts (MXU int8 throughput on TPU; plain XLA everywhere —
    no Pallas body, so every backend shares this graph).  Work scales
    with C_a instead of C: at paper geometry a boundary model keeps
    ~70-95% of clauses, a trained pool typically fewer.  Returns int32
    [B, m] class sums, bit-identical to the dense reference.
    """
    return ref.matmul_sparse_infer_ref(literals, include_active, weights_active)
