"""Pure-jnp oracles for the Pallas kernels (the ``ref.py`` layer).

These define the exact semantics the kernels must reproduce; every kernel
test sweeps shapes/dtypes and asserts allclose (bit-equality here — all
outputs are integers) against these functions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "clause_eval_ref",
    "class_sum_ref",
    "fused_infer_ref",
    "ingress_pack_ref",
]


def ingress_pack_ref(bool_images: jax.Array, spec) -> jax.Array:
    """Booleanized images [B, Y, X] -> packed literals uint32 [B, P, W].

    The jnp ingress composition itself (patch gather -> literals -> LSB
    pack); the Pallas ingress kernel must reproduce it bit for bit.
    """
    from repro.core.patches import extract_patch_features, make_literals, pack_bits

    feats = extract_patch_features(bool_images, spec)
    return pack_bits(make_literals(feats), spec.n_words)


def clause_eval_ref(
    lit_packed: jax.Array,      # uint32 [B, P, W]
    include_packed: jax.Array,  # uint32 [C, W]
    nonempty: jax.Array,        # bool/uint8 [C]
) -> jax.Array:
    """Sequential-OR clause outputs, uint8 0/1 [B, C].

    A clause fires on a patch iff every include bit is present in the
    literal word (include & ~lit == 0 for all words); it fires for the
    image iff it fires on >= 1 patch and is nonempty (Eq. 2+6).
    """
    viol = include_packed[None, None] & ~lit_packed[:, :, None, :]
    fires_patch = jnp.all(viol == 0, axis=-1)
    fired = jnp.any(fires_patch, axis=1) & (nonempty.astype(bool))[None]
    return fired.astype(jnp.uint8)


def class_sum_ref(fired: jax.Array, weights: jax.Array) -> jax.Array:
    """Eq. (3): int32 [B, m] = fired [B, C] . weights [m, C]^T."""
    return jax.lax.dot_general(
        fired.astype(jnp.int8),
        weights.astype(jnp.int8),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def fused_infer_ref(
    lit_packed: jax.Array,
    include_packed: jax.Array,
    nonempty: jax.Array,
    weights: jax.Array,
) -> jax.Array:
    """Fused clause-eval + class-sum oracle: int32 [B, m] class sums."""
    fired = clause_eval_ref(lit_packed, include_packed, nonempty)
    return class_sum_ref(fired, weights)
