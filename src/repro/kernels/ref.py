"""Pure-jnp oracles for the Pallas kernels (the ``ref.py`` layer).

These define the exact semantics the kernels must reproduce; every kernel
test sweeps shapes/dtypes and asserts allclose (bit-equality here — all
outputs are integers) against these functions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "clause_eval_ref",
    "class_sum_ref",
    "fused_infer_ref",
    "ingress_pack_ref",
    "clause_eval_sparse_ref",
    "sparse_infer_ref",
    "matmul_sparse_infer_ref",
]


def ingress_pack_ref(bool_images: jax.Array, spec) -> jax.Array:
    """Booleanized images [B, Y, X] -> packed literals uint32 [B, P, W].

    The jnp ingress composition itself (patch gather -> literals -> LSB
    pack); the Pallas ingress kernel must reproduce it bit for bit.
    """
    from repro.core.patches import extract_patch_features, make_literals, pack_bits

    feats = extract_patch_features(bool_images, spec)
    return pack_bits(make_literals(feats), spec.n_words)


def clause_eval_ref(
    lit_packed: jax.Array,      # uint32 [B, P, W]
    include_packed: jax.Array,  # uint32 [C, W]
    nonempty: jax.Array,        # bool/uint8 [C]
) -> jax.Array:
    """Sequential-OR clause outputs, uint8 0/1 [B, C].

    A clause fires on a patch iff every include bit is present in the
    literal word (include & ~lit == 0 for all words); it fires for the
    image iff it fires on >= 1 patch and is nonempty (Eq. 2+6).
    """
    viol = include_packed[None, None] & ~lit_packed[:, :, None, :]
    fires_patch = jnp.all(viol == 0, axis=-1)
    fired = jnp.any(fires_patch, axis=1) & (nonempty.astype(bool))[None]
    return fired.astype(jnp.uint8)


def class_sum_ref(fired: jax.Array, weights: jax.Array) -> jax.Array:
    """Eq. (3): int32 [B, m] = fired [B, C] . weights [m, C]^T."""
    return jax.lax.dot_general(
        fired.astype(jnp.int8),
        weights.astype(jnp.int8),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def fused_infer_ref(
    lit_packed: jax.Array,
    include_packed: jax.Array,
    nonempty: jax.Array,
    weights: jax.Array,
) -> jax.Array:
    """Fused clause-eval + class-sum oracle: int32 [B, m] class sums."""
    fired = clause_eval_ref(lit_packed, include_packed, nonempty)
    return class_sum_ref(fired, weights)


# --- clause-sparsity fast path (active clauses only) -----------------------
#
# Inputs come from serve.servable.analyze_sparsity: empty clauses are
# pruned at freeze time, so there is no ``nonempty`` mask here, and the
# model side is the packed EXCLUDE mask (~include, pad bits set).  A
# clause is satisfied by a patch iff every literal word is covered:
# ``~(lit | exclude) == 0`` — identical to ``include & ~lit == 0``.
# Class sums over active clauses equal class sums over the full pool bit
# for bit (empty clauses contribute w * 0); asserted in tests/test_sparse.py.


def clause_eval_sparse_ref(
    lit_packed: jax.Array,      # uint32 [B, P, W]
    exclude_packed: jax.Array,  # uint32 [C_a, W] ~include of active clauses
) -> jax.Array:
    """Sequential-OR outputs of the ACTIVE clauses, uint8 0/1 [B, C_a]."""
    viol = ~(lit_packed[:, :, None, :] | exclude_packed[None, None])
    fires_patch = jnp.all(viol == 0, axis=-1)
    return jnp.any(fires_patch, axis=1).astype(jnp.uint8)


def sparse_infer_ref(
    lit_packed: jax.Array,
    exclude_packed: jax.Array,
    weights_active: jax.Array,  # int8 [m, C_a]
) -> jax.Array:
    """Sparse clause-eval + class-sum oracle: int32 [B, m] class sums."""
    fired = clause_eval_sparse_ref(lit_packed, exclude_packed)
    return class_sum_ref(fired, weights_active)


def matmul_sparse_infer_ref(
    literals: jax.Array,        # uint8 0/1 [B, P, 2o] dense literals
    include_active: jax.Array,  # uint8 0/1 [C_a, 2o]
    weights_active: jax.Array,  # int8 [m, C_a]
) -> jax.Array:
    """int8 matmul violation-count oracle over active clauses.

    violations = (1 - literals) @ include_activeᵀ as an int8 x int8 ->
    int32 dot (counts <= 2o = 272 need the 32-bit accumulator); a clause
    fires on a patch iff it has zero violations.  Returns int32 [B, m].
    """
    neg = (1 - literals).astype(jnp.int8)                    # [B, P, 2o]
    inc = include_active.astype(jnp.int8)                    # [C_a, 2o]
    viol = jax.lax.dot_general(
        neg,
        inc,
        (((neg.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )                                                        # [B, P, C_a]
    fired = jnp.any(viol == 0, axis=1).astype(jnp.uint8)
    return class_sum_ref(fired, weights_active)
