"""Kernel -> oracle registry: every Pallas entry point names its ref.py twin.

The bit-identity discipline that lets the software reproduction match the
paper's ASIC results rests on one rule: **every** Pallas kernel has a
pure-jnp oracle in ``kernels/ref.py`` defining its exact semantics, and
tests sweep the kernel (``interpret=True`` on CPU, Mosaic on TPU) against
it.  The rule is only as strong as its enforcement — a new kernel landed
without an oracle silently opts out — so each kernel module declares a
module-level ``PALLAS_ORACLES`` mapping (pallas entry-point name ->
``ref.py`` function name), this module aggregates them into one runtime
registry, and ``tools/tmlint`` rule TM202 statically checks that every
``pl.pallas_call`` site lives inside a registered entry point whose
oracle really exists in ``ref.py``.

``oracle_for`` resolves an entry point to its oracle callable — property
tests use it to drive kernel/oracle pairs generically.
"""

from __future__ import annotations

from typing import Callable, Dict

from repro.kernels import class_sum, clause_eval, fused_infer, ingress, ref

__all__ = ["KERNEL_ORACLES", "oracle_for"]

#: Aggregated (entry point -> ref.py oracle name) over every kernel module.
KERNEL_ORACLES: Dict[str, str] = {}
for _mod in (class_sum, clause_eval, fused_infer, ingress):
    for _kernel, _oracle in _mod.PALLAS_ORACLES.items():
        if _kernel in KERNEL_ORACLES:
            raise ValueError(
                f"kernel {_kernel!r} registered by more than one module"
            )
        if not hasattr(ref, _oracle):
            raise AttributeError(
                f"kernel {_kernel!r} names oracle {_oracle!r}, which does "
                f"not exist in repro.kernels.ref"
            )
        KERNEL_ORACLES[_kernel] = _oracle


def oracle_for(kernel_name: str) -> Callable:
    """The ref.py oracle callable for a registered Pallas entry point."""
    try:
        return getattr(ref, KERNEL_ORACLES[kernel_name])
    except KeyError:
        raise KeyError(
            f"no oracle registered for kernel {kernel_name!r}; known: "
            f"{sorted(KERNEL_ORACLES)}"
        ) from None
