"""Shared block/grid shape helpers for the Pallas kernel wrappers.

Every kernel wrapper pads its operands up to block multiples and derives
its grid from the padded extents.  Those two computations used to be
re-derived per module with raw ``//`` and ``%`` arithmetic — which is
exactly how block/grid mismatches slip in (a grid computed from an
*unpadded* extent silently drops the remainder tile).  This module is
the single source of those expressions, and ``tools/tmlint`` rule TM203
enforces that kernel grid/BlockSpec arithmetic goes through these
helpers instead of raw division.

All helpers are shape-arithmetic on python ints (jit-static values);
``pad_axis``/``pad_axis_ones`` operate on arrays but only ever grow an
axis to an already-computed ``round_up`` target.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "cdiv",
    "clamp_block",
    "grid_blocks",
    "pad_axis",
    "pad_axis_ones",
    "round_up",
]


def round_up(x: int, multiple: int) -> int:
    """Smallest multiple of ``multiple`` >= ``x``."""
    return (x + multiple - 1) // multiple * multiple


def clamp_block(block: int, extent: int, multiple: int) -> int:
    """The block size a kernel wrapper actually dispatches with: the
    requested ``block``, shrunk to the extent's ``round_up`` target when
    the axis is smaller than one block (a 3-row batch must not pay for a
    128-row tile).  Shared by the ops.py wrappers and the tmverify TM405
    grid/VMEM audit, so the audit sees the same block shapes dispatch
    does."""
    return min(block, round_up(extent, multiple))


def cdiv(x: int, block: int) -> int:
    """Ceiling division: grid steps needed for ``x`` elements in blocks
    of ``block``.  Equal to ``x // block`` when ``x`` is already padded
    to a block multiple — but never silently drops a remainder tile."""
    return (x + block - 1) // block


def grid_blocks(extent: int, block: int, *, axis: str = "?") -> int:
    """Grid size along one axis of a pallas_call, with the padding
    contract checked: ``extent`` must already be a block multiple (the
    ops.py wrappers pad before dispatching)."""
    if extent % block:
        raise ValueError(
            f"unpadded {axis} axis: extent {extent} % block {block} != 0"
        )
    return cdiv(extent, block)


def pad_axis(x: jax.Array, axis: int, target: int) -> jax.Array:
    """Zero-pad ``axis`` up to ``target`` (no-op when already there)."""
    pad = target - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def pad_axis_ones(x: jax.Array, axis: int, target: int) -> jax.Array:
    """Pad ``axis`` up to ``target`` with all-ones uint32 words (the
    sparse kernels' clause-padding contract: an all-ones exclude mask
    fires everywhere and is sliced off / zero-weighted by the caller)."""
    pad = target - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=jnp.uint32(0xFFFFFFFF))
