import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell the appropriate step function (train_step / prefill /
decode) is jitted with explicit NamedSharding in/out_shardings against the
production mesh — (16,16) single pod and (2,16,16) two pods — and
``.lower().compile()`` must succeed.  memory_analysis() proves the state
fits per-chip HBM; cost_analysis() + the compiled HLO feed the roofline
terms (repro.roofline.analysis).

Results are written one JSON per cell under ``experiments/dryrun/`` and
are resumable (existing JSONs are skipped unless --force).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      [--multi-pod | --both] [--force] [--cells N]
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, TrainConfig, applicable_shapes, get_config
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.roofline.analysis import model_flops, roofline_terms

OUT_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def _named(mesh, tree_specs):
    return tree_specs  # already NamedShardings


def lower_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    cfg_override=None,
    profile: str | None = None,
) -> Dict[str, Any]:
    from repro.sharding.partition import set_profile

    cfg = cfg_override if cfg_override is not None else get_config(arch)
    shape = SHAPES[shape_name]
    # Sharding profile: decode cells use decode-resident weights
    # ('serve_tp') unless the arch prefers pure DP; train/prefill follow
    # the arch profile.  §Perf iterations pass explicit overrides.
    if profile is None:
        if shape.kind == "decode":
            profile = "serve_tp" if cfg.sharding_profile != "dp" else "dp"
        else:
            profile = cfg.sharding_profile
    set_profile(profile)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.time()

    with mesh:
        if shape.kind == "train":
            tcfg = TrainConfig(microbatches=S.microbatches_for(cfg, shape, mesh))
            from repro.train.train_step import make_train_step

            step = make_train_step(cfg, tcfg, mesh=mesh)

            def step_dictstate(state, batch):
                from repro.train.optimizer import OptState

                st = dict(state)
                st["opt"] = OptState(**state["opt"])
                new_state, metrics = step(st, batch)
                new_state = dict(new_state)
                o = new_state["opt"]
                new_state["opt"] = {
                    "step": o.step, "m": o.m, "v": o.v, "master": o.master
                }
                return new_state, metrics

            state = S.abstract_train_state(cfg, tcfg)
            batch = S.batch_specs(cfg, shape)
            st_sh = S.state_shardings(cfg, tcfg, mesh)
            b_sh = S.batch_shardings(cfg, shape, mesh)
            lowered = jax.jit(
                step_dictstate,
                in_shardings=(st_sh, b_sh),
                out_shardings=(st_sh, None),
                donate_argnums=(0,),
            ).lower(state, batch)
            tokens = shape.global_batch * shape.seq_len
            extra = {"microbatches": tcfg.microbatches}
        elif shape.kind == "prefill":
            from repro.train.serve_step import prefill

            params = S.abstract_model(cfg)
            batch = S.batch_specs(cfg, shape)
            from repro.models.base import pspec_tree

            p_sh = jax.tree.map(
                lambda s: jax.sharding.NamedSharding(mesh, s),
                pspec_tree(S.model_decls(cfg), mesh),
                is_leaf=lambda x: hasattr(x, "index"),
            )
            b_sh = S.batch_shardings(cfg, shape, mesh)
            lowered = jax.jit(
                lambda p, b: prefill(p, b, cfg, mesh=mesh),
                in_shardings=(p_sh, b_sh),
            ).lower(params, batch)
            tokens = shape.global_batch * shape.seq_len
            extra = {}
        else:  # decode
            from repro.train.serve_step import decode
            from repro.models.base import pspec_tree

            params = S.abstract_model(cfg)
            p_sh = jax.tree.map(
                lambda s: jax.sharding.NamedSharding(mesh, s),
                pspec_tree(S.model_decls(cfg), mesh),
                is_leaf=lambda x: hasattr(x, "index"),
            )
            cache = S.cache_specs(cfg, shape)
            c_sh = S.cache_shardings(cfg, shape, mesh)
            toks = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
            from repro.sharding.partition import sharding_for

            t_sh = sharding_for(toks.shape, ("batch", None), mesh)
            pos = jax.ShapeDtypeStruct((), jnp.int32)

            if cfg.is_encoder_decoder:
                fn = lambda p, t, c, po: decode(
                    p, t, c["self"], po, cfg, cross_cache=c["cross"], mesh=mesh
                )
                out_sh = (None, c_sh["self"])
            else:
                fn = lambda p, t, c, po: decode(p, t, c, po, cfg, mesh=mesh)
                out_sh = (None, c_sh)
            lowered = jax.jit(
                fn,
                in_shardings=(p_sh, t_sh, c_sh, None),
                out_shardings=out_sh,
                donate_argnums=(2,),
            ).lower(params, toks, cache, pos)
            tokens = shape.global_batch  # one token per sequence per step
            extra = {}

        compiled = lowered.compile()

    try:
        mem = compiled.memory_analysis()
    except Exception:
        mem = None
    cost_list = compiled.cost_analysis()
    cost = cost_list[0] if isinstance(cost_list, (list, tuple)) else cost_list
    hlo = compiled.as_text()
    terms = roofline_terms(dict(cost), hlo, chips=chips)

    n = cfg.param_count()
    na = cfg.active_param_count()
    ideal = model_flops(n, na, tokens, shape.kind)
    ideal_per_chip = ideal / chips
    hlo_total = terms["flops_per_chip"]
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "kind": shape.kind,
        "compile_s": round(time.time() - t0, 1),
        "params": n,
        "active_params": na,
        "tokens_per_step": tokens,
        "model_flops_total": ideal,
        "model_flops_per_chip": ideal_per_chip,
        "useful_flops_ratio": (
            ideal_per_chip / hlo_total if hlo_total else None
        ),
        "memory": {
            "bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None)
            if hasattr(mem, "peak_memory_in_bytes")
            else None,
        },
        "roofline": terms,
        **extra,
    }
    return result


def cell_path(arch: str, shape: str, multi_pod: bool) -> str:
    mesh = "2x16x16" if multi_pod else "16x16"
    return os.path.join(OUT_DIR, f"{arch}__{shape}__{mesh}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both", action="store_true", help="run both meshes")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--cells", type=int, default=0, help="stop after N cells")
    args = ap.parse_args()

    os.makedirs(OUT_DIR, exist_ok=True)
    archs = sorted(ARCHS) if args.arch == "all" else [args.arch]
    meshes = [False, True] if args.both else [args.multi_pod]

    n_devices = len(jax.devices())
    assert n_devices >= 512, f"dry-run needs 512 virtual devices, got {n_devices}"

    done = failed = 0
    for arch in archs:
        cfg = get_config(arch)
        shapes = (
            applicable_shapes(cfg) if args.shape == "all" else [args.shape]
        )
        for shape in shapes:
            for mp in meshes:
                path = cell_path(arch, shape, mp)
                if os.path.exists(path) and not args.force:
                    print(f"skip {path} (exists)")
                    continue
                print(f"=== lowering {arch} x {shape} x "
                      f"{'2x16x16' if mp else '16x16'} ===", flush=True)
                try:
                    res = lower_cell(arch, shape, mp)
                    with open(path, "w") as f:
                        json.dump(res, f, indent=1)
                    r = res["roofline"]
                    print(
                        f"  OK compile={res['compile_s']}s dominant={r['dominant']} "
                        f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
                        f"collective={r['collective_s']:.3e}s "
                        f"useful={res['useful_flops_ratio']}",
                        flush=True,
                    )
                    done += 1
                except Exception as e:  # noqa
                    failed += 1
                    print(f"  FAILED: {type(e).__name__}: {e}", flush=True)
                    with open(path + ".err", "w") as f:
                        f.write(traceback.format_exc())
                if args.cells and done + failed >= args.cells:
                    print(f"done={done} failed={failed}")
                    return
    print(f"done={done} failed={failed}")
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
