"""Zero-downtime model lifecycle driver: train -> shadow -> promote.

The paper's accelerator is programmable so new TM models load without
respinning silicon (Sec. IV); this driver is the software counterpart
for a *live* service — it wires the training engine to the serving
engine through the hot-swap lifecycle (ARCHITECTURE.md §Lifecycle):

  1. **train**    — ``TrainerEngine.fit`` advances the candidate a round
     of epochs from the checkpointable cursor;
  2. **freeze**   — ``TrainerEngine.freeze_servable`` stamps the frozen
     image with a :class:`~repro.serve.servable.ServableVersion`
     (epoch/step from the cursor, content digest) — once per candidate
     version, never re-frozen downstream;
  3. **shadow**   — the candidate registers under ``<name>@shadow`` on
     the live engine (its own sparsity analysis and, optionally, its own
     autotune pass — per version, never cached across swaps) and is
     scored against the live version **on the same mirrored requests**;
  4. **promote or reject** — promotion requires prediction agreement >=
     ``min_agreement`` and, when labels ride along, candidate accuracy
     no worse than live minus ``allow_accuracy_drop``; a promoted
     candidate installs via ``ServingEngine.swap`` (in-flight work
     completes on the old version; ``rollback()`` undoes it instantly),
     a rejected one leaves the live version untouched.

One-shot CLI round-trip at tiny geometry::

    PYTHONPATH=src python -m repro.launch.lifecycle \
        --arch convcotm-mnist --rounds 2 --epochs 1 --shadow-requests 128

The concurrency story (swap storms under open-loop Poisson load, version
attribution per ``ServiceResult``, bounded recompiles) is asserted in
``tests/test_lifecycle.py``; measured swap-pause numbers live in
EXPERIMENTS.md §Lifecycle.
"""

from __future__ import annotations

import argparse
import dataclasses
from typing import List, Optional, Tuple

import jax
import numpy as np

from repro.core.cotm import CoTMModel
from repro.data.pipeline import PipelineState
from repro.serve.engine import ServingEngine
from repro.serve.servable import ServableModel, ServableVersion
from repro.train.tm_engine import TMDataset, TrainerEngine

__all__ = ["LifecycleConfig", "ShadowReport", "LifecycleDriver", "shadow_slot"]


def shadow_slot(name: str) -> str:
    """The engine slot a candidate shadows under (``<name>@shadow``)."""
    return f"{name}@shadow"


@dataclasses.dataclass(frozen=True)
class LifecycleConfig:
    """Promotion policy knobs.

    ``min_agreement``      — fraction of mirrored requests on which the
                             candidate must predict the same class as
                             the live version (1.0 = bit-stable gate).
    ``allow_accuracy_drop``— with labels, the candidate may be at most
                             this much less accurate than live (0.0 =
                             never promote a regression).
    ``shadow_requests``    — mirrored requests per shadow evaluation.
    ``autotune_candidate`` — re-run the per-bucket autotuner on the
                             candidate during shadow registration (the
                             plan is per-version, like sparsity).
    ``checkpoint_promoted``— save every promoted servable (stamp +
                             tuned plan) via ``checkpoint.save_servable``
                             when a ``ckpt_dir`` is configured.
    """

    min_agreement: float = 0.98
    allow_accuracy_drop: float = 0.0
    shadow_requests: int = 256
    autotune_candidate: bool = False
    checkpoint_promoted: bool = True

    def __post_init__(self):
        if not 0.0 <= self.min_agreement <= 1.0:
            raise ValueError("min_agreement must be in [0, 1]")
        if self.allow_accuracy_drop < 0:
            raise ValueError("allow_accuracy_drop must be >= 0")
        if self.shadow_requests < 1:
            raise ValueError("shadow_requests must be >= 1")


@dataclasses.dataclass
class ShadowReport:
    """One shadow evaluation: candidate vs live on mirrored traffic."""

    n: int                               # mirrored requests scored
    agreement: float                     # fraction of matching predictions
    live_version: int                    # live monotonic id during scoring
    candidate_digest: str                # candidate content digest
    live_accuracy: Optional[float] = None
    candidate_accuracy: Optional[float] = None
    promoted: bool = False
    promoted_version: Optional[int] = None
    reason: str = ""

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class LifecycleDriver:
    """Train -> freeze -> shadow -> promote/reject over a live engine.

    The driver owns no event loop: it mutates the engine through the
    public lifecycle API only (``register``/``swap``/``rollback``), so
    it composes with a running :class:`~repro.serve.service.ServingService`
    — swaps land atomically under load (the service's requests keep
    their admission version; tests/test_lifecycle.py soaks exactly this
    composition).
    """

    def __init__(
        self,
        trainer: TrainerEngine,
        engine: ServingEngine,
        name: str,
        *,
        config: Optional[LifecycleConfig] = None,
        ckpt_dir: Optional[str] = None,
        booleanize_method: str = "threshold",
        eval_path: Optional[str] = None,
    ):
        self.trainer = trainer
        self.engine = engine
        self.name = name
        self.config = config or LifecycleConfig()
        self.ckpt_dir = ckpt_dir
        self.booleanize_method = booleanize_method
        self.eval_path = eval_path
        self.reports: List[ShadowReport] = []

    # --- train ------------------------------------------------------------

    def train_candidate(
        self,
        key: jax.Array,
        model: CoTMModel,
        train_ds: TMDataset,
        *,
        epochs: int = 1,
        state: Optional[PipelineState] = None,
    ) -> Tuple[jax.Array, CoTMModel, PipelineState, ServableModel]:
        """Advance training one round and freeze the stamped candidate."""
        key, model, state, _ = self.trainer.fit(
            key, model, train_ds, epochs=epochs, state=state
        )
        return key, model, state, self.trainer.freeze_servable(model, state)

    # --- shadow -----------------------------------------------------------

    def shadow_evaluate(
        self,
        candidate: ServableModel,
        requests: np.ndarray,
        labels: Optional[np.ndarray] = None,
    ) -> ShadowReport:
        """Score the candidate against the live version on the SAME
        requests (mirrored traffic), without touching the live slot.

        The candidate registers under :func:`shadow_slot` — a real
        registration on the live engine, so it gets its own per-version
        sparsity analysis (and autotune pass when configured) exactly as
        promotion would install it.  Each mirrored batch classifies on
        both slots; agreement is the fraction of identical predicted
        classes, and accuracies are computed when ``labels`` ride along.
        """
        cfg = self.config
        slot = shadow_slot(self.name)
        self.engine.register(
            slot,
            candidate,
            booleanize_method=self.booleanize_method,
            path=self.eval_path,
            autotune=cfg.autotune_candidate,
        )
        if cfg.autotune_candidate:
            self.engine.autotune(slot)
        n = min(len(requests), cfg.shadow_requests)
        live = self.engine.classify(self.name, requests[:n])
        shadow = self.engine.classify(slot, requests[:n])
        agree = float(np.mean(live.predictions == shadow.predictions))
        report = ShadowReport(
            n=n,
            agreement=agree,
            live_version=live.version,
            candidate_digest=(
                candidate.version.digest if candidate.version else ""
            ),
        )
        if labels is not None:
            y = np.asarray(labels[:n], np.int64)
            report.live_accuracy = float(np.mean(live.predictions == y))
            report.candidate_accuracy = float(np.mean(shadow.predictions == y))
        return report

    # --- promote / reject -------------------------------------------------

    def gate(self, report: ShadowReport) -> Tuple[bool, str]:
        """The promotion decision for one shadow report."""
        cfg = self.config
        if report.agreement < cfg.min_agreement:
            return False, (
                f"agreement {report.agreement:.4f} < {cfg.min_agreement:.4f}"
            )
        if (
            report.live_accuracy is not None
            and report.candidate_accuracy is not None
            and report.candidate_accuracy
            < report.live_accuracy - cfg.allow_accuracy_drop
        ):
            return False, (
                f"accuracy {report.candidate_accuracy:.4f} < live "
                f"{report.live_accuracy:.4f} - {cfg.allow_accuracy_drop:.4f}"
            )
        return True, "gates passed"

    def promote(self, candidate: ServableModel) -> ServableVersion:
        """Install the candidate on the live slot via an atomic swap.

        Carries the shadow slot's freshly measured tuned plan onto the
        live entry when the candidate was autotuned during shadowing;
        checkpoints the promoted servable when configured.
        """
        tuned = None
        if self.config.autotune_candidate:
            slot = shadow_slot(self.name)
            if slot in self.engine.models():
                tuned = self.engine.servable(slot).tuned
        stamp = self.engine.swap(self.name, candidate, tuned=tuned)
        if self.ckpt_dir and self.config.checkpoint_promoted:
            from repro.checkpoint.checkpointer import save_servable

            save_servable(
                self.engine.servable(self.name), self.ckpt_dir, stamp.version
            )
        return stamp

    def rollback(self) -> ServableVersion:
        """Undo the last promotion on the live slot (instant)."""
        return self.engine.rollback(self.name)

    # --- one full round ---------------------------------------------------

    def run_round(
        self,
        key: jax.Array,
        model: CoTMModel,
        train_ds: TMDataset,
        requests: np.ndarray,
        labels: Optional[np.ndarray] = None,
        *,
        epochs: int = 1,
        state: Optional[PipelineState] = None,
    ) -> Tuple[jax.Array, CoTMModel, PipelineState, ShadowReport]:
        """Train one round, shadow-evaluate, then promote or reject."""
        key, model, state, candidate = self.train_candidate(
            key, model, train_ds, epochs=epochs, state=state
        )
        report = self.shadow_evaluate(candidate, requests, labels)
        ok, reason = self.gate(report)
        report.reason = reason
        if ok:
            stamp = self.promote(candidate)
            report.promoted = True
            report.promoted_version = stamp.version
        self.reports.append(report)
        return key, model, state, report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--epochs", type=int, default=1,
                    help="training epochs per lifecycle round")
    ap.add_argument("--batch-size", type=int, default=100)
    ap.add_argument("--n-train", type=int, default=2048)
    ap.add_argument("--shadow-requests", type=int, default=256)
    ap.add_argument("--agreement", type=float, default=0.5,
                    help="min prediction agreement to promote (early "
                         "training rounds move predictions a lot)")
    ap.add_argument("--accuracy-drop", type=float, default=0.0,
                    help="max accuracy regression tolerated at promotion")
    ap.add_argument("--autotune", action="store_true",
                    help="re-autotune each candidate during shadowing")
    ap.add_argument("--ckpt-dir", default=None,
                    help="save every promoted servable (stamp + plan) here")
    ap.add_argument("--max-batch", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs.convcotm import BOOLEANIZE_METHOD, COTM_CONFIGS
    from repro.core.cotm import init_boundary_model
    from repro.data import get_dataset

    cfg = COTM_CONFIGS[args.arch]
    method = BOOLEANIZE_METHOD[args.arch]
    dataset = args.arch.split("-", 1)[1]
    tx, ty, vx, vy, source = get_dataset(
        dataset, n_train=args.n_train, n_test=args.shadow_requests
    )
    # Real datasets come back full-size (the kwargs only shape the
    # synthetic fallback); slice to the requested working-set sizes.
    tx, ty = tx[: args.n_train], ty[: args.n_train]
    vx, vy = vx[: args.shadow_requests], vy[: args.shadow_requests]

    trainer = TrainerEngine(cfg, batch_size=args.batch_size)
    train_ds = trainer.prepare(tx, ty)
    engine = ServingEngine(max_batch=args.max_batch)
    key = jax.random.PRNGKey(args.seed)
    model = init_boundary_model(key, cfg)
    engine.register(
        args.arch, trainer.freeze_servable(model), booleanize_method=method
    )
    engine.warmup(args.arch, forms=("raw",))
    print(
        f"{args.arch}: live v{engine.version_id(args.arch)} "
        f"({source} data, {train_ds.n} training samples)"
    )

    driver = LifecycleDriver(
        trainer, engine, args.arch,
        config=LifecycleConfig(
            min_agreement=args.agreement,
            allow_accuracy_drop=args.accuracy_drop,
            shadow_requests=args.shadow_requests,
            autotune_candidate=args.autotune,
        ),
        ckpt_dir=args.ckpt_dir,
        booleanize_method=method,
    )
    state = PipelineState()
    for r in range(args.rounds):
        key, model, state, rep = driver.run_round(
            key, model, train_ds, np.asarray(vx), np.asarray(vy),
            epochs=args.epochs, state=state,
        )
        acc = (
            f" | acc live {rep.live_accuracy:.4f} -> "
            f"cand {rep.candidate_accuracy:.4f}"
            if rep.live_accuracy is not None else ""
        )
        verdict = (
            f"PROMOTED as v{rep.promoted_version}" if rep.promoted
            else f"rejected ({rep.reason})"
        )
        print(
            f"round {r}: agreement {rep.agreement:.4f} over {rep.n} mirrored "
            f"requests vs live v{rep.live_version}{acc} | {verdict}"
        )
    print(f"{args.arch}: serving {engine.version(args.arch)}")


if __name__ == "__main__":
    main()
