"""Production meshes.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* any jax
initialization, and smoke tests must keep seeing 1 device.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_test_mesh", "required_devices"]


def required_devices(multi_pod: bool = False) -> int:
    return 512 if multi_pod else 256


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """(16, 16) ("data", "model") single pod; (2, 16, 16) ("pod", "data",
    "model") for the 2-pod = 512-chip dry-run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over however many devices the process has (tests)."""
    n = data * model
    devs = np.array(jax.devices()[:n]).reshape(data, model)
    return Mesh(devs, ("data", "model"))
