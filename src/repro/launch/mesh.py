"""Production meshes.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* any jax
initialization, and smoke tests must keep seeing 1 device.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = [
    "make_production_mesh",
    "make_serve_device_mesh",
    "make_test_mesh",
    "required_devices",
]


def required_devices(multi_pod: bool = False) -> int:
    return 512 if multi_pod else 256


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """(16, 16) ("data", "model") single pod; (2, 16, 16) ("pod", "data",
    "model") for the 2-pod = 512-chip dry-run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_serve_device_mesh(data: int = 1, model: int = 1) -> Mesh:
    """("data", "model") mesh over the first ``data * model`` local devices.

    The device grid under :class:`repro.serve.mesh.ServeMesh`: the "data"
    axis shards request batches, the "model" axis (optionally) shards the
    clause pool.  Raises with a remediation hint when the process has too
    few devices — on CPU the count is set with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before* jax
    initializes.
    """
    n = data * model
    have = len(jax.devices())
    if have < n:
        raise ValueError(
            f"mesh ({data} data x {model} model) needs {n} devices but the "
            f"process has {have}; on CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} before "
            f"jax initializes"
        )
    devs = np.array(jax.devices()[:n]).reshape(data, model)
    return Mesh(devs, ("data", "model"))


def make_test_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over however many devices the process has (tests)."""
    return make_serve_device_mesh(data, model)
