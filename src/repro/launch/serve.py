"""Serving driver.

ConvCoTM archs (the paper's accelerator) are served through the batched
``repro.serve`` engine — model frozen once to a :class:`ServableModel`,
raw pixel requests padded to power-of-two buckets and classified by the
fused device-resident ingress graph (``--ingress host`` replays the
legacy host pipeline):

    PYTHONPATH=src python -m repro.launch.serve \
        --arch convcotm-mnist --requests 64 --max-batch 256

``--mesh DATA[xMODEL]`` (with ``--shard batch|clause``) serves sharded
across a device mesh — request batches split over the "data" axis,
optionally the clause pool over "model" (``repro.serve.mesh``); on CPU
prefix with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.serve \
        --arch convcotm-mnist --mesh 8 --requests 64

``--service`` runs the same arch behind the asyncio ``ServingService``
(bounded queue, latency-aware microbatching, graceful drain) under an
open-loop Poisson arrival stream — the online-serving counterpart of the
one-shot request loop (see ``repro.serve.service``; rate sweeps live in
``benchmarks/bench_service.py``):

    PYTHONPATH=src python -m repro.launch.serve \
        --arch convcotm-mnist --service --rate 2000 --requests 512 \
        --max-delay-us 200

LM archs keep the prefill+decode loop:

    PYTHONPATH=src python -m repro.launch.serve \
        --arch xlstm-350m --reduced --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import asyncio
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.launch import specs as S
from repro.models import encdec as ed
from repro.models import transformer as tfm
from repro.models.base import init_params
from repro.train.serve_step import decode, sample_tokens

__all__ = ["generate", "parse_serve_mesh", "serve_tm", "serve_tm_service"]


def generate(
    cfg,
    params,
    prompt_tokens: jax.Array,     # [B, P]
    gen_len: int,
    *,
    mesh=None,
    max_seq: int | None = None,
    temperature: float = 0.0,
    frontend_embeds=None,
    seed: int = 0,
):
    """Prompt -> generated tokens [B, gen_len] via cached decode steps."""
    b, plen = prompt_tokens.shape
    max_seq = max_seq or (plen + gen_len)

    if cfg.is_encoder_decoder:
        enc_out = ed.encode(params, frontend_embeds, cfg, mesh=mesh)
        cross = ed.prepare_cross_cache(params, enc_out, cfg)
        cache = ed.init_self_cache(b, cfg, max_seq)
        dec_fn = jax.jit(
            lambda p, t, c, po: decode(p, t, c, po, cfg, cross_cache=cross, mesh=mesh)
        )
    else:
        cache = tfm.init_decode_cache(b, cfg, max_seq)
        dec_fn = jax.jit(lambda p, t, c, po: decode(p, t, c, po, cfg, mesh=mesh))

    key = jax.random.PRNGKey(seed)
    # Teacher-forced prefill through the decode path (exercises the cache
    # exactly as continuous serving does).
    logits = None
    for i in range(plen):
        logits, cache = dec_fn(params, prompt_tokens[:, i : i + 1], cache, jnp.int32(i))

    out = []
    done = jnp.zeros((b,), bool)
    tok = None
    for j in range(gen_len):
        key, k = jax.random.split(key)
        tok, done = sample_tokens(k, logits, temperature=temperature, done=done)
        out.append(tok)
        logits, cache = dec_fn(params, tok[:, None], cache, jnp.int32(plen + j))
    return jnp.stack(out, axis=1)


def parse_serve_mesh(spec: str | None, shard: str = "batch"):
    """``--mesh``/``--shard`` -> :class:`~repro.serve.mesh.ServeMesh`.

    ``spec`` is ``"DATA"`` or ``"DATAxMODEL"`` (e.g. ``8`` or ``4x2``);
    a bare count lands on the axis ``shard`` selects — ``batch`` (the
    data axis) or ``clause`` (the model axis, clause-sharded eval).
    ``None`` means single-device (no mesh).
    """
    if spec is None:
        return None
    from repro.serve.mesh import make_serve_mesh

    if "x" in spec:
        data, model = (int(p) for p in spec.split("x", 1))
    elif shard == "clause":
        data, model = 1, int(spec)
    else:
        data, model = int(spec), 1
    return make_serve_mesh(data, model, shard_clauses=shard == "clause" or model > 1)


def _tm_engine(
    arch: str,
    *,
    max_batch: int,
    eval_path: str | None,
    ckpt_dir: str | None,
    seed: int,
    mesh=None,
    autotune: bool = False,
):
    """Shared TM-serving setup: dataset, registered (or restored) model.

    Returns ``(engine, vx, vy, source)``; used by both the one-shot
    request loop and the async ``--service`` mode.  ``mesh`` (a
    :class:`~repro.serve.mesh.ServeMesh`) serves the model sharded
    across a device mesh.  ``autotune`` measures eval-path candidates
    per (form, bucket) during warmup and serves each from its winner
    (ARCHITECTURE.md §Autotune).
    """
    from repro.configs.convcotm import BOOLEANIZE_METHOD, COTM_CONFIGS
    from repro.core.cotm import init_boundary_model
    from repro.data import get_dataset
    from repro.serve import ServingEngine

    cfg = COTM_CONFIGS[arch]
    method = BOOLEANIZE_METHOD[arch]
    dataset = arch.split("-", 1)[1]               # convcotm-mnist -> mnist
    _, _, vx, vy, source = get_dataset(dataset, n_test=1024)

    engine = ServingEngine(max_batch=max_batch, mesh=mesh, autotune=autotune)
    if mesh is not None:
        print(
            f"{arch}: serving on a {mesh.n_data}x{mesh.n_model} "
            f'("data","model") mesh '
            f"({'clause-sharded' if mesh.shard_clauses else 'replicated'})"
        )
    if ckpt_dir is not None:
        engine.load_checkpoint(
            arch, ckpt_dir, cfg, booleanize_method=method, path=eval_path
        )
        print(f"{arch}: restored model from {ckpt_dir}")
    else:
        model = init_boundary_model(jax.random.PRNGKey(seed), cfg)
        engine.register(arch, model, cfg, booleanize_method=method, path=eval_path)
        print(f"{arch}: serving a randomly initialized model ({source} data)")
    return engine, vx, vy, source


def serve_tm(
    arch: str,
    *,
    n_requests: int = 32,
    max_batch: int = 256,
    eval_path: str | None = None,
    ckpt_dir: str | None = None,
    seed: int = 0,
    ingress: str = "device",
    mesh=None,
    autotune: bool = False,
) -> dict:
    """Drive the batched TM engine with a mixed-size request stream.

    The model comes from ``ckpt_dir`` (a ``repro.checkpoint`` directory of
    a trained CoTMModel) when given, else a randomly initialized model —
    enough to exercise the full raw->predictions spine (device-resident
    ingress fused into the bucketed jit classify; ``ingress='host'``
    replays the legacy host pipeline) and measure throughput; accuracy is
    reported when the dataset has labels.  ``mesh`` serves sharded across
    a device mesh (``--mesh``/``--shard``, see ``repro.serve.mesh``).
    """
    engine, vx, vy, source = _tm_engine(
        arch, max_batch=max_batch, eval_path=eval_path,
        ckpt_dir=ckpt_dir, seed=seed, mesh=mesh, autotune=autotune,
    )
    compiled = engine.warmup(arch)
    print(f"{arch}: warmed buckets {list(compiled)} (compiles excluded from stats)")
    if autotune:
        at = engine.stats(arch).autotune
        print(
            f"{arch}: autotuned in {at.get('total_s', 0.0):.1f}s -> "
            f"plan {at.get('plan')}"
        )

    rng = np.random.default_rng(seed)
    correct = total = 0
    for _ in range(n_requests):
        n = int(rng.integers(1, max_batch + 1))
        idx = rng.integers(0, len(vx), n)
        res = engine.classify(arch, vx[idx], ingress=ingress)
        correct += int((res.predictions == vy[idx].astype(np.int64)).sum())
        total += n
    st = engine.stats(arch)
    print(
        f"{arch}: {st.images} images in {st.requests} requests | "
        f"{st.classifications_per_s:,.0f} classifications/s | "
        f"mean latency {st.mean_latency_us:,.0f} us "
        f"(ingress {st.mean_ingress_us:,.0f} + device "
        f"{st.mean_device_us:,.0f}) | "
        f"buckets compiled {sorted(st.compiled_buckets)} "
        f"hits {dict(sorted(st.bucket_hits.items()))}"
    )
    if ckpt_dir is not None:
        print(f"{arch}: accuracy {correct / total:.4f} on {source} test data")
    return st.as_dict()


async def serve_tm_service(
    arch: str,
    *,
    n_requests: int = 256,
    rate: float = 2000.0,
    max_batch: int = 256,
    max_delay_us: float = 200.0,
    high_water: int = 4096,
    eval_path: str | None = None,
    ckpt_dir: str | None = None,
    seed: int = 0,
    submit_form: str = "raw",
    mesh=None,
    autotune: bool = False,
    deadline_s: float | None = None,
    malformed_frac: float = 0.0,
    abandon_frac: float = 0.0,
) -> dict:
    """Drive the async ServingService with open-loop Poisson arrivals.

    Single-image requests arrive at ``rate`` req/s on a precomputed
    exponential schedule (``repro.serve.loadgen.poisson_open_loop``),
    coalesce in the microbatcher under ``max_delay_us``, and the run
    ends with a graceful drain.  ``submit_form`` picks the request form:

      * ``'raw'`` (default) — raw pixels; the booleanize/patch/pack
        ingress runs device-side inside each microbatch's fused classify
        graph (amortized over the coalesced requests);
      * ``'preprocessed'`` — the pool is preprocessed once up front, so
        the run measures only the service spine (queue -> microbatch ->
        bucket -> classify);
      * ``'host'`` — raw pixels through the legacy per-request host
        ingress (the pre-device-ingress baseline).

    Prints the per-model ServiceStats snapshot (p50/p99 latency,
    ingress/device split, batch-occupancy histogram, rejections).

    The adversarial knobs (ARCHITECTURE.md §Faults) ride the same load:
    ``deadline_s`` stamps every request (past it, requests shed with
    ``ServiceExpired`` before dispatch), ``malformed_frac`` corrupts
    that fraction of submissions (rejected at validation),
    ``abandon_frac`` simulates clients that stop waiting — the service
    must still resolve their futures.
    """
    from repro.serve import ServiceConfig, ServingService
    from repro.serve.loadgen import poisson_open_loop

    if submit_form not in ("raw", "preprocessed", "host"):
        raise ValueError(f"unknown submit_form {submit_form!r}")
    engine, vx, vy, source = _tm_engine(
        arch, max_batch=max_batch, eval_path=eval_path,
        ckpt_dir=ckpt_dir, seed=seed, mesh=mesh, autotune=autotune,
    )
    engine.warmup(arch)
    if submit_form == "preprocessed":
        pool = engine.preprocess(arch, vx)   # the host ingress, run once
    else:
        pool = np.asarray(vx)

    service = ServingService(
        engine,
        ServiceConfig(max_delay_us=max_delay_us, high_water=high_water),
    )
    await service.start()
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, len(vx), n_requests)

    loop = asyncio.get_running_loop()
    t0 = loop.time()
    report = await poisson_open_loop(
        service, arch, [pool[j : j + 1] for j in idx], rate,
        seed=seed,
        preprocessed=submit_form == "preprocessed",
        host_ingress=submit_form == "host",
        deadline_s=deadline_s,
        malformed_frac=malformed_frac,
        abandon_frac=abandon_frac,
    )
    admitted, rejected = report.admitted, report.rejected
    # Abandoned futures are gathered too — the request-lifetime
    # guarantee says they resolve whether or not the client waits; with
    # a deadline set, some resolutions are ServiceExpired exceptions.
    outcomes = await asyncio.gather(
        *(f for _, f in admitted + report.abandoned), return_exceptions=True
    )
    await service.stop(drain=True)
    wall = loop.time() - t0

    st = service.stats(arch)
    offered = n_requests / wall
    print(
        f"{arch}: offered {offered:,.0f} req/s | completed {st.completed} "
        f"({st.completed / wall:,.0f}/s), rejected {rejected} | "
        f"p50 {st.p50_latency_us:,.0f} us p99 {st.p99_latency_us:,.0f} us | "
        f"split ingress {st.ingress_us_per_image:,.0f} / device "
        f"{st.device_us_per_image:,.0f} us/img | "
        f"mean occupancy {st.mean_occupancy:.2f} | "
        f"occupancy hist {st.occupancy_hist}"
    )
    if deadline_s is not None or malformed_frac or abandon_frac:
        health = service.health()
        print(
            f"{arch}: faults — expired {st.expired}, malformed "
            f"{report.malformed}, abandoned {len(report.abandoned)} "
            f"(all resolved), health {health.state}"
        )
    results = [
        (i, r) for (i, _), r in zip(admitted, outcomes)
        if not isinstance(r, BaseException)
    ]
    if ckpt_dir is not None and results:
        # each surviving result pairs with its request index i -> label
        # vy[idx[i]]; rejections/expiries therefore cannot shift the
        # pairing.
        correct = sum(
            int(r.predictions[0]) == int(vy[idx[i]]) for i, r in results
        )
        print(f"{arch}: accuracy {correct / len(results):.4f} on {source} test data")
    return st.as_dict()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    # TM serving flags
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=256)
    ap.add_argument("--eval-path", default=None)
    ap.add_argument("--autotune", action="store_true",
                    help="measure eval-path candidates per (form, bucket) "
                         "at warmup and serve each from its winner")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ingress", default="device", choices=["device", "host"],
                    help="raw-request ingress: fused device graph or the "
                         "legacy host pipeline")
    ap.add_argument("--mesh", default=None, metavar="DATA[xMODEL]",
                    help="serve across a device mesh, e.g. 8 (data-"
                         "parallel) or 4x2 (batch over 4, clauses over "
                         "2); on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N first")
    ap.add_argument("--shard", default="batch", choices=["batch", "clause"],
                    help="which axis a bare --mesh count shards: request "
                         "batches over \"data\" or the clause pool over "
                         "\"model\" (psum-reduced class sums)")
    ap.add_argument("--submit-form", default="raw",
                    choices=["raw", "preprocessed", "host"],
                    help="request form for --service submissions")
    # async service mode
    ap.add_argument("--service", action="store_true",
                    help="serve through the asyncio ServingService")
    ap.add_argument("--rate", type=float, default=2000.0,
                    help="Poisson arrival rate, requests/s (--service)")
    ap.add_argument("--max-delay-us", type=float, default=200.0,
                    help="microbatch coalescing deadline (--service)")
    ap.add_argument("--high-water", type=int, default=4096,
                    help="queued-image admission limit (--service)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request deadline in seconds (--service); "
                         "requests past it shed with ServiceExpired "
                         "before dispatch")
    ap.add_argument("--malformed-frac", type=float, default=0.0,
                    help="fraction of submissions shape-corrupted, to be "
                         "rejected at validation (--service)")
    ap.add_argument("--abandon-frac", type=float, default=0.0,
                    help="fraction of admitted requests whose client "
                         "walks away; their futures must still resolve "
                         "(--service)")
    args = ap.parse_args()

    from repro.configs.convcotm import COTM_CONFIGS

    if args.arch in COTM_CONFIGS:
        mesh = parse_serve_mesh(args.mesh, args.shard)
        if args.service:
            asyncio.run(
                serve_tm_service(
                    args.arch,
                    n_requests=args.requests,
                    rate=args.rate,
                    max_batch=args.max_batch,
                    max_delay_us=args.max_delay_us,
                    high_water=args.high_water,
                    eval_path=args.eval_path,
                    ckpt_dir=args.ckpt_dir,
                    submit_form=args.submit_form,
                    autotune=args.autotune,
                    mesh=mesh,
                    deadline_s=args.deadline_s,
                    malformed_frac=args.malformed_frac,
                    abandon_frac=args.abandon_frac,
                )
            )
            return
        serve_tm(
            args.arch,
            n_requests=args.requests,
            max_batch=args.max_batch,
            eval_path=args.eval_path,
            ckpt_dir=args.ckpt_dir,
            ingress=args.ingress,
            autotune=args.autotune,
            mesh=mesh,
        )
        return

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    key = jax.random.PRNGKey(0)
    params = init_params(S.model_decls(cfg), key)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )
    fe = None
    if cfg.is_encoder_decoder or cfg.modality == "vision":
        fe = jnp.asarray(
            rng.standard_normal((args.batch, 16, cfg.d_model)), cfg.dtype
        )
    t0 = time.time()
    toks = generate(
        cfg, params, prompts, args.gen, temperature=args.temperature,
        frontend_embeds=fe,
    )
    dt = time.time() - t0
    print(f"generated {toks.shape} in {dt:.1f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print(np.asarray(toks)[:2])


if __name__ == "__main__":
    main()
