"""Serving driver: prefill a batch of prompts, then batched decode.

CPU-scale example:  PYTHONPATH=src python -m repro.launch.serve \
    --arch xlstm-350m --reduced --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.launch import specs as S
from repro.models import encdec as ed
from repro.models import transformer as tfm
from repro.models.base import init_params
from repro.train.serve_step import decode, sample_tokens

__all__ = ["generate"]


def generate(
    cfg,
    params,
    prompt_tokens: jax.Array,     # [B, P]
    gen_len: int,
    *,
    mesh=None,
    max_seq: int | None = None,
    temperature: float = 0.0,
    frontend_embeds=None,
    seed: int = 0,
):
    """Prompt -> generated tokens [B, gen_len] via cached decode steps."""
    b, plen = prompt_tokens.shape
    max_seq = max_seq or (plen + gen_len)

    if cfg.is_encoder_decoder:
        enc_out = ed.encode(params, frontend_embeds, cfg, mesh=mesh)
        cross = ed.prepare_cross_cache(params, enc_out, cfg)
        cache = ed.init_self_cache(b, cfg, max_seq)
        dec_fn = jax.jit(
            lambda p, t, c, po: decode(p, t, c, po, cfg, cross_cache=cross, mesh=mesh)
        )
    else:
        cache = tfm.init_decode_cache(b, cfg, max_seq)
        dec_fn = jax.jit(lambda p, t, c, po: decode(p, t, c, po, cfg, mesh=mesh))

    key = jax.random.PRNGKey(seed)
    # Teacher-forced prefill through the decode path (exercises the cache
    # exactly as continuous serving does).
    logits = None
    for i in range(plen):
        logits, cache = dec_fn(params, prompt_tokens[:, i : i + 1], cache, jnp.int32(i))

    out = []
    done = jnp.zeros((b,), bool)
    tok = None
    for j in range(gen_len):
        key, k = jax.random.split(key)
        tok, done = sample_tokens(k, logits, temperature=temperature, done=done)
        out.append(tok)
        logits, cache = dec_fn(params, tok[:, None], cache, jnp.int32(plen + j))
    return jnp.stack(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    key = jax.random.PRNGKey(0)
    params = init_params(S.model_decls(cfg), key)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )
    fe = None
    if cfg.is_encoder_decoder or cfg.modality == "vision":
        fe = jnp.asarray(
            rng.standard_normal((args.batch, 16, cfg.d_model)), cfg.dtype
        )
    t0 = time.time()
    toks = generate(
        cfg, params, prompts, args.gen, temperature=args.temperature,
        frontend_embeds=fe,
    )
    dt = time.time() - t0
    print(f"generated {toks.shape} in {dt:.1f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print(np.asarray(toks)[:2])


if __name__ == "__main__":
    main()
