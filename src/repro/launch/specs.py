"""ShapeDtypeStruct input specs + shardings for every (arch x shape) cell.

``input_specs`` returns abstract stand-ins (no allocation — a 42 B-param
model's train state is described, never materialized) together with the
matching NamedShardings, ready for ``jax.jit(...).lower(...)``.

Cell kinds:
  train   -> lowers ``train_step``  (state + batch)
  prefill -> lowers ``prefill``     (params + full-sequence batch)
  decode  -> lowers ``decode``      (params + token + cache + pos)
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.models import encdec as ed
from repro.models import transformer as tfm
from repro.models.base import abstract_params, pspec_tree
from repro.sharding.partition import sharding_for, spec as logical_spec

__all__ = [
    "batch_specs",
    "batch_shardings",
    "abstract_model",
    "abstract_train_state",
    "state_shardings",
    "cache_specs",
    "cache_shardings",
    "microbatches_for",
]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def model_decls(cfg: ModelConfig) -> Dict:
    if cfg.is_encoder_decoder:
        return ed.encdec_decls(cfg)
    return tfm.model_decls(cfg)


def abstract_model(cfg: ModelConfig) -> Dict:
    return abstract_params(model_decls(cfg))


# ---------------------------------------------------------------------------
# Batches
# ---------------------------------------------------------------------------

def _frontend_split(cfg: ModelConfig, seq: int) -> Tuple[int, int]:
    """(frontend_len, token_len) for modality archs."""
    f = int(seq * cfg.frontend_fraction)
    return f, seq - f


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Full-sequence batch (train and prefill cells)."""
    gb, s = shape.global_batch, shape.seq_len
    if cfg.is_encoder_decoder:
        # Encoder sees the full assigned sequence; decoder text is shorter
        # (speech-to-text ratio, ARCHITECTURE.md §Substrate).
        return {
            "frontend_embeds": _sds((gb, s, cfg.d_model), cfg.dtype),
            "dec_tokens": _sds((gb, max(s // 4, 16)), jnp.int32),
        }
    if cfg.modality == "vision":
        fl, tl = _frontend_split(cfg, s)
        return {
            "tokens": _sds((gb, tl), jnp.int32),
            "frontend_embeds": _sds((gb, fl, cfg.d_model), cfg.dtype),
        }
    return {"tokens": _sds((gb, s), jnp.int32)}


def _batch_axes(name: str) -> Tuple:
    if name == "frontend_embeds":
        return ("batch", None, None)
    return ("batch", None)


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> Dict[str, Any]:
    return {
        k: sharding_for(v.shape, _batch_axes(k), mesh)
        for k, v in batch_specs(cfg, shape).items()
    }


# ---------------------------------------------------------------------------
# Train state
# ---------------------------------------------------------------------------

def abstract_train_state(cfg: ModelConfig, tcfg: TrainConfig) -> Dict:
    params = abstract_model(cfg)
    f32 = lambda t: jax.tree.map(lambda x: _sds(x.shape, jnp.float32), t)
    state = {
        "params": params,
        "opt": {
            "step": _sds((), jnp.int32),
            "m": f32(params),
            "v": f32(params),
            "master": f32(params),
        },
    }
    if tcfg.grad_compression:
        state["residual"] = f32(params)
    return state


def state_shardings(cfg: ModelConfig, tcfg: TrainConfig, mesh: Mesh) -> Dict:
    pspecs = pspec_tree(model_decls(cfg), mesh)
    named = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                         is_leaf=lambda x: hasattr(x, "index"))
    rep = NamedSharding(mesh, logical_spec((), mesh))
    state = {
        "params": named,
        "opt": {"step": rep, "m": named, "v": named, "master": named},
    }
    if tcfg.grad_compression:
        state["residual"] = named
    return state


# OptState is a dataclass pytree; rebuild it from the dict spec trees.
def opt_state_like(d: Dict):
    from repro.train.optimizer import OptState

    return OptState(step=d["step"], m=d["m"], v=d["v"], master=d["master"])


# ---------------------------------------------------------------------------
# Decode caches
# ---------------------------------------------------------------------------

_CACHE_AXES = {
    # kind -> {leaf: logical axes (unstacked)}
    "attn": {"k": ("batch", None, "seq", None), "v": ("batch", None, "seq", None)},
    "rglru": {"h": ("batch", "tensor"), "conv": ("batch", None, "tensor")},
    "mlstm": {"C": ("batch", None, None, None), "n": ("batch", None, None),
              "m": ("batch", None)},
    "slstm": {"c": ("batch", "tensor"), "n": ("batch", "tensor"),
              "h": ("batch", "tensor"), "m": ("batch", "tensor")},
}


def cache_specs(cfg: ModelConfig, shape: ShapeConfig) -> Any:
    """Abstract decode cache (eval_shape over the real initializer)."""
    gb, s = shape.global_batch, shape.seq_len
    if cfg.is_encoder_decoder:
        self_cache = jax.eval_shape(
            lambda: ed.init_self_cache(gb, cfg, s)
        )
        enc_len = s  # encoder length == assigned seq
        kvshape = (cfg.n_layers, gb, cfg.n_kv_heads, enc_len, cfg.head_dim)
        cross = {"k": _sds(kvshape, cfg.dtype), "v": _sds(kvshape, cfg.dtype)}
        return {"self": self_cache, "cross": cross}
    return jax.eval_shape(lambda: tfm.init_decode_cache(gb, cfg, s))


def cache_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> Any:
    """Shardings matching cache_specs' structure (shape-sanitized: axes
    that do not divide a dim — e.g. batch=1 long-context cells — drop)."""
    specs = cache_specs(cfg, shape)

    def kind_shardings(kind: str, spec_tree: Dict, stacked: bool):
        table = _CACHE_AXES[kind]
        return {
            leaf: sharding_for(
                spec_tree[leaf].shape,
                ((None,) + table[leaf]) if stacked else table[leaf],
                mesh,
            )
            for leaf in spec_tree
        }

    if cfg.is_encoder_decoder:
        kv_ax = _CACHE_AXES["attn"]
        return {
            part: {
                leaf: sharding_for(
                    specs[part][leaf].shape, (None,) + kv_ax[leaf], mesh
                )
                for leaf in specs[part]
            }
            for part in ("self", "cross")
        }

    pattern, n_full, tail = tfm.layer_split(cfg)
    out: Dict[str, Any] = {"cyc": {}, "tail": {}}
    if n_full:
        for i, kind in enumerate(pattern):
            out["cyc"][str(i)] = kind_shardings(kind, specs["cyc"][str(i)], True)
    for i, kind in enumerate(tail):
        out["tail"][str(i)] = kind_shardings(kind, specs["tail"][str(i)], False)
    return out


# ---------------------------------------------------------------------------
# Microbatching heuristic (activation-memory driven)
# ---------------------------------------------------------------------------

def microbatches_for(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> int:
    """Pick grad-accum count so each microbatch has <=2 sequences per
    data shard (bounds remat-saved activation memory)."""
    from repro.sharding.partition import mesh_axis_size

    dp = mesh_axis_size(mesh, "batch")
    per_dev = max(shape.global_batch // max(dp, 1), 1)
    k = max(per_dev // 2, 1)
    while shape.global_batch % (k * 1) and k > 1:  # keep divisibility
        k -= 1
    while k > 1 and (shape.global_batch // k) % 1:
        k -= 1
    # ensure global batch divides k
    while k > 1 and shape.global_batch % k:
        k -= 1
    return k
