"""End-to-end training driver (LM archs + the ConvCoTM itself).

CPU-scale example:  PYTHONPATH=src python -m repro.launch.train \
    --arch h2o-danube-1.8b --reduced --steps 20 --batch 8 --seq 128

The same driver is what a production job runs: build mesh -> shard state
-> jit train_step with NamedShardings -> run with checkpoint/restart and
straggler monitoring (distributed/fault_tolerance).
"""

from __future__ import annotations

import argparse
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer, latest_step
from repro.configs import TrainConfig, get_config, reduced_config
from repro.distributed.fault_tolerance import StragglerPolicy
from repro.launch import specs as S
from repro.models.base import init_params, param_count, pspec_tree
from repro.sharding.partition import sharding_for
from repro.train.train_step import init_train_state, make_train_step

__all__ = ["run_training", "synthetic_lm_batch"]


def _token_stream(rng, batch: int, seq: int, vocab: int, noise: float = 0.05):
    """LEARNABLE synthetic stream: ascending runs (successor rule with
    random restarts) plus noise — uniform-random tokens would put the loss
    floor at ln(V) and nothing could train."""
    starts = rng.integers(0, vocab, batch)
    ramp = starts[:, None] + np.arange(seq)[None, :]
    restart = rng.random((batch, seq)) < 0.02
    offsets = np.cumsum(restart * rng.integers(1, vocab, (batch, seq)), axis=1)
    toks = (ramp + offsets) % vocab
    flip = rng.random((batch, seq)) < noise
    toks = np.where(flip, rng.integers(0, vocab, (batch, seq)), toks)
    return jnp.asarray(toks, jnp.int32)


def synthetic_lm_batch(cfg, batch: int, seq: int, step: int) -> Dict[str, Any]:
    """Deterministic synthetic batch (offline container)."""
    rng = np.random.default_rng(1234 + step)
    if cfg.is_encoder_decoder:
        return {
            "frontend_embeds": jnp.asarray(
                rng.standard_normal((batch, seq, cfg.d_model)), cfg.dtype
            ),
            "dec_tokens": _token_stream(rng, batch, max(seq // 4, 16), cfg.vocab_size),
        }
    out = {"tokens": _token_stream(rng, batch, seq, cfg.vocab_size)}
    if cfg.modality == "vision":
        nv = max(seq // 4, 4)
        out["tokens"] = out["tokens"][:, : seq - nv]
        out["frontend_embeds"] = jnp.asarray(
            rng.standard_normal((batch, nv, cfg.d_model)), cfg.dtype
        )
    return out


def run_training(
    cfg,
    tcfg: TrainConfig,
    mesh,
    *,
    batch: int,
    seq: int,
    steps: int,
    ckpt_dir: str | None = None,
    log_every: int = 5,
    batch_fn=None,
) -> Dict[str, float]:
    """Train loop with checkpoint/resume + straggler policy. Returns final
    metrics."""
    batch_fn = batch_fn or (lambda step: synthetic_lm_batch(cfg, batch, seq, step))
    key = jax.random.PRNGKey(tcfg.seed)
    decls = S.model_decls(cfg)
    with mesh:
        params = init_params(decls, key)
        state = init_train_state(params, tcfg)
        step_fn = jax.jit(make_train_step(cfg, tcfg, mesh=mesh), donate_argnums=(0,))

        start = 0
        ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
        if ckpt and latest_step(ckpt_dir) is not None:
            state, start, extra = ckpt.restore(state)
            print(f"resumed from step {start}")

        policy = StragglerPolicy()
        metrics = {}
        first_loss = None
        for step in range(start, steps):
            t0 = time.time()
            state, metrics = step_fn(state, batch_fn(step))
            jax.block_until_ready(metrics["loss"])
            if first_loss is None:
                first_loss = float(metrics["loss"])
            dt = time.time() - t0
            verdict = policy.observe(dt)
            if verdict != "ok":
                print(f"[straggler-policy] step {step}: {verdict} ({dt:.2f}s)")
            if step % log_every == 0 or step == steps - 1:
                print(
                    f"step {step:5d} loss {float(metrics['loss']):.4f} "
                    f"gnorm {float(metrics['grad_norm']):.3f} "
                    f"lr {float(metrics['lr']):.2e} {dt:.2f}s"
                )
            if ckpt and (step + 1) % tcfg.checkpoint_every == 0:
                ckpt.save(state, step + 1)
        if ckpt:
            ckpt.save(state, steps)
            ckpt.wait()
    out = {k: float(v) for k, v in metrics.items()}
    out["first_loss"] = first_loss if first_loss is not None else float("nan")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--grad-compression", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    tcfg = TrainConfig(
        learning_rate=args.lr,
        total_steps=args.steps,
        warmup_steps=max(args.steps // 10, 1),
        microbatches=args.microbatches,
        grad_compression=args.grad_compression,
        checkpoint_every=max(args.steps // 2, 1),
    )
    from repro.sharding.partition import single_device_mesh

    mesh = single_device_mesh()
    n = param_count(S.model_decls(cfg))
    print(f"arch={cfg.name} params={n/1e6:.1f}M devices={mesh.size}")
    run_training(
        cfg, tcfg, mesh, batch=args.batch, seq=args.seq, steps=args.steps,
        ckpt_dir=args.ckpt_dir,
    )


if __name__ == "__main__":
    main()
