"""End-to-end training driver (LM archs + the ConvCoTM itself).

CPU-scale examples:

    PYTHONPATH=src python -m repro.launch.train \
        --arch h2o-danube-1.8b --reduced --steps 20 --batch 8 --seq 128

    PYTHONPATH=src python -m repro.launch.train \
        --arch convcotm-mnist --epochs 5 --batch 100

LM archs: build mesh -> shard state -> jit train_step with NamedShardings
-> run with checkpoint/restart and straggler monitoring
(distributed/fault_tolerance).  ConvCoTM archs (the paper's accelerator)
train through ``repro.train.tm_engine.TrainerEngine`` — dataset literals
frozen once, jitted lax.scan epochs, checkpointed model + pipeline cursor.
"""

from __future__ import annotations

import argparse
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer, latest_step
from repro.configs import TrainConfig, get_config, reduced_config
from repro.distributed.fault_tolerance import StragglerPolicy
from repro.launch import specs as S
from repro.models.base import init_params, param_count
from repro.train.train_step import init_train_state, make_train_step

__all__ = ["run_training", "run_tm_training", "synthetic_lm_batch"]


def _token_stream(rng, batch: int, seq: int, vocab: int, noise: float = 0.05):
    """LEARNABLE synthetic stream: ascending runs (successor rule with
    random restarts) plus noise — uniform-random tokens would put the loss
    floor at ln(V) and nothing could train."""
    starts = rng.integers(0, vocab, batch)
    ramp = starts[:, None] + np.arange(seq)[None, :]
    restart = rng.random((batch, seq)) < 0.02
    offsets = np.cumsum(restart * rng.integers(1, vocab, (batch, seq)), axis=1)
    toks = (ramp + offsets) % vocab
    flip = rng.random((batch, seq)) < noise
    toks = np.where(flip, rng.integers(0, vocab, (batch, seq)), toks)
    return jnp.asarray(toks, jnp.int32)


def synthetic_lm_batch(cfg, batch: int, seq: int, step: int) -> Dict[str, Any]:
    """Deterministic synthetic batch (offline container)."""
    rng = np.random.default_rng(1234 + step)
    if cfg.is_encoder_decoder:
        return {
            "frontend_embeds": jnp.asarray(
                rng.standard_normal((batch, seq, cfg.d_model)), cfg.dtype
            ),
            "dec_tokens": _token_stream(rng, batch, max(seq // 4, 16), cfg.vocab_size),
        }
    out = {"tokens": _token_stream(rng, batch, seq, cfg.vocab_size)}
    if cfg.modality == "vision":
        nv = max(seq // 4, 4)
        out["tokens"] = out["tokens"][:, : seq - nv]
        out["frontend_embeds"] = jnp.asarray(
            rng.standard_normal((batch, nv, cfg.d_model)), cfg.dtype
        )
    return out


def run_training(
    cfg,
    tcfg: TrainConfig,
    mesh,
    *,
    batch: int,
    seq: int,
    steps: int,
    ckpt_dir: str | None = None,
    log_every: int = 5,
    batch_fn=None,
) -> Dict[str, float]:
    """Train loop with checkpoint/resume + straggler policy. Returns final
    metrics."""
    batch_fn = batch_fn or (lambda step: synthetic_lm_batch(cfg, batch, seq, step))
    key = jax.random.PRNGKey(tcfg.seed)
    decls = S.model_decls(cfg)
    with mesh:
        params = init_params(decls, key)
        state = init_train_state(params, tcfg)
        step_fn = jax.jit(make_train_step(cfg, tcfg, mesh=mesh), donate_argnums=(0,))

        start = 0
        ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
        if ckpt and latest_step(ckpt_dir) is not None:
            state, start, extra = ckpt.restore(state)
            print(f"resumed from step {start}")

        policy = StragglerPolicy()
        metrics = {}
        first_loss = None
        for step in range(start, steps):
            t0 = time.time()
            state, metrics = step_fn(state, batch_fn(step))
            jax.block_until_ready(metrics["loss"])
            if first_loss is None:
                first_loss = float(metrics["loss"])
            dt = time.time() - t0
            verdict = policy.observe(dt)
            if verdict != "ok":
                print(f"[straggler-policy] step {step}: {verdict} ({dt:.2f}s)")
            if step % log_every == 0 or step == steps - 1:
                print(
                    f"step {step:5d} loss {float(metrics['loss']):.4f} "
                    f"gnorm {float(metrics['grad_norm']):.3f} "
                    f"lr {float(metrics['lr']):.2e} {dt:.2f}s"
                )
            if ckpt and (step + 1) % tcfg.checkpoint_every == 0:
                ckpt.save(state, step + 1)
        if ckpt:
            ckpt.save(state, steps)
            ckpt.wait()
    out = {k: float(v) for k, v in metrics.items()}
    out["first_loss"] = first_loss if first_loss is not None else float("nan")
    return out


def run_tm_training(
    arch: str,
    *,
    epochs: int = 5,
    batch: int = 100,
    mode: str = "batch",
    n_train: int = 4000,
    n_test: int = 800,
    ckpt_dir: str | None = None,
    seed: int = 0,
) -> Dict[str, float]:
    """Train a ConvCoTM arch through the TrainerEngine (checkpoint/resume).

    The same driver shape as ``run_training``: restore (model + pipeline
    cursor + PRNG key) if a checkpoint exists, run jitted epochs up to the
    requested ``epochs`` total, checkpoint after every epoch, report
    accuracy and samples/s.  A restarted job finishes the run — it does
    not train ``epochs`` additional epochs — and continues the exact key
    chain an uninterrupted run would have used.
    """
    from repro.configs.convcotm import BOOLEANIZE_METHOD, COTM_CONFIGS
    from repro.data import PipelineState, get_dataset
    from repro.train.tm_engine import TrainerEngine

    cfg = COTM_CONFIGS[arch]
    method = BOOLEANIZE_METHOD[arch]
    dataset = arch.split("-", 1)[1]               # convcotm-mnist -> mnist
    tx, ty, vx, vy, source = get_dataset(dataset, n_train=n_train, n_test=n_test)
    print(f"{arch}: dataset source {source} ({len(tx)} train / {len(vx)} test)")

    engine = TrainerEngine(cfg, batch_size=batch, mode=mode)
    train_ds = engine.prepare(tx, ty, booleanize_method=method)
    eval_ds = engine.prepare(vx, vy, booleanize_method=method)

    key = jax.random.PRNGKey(seed)
    model = engine.init_model(key)
    state = PipelineState(seed=seed)
    trainer_meta = {"batch_size": batch, "mode": mode, "seed": seed}
    if ckpt_dir and latest_step(ckpt_dir) is not None:
        from repro.checkpoint.checkpointer import restore_pytree

        model, step, extra = restore_pytree(model, ckpt_dir)
        # Missing metadata is unknown provenance, not a match — default
        # to None so such checkpoints fail the guard rather than pass it.
        saved = extra.get("trainer")
        if saved != trainer_meta:
            # Different batch/mode/seed changes steps-per-epoch and the
            # per-step key chain — the run would no longer be equivalent
            # to any uninterrupted run.
            raise ValueError(
                f"checkpoint at {ckpt_dir} was trained with {saved}; "
                f"resuming with {trainer_meta} would break the key-chain "
                f"contract — restart with matching flags or a fresh dir"
            )
        state = PipelineState.from_dict(extra["pipeline"])
        key = jnp.asarray(np.asarray(extra["key"], np.uint32))
        print(f"{arch}: resumed from epoch {state.epoch} (step {step})")

    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
    reports = []
    while state.epoch < epochs:
        key, model, state, reps = engine.fit(
            key, model, train_ds, epochs=1, eval_ds=eval_ds, state=state,
            log=lambda s: print(f"{arch}: {s}"),
        )
        reports.extend(reps)
        if ckpt:
            ckpt.save(
                model,
                state.epoch,
                extra={
                    "pipeline": state.as_dict(),
                    "key": np.asarray(key).tolist(),
                    "trainer": trainer_meta,
                },
            )
    if ckpt:
        ckpt.wait()
    if not reports:
        print(f"{arch}: checkpoint already at epoch {state.epoch} >= {epochs}")
        return {
            "accuracy": engine.evaluate(model, eval_ds),
            "samples_per_s": 0.0,
            "epochs": float(state.epoch),
        }
    last = reports[-1]
    return {
        "accuracy": last.accuracy if last.accuracy is not None else float("nan"),
        "samples_per_s": last.samples_per_s,
        "epochs": float(state.epoch),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    # per-arch default resolved after parsing: 8 for LM, 100 for ConvCoTM
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--grad-compression", action="store_true")
    # ConvCoTM (TrainerEngine) flags
    ap.add_argument("--epochs", type=int, default=5)
    ap.add_argument("--mode", default="batch", choices=["batch", "scan"])
    args = ap.parse_args()

    from repro.configs.convcotm import COTM_CONFIGS

    if args.arch in COTM_CONFIGS:
        out = run_tm_training(
            args.arch,
            epochs=args.epochs,
            batch=args.batch if args.batch is not None else 100,
            mode=args.mode,
            ckpt_dir=args.ckpt_dir,
        )
        print(
            f"final: acc {out['accuracy']:.4f} "
            f"{out['samples_per_s']:,.0f} samples/s"
        )
        return

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    tcfg = TrainConfig(
        learning_rate=args.lr,
        total_steps=args.steps,
        warmup_steps=max(args.steps // 10, 1),
        microbatches=args.microbatches,
        grad_compression=args.grad_compression,
        checkpoint_every=max(args.steps // 2, 1),
    )
    from repro.sharding.partition import single_device_mesh

    mesh = single_device_mesh()
    n = param_count(S.model_decls(cfg))
    print(f"arch={cfg.name} params={n/1e6:.1f}M devices={mesh.size}")
    run_training(
        cfg, tcfg, mesh,
        batch=args.batch if args.batch is not None else 8,
        seq=args.seq, steps=args.steps,
        ckpt_dir=args.ckpt_dir,
    )


if __name__ == "__main__":
    main()
