from repro.models.base import (
    ParamDecl,
    abstract_params,
    init_params,
    param_bytes,
    param_count,
    pspec_tree,
)

__all__ = [
    "ParamDecl",
    "abstract_params",
    "init_params",
    "param_bytes",
    "param_count",
    "pspec_tree",
]
