"""Attention: full / sliding-window / local, GQA, chunked memory-bounded
softmax, and single-token KV-cache decode (with ring buffers for windowed
caches so long_500k decode stores only the window).

Shapes: activations [B, S, D]; heads [B, S, H, hd]; caches [B, KV, S, hd].
All softmax math in fp32.  The query axis is processed in chunks with
``lax.scan`` so the [S, S] score matrix never materializes for 32k
sequences (peak score memory = chunk x S per head group).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.base import ParamDecl
from repro.models.layers import mrope, rope

__all__ = [
    "attention_decls",
    "attention_apply",
    "decode_attention",
    "init_kv_cache",
    "chunked_attention",
]

NEG_INF = -2.0e38


def attention_decls(cfg: ModelConfig, cross: bool = False) -> Dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = cfg.dtype
    return {
        "wq": ParamDecl((d, h * hd), ("fsdp", "tensor"), dtype=dt),
        "wk": ParamDecl((d, kv * hd), ("fsdp", "tensor"), dtype=dt),
        "wv": ParamDecl((d, kv * hd), ("fsdp", "tensor"), dtype=dt),
        "wo": ParamDecl((h * hd, d), ("tensor", "fsdp"), dtype=dt),
    }


def _split_heads(x: jax.Array, n: int, hd: int) -> jax.Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n, hd)


def _mask_bias(
    qpos: jax.Array,          # [Sq] absolute query positions
    kpos: jax.Array,          # [Sk] absolute key positions
    causal: bool,
    window: Optional[int],
) -> jax.Array:
    """Additive fp32 bias [Sq, Sk]: 0 where visible, NEG_INF where masked."""
    ok = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        ok &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        ok &= qpos[:, None] - kpos[None, :] < window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _sdpa(
    q: jax.Array,             # [B, KV, G, Sq, hd]
    k: jax.Array,             # [B, KV, Sk, hd]
    v: jax.Array,             # [B, KV, Sk, hd]
    bias: jax.Array,          # [Sq, Sk]
) -> jax.Array:
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bkgqh,bksh->bkgqs", q, k, preferred_element_type=jnp.float32)
    scores = scores * scale + bias[None, None, None]
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bkgqs,bksh->bkgqh", w.astype(v.dtype), v)


def chunked_attention(
    q: jax.Array,             # [B, H, Sq, hd]
    k: jax.Array,             # [B, KV, Sk, hd]
    v: jax.Array,             # [B, KV, Sk, hd]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    chunk: int = 512,
) -> jax.Array:
    """Memory-bounded attention: scan over query chunks.

    Returns [B, H, Sq, hd].  ``q_offset`` is the absolute position of q[0]
    (used by prefill continuation).  GQA grouping is derived from H vs KV.
    """
    b, h, sq, hd = q.shape
    kvh = k.shape[1]
    g = h // kvh
    qg = q.reshape(b, kvh, g, sq, hd)
    kpos = jnp.arange(k.shape[2])

    chunk = min(chunk, sq)
    if sq % chunk:
        chunk = sq  # fall back to single chunk for ragged sizes
    nc = sq // chunk
    if nc == 1:
        qpos = q_offset + jnp.arange(sq)
        out = _sdpa(qg, k, v, _mask_bias(qpos, kpos, causal, window))
        return out.reshape(b, h, sq, hd)

    qc = qg.reshape(b, kvh, g, nc, chunk, hd)
    qc = jnp.moveaxis(qc, 3, 0)                       # [nc, B, KV, G, chunk, hd]

    def body(_, xs):
        qb, ci = xs
        qpos = q_offset + ci * chunk + jnp.arange(chunk)
        return None, _sdpa(qb, k, v, _mask_bias(qpos, kpos, causal, window))

    _, outs = jax.lax.scan(body, None, (qc, jnp.arange(nc)))
    outs = jnp.moveaxis(outs, 0, 3)                   # [B, KV, G, nc, chunk, hd]
    return outs.reshape(b, h, sq, hd)


def attention_apply(
    p: Dict,
    x: jax.Array,                       # [B, S, D]
    cfg: ModelConfig,
    positions: jax.Array,               # [B, S] or [3, B, S] for M-RoPE
    *,
    causal: bool = True,
    window: Optional[int] = None,
    use_rope: bool = True,
    kv_source: Optional[jax.Array] = None,   # cross-attention encoder output
    chunk: int = 512,
) -> jax.Array:
    """Train/prefill attention (no cache)."""
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _split_heads(x @ p["wq"], h, hd)
    src = x if kv_source is None else kv_source
    k = _split_heads(src @ p["wk"], kv, hd)
    vv = _split_heads(src @ p["wv"], kv, hd)
    if use_rope and kv_source is None:
        if cfg.mrope_sections is not None:
            q = mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
    out = chunked_attention(
        jnp.moveaxis(q, 1, 2),
        jnp.moveaxis(k, 1, 2),
        jnp.moveaxis(vv, 1, 2),
        causal=causal and kv_source is None,
        window=window,
        chunk=chunk,
    )
    out = jnp.moveaxis(out, 1, 2).reshape(x.shape[0], x.shape[1], h * hd)
    return out @ p["wo"]


# ---------------------------------------------------------------------------
# Decode path (KV cache)
# ---------------------------------------------------------------------------

def cache_len(cfg: ModelConfig, max_seq: int) -> int:
    """Ring-buffer length: SWA/local archs only ever keep the window."""
    win = cfg.sliding_window or cfg.local_window
    if win is not None:
        return min(win, max_seq)
    return max_seq


def init_kv_cache(
    batch: int, cfg: ModelConfig, max_seq: int, n_layers: int
) -> Dict[str, jax.Array]:
    """Stacked-over-layers cache {k, v}: [L, B, KV, S_cache, hd]."""
    s = cache_len(cfg, max_seq)
    shape = (n_layers, batch, cfg.n_kv_heads, s, cfg.head_dim)
    return {"k": jnp.zeros(shape, cfg.dtype), "v": jnp.zeros(shape, cfg.dtype)}


def decode_attention(
    p: Dict,
    x: jax.Array,                       # [B, 1, D] current token activations
    cache_k: jax.Array,                 # [B, KV, S_cache, hd]
    cache_v: jax.Array,
    pos: jax.Array,                     # scalar int32 — current position
    cfg: ModelConfig,
    *,
    window: Optional[int] = None,
    positions_3d: Optional[jax.Array] = None,  # [3, B, 1] for M-RoPE decode
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step; returns (out [B, 1, D], new_k, new_v).

    Windowed caches are ring buffers (slot = pos % cache_len); full caches
    write at slot = pos.  Masking recovers absolute key positions from slot
    indices, so both layouts share one code path.
    """
    b = x.shape[0]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s_cache = cache_k.shape[2]

    q = _split_heads(x @ p["wq"], h, hd)              # [B, 1, H, hd]
    k = _split_heads(x @ p["wk"], kv, hd)
    v = _split_heads(x @ p["wv"], kv, hd)
    posb = jnp.broadcast_to(pos, (b, 1))
    if cfg.mrope_sections is not None:
        p3 = positions_3d
        if p3 is None:
            p3 = jnp.broadcast_to(pos, (3, b, 1))
        q = mrope(q, p3, cfg.rope_theta, cfg.mrope_sections)
        k = mrope(k, p3, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = rope(q, posb, cfg.rope_theta)
        k = rope(k, posb, cfg.rope_theta)

    slot = jax.lax.rem(pos, s_cache)
    k_t = jnp.moveaxis(k, 1, 2)                       # [B, KV, 1, hd]
    v_t = jnp.moveaxis(v, 1, 2)
    new_k = jax.lax.dynamic_update_slice(cache_k, k_t.astype(cache_k.dtype), (0, 0, slot, 0))
    new_v = jax.lax.dynamic_update_slice(cache_v, v_t.astype(cache_v.dtype), (0, 0, slot, 0))

    # Absolute position of each ring slot given current write pos.
    slots = jnp.arange(s_cache)
    base = pos - slot                                  # start of current wrap
    abs_pos = jnp.where(slots <= slot, base + slots, base - s_cache + slots)
    ok = (abs_pos >= 0) & (abs_pos <= pos)
    if window is not None:
        ok &= pos - abs_pos < window
    bias = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)  # [S_cache]

    g = h // kv
    qg = jnp.moveaxis(q, 1, 2).reshape(b, kv, g, 1, hd)
    out = _sdpa(qg, new_k, new_v, bias[None, :])
    out = jnp.moveaxis(out.reshape(b, kv * g, 1, hd), 1, 2).reshape(b, 1, h * hd)
    return out @ p["wo"], new_k, new_v
