"""Declarative parameter system (pure JAX, no flax).

Modules describe their parameters as a nested dict of :class:`ParamDecl`
(shape, dtype, init, logical sharding axes).  Generic walkers turn the
declaration tree into

  * real initialized arrays      (``init_params``),
  * ShapeDtypeStructs            (``abstract_params`` — used by the
    dry-run so no host memory is allocated for 42 B-parameter models),
  * PartitionSpecs for a mesh    (``pspec_tree`` / ``sharding_tree``).

Apply functions are plain functions ``f(params, x, cfg, ...)``; the tree
structure of ``params`` mirrors the declaration tree 1:1.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.partition import spec as logical_spec

__all__ = [
    "ParamDecl",
    "init_params",
    "abstract_params",
    "pspec_tree",
    "param_count",
    "param_bytes",
]


@dataclasses.dataclass(frozen=True)
class ParamDecl:
    """One parameter: shape, dtype, init scheme, logical sharding axes."""

    shape: Tuple[int, ...]
    axes: Tuple[Any, ...]                 # logical axes, len == ndim
    dtype: Any = jnp.bfloat16
    init: str = "normal"                  # normal | zeros | ones | embed
    scale: Optional[float] = None         # stddev override

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes}")


def _is_decl(x) -> bool:
    return isinstance(x, ParamDecl)


def _map_decls(fn: Callable[[ParamDecl], Any], tree: Any) -> Any:
    if _is_decl(tree):
        return fn(tree)
    if isinstance(tree, dict):
        return {k: _map_decls(fn, v) for k, v in tree.items()}
    raise TypeError(f"decl trees are nested dicts of ParamDecl, got {type(tree)}")


def _init_one(decl: ParamDecl, key: jax.Array) -> jax.Array:
    if decl.init == "zeros":
        return jnp.zeros(decl.shape, decl.dtype)
    if decl.init == "ones":
        return jnp.ones(decl.shape, decl.dtype)
    fan_in = decl.shape[0] if decl.shape else 1
    if decl.init == "embed":
        std = decl.scale if decl.scale is not None else 1.0
    else:
        std = decl.scale if decl.scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, decl.shape, jnp.float32) * std).astype(decl.dtype)


def init_params(decls: Dict, key: jax.Array) -> Dict:
    """Initialize real arrays for a declaration tree."""
    leaves = []

    def collect(tree, path):
        if _is_decl(tree):
            leaves.append((path, tree))
        else:
            for k in sorted(tree):
                collect(tree[k], path + (k,))

    collect(decls, ())
    keys = jax.random.split(key, max(len(leaves), 1))
    arrays = {path: _init_one(d, k) for (path, d), k in zip(leaves, keys)}

    def build(tree, path):
        if _is_decl(tree):
            return arrays[path]
        return {k: build(tree[k], path + (k,)) for k in tree}

    return build(decls, ())


def abstract_params(decls: Dict) -> Dict:
    """ShapeDtypeStructs (dry-run: no allocation)."""
    return _map_decls(lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), decls)


def pspec_tree(decls: Dict, mesh) -> Dict:
    """PartitionSpec tree for ``mesh`` (same structure as params).

    Dims whose size is not divisible by the product of the mapped mesh
    axes are left unsharded (e.g. seamless's 256 206 vocab on a 16-way
    tensor axis) — jit input shardings require exact divisibility.
    """
    from jax.sharding import PartitionSpec as P

    def one(d: ParamDecl):
        spec = logical_spec(d.axes, mesh)
        fixed = []
        for dim, axes in zip(d.shape, spec):
            if axes is None:
                fixed.append(None)
                continue
            ax_tuple = axes if isinstance(axes, tuple) else (axes,)
            size = 1
            for a in ax_tuple:
                size *= mesh.shape[a]
            fixed.append(axes if dim % size == 0 else None)
        return P(*fixed)

    return _map_decls(one, decls)


def param_count(decls: Dict) -> int:
    n = 0

    def add(d: ParamDecl):
        nonlocal n
        n += int(np.prod(d.shape)) if d.shape else 1

    _map_decls(lambda d: add(d), decls)
    return n


def param_bytes(decls: Dict) -> int:
    n = 0

    def add(d: ParamDecl):
        nonlocal n
        n += int(np.prod(d.shape)) * jnp.dtype(d.dtype).itemsize

    _map_decls(lambda d: add(d), decls)
    return n
