"""Encoder-decoder backbone (SeamlessM4T-large-v2's transformer core).

The modality frontend (speech feature extractor) is a stub per the brief:
``input_specs()`` supplies precomputed frame embeddings [B, S_enc, d] for
the encoder.  The decoder is a standard causal transformer with
cross-attention; decode uses a self-attention ring cache plus a
precomputed cross-attention K/V cache.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.layers import (
    embed_decls,
    embed_lookup,
    lm_logits,
    mlp,
    mlp_decls,
    rmsnorm,
    rmsnorm_decls,
)
from repro.models.transformer import _stack_decls
from repro.sharding.partition import shard

__all__ = [
    "encdec_decls",
    "encdec_forward",
    "encdec_loss",
    "encode",
    "prepare_cross_cache",
    "init_self_cache",
    "encdec_decode_step",
]


def _enc_layer_decls(cfg: ModelConfig) -> Dict:
    return {
        "attn_norm": rmsnorm_decls(cfg.d_model),
        "attn": attn.attention_decls(cfg),
        "mlp_norm": rmsnorm_decls(cfg.d_model),
        "mlp": mlp_decls(cfg.d_model, cfg.d_ff, cfg.dtype),
    }


def _dec_layer_decls(cfg: ModelConfig) -> Dict:
    return {
        "self_norm": rmsnorm_decls(cfg.d_model),
        "self_attn": attn.attention_decls(cfg),
        "cross_norm": rmsnorm_decls(cfg.d_model),
        "cross_attn": attn.attention_decls(cfg, cross=True),
        "mlp_norm": rmsnorm_decls(cfg.d_model),
        "mlp": mlp_decls(cfg.d_model, cfg.d_ff, cfg.dtype),
    }


def encdec_decls(cfg: ModelConfig) -> Dict:
    n_enc = cfg.n_encoder_layers or cfg.n_layers
    return {
        "embed": embed_decls(cfg),
        "enc": _stack_decls(_enc_layer_decls(cfg), n_enc),
        "enc_norm": rmsnorm_decls(cfg.d_model),
        "dec": _stack_decls(_dec_layer_decls(cfg), cfg.n_layers),
        "dec_norm": rmsnorm_decls(cfg.d_model),
    }


def encode(
    params: Dict, frontend_embeds: jax.Array, cfg: ModelConfig, *, mesh=None,
    remat: bool = True,
) -> jax.Array:
    """Bidirectional encoder over stub frame embeddings [B, S_enc, d]."""
    x = frontend_embeds.astype(cfg.dtype)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if mesh is not None:
        x = shard(x, ("batch", None, None), mesh)

    def body(x, lp):
        h = rmsnorm(lp["attn_norm"], x, cfg.norm_eps)
        x = x + attn.attention_apply(lp["attn"], h, cfg, positions, causal=False)
        h = rmsnorm(lp["mlp_norm"], x, cfg.norm_eps)
        x = x + mlp(lp["mlp"], h)
        if mesh is not None:
            x = shard(x, ("batch", None, None), mesh)
        return x, None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc"])
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def encdec_forward(
    params: Dict,
    frontend_embeds: jax.Array,
    dec_tokens: jax.Array,
    cfg: ModelConfig,
    *,
    mesh=None,
    remat: bool = True,
) -> jax.Array:
    """Returns decoder hidden states [B, S_dec, d]."""
    enc_out = encode(params, frontend_embeds, cfg, mesh=mesh, remat=remat)
    x = embed_lookup(params["embed"], dec_tokens)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if mesh is not None:
        x = shard(x, ("batch", None, None), mesh)

    def body(x, lp):
        h = rmsnorm(lp["self_norm"], x, cfg.norm_eps)
        x = x + attn.attention_apply(lp["self_attn"], h, cfg, positions, causal=True)
        h = rmsnorm(lp["cross_norm"], x, cfg.norm_eps)
        x = x + attn.attention_apply(
            lp["cross_attn"], h, cfg, positions, kv_source=enc_out
        )
        h = rmsnorm(lp["mlp_norm"], x, cfg.norm_eps)
        x = x + mlp(lp["mlp"], h)
        if mesh is not None:
            x = shard(x, ("batch", None, None), mesh)
        return x, None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, params["dec"])
    return rmsnorm(params["dec_norm"], x, cfg.norm_eps)


def encdec_loss(
    params: Dict,
    frontend_embeds: jax.Array,
    dec_tokens: jax.Array,
    cfg: ModelConfig,
    *,
    mesh=None,
    loss_chunk: int = 1024,
    remat: bool = True,
) -> jax.Array:
    hidden = encdec_forward(
        params, frontend_embeds, dec_tokens, cfg, mesh=mesh, remat=remat
    )
    inputs = hidden[:, :-1]
    targets = dec_tokens[:, 1:]
    head = params["embed"]["tok"].T if cfg.tie_embeddings else params["embed"]["head"]
    logits = (inputs @ head).astype(jnp.float32)
    if mesh is not None:
        logits = shard(logits, ("batch", None, "tensor"), mesh)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - tgt)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def prepare_cross_cache(
    params: Dict, enc_out: jax.Array, cfg: ModelConfig
) -> Dict[str, jax.Array]:
    """Precompute per-layer cross-attention K/V: [L, B, KV, S_enc, hd]."""
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    b, s, _ = enc_out.shape

    def per_layer(lp):
        k = (enc_out @ lp["cross_attn"]["wk"]).reshape(b, s, kv, hd)
        v = (enc_out @ lp["cross_attn"]["wv"]).reshape(b, s, kv, hd)
        return jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2)

    ks, vs = jax.vmap(per_layer)(params["dec"])
    return {"k": ks, "v": vs}


def init_self_cache(batch: int, cfg: ModelConfig, max_seq: int) -> Dict:
    return attn.init_kv_cache(batch, cfg, max_seq, cfg.n_layers)


def encdec_decode_step(
    params: Dict,
    tokens: jax.Array,           # [B, 1]
    self_cache: Dict,            # {k, v}: [L, B, KV, S_cache, hd]
    cross_cache: Dict,           # {k, v}: [L, B, KV, S_enc, hd]
    pos: jax.Array,
    cfg: ModelConfig,
    *,
    mesh=None,
) -> Tuple[jax.Array, Dict]:
    x = embed_lookup(params["embed"], tokens)
    h_heads, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    b = x.shape[0]

    def body(x, xs):
        lp, ck, cv, xk, xv = xs
        h = rmsnorm(lp["self_norm"], x, cfg.norm_eps)
        y, nk, nv = attn.decode_attention(lp["self_attn"], h, ck, cv, pos, cfg)
        x = x + y
        # Cross attention against the fixed encoder K/V.
        h = rmsnorm(lp["cross_norm"], x, cfg.norm_eps)
        q = (h @ lp["cross_attn"]["wq"]).reshape(b, 1, h_heads, hd)
        g = h_heads // kvh
        qg = jnp.moveaxis(q, 1, 2).reshape(b, kvh, g, 1, hd)
        bias = jnp.zeros((1, xk.shape[2]), jnp.float32)
        o = attn._sdpa(qg, xk, xv, bias)
        o = o.reshape(b, h_heads, 1, hd)
        o = jnp.moveaxis(o, 1, 2).reshape(b, 1, h_heads * hd)
        x = x + o @ lp["cross_attn"]["wo"]
        h = rmsnorm(lp["mlp_norm"], x, cfg.norm_eps)
        x = x + mlp(lp["mlp"], h)
        return x, {"k": nk, "v": nv}

    x, new_self = jax.lax.scan(
        body,
        x,
        (params["dec"], self_cache["k"], self_cache["v"], cross_cache["k"], cross_cache["v"]),
    )
    x = rmsnorm(params["dec_norm"], x, cfg.norm_eps)
    logits = lm_logits(params["embed"], x[:, 0], cfg).astype(jnp.float32)
    if mesh is not None:
        logits = shard(logits, ("batch", "tensor"), mesh)
    return logits, new_self
