"""Shared neural-net layers (pure-JAX functional, ParamDecl-declared)."""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.base import ParamDecl

__all__ = [
    "rmsnorm_decls",
    "rmsnorm",
    "rope",
    "mrope",
    "mlp_decls",
    "mlp",
    "embed_decls",
    "embed_lookup",
    "softcap",
]


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_decls(d: int) -> Dict:
    return {"scale": ParamDecl((d,), (None,), init="ones", dtype=jnp.float32)}


def rmsnorm(p: Dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * p["scale"]
    return y.astype(dt)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    """Gemma-style logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (GPT-NeoX half-rotation convention)
# ---------------------------------------------------------------------------

def _rope_angles(positions: jax.Array, dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions [...] -> (sin, cos) [..., dim/2] in fp32."""
    freqs = jnp.exp(
        -jnp.log(theta) * jnp.arange(0, dim, 2, dtype=jnp.float32) / dim
    )
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def _apply_rot(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x [..., hd]; sin/cos broadcastable [..., hd/2]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1
    ).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Standard RoPE. x [B, S, H, hd]; positions [B, S] (or [S])."""
    if positions.ndim == 1:
        positions = positions[None]
    sin, cos = _rope_angles(positions, x.shape[-1], theta)      # [B, S, hd/2]
    return _apply_rot(x, sin[:, :, None, :], cos[:, :, None, :])


def mrope(
    x: jax.Array,
    positions: jax.Array,
    theta: float,
    sections: Tuple[int, int, int],
) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    Args:
      x: [B, S, H, hd].
      positions: [3, B, S] — temporal / height / width position ids (all
        equal for pure text).
      sections: per-axis number of *pairs*; sums to hd/2 (e.g. (16, 24, 24)
        for hd=128).
    """
    hd = x.shape[-1]
    if sum(sections) != hd // 2:
        raise ValueError(f"mrope sections {sections} != head_dim/2 = {hd // 2}")
    sins, coss = [], []
    for i, sec in enumerate(sections):
        # Each section s uses its own position stream but the global freq
        # table slice [offset : offset+sec] — matching HF's implementation.
        s, c = _rope_angles(positions[i], hd, theta)             # [B, S, hd/2]
        off = sum(sections[:i])
        sins.append(s[..., off : off + sec])
        coss.append(c[..., off : off + sec])
    sin = jnp.concatenate(sins, axis=-1)
    cos = jnp.concatenate(coss, axis=-1)
    return _apply_rot(x, sin[:, :, None, :], cos[:, :, None, :])


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU/GeGLU)
# ---------------------------------------------------------------------------

def mlp_decls(d: int, ff: int, dtype=jnp.bfloat16) -> Dict:
    return {
        "w_gate": ParamDecl((d, ff), ("fsdp", "tensor"), dtype=dtype),
        "w_up": ParamDecl((d, ff), ("fsdp", "tensor"), dtype=dtype),
        "w_down": ParamDecl((ff, d), ("tensor", "fsdp"), dtype=dtype),
    }


def mlp(p: Dict, x: jax.Array, activation: str = "silu") -> jax.Array:
    g = x @ p["w_gate"]
    u = x @ p["w_up"]
    act = jax.nn.silu if activation == "silu" else jax.nn.gelu
    return (act(g) * u) @ p["w_down"]


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def embed_decls(cfg: ModelConfig) -> Dict:
    d = {
        "tok": ParamDecl(
            (cfg.vocab_size, cfg.d_model), ("tensor", "fsdp"),
            dtype=cfg.dtype, init="embed", scale=0.02,
        )
    }
    if not cfg.tie_embeddings:
        d["head"] = ParamDecl(
            (cfg.d_model, cfg.vocab_size), ("fsdp", "tensor"), dtype=cfg.dtype
        )
    return d


def embed_lookup(p: Dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["tok"], tokens, axis=0)


def lm_logits(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return x @ p["tok"].T
    return x @ p["head"]
