"""Mixture-of-experts layer: top-k routing, capacity-bounded einsum
dispatch (Switch-style, GSPMD-friendly), optional always-on shared experts
(Qwen2-MoE) and load-balancing auxiliary loss.

Expert sharding (see ARCHITECTURE.md §Substrate): if the expert count divides the tensor
axis (Phi-3.5-MoE: 16 experts on a 16-way "model" axis) the expert dim is
sharded over "model" — true expert parallelism, the dispatch einsum lowers
to an all-to-all.  Otherwise (Qwen2-MoE: 60 experts) experts are kept
whole and their ff dim is tensor-sharded.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.base import ParamDecl

__all__ = ["moe_decls", "moe_apply"]

TENSOR_AXIS_SIZE = 16  # production mesh "model" axis; only affects layout


def _expert_axes(cfg: ModelConfig) -> Tuple:
    if cfg.n_experts % TENSOR_AXIS_SIZE == 0:
        return ("expert", "fsdp", None)       # expert parallelism
    return (None, "fsdp", "tensor")           # tensor-parallel experts


def moe_decls(cfg: ModelConfig) -> Dict:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ax = _expert_axes(cfg)
    dt = cfg.dtype
    decls = {
        "router": ParamDecl((d, e), (None, None), dtype=jnp.float32, scale=0.02),
        "w_gate": ParamDecl((e, d, ff), ax, dtype=dt),
        "w_up": ParamDecl((e, d, ff), ax, dtype=dt),
        "w_down": ParamDecl((e, ff, d), (ax[0], ax[2], ax[1]), dtype=dt),
    }
    if cfg.n_shared_experts:
        ffs = cfg.d_ff_shared or cfg.d_ff * cfg.n_shared_experts
        decls.update(
            {
                "shared_gate": ParamDecl((d, ffs), ("fsdp", "tensor"), dtype=dt),
                "shared_up": ParamDecl((d, ffs), ("fsdp", "tensor"), dtype=dt),
                "shared_down": ParamDecl((ffs, d), ("tensor", "fsdp"), dtype=dt),
                "shared_mix": ParamDecl((d, 1), (None, None), dtype=jnp.float32),
            }
        )
    return decls


def moe_apply(p: Dict, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Returns (y [B, S, d], aux_loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.n_experts_per_token
    t = b * s
    tg = min(cfg.router_group_size, t)
    if t % tg:
        tg = t
    g = t // tg
    xf = x.reshape(g, tg, d)

    logits = (xf.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # [G,Tg,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)                              # [G,Tg,k]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # Load-balancing aux loss (Switch): E * mean_e(frac_tokens_e * mean_prob_e).
    sel_onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)                # [G,Tg,k,E]
    frac = jnp.mean(jnp.sum(sel_onehot, axis=2), axis=(0, 1))             # [E]
    mean_p = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac / k * mean_p)

    cap = max(4, int(tg * k / e * cfg.capacity_factor))
    # Position of each (token, k) assignment within its expert, per group.
    flat_sel = sel_onehot.reshape(g, tg * k, e)
    pos = jnp.cumsum(flat_sel, axis=1) * flat_sel - 1.0                   # [G,Tg*k,E]
    pos = pos.reshape(g, tg, k, e)
    within = (pos >= 0) & (pos < cap)
    pos_oh = (
        jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)
        * within[..., None]
    )

    # dispatch [G,Tg,E,C] (0/1); combine adds the gate weight.
    dispatch = jnp.sum(pos_oh, axis=2)
    combine = jnp.einsum("gsk,gske,gskec->gsec", gate_vals, sel_onehot, pos_oh)

    xe = jnp.einsum("gsec,gsd->egcd", dispatch.astype(x.dtype), xf)       # [E,G,C,d]
    xe = xe.reshape(e, g * cap, d)
    h = jnp.einsum("etd,edf->etf", xe, p["w_gate"])
    u = jnp.einsum("etd,edf->etf", xe, p["w_up"])
    ye = jnp.einsum("etf,efd->etd", jax.nn.silu(h) * u, p["w_down"])
    ye = ye.reshape(e, g, cap, d)
    y = jnp.einsum("gsec,egcd->gsd", combine.astype(x.dtype), ye)

    if cfg.n_shared_experts:
        sh = jax.nn.silu(xf @ p["shared_gate"]) * (xf @ p["shared_up"])
        sh = sh @ p["shared_down"]
        mix = jax.nn.sigmoid(xf.astype(jnp.float32) @ p["shared_mix"])
        y = y + (mix.astype(x.dtype) * sh)

    return y.reshape(b, s, d), aux
