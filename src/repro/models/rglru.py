"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

The Real-Gated Linear Recurrent Unit:

    r_t = sigmoid(W_a x_t)                 (recurrence gate)
    i_t = sigmoid(W_x x_t)                 (input gate)
    a_t = a^(c * r_t)        with a = sigmoid(Lambda), c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

It is a *diagonal* linear recurrence, so training uses
``jax.lax.associative_scan`` over (a_t, b_t) pairs — O(log S) depth — and
decode is a one-step update.  The full residual block is:
conv1d(W_x branch) -> RG-LRU -> gated (gelu) merge -> out projection,
as in the Griffin recurrent block.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.base import ParamDecl
from repro.models.layers import rmsnorm, rmsnorm_decls

__all__ = [
    "rglru_decls",
    "rglru_apply",
    "rglru_decode",
    "rglru_init_state",
]

_C = 8.0
_MAX_LOG = -8.0  # softplus-parameterized min decay (Griffin's Lambda init)


def _lru_width(cfg: ModelConfig) -> int:
    return cfg.rglru_lru_width or cfg.d_model


def rglru_decls(cfg: ModelConfig) -> Dict:
    d, w = cfg.d_model, _lru_width(cfg)
    dt = cfg.dtype
    return {
        "norm": rmsnorm_decls(d),
        "w_x": ParamDecl((d, w), ("fsdp", "tensor"), dtype=dt),
        "w_gate": ParamDecl((d, w), ("fsdp", "tensor"), dtype=dt),
        "conv_w": ParamDecl((cfg.conv_width, w), (None, "tensor"), dtype=dt, scale=0.1),
        "conv_b": ParamDecl((w,), ("tensor",), dtype=dt, init="zeros"),
        "gate_a": ParamDecl((w, w), ("fsdp", "tensor"), dtype=dt, scale=0.02),
        "gate_x": ParamDecl((w, w), ("fsdp", "tensor"), dtype=dt, scale=0.02),
        "lambda_p": ParamDecl((w,), (None,), dtype=jnp.float32, init="ones"),
        "w_out": ParamDecl((w, d), ("tensor", "fsdp"), dtype=dt),
    }


def rglru_init_state(batch: int, cfg: ModelConfig) -> Dict[str, jax.Array]:
    w = _lru_width(cfg)
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), jnp.float32),
    }


def _log_a(p: Dict, gx: jax.Array) -> jax.Array:
    """log a_t = c * r_t * log sigmoid(Lambda); fp32, strictly negative."""
    r = jax.nn.sigmoid(gx)
    log_a_base = jax.nn.log_sigmoid(_MAX_LOG * jax.nn.softplus(p["lambda_p"]))
    return _C * r * log_a_base[None]


def _conv1d(p: Dict, x: jax.Array, history: jax.Array | None) -> jax.Array:
    """Causal depthwise conv over time. x [B, S, W]; history [B, cw-1, W]."""
    cw = p["conv_w"].shape[0]
    if history is None:
        history = jnp.zeros((x.shape[0], cw - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([history.astype(x.dtype), x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1]] * p["conv_w"][i][None, None] for i in range(cw)
    )
    return out + p["conv_b"][None, None]


def rglru_apply(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Full-sequence recurrent block: [B, S, d] -> [B, S, d] (residual in)."""
    b, s, d = x.shape
    w = _lru_width(cfg)
    xn = rmsnorm(p["norm"], x, cfg.norm_eps)
    u = _conv1d(p, xn @ p["w_x"], None)                     # [B,S,W]
    gate = jax.nn.gelu(xn @ p["w_gate"])

    uf = u.astype(jnp.float32)
    log_a = _log_a(p, uf @ p["gate_a"].astype(jnp.float32))  # [B,S,W]
    ig = jax.nn.sigmoid(uf @ p["gate_x"].astype(jnp.float32))
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    bt = beta * ig * uf

    # Diagonal linear recurrence h_t = a_t h_{t-1} + b_t via associative scan.
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_sc, h = jax.lax.associative_scan(combine, (a, bt), axis=1)
    y = (h.astype(x.dtype) * gate) @ p["w_out"]
    return x + y


def rglru_decode(
    p: Dict, x: jax.Array, state: Dict[str, jax.Array], cfg: ModelConfig
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token step. x [B, 1, d] -> (y [B, 1, d], new state)."""
    xn = rmsnorm(p["norm"], x, cfg.norm_eps)
    ux = xn @ p["w_x"]                                       # [B,1,W]
    u = _conv1d(p, ux, state["conv"])
    new_conv = jnp.concatenate(
        [state["conv"][:, 1:], ux.astype(jnp.float32)], axis=1
    )
    gate = jax.nn.gelu(xn @ p["w_gate"])

    uf = u.astype(jnp.float32)[:, 0]
    log_a = _log_a(p, uf @ p["gate_a"].astype(jnp.float32))
    ig = jax.nn.sigmoid(uf @ p["gate_x"].astype(jnp.float32))
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    h_new = a * state["h"] + beta * ig * uf
    y = (h_new[:, None].astype(x.dtype) * gate) @ p["w_out"]
    return x + y, {"h": h_new, "conv": new_conv}
