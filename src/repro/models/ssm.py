"""xLSTM blocks (Beck et al., arXiv:2405.04517): mLSTM and sLSTM.

mLSTM — matrix-memory LSTM with exponential gating.  Training uses the
*chunkwise-parallel* form (quadratic within a chunk, linear across chunks
with a carried (C, n, m) state and log-space stabilization), so the scan
length is seq/chunk instead of seq; decode uses the exact single-step
recurrence.  Cell (per head):

    m_t = max(lf_t + m_{t-1}, i_t)
    C_t = exp(lf_t + m_{t-1} - m_t) C_{t-1} + exp(i_t - m_t) k_t v_t^T
    n_t = exp(lf_t + m_{t-1} - m_t) n_{t-1} + exp(i_t - m_t) k_t
    h_t = C_t^T q_t / max(|n_t . q_t|, exp(-m_t))

sLSTM — scalar-memory LSTM with exponential gating and per-head
block-diagonal recurrence; inherently sequential (lax.scan over time).

Block layout follows the paper: mLSTM blocks are pre-up-projection
(proj_factor x) with a gated residual; sLSTM blocks post-project with a
gated FFN.  The assigned xlstm-350m config has d_ff=0, meaning all FFN
capacity lives inside the blocks (proj_factor).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.base import ParamDecl
from repro.models.layers import rmsnorm, rmsnorm_decls

__all__ = [
    "mlstm_decls",
    "mlstm_apply",
    "mlstm_decode",
    "mlstm_init_state",
    "slstm_decls",
    "slstm_apply",
    "slstm_decode",
    "slstm_init_state",
]


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def _mlstm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    up = int(cfg.d_model * cfg.proj_factor)
    h = cfg.n_heads
    return up, h, up // h


def mlstm_decls(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    up, h, hd = _mlstm_dims(cfg)
    dt = cfg.dtype
    return {
        "norm": rmsnorm_decls(d),
        "w_up": ParamDecl((d, up), ("fsdp", "tensor"), dtype=dt),
        "w_gate": ParamDecl((d, up), ("fsdp", "tensor"), dtype=dt),
        "wq": ParamDecl((up, up), ("fsdp", "tensor"), dtype=dt),
        "wk": ParamDecl((up, up), ("fsdp", "tensor"), dtype=dt),
        "wv": ParamDecl((up, up), ("fsdp", "tensor"), dtype=dt),
        "w_if": ParamDecl((up, 2 * h), (None, None), dtype=jnp.float32, scale=0.02),
        "b_if": ParamDecl((2 * h,), (None,), dtype=jnp.float32, init="zeros"),
        "out_norm": rmsnorm_decls(up),
        "w_down": ParamDecl((up, d), ("tensor", "fsdp"), dtype=dt),
    }


def mlstm_init_state(batch: int, cfg: ModelConfig) -> Dict[str, jax.Array]:
    _, h, hd = _mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, h, hd), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
    }


def _mlstm_chunk_scan(
    q: jax.Array,   # [B, H, S, hd]   (already scaled)
    k: jax.Array,
    v: jax.Array,
    ig: jax.Array,  # [B, H, S] log input gate (pre-activation)
    lf: jax.Array,  # [B, H, S] log forget gate (logsigmoid(f_pre))
    state: Dict[str, jax.Array],
    chunk: int,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    b, h, s, hd = q.shape
    chunk = min(chunk, s)
    if s % chunk:
        chunk = s
    nc = s // chunk

    def to_chunks(x):
        return jnp.moveaxis(x.reshape(b, h, nc, chunk, *x.shape[3:]), 2, 0)

    qc, kc, vc, igc, lfc = map(to_chunks, (q, k, v, ig, lf))

    idx = jnp.arange(chunk)
    tri = idx[:, None] >= idx[None, :]                       # causal within chunk

    def body(carry, xs):
        C, n, m = carry                                       # [B,H,hd,hd],[B,H,hd],[B,H]
        qb, kb, vb, igb, lfb = xs
        bsum = jnp.cumsum(lfb, axis=-1)                       # [B,H,L] inclusive
        btot = bsum[..., -1]                                  # [B,H]
        # log weight of source k contributing to target j (within chunk):
        #   a_{jk} = bsum_j - bsum_k + ig_k   (k <= j)
        a = bsum[..., :, None] - bsum[..., None, :] + igb[..., None, :]
        a = jnp.where(tri[None, None], a, -jnp.inf)
        m_local = jnp.max(a, axis=-1)                         # [B,H,L]
        m_j = jnp.maximum(bsum + m[..., None], m_local)       # stabilizer per target
        d = jnp.exp(a - m_j[..., None])                       # [B,H,L,L]
        g_inter = jnp.exp(bsum + m[..., None] - m_j)          # [B,H,L]

        scores = jnp.einsum("bhld,bhmd->bhlm", qb, kb, preferred_element_type=jnp.float32)
        intra = jnp.einsum("bhlm,bhmd->bhld", scores * d, vb.astype(jnp.float32))
        inter = jnp.einsum("bhld,bhde->bhle", qb.astype(jnp.float32), C)
        num = inter * g_inter[..., None] + intra

        norm_inter = jnp.einsum("bhld,bhd->bhl", qb.astype(jnp.float32), n)
        # intra normalizer: sum_k d_{jk} (q_j . k_k)
        norm_intra = jnp.sum(scores * d, axis=-1)
        denom = jnp.maximum(
            jnp.abs(norm_inter * g_inter + norm_intra), jnp.exp(-m_j)
        )
        hout = (num / denom[..., None]).astype(qb.dtype)

        # State update to chunk end.
        m_k = btot[..., None] - bsum + igb                    # [B,H,L]
        m_new = jnp.maximum(btot + m, jnp.max(m_k, axis=-1))
        w_old = jnp.exp(btot + m - m_new)                     # [B,H]
        w_k = jnp.exp(m_k - m_new[..., None])                 # [B,H,L]
        kw = kb.astype(jnp.float32) * w_k[..., None]
        C_new = C * w_old[..., None, None] + jnp.einsum("bhld,bhle->bhde", kw, vb.astype(jnp.float32))
        n_new = n * w_old[..., None] + jnp.sum(kw, axis=2)
        return (C_new, n_new, m_new), hout

    carry = (state["C"], state["n"], state["m"])
    carry, outs = jax.lax.scan(body, carry, (qc, kc, vc, igc, lfc))
    out = jnp.moveaxis(outs, 0, 2).reshape(b, h, s, hd)
    return out, {"C": carry[0], "n": carry[1], "m": carry[2]}


def _mlstm_qkv(p: Dict, xn: jax.Array, cfg: ModelConfig):
    up, h, hd = _mlstm_dims(cfg)
    bsz = xn.shape[0]
    s = xn.shape[1]
    u = xn @ p["w_up"]                                        # [B,S,up]
    q = (u @ p["wq"]).reshape(bsz, s, h, hd) * (hd ** -0.5)
    k = (u @ p["wk"]).reshape(bsz, s, h, hd) * (hd ** -0.5)
    v = (u @ p["wv"]).reshape(bsz, s, h, hd)
    gates = u.astype(jnp.float32) @ p["w_if"] + p["b_if"]     # [B,S,2H]
    ig = gates[..., :h]
    lf = jax.nn.log_sigmoid(gates[..., h:])
    tr = lambda x: jnp.moveaxis(x, 1, 2)                      # -> [B,H,S,...]
    return u, tr(q), tr(k), tr(v), jnp.moveaxis(ig, 1, 2), jnp.moveaxis(lf, 1, 2)


def mlstm_apply(
    p: Dict, x: jax.Array, cfg: ModelConfig, chunk: int = 64
) -> jax.Array:
    """Full-sequence mLSTM block: [B, S, d] -> [B, S, d] (residual inside)."""
    up, h, hd = _mlstm_dims(cfg)
    b, s, d = x.shape
    xn = rmsnorm(p["norm"], x, cfg.norm_eps)
    u, q, k, v, ig, lf = _mlstm_qkv(p, xn, cfg)
    state = mlstm_init_state(b, cfg)
    hseq, _ = _mlstm_chunk_scan(q, k, v, ig, lf, state, chunk)
    hseq = jnp.moveaxis(hseq, 1, 2).reshape(b, s, up)
    hseq = rmsnorm(p["out_norm"], hseq, cfg.norm_eps)
    gate = jax.nn.silu(xn @ p["w_gate"])
    return x + (hseq * gate) @ p["w_down"]


def mlstm_decode(
    p: Dict, x: jax.Array, state: Dict[str, jax.Array], cfg: ModelConfig
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token mLSTM step. x [B, 1, d] -> (y [B, 1, d], new state)."""
    up, h, hd = _mlstm_dims(cfg)
    b = x.shape[0]
    xn = rmsnorm(p["norm"], x, cfg.norm_eps)
    u, q, k, v, ig, lf = _mlstm_qkv(p, xn, cfg)
    q, k, v = (t[:, :, 0].astype(jnp.float32) for t in (q, k, v))   # [B,H,hd]
    ig, lf = ig[:, :, 0], lf[:, :, 0]                               # [B,H]

    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(lf + m, ig)
    wf = jnp.exp(lf + m - m_new)
    wi = jnp.exp(ig - m_new)
    C_new = C * wf[..., None, None] + wi[..., None, None] * k[..., :, None] * v[..., None, :]
    n_new = n * wf[..., None] + wi[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C_new)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n_new)), jnp.exp(-m_new))
    hvec = (num / denom[..., None]).reshape(b, 1, up).astype(x.dtype)
    hvec = rmsnorm(p["out_norm"], hvec, cfg.norm_eps)
    gate = jax.nn.silu(xn @ p["w_gate"])
    y = x + (hvec * gate) @ p["w_down"]
    return y, {"C": C_new, "n": n_new, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_decls(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    dt = cfg.dtype
    decls = {
        "norm": rmsnorm_decls(d),
        # input projections for z, i, f, o (fused)
        "w_in": ParamDecl((d, 4 * d), ("fsdp", "tensor"), dtype=dt),
        # block-diagonal recurrence per head: [H, hd, 4*hd]
        "r_rec": ParamDecl(
            (cfg.n_heads, d // cfg.n_heads, 4 * (d // cfg.n_heads)),
            (None, None, None), dtype=jnp.float32, scale=0.02,
        ),
        "b": ParamDecl((4 * d,), (None,), dtype=jnp.float32, init="zeros"),
        "out_norm": rmsnorm_decls(d),
    }
    if cfg.d_ff:
        from repro.models.layers import mlp_decls

        decls["ffn"] = mlp_decls(d, cfg.d_ff, dt)
        decls["ffn_norm"] = rmsnorm_decls(d)
    return decls


def slstm_init_state(batch: int, cfg: ModelConfig) -> Dict[str, jax.Array]:
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.zeros((batch, d), jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
        "m": jnp.full((batch, d), -1e30, jnp.float32),
    }


def _slstm_cell(p, state, x_proj, cfg: ModelConfig):
    """One sLSTM step. x_proj [B, 4d] precomputed input projection."""
    d = cfg.d_model
    h_heads = state["h"].reshape(-1, cfg.n_heads, d // cfg.n_heads)
    rec = jnp.einsum("bhd,hde->bhe", h_heads, p["r_rec"])     # [B,H,4hd]
    rec = rec.reshape(-1, 4 * d)
    pre = x_proj.astype(jnp.float32) + rec + p["b"]
    z, i_pre, f_pre, o = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(z)
    o = jax.nn.sigmoid(o)
    lf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(lf + state["m"], i_pre)
    i = jnp.exp(i_pre - m_new)
    f = jnp.exp(lf + state["m"] - m_new)
    c_new = f * state["c"] + i * z
    n_new = f * state["n"] + i
    h_new = o * c_new / jnp.maximum(jnp.abs(n_new), 1.0)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def slstm_apply(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Full-sequence sLSTM block (sequential scan over time)."""
    b, s, d = x.shape
    xn = rmsnorm(p["norm"], x, cfg.norm_eps)
    xp = xn @ p["w_in"]                                       # [B,S,4d]
    state = slstm_init_state(b, cfg)

    def body(st, xt):
        st = _slstm_cell(p, st, xt, cfg)
        return st, st["h"]

    _, hs = jax.lax.scan(body, state, jnp.moveaxis(xp, 0, 1))
    hseq = jnp.moveaxis(hs, 0, 1).astype(x.dtype)             # [B,S,d]
    y = x + rmsnorm(p["out_norm"], hseq, cfg.norm_eps)
    if "ffn" in p:
        from repro.models.layers import mlp

        y = y + mlp(p["ffn"], rmsnorm(p["ffn_norm"], y, cfg.norm_eps))
    return y


def slstm_decode(
    p: Dict, x: jax.Array, state: Dict[str, jax.Array], cfg: ModelConfig
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    xn = rmsnorm(p["norm"], x, cfg.norm_eps)
    xp = (xn @ p["w_in"])[:, 0]
    st = _slstm_cell(p, state, xp, cfg)
    y = x + rmsnorm(p["out_norm"], st["h"][:, None].astype(x.dtype), cfg.norm_eps)
    if "ffn" in p:
        from repro.models.layers import mlp

        y = y + mlp(p["ffn"], rmsnorm(p["ffn_norm"], y, cfg.norm_eps))
    return y, st
