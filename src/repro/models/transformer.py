"""Decoder-only LM assembler.

Supports every assigned decoder-only family through ``cfg.block_pattern``:
pure attention (dense/MoE archs, pattern None -> all 'attn'), xLSTM
('mlstm'/'slstm'), and RecurrentGemma hybrids ('rglru' + 'attn').

Layer stacking: the pattern is cycled; full cycles are stacked and run
under one rematerialized ``lax.scan`` (HLO stays one-cycle-sized no matter
the depth), remainder layers run unrolled with their own params.  The same
cycles+tail structure threads the decode caches.

Params, caches, and pspecs all share the tree:
    {embed, layers: {cyc: {pos: stacked-decls}, tail: {i: decls}}, final_norm}
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.base import ParamDecl
from repro.models.layers import (
    embed_decls,
    embed_lookup,
    lm_logits,
    mlp,
    mlp_decls,
    rmsnorm,
    rmsnorm_decls,
    softcap,
)
from repro.sharding.partition import shard

__all__ = [
    "model_decls",
    "forward",
    "lm_loss",
    "init_decode_cache",
    "decode_step",
    "layer_split",
]


# ---------------------------------------------------------------------------
# Declarations
# ---------------------------------------------------------------------------

def _block_decls(kind: str, cfg: ModelConfig) -> Dict:
    if kind == "attn":
        d = {
            "attn_norm": rmsnorm_decls(cfg.d_model),
            "attn": attn.attention_decls(cfg),
            "mlp_norm": rmsnorm_decls(cfg.d_model),
        }
        if cfg.is_moe:
            d["moe"] = moe_mod.moe_decls(cfg)
        else:
            d["mlp"] = mlp_decls(cfg.d_model, cfg.d_ff, cfg.dtype)
        return d
    if kind == "rglru":
        return {
            "rglru": rglru_mod.rglru_decls(cfg),
            "mlp_norm": rmsnorm_decls(cfg.d_model),
            "mlp": mlp_decls(cfg.d_model, cfg.d_ff, cfg.dtype),
        }
    if kind == "mlstm":
        return {"mlstm": ssm_mod.mlstm_decls(cfg)}
    if kind == "slstm":
        return {"slstm": ssm_mod.slstm_decls(cfg)}
    raise ValueError(f"unknown block kind {kind}")


def _stack_decls(tree: Any, n: int) -> Any:
    if isinstance(tree, ParamDecl):
        return ParamDecl(
            (n,) + tree.shape, (None,) + tree.axes, dtype=tree.dtype,
            init=tree.init, scale=tree.scale,
        )
    return {k: _stack_decls(v, n) for k, v in tree.items()}


def layer_split(cfg: ModelConfig) -> Tuple[Tuple[str, ...], int, Tuple[str, ...]]:
    """(pattern, n_full_cycles, tail_kinds)."""
    pattern = cfg.block_pattern or ("attn",)
    lp = len(pattern)
    n_full = cfg.n_layers // lp
    tail = tuple(pattern[i] for i in range(cfg.n_layers - n_full * lp))
    return pattern, n_full, tail


def model_decls(cfg: ModelConfig) -> Dict:
    pattern, n_full, tail = layer_split(cfg)
    layers: Dict[str, Any] = {"cyc": {}, "tail": {}}
    if n_full:
        for i, kind in enumerate(pattern):
            layers["cyc"][str(i)] = _stack_decls(_block_decls(kind, cfg), n_full)
    for i, kind in enumerate(tail):
        layers["tail"][str(i)] = _block_decls(kind, cfg)
    return {
        "embed": embed_decls(cfg),
        "layers": layers,
        "final_norm": rmsnorm_decls(cfg.d_model),
    }


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _attn_window(cfg: ModelConfig) -> Optional[int]:
    return cfg.sliding_window or cfg.local_window


def _block_apply(
    kind: str,
    p: Dict,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    mesh,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "attn":
        if cfg.use_parallel_block and not cfg.is_moe:
            # PaLM-style parallel attention+MLP: both branches read one
            # norm and their partial-sum outputs merge under a SINGLE
            # tensor-parallel all-reduce per layer (GSPMD fuses the two
            # partial reductions after the add) — §Perf iteration.
            h = rmsnorm(p["attn_norm"], x, cfg.norm_eps)
            a = attn.attention_apply(
                p["attn"], h, cfg, positions, window=_attn_window(cfg)
            )
            x = x + a + mlp(p["mlp"], h)
        else:
            h = rmsnorm(p["attn_norm"], x, cfg.norm_eps)
            x = x + attn.attention_apply(
                p["attn"], h, cfg, positions, window=_attn_window(cfg)
            )
            h = rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
            if cfg.is_moe:
                y, aux = moe_mod.moe_apply(p["moe"], h, cfg)
                x = x + y
            else:
                x = x + mlp(p["mlp"], h)
    elif kind == "rglru":
        x = rglru_mod.rglru_apply(p["rglru"], x, cfg)
        h = rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
        x = x + mlp(p["mlp"], h, activation="gelu")
    elif kind == "mlstm":
        x = ssm_mod.mlstm_apply(p["mlstm"], x, cfg)
    elif kind == "slstm":
        x = ssm_mod.slstm_apply(p["slstm"], x, cfg)
    else:
        raise ValueError(kind)
    if mesh is not None:
        x = shard(x, ("batch", None, None), mesh)
    return x, aux


def forward(
    params: Dict,
    tokens: Optional[jax.Array],
    cfg: ModelConfig,
    *,
    mesh=None,
    positions: Optional[jax.Array] = None,
    frontend_embeds: Optional[jax.Array] = None,
    remat: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Token ids (and/or frontend embeds) -> (hidden [B, S, d], aux loss).

    ``frontend_embeds`` [B, S_f, d] are prepended to the token embeddings
    (the stub modality frontends of the audio/VLM archs).
    """
    parts = []
    if frontend_embeds is not None:
        parts.append(frontend_embeds.astype(cfg.dtype))
    if tokens is not None:
        parts.append(embed_lookup(params["embed"], tokens))
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        if cfg.mrope_sections is not None:
            positions = jnp.broadcast_to(positions, (3, b, s))
    if mesh is not None:
        x = shard(x, ("batch", None, None), mesh)

    pattern, n_full, tail = layer_split(cfg)
    aux_total = jnp.zeros((), jnp.float32)

    if n_full:
        def cycle_body(carry, cyc_params):
            x, aux = carry
            for i, kind in enumerate(pattern):
                x, a = _block_apply(kind, cyc_params[str(i)], x, cfg, positions, mesh)
                aux = aux + a
            return (x, aux), None

        body = jax.checkpoint(cycle_body) if remat else cycle_body
        (x, aux_total), _ = jax.lax.scan(
            body, (x, aux_total), params["layers"]["cyc"]
        )
    for i, kind in enumerate(tail):
        x, a = _block_apply(
            kind, params["layers"]["tail"][str(i)], x, cfg, positions, mesh
        )
        aux_total = aux_total + a

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux_total


def lm_loss(
    params: Dict,
    tokens: jax.Array,
    cfg: ModelConfig,
    *,
    mesh=None,
    loss_chunk: int = 1024,
    frontend_embeds: Optional[jax.Array] = None,
    remat: bool = True,
) -> jax.Array:
    """Next-token cross entropy, computed in sequence chunks so the
    [B, S, vocab] logits never materialize (vocab up to 256 k)."""
    hidden, aux = forward(
        params, tokens, cfg, mesh=mesh, frontend_embeds=frontend_embeds,
        remat=remat,
    )
    # Align: predict token t+1 from hidden t over the *token* region only.
    off = hidden.shape[1] - tokens.shape[1]
    hidden = hidden[:, off:, :]
    inputs = hidden[:, :-1]
    targets = tokens[:, 1:]
    b, sm1, d = inputs.shape
    chunk = min(loss_chunk, sm1)
    if sm1 % chunk:
        chunk = sm1
    nc = sm1 // chunk
    head = params["embed"]["tok"].T if cfg.tie_embeddings else params["embed"]["head"]

    def body(acc, xs):
        h, t = xs                                    # [B, chunk, d], [B, chunk]
        logits = (h @ head).astype(jnp.float32)
        logits = softcap(logits, cfg.logit_softcap)
        if mesh is not None:
            logits = shard(logits, ("batch", None, "tensor"), mesh)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - tgt), None

    hc = jnp.moveaxis(inputs.reshape(b, nc, chunk, d), 1, 0)
    tc = jnp.moveaxis(targets.reshape(b, nc, chunk), 1, 0)
    body = jax.checkpoint(body) if remat else body
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, tc))
    loss = total / (b * sm1)
    return loss + 0.01 * aux


# ---------------------------------------------------------------------------
# Decode (single-token serve step)
# ---------------------------------------------------------------------------

def _block_cache(kind: str, batch: int, cfg: ModelConfig, max_seq: int):
    if kind == "attn":
        s = attn.cache_len(cfg, max_seq)
        shape = (batch, cfg.n_kv_heads, s, cfg.head_dim)
        return {"k": jnp.zeros(shape, cfg.dtype), "v": jnp.zeros(shape, cfg.dtype)}
    if kind == "rglru":
        return rglru_mod.rglru_init_state(batch, cfg)
    if kind == "mlstm":
        return ssm_mod.mlstm_init_state(batch, cfg)
    if kind == "slstm":
        return ssm_mod.slstm_init_state(batch, cfg)
    raise ValueError(kind)


def init_decode_cache(batch: int, cfg: ModelConfig, max_seq: int) -> Dict:
    """Cache pytree mirroring the cycles+tail layer structure."""
    pattern, n_full, tail = layer_split(cfg)

    def stack(tree):
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_full,) + a.shape).copy(), tree
        )

    cache: Dict[str, Any] = {"cyc": {}, "tail": {}}
    if n_full:
        for i, kind in enumerate(pattern):
            cache["cyc"][str(i)] = stack(_block_cache(kind, batch, cfg, max_seq))
    for i, kind in enumerate(tail):
        cache["tail"][str(i)] = _block_cache(kind, batch, cfg, max_seq)
    return cache


def _block_decode(
    kind: str, p: Dict, x: jax.Array, cache, pos, cfg: ModelConfig
):
    if kind == "attn":
        h = rmsnorm(p["attn_norm"], x, cfg.norm_eps)
        y, nk, nv = attn.decode_attention(
            p["attn"], h, cache["k"], cache["v"], pos, cfg,
            window=_attn_window(cfg),
        )
        x = x + y
        h = rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
        if cfg.is_moe:
            y, _ = moe_mod.moe_apply(p["moe"], h, cfg)
            x = x + y
        else:
            x = x + mlp(p["mlp"], h)
        return x, {"k": nk, "v": nv}
    if kind == "rglru":
        x, st = rglru_mod.rglru_decode(p["rglru"], x, cache, cfg)
        h = rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
        return x + mlp(p["mlp"], h, activation="gelu"), st
    if kind == "mlstm":
        return ssm_mod.mlstm_decode(p["mlstm"], x, cache, cfg)
    if kind == "slstm":
        return ssm_mod.slstm_decode(p["slstm"], x, cache, cfg)
    raise ValueError(kind)


def decode_step(
    params: Dict,
    tokens: jax.Array,            # [B, 1] current token ids
    cache: Dict,
    pos: jax.Array,               # scalar int32 current position
    cfg: ModelConfig,
    *,
    mesh=None,
) -> Tuple[jax.Array, Dict]:
    """One serve step: returns (logits [B, vocab], new cache)."""
    x = embed_lookup(params["embed"], tokens)
    if mesh is not None:
        x = shard(x, ("batch", None, None), mesh)
    pattern, n_full, tail = layer_split(cfg)
    new_cache: Dict[str, Any] = {"cyc": {}, "tail": {}}

    if n_full:
        def cycle_body(x, xs):
            cyc_params, cyc_cache = xs
            new_c = {}
            for i, kind in enumerate(pattern):
                x, new_c[str(i)] = _block_decode(
                    kind, cyc_params[str(i)], x, cyc_cache[str(i)], pos, cfg
                )
            return x, new_c

        x, new_cache["cyc"] = jax.lax.scan(
            cycle_body, x, (params["layers"]["cyc"], cache["cyc"])
        )
    for i, kind in enumerate(tail):
        x, new_cache["tail"][str(i)] = _block_decode(
            kind, params["layers"]["tail"][str(i)], x, cache["tail"][str(i)], pos, cfg
        )

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_logits(params["embed"], x[:, 0], cfg)
    logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    if mesh is not None:
        logits = shard(logits, ("batch", "tensor"), mesh)
    return logits, new_cache
