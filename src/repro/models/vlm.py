"""Qwen2-VL backbone helpers (M-RoPE position ids + stub vision frontend).

Per the brief, ``[vlm]`` entries specify the transformer backbone only; the
vision tower is a stub — ``input_specs()`` supplies precomputed patch
embeddings [B, S_vis, d_model], which `transformer.forward` prepends to the
text embeddings.  This module builds the 3-axis M-RoPE position ids the
backbone needs: vision tokens get (t, h, w) grid positions, text tokens a
shared running index (HF's get_rope_index semantics for one image).
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp


__all__ = ["mrope_positions", "vision_grid"]


def vision_grid(n_vis: int) -> Tuple[int, int]:
    """Factor a stub patch count into a (h, w) grid (closest to square)."""
    h = int(math.sqrt(n_vis))
    while n_vis % h:
        h -= 1
    return h, n_vis // h


def mrope_positions(batch: int, n_vis: int, n_text: int) -> jax.Array:
    """[3, B, S] position ids for one prepended image + text.

    Vision tokens: temporal=0, height=row, width=col over the patch grid.
    Text tokens: all three axes share max_vision_pos + 1 + arange.
    """
    gh, gw = vision_grid(n_vis) if n_vis else (0, 0)
    if n_vis:
        rows = jnp.repeat(jnp.arange(gh), gw)
        cols = jnp.tile(jnp.arange(gw), gh)
        vis = jnp.stack([jnp.zeros(n_vis, jnp.int32), rows, cols])   # [3, n_vis]
        start = max(gh, gw)
    else:
        vis = jnp.zeros((3, 0), jnp.int32)
        start = 0
    text = start + jnp.arange(n_text, dtype=jnp.int32)
    text = jnp.broadcast_to(text, (3, n_text))
    pos = jnp.concatenate([vis.astype(jnp.int32), text], axis=1)     # [3, S]
    return jnp.broadcast_to(pos[:, None], (3, batch, n_vis + n_text))
