"""Roofline analysis from compiled dry-run artifacts (no hardware).

TPU v5e per-chip constants (targets; the container is CPU-only):
  peak bf16 compute : 197 TFLOP/s
  HBM bandwidth     : 819 GB/s
  ICI link bandwidth: ~50 GB/s per link

The compiled module after GSPMD partitioning is the *per-device* program,
so ``cost_analysis()`` FLOPs/bytes are per-chip numbers; the three terms
are therefore computed per chip directly:

  compute term    = flops / 197e12                       [s]
  memory term     = bytes_accessed / 819e9               [s]
  collective term = sum_op (wire_bytes(op) / 50e9)       [s]

wire_bytes uses ring-algorithm factors on the *operand* bytes parsed from
the HLO text: all-reduce ~2x(N-1)/N, all-gather/reduce-scatter/
collective-permute ~1x, all-to-all ~(N-1)/N.  N is unknown per-op from
text alone, so the asymptotic factors (2, 1, 1, 1) are used — an upper
bound within (N-1)/N of exact.
"""

from __future__ import annotations

import re
from typing import Any, Dict, Optional

__all__ = [
    "HW",
    "parse_collective_bytes",
    "roofline_terms",
    "model_flops",
    "tm_path_roofline",
]

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

HW = {"peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW, "ici_bw": ICI_BW}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_WIRE_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

# "%name = f32[1,2,3]{...}" or tuple results "(f32[..], f32[..])"
_DEF_RE = re.compile(r"%?([\w.\-]+)\s*=\s*(\(?)([^=]*?)\s+(\S[\w\-]*)\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_text: str) -> int:
    """Sum bytes over every 'dtype[dims]' occurrence in a type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collective_bytes(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per collective opcode: {count, operand_bytes, wire_bytes}.

    Two-pass: build def-name -> shape-bytes map, then for each collective
    instruction sum its operands' bytes (falling back to the result shape
    when an operand is unknown, e.g. a constant folded inline).
    """
    defs: Dict[str, int] = {}
    lines = hlo_text.splitlines()
    for ln in lines:
        m = re.match(r"\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s([\w\-]+)\(", ln)
        if m:
            defs[m.group(1)] = _shape_bytes(m.group(2))

    out = {op: {"count": 0, "operand_bytes": 0.0, "wire_bytes": 0.0}
           for op in COLLECTIVE_OPS}
    for ln in lines:
        m = re.match(r"\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s([\w\-]+)\((.*)", ln)
        if not m:
            continue
        name, result_type, opcode, rest = m.groups()
        base = None
        for op in COLLECTIVE_OPS:
            if opcode == op or opcode.startswith(op + "-start"):
                base = op
                break
        # Also catch fused start/done forms like "all-gather-start".
        if base is None:
            for op in COLLECTIVE_OPS:
                if opcode.startswith(op):
                    base = op
                    break
        if base is None or opcode.endswith("-done"):
            continue
        # Operand names inside the first (...) group.
        operand_names = re.findall(r"%?([\w.\-]+)", rest.split(")")[0])
        ob = sum(defs.get(n, 0) for n in operand_names if n in defs)
        if ob == 0:
            ob = _shape_bytes(result_type)
        out[base]["count"] += 1
        out[base]["operand_bytes"] += float(ob)
        out[base]["wire_bytes"] += float(ob) * _WIRE_FACTOR[base]
    return out


def collective_counts_by_computation(hlo_text: str) -> Dict[str, Dict[str, int]]:
    """Collective instruction counts per HLO computation (e.g. the
    while-loop body of the layer scan vs the entry) — used to verify that
    a sharding/architecture change really removed collectives from the
    per-layer body (EXPERIMENTS.md §Perf evidence)."""
    out: Dict[str, Dict[str, int]] = {}
    current = "<entry>"
    for ln in hlo_text.splitlines():
        m = re.match(r"\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->", ln)
        if m and "=" not in ln.split("->")[0]:
            current = m.group(1)
            continue
        m2 = re.match(r"\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*.+?\s([\w\-]+)\(", ln)
        if not m2:
            continue
        opcode = m2.group(1)
        for op in COLLECTIVE_OPS:
            if opcode == op or (opcode.startswith(op) and not opcode.endswith("-done")):
                out.setdefault(current, {}).setdefault(op, 0)
                out[current][op] += 1
                break
    return out


def roofline_terms(
    cost: Dict[str, float],
    hlo_text: str,
    *,
    chips: int,
) -> Dict[str, Any]:
    """Three roofline terms in seconds (per-chip program)."""
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    coll = parse_collective_bytes(hlo_text)
    wire = sum(v["wire_bytes"] for v in coll.values())
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_acc / HBM_BW,
        "collective_s": wire / ICI_BW,
        "flops_per_chip": flops,
        "bytes_per_chip": bytes_acc,
        "wire_bytes_per_chip": wire,
        "collectives": coll,
        "chips": chips,
    }
    dom = max(("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
    terms["dominant"] = dom.replace("_s", "")
    step = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    terms["bound_step_s"] = step
    terms["roofline_fraction"] = terms["compute_s"] / step if step > 0 else 0.0
    return terms


def model_flops(
    n_params: int,
    n_active_params: int,
    tokens: int,
    kind: str,
) -> float:
    """Ideal model FLOPs: 6·N·D train, 2·N·D forward-only (per step)."""
    n = n_active_params
    return (6.0 if kind == "train" else 2.0) * n * tokens


# ---------------------------------------------------------------------------
# ConvCoTM serving paths
# ---------------------------------------------------------------------------

def tm_path_roofline(
    config,
    path_name: str,
    batch: int = 1,
    *,
    n_active: Optional[int] = None,
    measured_cls_per_s: Optional[float] = None,
) -> Dict[str, Any]:
    """Roofline ceiling for one ConvCoTM eval-path batch on the target HW.

    Uses the analytic per-batch op/byte model from
    ``roofline.flops.tm_serve_costs`` against the v5e constants:

      ``ceiling_cls_per_s`` = batch / max(ops / peak, bytes / bw)

    Word/bit ops are charged at the bf16 peak rate — optimistic for VPU
    integer work, which makes the ceiling a true upper bound and the
    achieved fraction conservative.  With ``measured_cls_per_s`` the
    result also carries ``achieved_fraction`` (measured / ceiling) —
    the column benchmark rows report so a path's headroom is visible
    next to its throughput (EXPERIMENTS.md §Sparsity).
    """
    from repro.roofline.flops import tm_serve_costs

    costs = tm_serve_costs(config, path_name, batch, n_active=n_active)
    compute_s = costs["ops"] / PEAK_FLOPS
    memory_s = costs["bytes"] / HBM_BW
    bound_s = max(compute_s, memory_s)
    out: Dict[str, Any] = {
        "path": path_name,
        "batch": batch,
        "ops": costs["ops"],
        "bytes": costs["bytes"],
        "clauses_evaluated": costs["clauses_evaluated"],
        "compute_s": compute_s,
        "memory_s": memory_s,
        "bound": "compute" if compute_s >= memory_s else "memory",
        "ceiling_cls_per_s": batch / bound_s if bound_s > 0 else float("inf"),
    }
    if measured_cls_per_s is not None:
        out["measured_cls_per_s"] = measured_cls_per_s
        out["achieved_fraction"] = (
            measured_cls_per_s / out["ceiling_cls_per_s"]
            if out["ceiling_cls_per_s"] > 0
            else 0.0
        )
    return out
