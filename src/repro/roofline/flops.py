"""Analytic FLOPs / HBM-bytes / collective-bytes models per (arch, shape).

Why analytic: XLA's ``cost_analysis()`` counts a while-loop body ONCE
regardless of trip count, and this framework deliberately keeps HLO small
with scans (layers, microbatches, attention q-chunks, loss chunks, mLSTM
chunks).  The compiled numbers therefore undercount by ~the product of
trip counts.  The roofline terms are instead derived here from the model
structure — the formulas follow the code in repro/models 1:1 — and are
*validated against an unrolled single-cycle lowering* (scan trip counts of
1 are inlined by XLA's WhileLoopSimplifier, so cost_analysis is exact
there); see tests/test_roofline.py and benchmarks/flops_validation.py.

All numbers are GLOBAL per step; divide by chips for per-chip terms.
Matmul flops = 2*m*n*k; backward = 2x forward; train = 3x forward.
"""

from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig

__all__ = [
    "flops_estimate",
    "hbm_bytes_estimate",
    "collective_bytes_estimate",
    "tm_serve_costs",
]


def _causal_window_pairs(s: int, window) -> float:
    """Sum over query i of visible keys (causal, optional window)."""
    if window is None or window >= s:
        return s * (s + 1) / 2.0
    w = window
    return w * (w + 1) / 2.0 + (s - w) * float(w)


def _attn_layer_flops(cfg: ModelConfig, b: int, s: int, window) -> float:
    """EXECUTED flops: the chunked-attention implementation computes the
    full [Sq, Sk] score matrix per chunk and masks (causal + window) — so
    executed attention flops are the full product, not the visible-pair
    count.  Skipping fully-masked K blocks is a tracked optimization
    (EXPERIMENTS.md §Perf); ``_causal_window_pairs`` gives the ideal."""
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    proj = 2.0 * b * s * d * (h * hd + 2 * kv * hd + h * hd)
    attn = 2.0 * b * h * hd * float(s) * float(s) * 2.0   # QK^T and AV
    return proj + attn


def _mlp_flops(cfg: ModelConfig, b: int, s: int) -> float:
    if cfg.d_ff == 0:
        return 0.0
    return 2.0 * b * s * cfg.d_model * cfg.d_ff * 3.0


def _moe_flops(cfg: ModelConfig, b: int, s: int) -> float:
    t = b * s
    d, ff = cfg.d_model, cfg.d_ff
    e, k = cfg.n_experts, cfg.n_experts_per_token
    router = 2.0 * t * d * e
    # Capacity-padded expert compute (the einsum really does E*C rows).
    cap_tokens = t * k * cfg.capacity_factor
    expert = 2.0 * cap_tokens * d * ff * 3.0
    dispatch = 2.0 * cap_tokens * d * 2.0          # dispatch + combine einsums
    shared = 0.0
    if cfg.n_shared_experts:
        ffs = cfg.d_ff_shared or ff * cfg.n_shared_experts
        shared = 2.0 * t * d * ffs * 3.0 + 2.0 * t * d
    return router + expert + dispatch + shared


def _mlstm_flops(cfg: ModelConfig, b: int, s: int, chunk: int = 64) -> float:
    up = int(cfg.d_model * cfg.proj_factor)
    h = cfg.n_heads
    hd = up // h
    d = cfg.d_model
    proj = 2.0 * b * s * (d * up * 2 + up * up * 3 + up * d + up * 2 * h)
    lc = min(chunk, s)
    nc = max(s // lc, 1)
    # per chunk per head: scores L^2 hd, intra AV L^2 hd, inter q@C L hd^2,
    # state update k@v^T L hd^2.
    cell = nc * b * h * (2.0 * lc * lc * hd * 2 + 2.0 * lc * hd * hd * 2)
    return proj + cell


def _slstm_flops(cfg: ModelConfig, b: int, s: int) -> float:
    d = cfg.d_model
    hd = d // cfg.n_heads
    proj = 2.0 * b * s * d * 4 * d
    rec = 2.0 * b * s * d * 4 * hd                 # block-diagonal recurrence
    ffn = _mlp_flops(cfg, b, s)
    return proj + rec + ffn


def _rglru_flops(cfg: ModelConfig, b: int, s: int) -> float:
    d = cfg.d_model
    w = cfg.rglru_lru_width or d
    proj = 2.0 * b * s * (d * w * 2 + w * d)
    gates = 2.0 * b * s * w * w * 2
    conv = 2.0 * b * s * w * cfg.conv_width
    return proj + gates + conv + _mlp_flops(cfg, b, s)


def _layer_flops(cfg: ModelConfig, kind: str, b: int, s: int) -> float:
    window = cfg.sliding_window or cfg.local_window
    if kind == "attn":
        mlp = _moe_flops(cfg, b, s) if cfg.is_moe else _mlp_flops(cfg, b, s)
        return _attn_layer_flops(cfg, b, s, window) + mlp
    if kind == "rglru":
        return _rglru_flops(cfg, b, s)
    if kind == "mlstm":
        return _mlstm_flops(cfg, b, s)
    if kind == "slstm":
        return _slstm_flops(cfg, b, s)
    raise ValueError(kind)


def _forward_flops(cfg: ModelConfig, b: int, s: int) -> float:
    total = 0.0
    for i in range(cfg.n_layers):
        total += _layer_flops(cfg, cfg.pattern_for_layer(i), b, s)
    if cfg.is_encoder_decoder:
        # Encoder (bidirectional full attention) + decoder cross-attention.
        for _ in range(cfg.n_encoder_layers):
            total += (
                2.0 * b * s * cfg.d_model
                * (2 * cfg.n_heads * cfg.head_dim + 2 * cfg.n_kv_heads * cfg.head_dim)
                + 2.0 * b * cfg.n_heads * cfg.head_dim * s * s * 2.0
                + _mlp_flops(cfg, b, s)
            )
        # cross-attn per decoder layer: q from dec len sd, kv over enc len s
        sd = max(s // 4, 16)
        total += cfg.n_layers * (
            2.0 * b * sd * cfg.d_model * 2 * cfg.n_heads * cfg.head_dim
            + 2.0 * b * cfg.n_heads * cfg.head_dim * sd * s * 2.0
        )
    return total


def _head_flops(cfg: ModelConfig, b: int, s: int) -> float:
    return 2.0 * b * s * cfg.d_model * cfg.vocab_size


def _decode_layer_flops(cfg: ModelConfig, kind: str, b: int, kv_len: int) -> float:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    window = cfg.sliding_window or cfg.local_window
    if kind == "attn":
        eff = min(kv_len, window) if window else kv_len
        proj = 2.0 * b * d * (h * hd + 2 * kv * hd + h * hd)
        att = 2.0 * b * h * hd * eff * 2.0
        mlp = (_moe_flops(cfg, b, 1) if cfg.is_moe else _mlp_flops(cfg, b, 1))
        return proj + att + mlp
    if kind == "rglru":
        return _rglru_flops(cfg, b, 1)
    if kind == "mlstm":
        up = int(d * cfg.proj_factor)
        hd2 = up // cfg.n_heads
        proj = 2.0 * b * (d * up * 2 + up * up * 3 + up * d)
        cell = 2.0 * b * cfg.n_heads * hd2 * hd2 * 2
        return proj + cell
    if kind == "slstm":
        return _slstm_flops(cfg, b, 1)
    raise ValueError(kind)


def flops_estimate(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Global FLOPs per step for the cell."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.is_encoder_decoder:
            sd = max(s // 4, 16)
            fwd = _forward_flops(cfg, b, s) + _head_flops(cfg, b, sd)
        else:
            fwd = _forward_flops(cfg, b, s) + _head_flops(cfg, b, s)
        return 3.0 * fwd
    if shape.kind == "prefill":
        if cfg.is_encoder_decoder:
            return _forward_flops(cfg, b, s) + _head_flops(cfg, b, 1)
        return _forward_flops(cfg, b, s) + _head_flops(cfg, b, 1)
    # decode: one token against a kv_len cache
    total = 0.0
    for i in range(cfg.n_layers):
        total += _decode_layer_flops(cfg, cfg.pattern_for_layer(i), b, s)
    if cfg.is_encoder_decoder:
        # cross-attn against enc len s
        total += cfg.n_layers * (
            2.0 * b * cfg.d_model * 2 * cfg.n_heads * cfg.head_dim
            + 2.0 * b * cfg.n_heads * cfg.head_dim * s * 2.0
        )
    return total + _head_flops(cfg, b, 1)


# ---------------------------------------------------------------------------
# HBM traffic (per chip)
# ---------------------------------------------------------------------------

def hbm_bytes_estimate(
    cfg: ModelConfig, shape: ShapeConfig, chips: int, microbatches: int = 1
) -> float:
    """Per-chip HBM bytes per step (weight streams + major activations).

    Weights: each microbatch's fwd+bwd reads the (sharded) weights from
    HBM; optimizer reads+writes master/m/v once.  Activations: remat saves
    layer inputs; attention KV and logits streams included.  This is a
    floor model (perfect fusion assumed) — good to ~2x, which is enough to
    identify the dominant roofline term.
    """
    pb = 2.0 * cfg.param_count() / chips               # bf16 shard
    b, s = shape.global_batch, shape.seq_len
    d = cfg.d_model
    if shape.kind == "train":
        w = pb * (2 * microbatches + 1)                # fwd+bwd per microbatch
        opt = (cfg.param_count() / chips) * 4.0 * 3 * 2  # m,v,master rw fp32
        act = 2.0 * b * s * d * 2 * cfg.n_layers / chips * 2
        return w + opt + act
    if shape.kind == "prefill":
        act = 2.0 * b * s * d * 2 * cfg.n_layers / chips
        return pb + act
    # decode: weights + KV cache read + state
    window = cfg.sliding_window or cfg.local_window
    kv_len = min(s, window) if window else s
    n_attn = sum(1 for i in range(cfg.n_layers) if cfg.pattern_for_layer(i) == "attn")
    kv_bytes = (
        2.0 * b * cfg.n_kv_heads * kv_len * cfg.head_dim * 2 * n_attn / chips
    )
    return pb * (cfg.active_param_count() / max(cfg.param_count(), 1)) + kv_bytes


# ---------------------------------------------------------------------------
# Collective traffic (per chip, wire bytes)
# ---------------------------------------------------------------------------

def _ar_per_layer(cfg: ModelConfig, parallel_block: bool) -> float:
    """Tensor-parallel all-reduces per layer (forward), by block kind."""
    per_kind = {"attn": 1.0 if parallel_block else 2.0,
                "rglru": 2.0, "mlstm": 1.0, "slstm": 2.0}
    total = 0.0
    for i in range(cfg.n_layers):
        total += per_kind[cfg.pattern_for_layer(i)]
    if cfg.is_encoder_decoder:
        total += 2.0 * cfg.n_encoder_layers + cfg.n_layers  # enc + cross-attn
    return total


def collective_bytes_estimate(
    cfg: ModelConfig,
    shape: ShapeConfig,
    *,
    dp: int,
    tp: int,
    pods: int = 1,
    microbatches: int = 1,
    profile: str = "tp",
    parallel_block: bool = False,
    gather_hoisted: bool = False,
    pod_int8: bool = False,
) -> Dict[str, float]:
    """Per-chip wire bytes per step, by mechanism.

    * tp — activation all-reduces (ring wire 2x of b_dev*s*d bf16), count
      per layer from the block mix; x3 for train (fwd + 2 bwd dgrads).
      parallel_block=True merges attn+mlp into one AR (code-real; verified
      by HLO AR counts in EXPERIMENTS.md §Perf).
    * fsdp — ZeRO param all-gathers (bf16) per microbatch fwd + bwd, and
      fp32 grad reduce-scatter per microbatch.  gather_hoisted models
      XLA hoisting the loop-invariant fwd gather out of the microbatch
      scan (one gather per step + per-microbatch bwd regather).
      Profiles: 'tp' gathers params/tp per chip over the data axis;
      'dp' gathers FULL params per chip (no TP); 'serve_tp' gathers
      nothing (decode-resident weights).
    * pod — inter-pod fp32 gradient all-reduce of each chip's shard;
      /4 when int8+EF compression is enabled.
    * ep — MoE expert-parallel all-to-all (dispatch+combine).
    """
    b, s = shape.global_batch, shape.seq_len
    d = cfg.d_model
    params = cfg.param_count()
    out: Dict[str, float] = {"fsdp": 0.0, "tp": 0.0, "pod": 0.0, "ep": 0.0}
    k = microbatches
    tp_eff = 1 if profile == "dp" else tp
    b_dev = max(b // (dp * pods), 1)
    tokens_dev = b_dev * (s if shape.kind != "decode" else 1)

    # --- fsdp param gathers + grad reduce-scatter ---
    if profile == "serve_tp":
        gathered = 0.0
    elif profile == "dp":
        gathered = 2.0 * params                       # full params, bf16
    else:
        gathered = 2.0 * params / tp                  # data-axis shard only
    if shape.kind == "train":
        n_gather = (1 + k) if gather_hoisted else (2 * k)
        rs = (2.0 * gathered) * k                     # fp32 grads, ring ~1x
        out["fsdp"] = gathered * n_gather + rs
    elif gathered:
        out["fsdp"] = gathered                        # one gather per call

    # --- tensor-parallel activation all-reduces ---
    if tp_eff > 1:
        n_ar_fwd = _ar_per_layer(cfg, parallel_block)
        mult = 3.0 if shape.kind == "train" else 1.0
        per_ar = tokens_dev * d * 2.0 * 2.0           # bf16, ring wire 2x
        out["tp"] = per_ar * n_ar_fwd * mult

    # --- inter-pod gradient sync ---
    if pods > 1 and shape.kind == "train":
        pod_bytes = 2.0 * 4.0 * params / (dp * tp_eff)
        out["pod"] = pod_bytes / (4.0 if pod_int8 else 1.0)

    # --- expert-parallel all-to-all ---
    if cfg.is_moe and cfg.n_experts % tp == 0 and tp > 1 and profile != "dp":
        cap = tokens_dev * cfg.n_experts_per_token * cfg.capacity_factor
        mult = 3.0 if shape.kind == "train" else 1.0
        out["ep"] = 2.0 * cap * d * 2.0 * mult

    out["total"] = sum(out.values())
    return out


# ---------------------------------------------------------------------------
# ConvCoTM serving paths (ops / HBM bytes per batch)
# ---------------------------------------------------------------------------

#: Paths whose clause axis is the ACTIVE pool (empty clauses pruned by
#: serve.servable.analyze_sparsity) rather than the full pool.
TM_SPARSE_PATHS = ("sparse", "fused_sparse", "matmul_sparse")

#: Paths whose clause outputs never round-trip through HBM (class sums
#: computed in-register on the last patch chunk).
TM_FUSED_PATHS = ("fused", "fused_sparse")


def tm_serve_costs(
    config, path_name: str, batch: int = 1, *, n_active=None
) -> Dict[str, float]:
    """Analytic op/byte costs of one ConvCoTM eval-path batch.

    ``config`` is a ``repro.core.cotm.CoTMConfig`` (only geometry fields
    are read); ``n_active`` is the active-clause count for the sparse
    paths (defaults to the full pool — i.e. a model with no empty
    clauses).  Returns a dict with:

      * ``ops``   — elementary operations executed: MXU flops for the
        matmul paths, word-level bit operations (and/or/not/popcount/
        compare) for the packed paths, byte-level AND/compare for dense.
        One op = one lane-element operation, the same accounting XLA's
        cost model uses for integer vectors.
      * ``bytes`` — HBM floor traffic: literal stream in, model image
        (read once per batch — it is VMEM-resident across patch chunks),
        clause-output round-trip for non-fused paths, class sums out.

    The formulas mirror ``kernels/ref.py`` / ``serve/paths.py`` 1:1 per
    path; the roofline ceilings derived from them live in
    ``roofline/analysis.py`` (``tm_path_roofline``) and annotate the
    benchmark rows in ``benchmarks/bench_serve.py``.
    """
    spec = config.patch
    b = float(batch)
    p = float(spec.n_patches)        # patches per image
    lit = float(spec.n_literals)     # 2o dense literal bits
    w = float(spec.n_words)          # packed uint32 words per patch
    c = float(config.n_clauses)
    m = float(config.n_classes)
    c_a = c if n_active is None else float(n_active)
    if path_name in TM_SPARSE_PATHS:
        c_eval = c_a
    else:
        c_eval = c

    sums_ops = 2.0 * b * c_eval * m          # Eq. (3) int8 dot
    or_ops = b * c_eval * p                  # sequential OR (Eq. 6)

    if path_name in ("dense",):
        ops = 2.0 * b * p * c * lit + or_ops + sums_ops     # AND + reduce
        lit_bytes = b * p * lit                              # uint8 stream
        model_bytes = c * lit + c + m * c
    elif path_name in ("matmul", "matmul_sparse"):
        # int8 violation-count matmul: 2*B*P*C*2o MACs + zero-compare.
        ops = 2.0 * b * p * c_eval * lit + b * p * c_eval + or_ops + sums_ops
        lit_bytes = b * p * lit
        model_bytes = c_eval * lit + m * c_eval
    elif path_name in ("bitpacked", "kernel", "fused", "sparse", "fused_sparse"):
        # Word ops per (patch, clause): not/and(+popcount)/compare ~ 3.
        ops = 3.0 * b * p * c_eval * w + or_ops + sums_ops
        lit_bytes = b * p * w * 4.0                          # uint32 stream
        model_bytes = c_eval * w * 4.0 + m * c_eval
        if path_name in ("bitpacked", "kernel"):
            model_bytes += c                                 # nonempty mask
    else:
        raise ValueError(f"no cost model for eval path {path_name!r}")

    out_bytes = b * m * 4.0                                  # int32 class sums
    fired_bytes = 0.0 if path_name in TM_FUSED_PATHS else 2.0 * b * c_eval
    return {
        "ops": ops,
        "bytes": lit_bytes + model_bytes + fired_bytes + out_bytes,
        "lit_bytes": lit_bytes,
        "model_bytes": model_bytes,
        "clauses_evaluated": c_eval,
    }
