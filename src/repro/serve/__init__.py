"""Serving subsystem: frozen model artifacts + batched classification engine.

``servable``  — :class:`ServableModel`, the software image of the ASIC's
                45k-bit register file (frozen include bits, packed include
                words, nonempty mask, int8-clamped weights), prepared
                exactly once per model.
``paths``     — registry of functionally identical evaluation paths
                (dense / bitpacked / matmul / kernel / fused); every
                inference consumer dispatches through it.
``engine``    — :class:`ServingEngine`, batched multi-dataset serving with
                power-of-two batch bucketing and latency accounting.
"""

from repro.serve.engine import ClassifyResult, ServeStats, ServingEngine
from repro.serve.paths import (
    EvalPath,
    available_paths,
    get_path,
    register_path,
    run_path,
)
from repro.serve.servable import ServableModel, freeze

__all__ = [
    "ClassifyResult",
    "EvalPath",
    "ServableModel",
    "ServeStats",
    "ServingEngine",
    "available_paths",
    "freeze",
    "get_path",
    "register_path",
    "run_path",
]
