"""Serving subsystem: frozen model artifacts + batched engine + async service.

``servable``  — :class:`ServableModel`, the software image of the ASIC's
                45k-bit register file (frozen include bits, packed include
                words, nonempty mask, int8-clamped weights), prepared
                exactly once per model.
``paths``     — registry of functionally identical evaluation paths
                (dense / bitpacked / matmul / kernel / fused), each
                owning its full raw->sums graph via an ``ingress_fn``;
                every inference consumer dispatches through it.
``engine``    — :class:`ServingEngine`, batched multi-dataset serving with
                power-of-two batch bucketing, the fused device-resident
                raw classify step, async dispatch handles and
                ingress/device latency accounting (the synchronous
                library layer).
``scheduler`` — :class:`MicrobatchScheduler`, the latency-aware
                microbatching policy (per-model queues, round-robin,
                deadline coalescing, high-water admission).
``service``   — :class:`ServingService`, the asyncio request-queue front
                end over the engine: backpressure, microbatching,
                multi-model fairness, graceful drain, p50/p99 stats.
``mesh``      — :class:`ServeMesh`, multi-device placement: servables
                replicated (or clause-sharded) across a ("data","model")
                mesh, request buckets sharded over "data" inside the
                engine's jitted steps — bit-identical to single-device.
``autotune``  — :class:`TunedPlan` + the per-bucket eval-path autotuner:
                measures every admissible (path, params) candidate per
                (form, bucket) and pins deterministic winners on the
                servable (hashable, JSON-serializable with checkpoints).
``faults``    — :class:`FaultPlan` deterministic fault injection,
                :class:`DegradationPolicy` circuit-breaker knobs,
                :class:`ServiceHealth` state, the structured fault errors
                every request future resolves with, and the chaos-soak
                driver (ARCHITECTURE.md §Faults).
"""

from repro.serve.autotune import AutotuneReport, TunedPlan, autotune_servable
from repro.serve.engine import (
    ClassifyResult,
    InFlightClassify,
    ServeStats,
    ServingEngine,
    classify_raw_step,
    classify_step,
)
from repro.serve.faults import (
    DegradationPolicy,
    DeviceLost,
    FaultError,
    FaultPlan,
    InjectedEngineError,
    PoisonedPayload,
    ServiceExpired,
    ServiceHealth,
    WorkerCrashed,
    chaos_soak,
)
from repro.serve.loadgen import LoadReport, poisson_open_loop
from repro.serve.mesh import ServeMesh, classify_step_clause_sharded, make_serve_mesh
from repro.serve.paths import (
    DENSE,
    PACKED,
    RAW,
    EvalPath,
    available_paths,
    degraded_fallback,
    get_path,
    register_path,
    resolve_path,
    run_path,
    run_path_raw,
)
from repro.serve.scheduler import (
    MicrobatchScheduler,
    PendingRequest,
    QueueFull,
    SchedulerConfig,
)
from repro.serve.servable import (
    ClauseSparsity,
    ServableModel,
    ServableVersion,
    active_pad,
    analyze_sparsity,
    freeze,
    servable_digest,
)
from repro.serve.service import (
    ServiceConfig,
    ServiceOverloaded,
    ServiceResult,
    ServiceStats,
    ServiceStopped,
    ServingService,
)

__all__ = [
    "DENSE",
    "PACKED",
    "RAW",
    "AutotuneReport",
    "ClassifyResult",
    "ClauseSparsity",
    "DegradationPolicy",
    "DeviceLost",
    "EvalPath",
    "FaultError",
    "FaultPlan",
    "InFlightClassify",
    "InjectedEngineError",
    "LoadReport",
    "MicrobatchScheduler",
    "PendingRequest",
    "PoisonedPayload",
    "QueueFull",
    "SchedulerConfig",
    "ServableModel",
    "ServableVersion",
    "ServeMesh",
    "ServeStats",
    "ServiceConfig",
    "ServiceExpired",
    "ServiceHealth",
    "ServiceOverloaded",
    "ServiceResult",
    "ServiceStats",
    "ServiceStopped",
    "ServingEngine",
    "ServingService",
    "TunedPlan",
    "WorkerCrashed",
    "active_pad",
    "analyze_sparsity",
    "autotune_servable",
    "available_paths",
    "chaos_soak",
    "classify_raw_step",
    "classify_step",
    "classify_step_clause_sharded",
    "degraded_fallback",
    "freeze",
    "make_serve_mesh",
    "get_path",
    "poisson_open_loop",
    "register_path",
    "resolve_path",
    "run_path",
    "run_path_raw",
    "servable_digest",
]
