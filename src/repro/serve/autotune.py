"""Per-bucket evaluation-path autotuner (ARCHITECTURE.md §Autotune).

The serving engine keys every executable on (model geometry, eval path,
static kernel parameters, request form, bucket).  Which point in that
space is fastest depends on geometry and backend in ways no heuristic
captures: at paper geometry the fused kernel's in-register class sums win
on TPU, while at tiny clause counts XLA's dense matmul beats everything;
a mostly-empty clause pool flips the balance toward the sparse paths.
Rather than hardcode a table, the autotuner *measures*: for each
(request form, bucket) it times every admissible (path, params) candidate
on zero-filled inputs of exactly the shapes serving will dispatch, and
records the winner in a :class:`TunedPlan`.

Contract (relied on by the engine and tests/test_autotune.py):

  * **Deterministic.** Candidate enumeration is sorted; measurements are
    memoized per process on the full static key (geometry, backend, mesh,
    sparsity shape, form, bucket, path, params), so re-registering the
    same model yields the *same* plan even though wall-clock timings
    jitter; ties break lexicographically on (path, params).
  * **Bit-identity is free.** Every candidate is a registered
    :class:`~repro.serve.paths.EvalPath`, and all registered paths are
    asserted bit-identical to ``kernels/ref.py`` — the tuner can never
    trade correctness for speed, so it never has to check outputs.
  * **Hashable + serializable.** A :class:`TunedPlan` is hashable (it
    rides on :class:`~repro.serve.servable.ServableModel` as jit-static
    metadata) and round-trips through JSON (``to_json``/``from_json``)
    so a tuned plan checkpoints alongside the model and restores without
    re-measuring.
  * **Admissibility.** Literal-form requests arrive already converted to
    the registered path's input form, so only same-form paths compete;
    raw-form requests own their ingress in-graph, so every path competes.
    Sparse paths that would resolve to their dense fallback (no sparsity
    analysis attached) are deduplicated away.  Non-default kernel
    parameter sets are swept only where the Pallas kernels actually
    compile (TPU backend, unmeshed).

The measured trajectory (winner + every candidate's time) is surfaced in
``ServeStats.autotune`` and in ``benchmarks/bench_serve.py`` rows.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.ingress import IngressSpec, raw_trailing_shape
from repro.serve import paths as sp
from repro.serve.servable import ServableModel, servable_digest

__all__ = [
    "TunedPlan",
    "AutotuneReport",
    "autotune_servable",
    "clear_measure_memo",
]

#: ((name, value), ...) static kernel parameters — see paths.Params.
Params = sp.Params

FORMS = ("literals", "raw")


@dataclasses.dataclass(frozen=True)
class TunedPlan:
    """The autotuner's decisions: (form, bucket) -> (path, params).

    ``entries`` is a sorted tuple of ``(form, bucket, path_name, params)``
    — pure strings/ints, so the plan is hashable and participates in jit
    static keys without ever forcing a recompile on re-measurement (the
    measured times live in :class:`AutotuneReport`, not here).

    ``digest`` records the :func:`~repro.serve.servable.servable_digest`
    of the register image the plan was measured on (lifecycle
    provenance: a plan carried across a hot swap is identifiable as
    tuned-for-a-prior-version).  ``""`` means unstamped — pre-lifecycle
    plans deserialize with it and stay bit-compatible.
    """

    entries: Tuple[Tuple[str, int, str, Params], ...] = ()
    digest: str = ""

    def lookup(self, form: str, bucket: int) -> Optional[Tuple[str, Params]]:
        """The tuned (path, params) for a dispatch, or None if untuned.

        Exact (form, bucket) match first; otherwise the nearest tuned
        bucket for the form (largest tuned <= bucket, else smallest
        tuned) — a bucket between tuned endpoints behaves like its
        closest measured neighbor rather than falling back to defaults.
        """
        best = None
        below, above = None, None
        for f, b, path, params in self.entries:
            if f != form:
                continue
            if b == bucket:
                return (path, params)
            if b < bucket and (below is None or b > below[0]):
                below = (b, path, params)
            if b > bucket and (above is None or b < above[0]):
                above = (b, path, params)
        pick = below or above
        return (pick[1], pick[2]) if pick else best

    def with_entry(
        self, form: str, bucket: int, path: str, params: Params
    ) -> "TunedPlan":
        kept = tuple(
            e for e in self.entries if not (e[0] == form and e[1] == bucket)
        )
        return TunedPlan(
            entries=tuple(sorted(kept + ((form, bucket, path, params),))),
            digest=self.digest,
        )

    def to_json(self) -> str:
        entries = [
            {"form": f, "bucket": b, "path": p, "params": [list(kv) for kv in ps]}
            for f, b, p, ps in self.entries
        ]
        if not self.digest:
            # Unstamped plans keep the legacy bare-list format so older
            # readers (and committed fixtures) stay byte-compatible.
            return json.dumps(entries)
        return json.dumps({"digest": self.digest, "entries": entries})

    @classmethod
    def from_json(cls, text: str) -> "TunedPlan":
        doc = json.loads(text)
        digest = ""
        if isinstance(doc, dict):        # stamped format
            digest = str(doc.get("digest", ""))
            doc = doc.get("entries", [])
        entries = tuple(
            sorted(
                (
                    e["form"],
                    int(e["bucket"]),
                    e["path"],
                    tuple((str(k), v) for k, v in e["params"]),
                )
                for e in doc
            )
        )
        return cls(entries=entries, digest=digest)


@dataclasses.dataclass
class AutotuneReport:
    """Everything the tuner measured (one row per (form, bucket))."""

    rows: List[Dict] = dataclasses.field(default_factory=list)
    total_s: float = 0.0

    def as_dict(self) -> Dict:
        return {"rows": list(self.rows), "total_s": self.total_s}


# Measurements memoized on the full static key so two register() calls in
# one process produce identical plans (wall clock jitters; the memo does
# not).  Cross-process determinism is what TunedPlan serialization is for.
_MEASURE_MEMO: Dict[Tuple, float] = {}


def clear_measure_memo() -> None:
    """Drop memoized timings (tests re-measuring on purpose)."""
    _MEASURE_MEMO.clear()


def _zero_input(
    servable: ServableModel, path: "sp.EvalPath", form: str,
    bucket: int, ingress: IngressSpec,
) -> np.ndarray:
    spec = servable.config.patch
    if form == "raw":
        return np.zeros((bucket,) + raw_trailing_shape(ingress), np.uint8)
    if path.input_form == sp.PACKED:
        return np.zeros((bucket, spec.n_patches, spec.n_words), np.uint32)
    return np.zeros((bucket, spec.n_patches, spec.n_literals), np.uint8)


def _candidates(
    servable: ServableModel,
    registered: "sp.EvalPath",
    form: str,
    *,
    sweep_params: bool,
) -> List[Tuple[str, Params]]:
    """Sorted, deduplicated (path, params) candidates for one form."""
    out: List[Tuple[str, Params]] = []
    seen = set()
    for name in sp.available_paths():
        path = sp.get_path(name)
        if form == "literals" and path.input_form != registered.input_form:
            continue
        resolved = sp.resolve_path(path, servable)
        if resolved is not path:
            continue    # would fall back: the fallback competes on its own
        psets = path.tunable if sweep_params else ((),)
        for params in psets:
            key = (name, params)
            if key not in seen:
                seen.add(key)
                out.append(key)
    return sorted(out)


def _time_candidate(step, *args, repeats: int) -> float:
    """Best-of-``repeats`` seconds per call (after one untimed warm call)."""
    jax.block_until_ready(step(*args))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(step(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def autotune_servable(
    servable: ServableModel,
    path_name: str,
    ingress: IngressSpec,
    buckets: Sequence[int],
    forms: Sequence[str] = FORMS,
    *,
    repeats: int = 3,
    smesh=None,
    max_seconds: Optional[float] = None,
) -> Tuple[TunedPlan, AutotuneReport]:
    """Measure every admissible candidate per (form, bucket); return the
    winning :class:`TunedPlan` plus the full :class:`AutotuneReport`.

    ``smesh`` (a ServeMesh) measures through the meshed steps the engine
    will actually dispatch; clause-sharded meshes restrict candidates to
    default params (the shard_map step takes none).  ``max_seconds``
    bounds wall clock: once exceeded, remaining candidates are skipped
    (the best-so-far still wins — noted in the report) and remaining
    (form, bucket) cells keep the registered path.  Leave it None for
    byte-reproducible plans.
    """
    # Engine-layer steps imported here (engine imports this module too).
    from repro.serve.engine import classify_raw_step, classify_step
    from repro.serve.mesh import classify_step_clause_sharded

    backend = jax.default_backend()
    clause_sharded = smesh is not None and smesh.shard_clauses
    sweep = backend == "tpu" and smesh is None
    registered = sp.get_path(path_name)
    sparsity_key = None if servable.sparsity is None else servable.sparsity.n_active
    plan = servable.tuned or TunedPlan()
    report = AutotuneReport()
    t_start = time.perf_counter()
    budget_hit = False

    for form in forms:
        if form not in FORMS:
            raise ValueError(f"unknown autotune form {form!r} (use {FORMS})")
        for bucket in dict.fromkeys(int(b) for b in buckets):
            cands = _candidates(servable, registered, form, sweep_params=sweep)
            timed: List[Tuple[float, str, Params]] = []
            skipped = []
            for name, params in cands:
                if max_seconds is not None and (
                    time.perf_counter() - t_start > max_seconds
                ):
                    budget_hit = True
                if budget_hit and timed:
                    skipped.append(name)
                    continue
                memo_key = (
                    servable.config, backend, smesh, sparsity_key,
                    form, bucket, name, params,
                )
                if memo_key not in _MEASURE_MEMO:
                    arr = _zero_input(
                        servable, sp.get_path(name), form, bucket, ingress
                    )
                    if smesh is not None:
                        x = smesh.place_batch(arr)
                        if clause_sharded:
                            step = lambda: classify_step_clause_sharded(
                                servable, x, smesh=smesh, path_name=name,
                                ingress=ingress if form == "raw" else None,
                            )
                        elif form == "raw":
                            step = lambda: classify_raw_step(
                                servable, x, name, ingress
                            )
                        else:
                            step = lambda: classify_step(
                                servable, x, name, params=params
                            )
                    elif form == "raw":
                        x = arr
                        step = lambda: classify_raw_step(
                            servable, x, name, ingress, params=params
                        )
                    else:
                        x = arr
                        step = lambda: classify_step(
                            servable, x, name, params=params
                        )
                    _MEASURE_MEMO[memo_key] = _time_candidate(
                        step, repeats=repeats
                    )
                timed.append((_MEASURE_MEMO[memo_key], name, params))
            if not timed:
                continue
            # Deterministic winner: min time, ties by (path, params).
            best_t, best_name, best_params = min(
                timed, key=lambda t: (t[0], t[1], t[2])
            )
            plan = plan.with_entry(form, bucket, best_name, best_params)
            report.rows.append(
                {
                    "form": form,
                    "bucket": bucket,
                    "winner": best_name,
                    "params": [list(kv) for kv in best_params],
                    "us_per_call": best_t * 1e6,
                    "candidates": [
                        {
                            "path": n,
                            "params": [list(kv) for kv in ps],
                            "us_per_call": t * 1e6,
                        }
                        for t, n, ps in sorted(timed)
                    ],
                    "skipped": skipped,
                }
            )
    report.total_s = time.perf_counter() - t_start
    # Provenance stamp: the plan is tuned for THIS register image.  The
    # entries stay pure strings/ints; re-measuring the same image yields
    # the same digest, so determinism (and the jit static key) holds.
    plan = dataclasses.replace(plan, digest=servable_digest(servable))
    return plan, report
