"""Batched ConvCoTM serving engine.

The software counterpart of the chip's continuous classification mode
(Sec. IV-C): models are frozen once into :class:`ServableModel` register
images, registered under a dataset key (MNIST / Fashion-MNIST /
Kuzushiji-MNIST, ...), and request batches stream through a jitted
classify step.

Device-resident ingress
-----------------------
``classify`` accepts three request forms:

  * **raw** (the default): uint8 pixel batches ``[n, Y, X]``.  The whole
    raw -> booleanize -> patches -> literals -> pack -> class sums path
    runs as ONE jitted graph (:data:`classify_raw_step`, input buffer
    donated) — one H2D copy in, one D2H copy out, mirroring the ASIC
    where booleanized pixels stream straight into the clause datapath.
  * ``ingress='host'``: the legacy host-side pipeline
    (``data.pipeline.preprocess_for_serving``), kept as the baseline the
    device path is asserted bit-identical against.
  * ``preprocessed=True``: literals already in the path's input form
    (validated, then the literal-form :data:`classify_step`).

Batch bucketing
---------------
jit recompiles per input shape, so arbitrary request sizes would compile
without bound.  Requests are padded up to the nearest power-of-two bucket
(clamped to ``max_batch``) and results sliced back — at most
``log2(max_batch) + 1`` compilations per (model, path, request form)
ever, after which every request hits a warm executable.  Padding rows
(zero images / zero literal words) produce garbage predictions that are
sliced off and cannot perturb real rows (no cross-batch interaction in
the datapath).

Async dispatch
--------------
:meth:`ServingEngine.dispatch` submits a request and returns an
:class:`InFlightClassify` immediately — JAX dispatch is asynchronous, so
the device crunches batch k while the caller pads/dispatches batch k+1
(the ``ServingService`` worker does exactly this).  ``classify`` is
``dispatch(...).result()``.

Per-request latency is split into ``ingress`` (host-side preprocessing /
validation) and ``device`` (dispatch -> results ready) components so the
bottleneck is visible per model; throughput is compared against the
paper's 60.3k classifications/s (measured numbers in EXPERIMENTS.md
§Serve and §Ingress).

Multi-device serving
--------------------
Constructed with a :class:`~repro.serve.mesh.ServeMesh`, the engine
places each registered servable across the mesh (replicated, or
clause-sharded over the "model" axis) and shards every dispatched bucket
over the "data" axis — the same bucketed jit steps then execute one
program across all mesh devices and results gather on ``.result()``,
bit-identical to the single-device engine (``serve/mesh.py``,
ARCHITECTURE.md §ServeMesh).  Buckets are clamped from below to the
data-axis size so padding always splits evenly.

This is the synchronous library layer.  Online serving — request queue,
admission control, latency-aware microbatching across concurrent
submitters, multi-model fairness — lives one layer up in
:mod:`repro.serve.service` (``ServingService``).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clauses as cl
from repro.core.cotm import CoTMConfig, CoTMModel
from repro.core.ingress import IngressSpec, raw_trailing_shape
from repro.data.pipeline import preprocess_for_serving
from repro.serve.autotune import TunedPlan, autotune_servable
from repro.serve.mesh import ServeMesh, classify_step_clause_sharded
from repro.serve.paths import PACKED, Params, get_path, run_path, run_path_raw
from repro.serve.servable import (
    ServableModel,
    ServableVersion,
    analyze_sparsity,
    freeze,
    servable_digest,
)

__all__ = [
    "ClassifyResult",
    "InFlightClassify",
    "ServeStats",
    "ServingEngine",
    "classify_step",
    "classify_raw_step",
    "raw_step_jit",
]


@dataclasses.dataclass
class ClassifyResult:
    """One request's outcome."""

    predictions: np.ndarray   # int32 [n]
    class_sums: np.ndarray    # int32 [n, m]
    latency_s: float          # wall clock incl. ingress
    bucket: int               # largest padded batch size executed
    ingress_s: float = 0.0    # host-side ingress / validation share
    device_s: float = 0.0     # dispatch -> device results ready share
    version: int = 0          # monotonic id of the version that computed it


@dataclasses.dataclass
class ServeStats:
    """Running per-model accounting.

    ``devices`` is the mesh size the model serves on (1 unmeshed);
    buckets are *global* batch sizes — on a mesh each device executes
    ``bucket // data_shards`` rows (:attr:`per_device_bucket_hits`).
    """

    requests: int = 0
    images: int = 0
    total_latency_s: float = 0.0
    ingress_s: float = 0.0            # host ingress share of the latency
    device_s: float = 0.0             # device share of the latency
    bucket_hits: Dict[int, int] = dataclasses.field(default_factory=dict)
    compiled_buckets: Tuple[int, ...] = ()
    devices: int = 1                  # mesh size (1 = unmeshed)
    data_shards: int = 1              # batch shards over the "data" axis
    # Autotune outcome: {"rows": [...], "total_s": ..., "plan": [...]}
    # (see serve/autotune.py); empty dict when the model was not tuned.
    autotune: Dict = dataclasses.field(default_factory=dict)
    # Degradation state (ARCHITECTURE.md §Faults): the fallback path the
    # circuit breaker moved this model onto (None = registered path),
    # and how many degrade steps have been taken.
    fallback_path: Optional[str] = None
    degrade_steps: int = 0

    @property
    def classifications_per_s(self) -> float:
        return self.images / self.total_latency_s if self.total_latency_s else 0.0

    @property
    def mean_latency_us(self) -> float:
        return self.total_latency_s / self.requests * 1e6 if self.requests else 0.0

    @property
    def mean_ingress_us(self) -> float:
        return self.ingress_s / self.requests * 1e6 if self.requests else 0.0

    @property
    def mean_device_us(self) -> float:
        return self.device_s / self.requests * 1e6 if self.requests else 0.0

    @property
    def per_device_bucket_hits(self) -> Dict[int, int]:
        """Bucket hits keyed by the rows each device actually executed."""
        return {b // self.data_shards: h for b, h in self.bucket_hits.items()}

    def as_dict(self) -> Dict:
        return {
            "requests": self.requests,
            "images": self.images,
            "classifications_per_s": self.classifications_per_s,
            "mean_latency_us": self.mean_latency_us,
            "mean_ingress_us": self.mean_ingress_us,
            "mean_device_us": self.mean_device_us,
            "bucket_hits": dict(self.bucket_hits),
            "compiled_buckets": list(self.compiled_buckets),
            "devices": self.devices,
            "data_shards": self.data_shards,
            "per_device_bucket_hits": dict(self.per_device_bucket_hits),
            "autotune": dict(self.autotune),
            "fallback_path": self.fallback_path,
            "degrade_steps": self.degrade_steps,
        }


@dataclasses.dataclass
class _Entry:
    servable: ServableModel
    booleanize_method: str
    booleanize_kw: Dict
    path_name: str
    ingress: IngressSpec
    stats: ServeStats
    # (form, bucket) pairs whose executable is warm; 'raw' and 'literals'
    # compile separately but share the user-visible compiled_buckets list.
    # Reset on swap/rollback: bucket warmth is per register image (the
    # sparsity shape can change between versions).
    compiled: set = dataclasses.field(default_factory=set)
    autotune: bool = False
    # Lifecycle stamp of the image currently installed, and the one-deep
    # history rollback() restores from (the whole placed image is kept,
    # so rollback is an O(1) pointer flip — no re-analysis, no H2D).
    version: ServableVersion = dataclasses.field(default_factory=ServableVersion)
    previous: Optional[Tuple[ServableModel, ServableVersion]] = None
    # Memo of the stamped image servable() hands out, so repeated reads of
    # an unchanged version return the identical object (pack-once contract).
    stamped: Optional[ServableModel] = None

    def resolve(self, form: str, bucket: int) -> Tuple[str, Params]:
        """The (path, params) this entry dispatches for a (form, bucket):
        the tuned winner when a plan covers it, else the registered path
        at default params."""
        plan = self.servable.tuned
        if plan is not None:
            hit = plan.lookup(form, bucket)
            if hit is not None:
                return hit
        return self.path_name, ()


def _classify_step(
    servable: ServableModel, lits: jax.Array, path_name: str, params: Params = ()
):
    path = get_path(path_name)
    v = run_path(path, servable, lits, params)
    return cl.argmax_predict(v), v


#: The literal-form jitted classify step: (servable, literals, path_name
#: [, params]) -> (predictions, class_sums).  Module-level so every
#: engine instance (and ``train.serve_step.make_tm_serve_fn``) shares one
#: compile cache; jit keys on (bucket shape, model config, path, params)
#: — the bounded-recompile contract.
classify_step = jax.jit(_classify_step, static_argnames=("path_name", "params"))


def _classify_raw_step(
    servable: ServableModel,
    raw: jax.Array,
    path_name: str,
    ingress: IngressSpec,
    params: Params = (),
):
    path = get_path(path_name)
    v = run_path_raw(path, servable, raw, ingress, params)
    return cl.argmax_predict(v), v


#: Lazily built so jax.default_backend() (which initializes the backend)
#: is not forced at import time — importing repro.serve must not freeze
#: the platform choice before e.g. jax.config.update/distributed init.
_raw_step_jit = None


def raw_step_jit():
    """Build (once) and return the raw-form jitted step.

    The jit wrapper — and with it the donation decision — is built on
    first use, when the backend is actually resolved.  Exposed so
    ``tools/tmverify`` can audit the very wrapper dispatch uses (its
    ``donate_argnums`` and static keys) instead of a reconstruction.
    """
    global _raw_step_jit
    if _raw_step_jit is None:
        _raw_step_jit = jax.jit(
            _classify_raw_step,
            static_argnames=("path_name", "ingress", "params"),
            donate_argnums=() if jax.default_backend() == "cpu" else (1,),
        )
    return _raw_step_jit


def classify_raw_step(
    servable, raw, path_name: str, ingress: IngressSpec, params: Params = ()
):
    """The raw-form jitted classify step: the ENTIRE ingress (booleanize
    -> patches -> literals -> pack) plus clause evaluation and class sums
    in one executable.  The raw pixel buffer is donated where the backend
    supports it — after the single H2D copy the input storage is recycled
    inside the graph (on CPU donation is a no-op and only warns, so it is
    skipped).  jit keys on (bucket shape, model config, path, IngressSpec).
    """
    return raw_step_jit()(
        servable, raw, path_name=path_name, ingress=ingress, params=params
    )


class InFlightClassify:
    """A dispatched classify request whose device work may still be running.

    ``result()`` blocks until the device arrays are ready, slices off the
    bucket padding, records the request's stats and returns the
    :class:`ClassifyResult`; it is idempotent.
    """

    def __init__(
        self,
        entry: _Entry,
        parts,
        n: int,
        t0: float,
        t_dispatch: float,
        version: int = 0,
    ):
        self._entry = entry
        self._parts = parts            # [(preds, sums, n_i, bucket)], lazy
        self._n = n
        self._t0 = t0
        self._t_dispatch = t_dispatch  # ingress done / device dispatch start
        # Version id captured atomically at dispatch: a swap after this
        # point cannot retroactively change which weights computed us.
        self.version = version
        self._result: Optional[ClassifyResult] = None

    def result(self) -> ClassifyResult:
        if self._result is not None:
            return self._result
        jax.block_until_ready([(p, s) for p, s, _, _ in self._parts])
        t2 = time.perf_counter()
        preds = np.concatenate([np.asarray(p)[:ni] for p, _, ni, _ in self._parts])
        sums = np.concatenate([np.asarray(s)[:ni] for _, s, ni, _ in self._parts])
        ingress_s = self._t_dispatch - self._t0
        device_s = t2 - self._t_dispatch
        st = self._entry.stats
        st.requests += 1
        st.images += self._n
        st.total_latency_s += t2 - self._t0
        st.ingress_s += ingress_s
        st.device_s += device_s
        self._result = ClassifyResult(
            predictions=preds,
            class_sums=sums,
            latency_s=t2 - self._t0,
            bucket=max(b for _, _, _, b in self._parts),
            ingress_s=ingress_s,
            device_s=device_s,
            version=self.version,
        )
        return self._result


class ServingEngine:
    """Multi-model batched classification service.

    ``mesh`` (a :class:`~repro.serve.mesh.ServeMesh`, or a bare
    ``jax.sharding.Mesh`` wrapped as a replicated ServeMesh) turns the
    engine multi-device: registered servables are placed across the mesh
    and every dispatched bucket is sharded over its "data" axis — one
    program across all devices, one gathered result, bit-identical to
    the single-device engine (see ``serve/mesh.py``).  The data-axis
    size must be a power of two <= ``max_batch`` so every pow2 bucket
    splits evenly.
    """

    def __init__(
        self,
        max_batch: int = 256,
        mesh: Optional[ServeMesh] = None,
        *,
        autotune: bool = False,
        autotune_repeats: int = 3,
        autotune_max_seconds: Optional[float] = None,
        faults=None,
    ):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if mesh is not None and not isinstance(mesh, ServeMesh):
            mesh = ServeMesh(mesh)
        if mesh is not None:
            nd = mesh.n_data
            if nd & (nd - 1):
                raise ValueError(
                    f'"data" axis size {nd} must be a power of two so pow2 '
                    f"buckets split evenly"
                )
            if nd > max_batch:
                raise ValueError(
                    f'"data" axis size {nd} exceeds max_batch={max_batch}'
                )
        self.max_batch = max_batch
        self.mesh = mesh
        # Optional FaultPlan (serve/faults.py): its on_engine_dispatch
        # seam runs at the top of every dispatch, so chaos tests can
        # inject engine failures mid-microbatch deterministically.
        self.faults = faults
        self.autotune_default = autotune
        self.autotune_repeats = autotune_repeats
        self.autotune_max_seconds = autotune_max_seconds
        self._servables: Dict[str, _Entry] = {}
        # Serializes entry mutation (swap/rollback/autotune) against
        # dispatch: a dispatch captures (servable, version) atomically,
        # so already-submitted microbatches complete on the old image
        # while new dispatches see the new one.  Re-entrant so the
        # service can pin one version across a multi-form microbatch
        # (``swap_guard``) around its own ``dispatch`` calls.
        self._lock = threading.RLock()

    @property
    def devices(self) -> int:
        """Mesh size (1 for the single-device engine)."""
        return 1 if self.mesh is None else self.mesh.devices

    @property
    def data_shards(self) -> int:
        """Batch shards per dispatched bucket (the "data" axis size)."""
        return 1 if self.mesh is None else self.mesh.n_data

    # --- registry ---------------------------------------------------------

    def _stamp(
        self,
        servable: ServableModel,
        source: Optional[ServableVersion],
        version_id: int,
    ) -> ServableVersion:
        """Engine-assigned monotonic id + provenance from ``source``
        (an explicit stamp, or the one riding on the servable); the
        content digest is computed when the source carries none."""
        return ServableVersion(
            version=version_id,
            epoch=source.epoch if source else 0,
            step=source.step if source else 0,
            digest=(
                source.digest
                if source and source.digest
                else servable_digest(servable)
            ),
        )

    def register(
        self,
        name: str,
        model: CoTMModel | ServableModel,
        config: Optional[CoTMConfig] = None,
        *,
        booleanize_method: str = "threshold",
        path: Optional[str] = None,
        booleanize_kw: Optional[Dict] = None,
        autotune: Optional[bool] = None,
        tuned: Optional[TunedPlan] = None,
        version: Optional[ServableVersion] = None,
    ) -> ServableModel:
        """Freeze (if needed) and register a model under a dataset key.

        Freezing happens here, exactly once — ``classify`` reuses the
        cached ``ServableModel`` arrays for every subsequent batch, and
        the freeze-time sparsity analysis (active-clause image, see
        ``serve/servable.py``) is attached here so the sparse eval paths
        are available.  The model's :class:`IngressSpec` (booleanize
        method + knobs, literal form of the eval path) is also fixed
        here; it is the static key of the raw-form classify executable.

        ``autotune`` (default: the engine's ``autotune`` flag) arms the
        per-bucket path autotuner — it runs at :meth:`warmup` (or via
        :meth:`autotune` directly), never per request.  ``tuned``
        attaches a previously measured :class:`TunedPlan` (e.g. restored
        alongside a checkpoint) without re-measuring.

        ``version`` (or a stamp already riding on a ``ServableModel``)
        supplies lifecycle provenance (epoch/step/digest); the monotonic
        id itself is engine-assigned — 1 for a fresh slot, and a
        re-register of a live slot continues its id sequence like a
        :meth:`swap` would.  The dispatched image is stamp-stripped so
        version churn never touches jit cache keys.
        """
        if isinstance(model, ServableModel):
            servable = model
        else:
            if config is None:
                raise ValueError("config required when registering a CoTMModel")
            servable = freeze(model, config)
        path_name = path or servable.config.eval_path
        eval_path = get_path(path_name)  # fail fast on unknown paths
        booleanize_kw = dict(booleanize_kw or {})
        ingress = eval_path.ingress_spec(
            servable.config.patch, method=booleanize_method, **booleanize_kw
        )
        source = version if version is not None else servable.version
        # Freeze-time sparsity analysis (skipped on clause-sharded meshes,
        # where the active set is not shard-uniform and placement drops it
        # anyway — sparse paths then resolve to their dense fallbacks).
        if self.mesh is None or not self.mesh.shard_clauses:
            servable = analyze_sparsity(servable)
        if tuned is not None:
            servable = dataclasses.replace(servable, tuned=tuned)
        stamp = self._stamp(servable, source, self._next_version_id(name))
        servable = dataclasses.replace(servable, version=None)
        if self.mesh is not None:
            # Placement happens once, here: replicated register image or
            # clause-sharded splits (validates n_clauses divisibility).
            servable = self.mesh.place_servable(servable)
        with self._lock:
            self._servables[name] = _Entry(
                servable=servable,
                booleanize_method=booleanize_method,
                booleanize_kw=booleanize_kw,
                path_name=path_name,
                ingress=ingress,
                stats=ServeStats(
                    devices=self.devices, data_shards=self.data_shards
                ),
                autotune=self.autotune_default if autotune is None else autotune,
                version=stamp,
            )
        return servable

    def _next_version_id(self, name: str) -> int:
        prev = self._servables.get(name)
        return prev.version.version + 1 if prev is not None else 1

    def load_checkpoint(
        self,
        name: str,
        directory: str,
        config: CoTMConfig,
        *,
        step: Optional[int] = None,
        booleanize_method: str = "threshold",
        path: Optional[str] = None,
    ) -> ServableModel:
        """Restore a trained model from ``checkpoint/`` and register it.

        Handles both checkpoint flavors: raw ``CoTMModel`` trees written
        by the training loop, and stamped register images written by
        :func:`~repro.checkpoint.checkpointer.save_servable` (the
        lifecycle driver's promote path) — the manifest's leaf names say
        which restore applies, so ``--ckpt-dir`` works on either."""
        import json
        import os

        from repro.checkpoint.checkpointer import (
            latest_step,
            restore_pytree,
            restore_servable,
        )

        resolved = latest_step(directory) if step is None else step
        if resolved is None:
            raise FileNotFoundError(f"no committed checkpoint under {directory}")
        manifest = os.path.join(
            directory, f"step_{resolved:08d}", "manifest.json"
        )
        with open(manifest) as f:
            leaves = json.load(f).get("leaves", {})
        if "include" in leaves and ".ta_state" not in leaves:
            servable, _ = restore_servable(config, directory, resolved)
            # Stamp provenance + TunedPlan ride on the servable itself.
            return self.register(
                name, servable,
                booleanize_method=booleanize_method, path=path,
            )
        template = CoTMModel(
            ta_state=jnp.zeros((config.n_clauses, config.n_literals), jnp.uint8),
            weights=jnp.zeros((config.n_classes, config.n_clauses), jnp.int32),
        )
        model, _, extra = restore_pytree(template, directory, resolved)
        extra = extra or {}
        stamp = ServableVersion.from_dict(extra.get("servable_version"))
        tuned = None
        if extra.get("tuned_plan"):
            tuned = TunedPlan.from_json(extra["tuned_plan"])
        return self.register(
            name, model, config, booleanize_method=booleanize_method, path=path,
            tuned=tuned, version=stamp if stamp != ServableVersion() else None,
        )

    def models(self) -> Tuple[str, ...]:
        return tuple(sorted(self._servables))

    def stats(self, name: str) -> ServeStats:
        return self._servables[name].stats

    def ingress_spec(self, name: str) -> IngressSpec:
        """The registered model's raw-form ingress description."""
        return self._servables[name].ingress

    def servable(self, name: str) -> ServableModel:
        """The frozen (and possibly placed) register image being served.

        Re-stamped with the entry's live :class:`ServableVersion` — the
        dispatched image itself is kept stamp-free (see :meth:`register`),
        so the stamp is attached on the way out for checkpointing and
        hand-offs.  Memoized per install: repeated reads of an unchanged
        version return the identical object."""
        with self._lock:
            entry = self._servables[name]
            if (
                entry.stamped is None
                or entry.stamped.version is not entry.version
            ):
                entry.stamped = dataclasses.replace(
                    entry.servable, version=entry.version
                )
            return entry.stamped

    def version(self, name: str) -> ServableVersion:
        """The lifecycle stamp of the version currently being served."""
        return self._servables[name].version

    def version_id(self, name: str) -> int:
        """Monotonic id of the version currently being served."""
        return self._servables[name].version.version

    def resolved_path(self, name: str, form: str, bucket: int) -> Tuple[str, Params]:
        """The (path, params) a (form, bucket) dispatch would actually
        evaluate: the tuned winner (or the registered path), with sparse
        paths resolved to their dense fallback when the servable carries
        no sparsity analysis.  Benchmarks use this to label rows with the
        path that really ran."""
        entry = self._servables[name]
        path_name, params = entry.resolve(form, self.bucket_for(bucket))
        resolved = get_path(path_name)
        from repro.serve.paths import resolve_path

        final = resolve_path(resolved, entry.servable)
        return final.name, (params if final is resolved else ())

    # --- lifecycle (ARCHITECTURE.md §Lifecycle) ---------------------------

    def swap_guard(self):
        """The engine lock, for callers that must pin ONE version across
        several ``dispatch`` calls (the service holds it around a multi-
        form-group microbatch so no microbatch spans two versions).
        Re-entrant with dispatch's own locking."""
        return self._lock

    def swap(
        self,
        name: str,
        model: CoTMModel | ServableModel,
        config: Optional[CoTMConfig] = None,
        *,
        version: Optional[ServableVersion] = None,
        tuned: Optional[TunedPlan] = None,
        retune: bool = False,
    ) -> ServableVersion:
        """Atomically replace ``name``'s weights under live load.

        The new image inherits the slot's serving contract — eval path,
        ingress spec, booleanize knobs, mesh placement — so only the
        weights change.  In-flight microbatches hold references to the
        old placed arrays and complete on the old version; dispatches
        entering after the install see the new one; nothing is dropped.

        Compiles only the delta: the dispatched image is stamp-stripped
        (version is never a jit key), geometry must match the live
        config, and the candidate's sparsity analysis is padded to
        :func:`~repro.serve.servable.active_pad` bins so swap storms
        re-use warm executables instead of compiling one shape per
        trained version.  A swap whose padded active count lands in an
        already-served bin compiles nothing (asserted with
        ``tools/recompile_guard.py`` in tests/test_lifecycle.py).

        ``tuned`` pins a plan measured for the candidate; by default the
        live version's plan is carried over (its ``digest`` marks it as
        tuned-for-a-prior-version); ``retune=True`` re-measures on the
        candidate instead.  Returns the freshly installed stamp; the
        displaced version is retained whole for :meth:`rollback`.
        """
        entry = self._servables[name]   # KeyError for unknown slots
        if isinstance(model, ServableModel):
            candidate = model
        else:
            if config is None:
                raise ValueError("config required when swapping in a CoTMModel")
            candidate = freeze(model, config)
        live_cfg = entry.servable.config
        if candidate.config != live_cfg:
            raise ValueError(
                f"swap({name!r}) config mismatch: a swap replaces weights "
                f"only — got {candidate.config!r}, serving {live_cfg!r} "
                f"(re-register for a geometry change)"
            )
        source = version if version is not None else candidate.version
        candidate = dataclasses.replace(candidate, sparsity=None)
        if self.mesh is None or not self.mesh.shard_clauses:
            # Per-version sparsity analysis (never cached across swaps —
            # the active set belongs to the weights), padded to pow2 bins
            # so the analysis *shape* is shared across versions.
            candidate = analyze_sparsity(candidate, pad_to="pow2")
        stamp = self._stamp(candidate, source, self._next_version_id(name))
        carried = entry.servable.tuned if tuned is None and not retune else tuned
        candidate = dataclasses.replace(
            candidate, tuned=carried, version=None
        )
        if self.mesh is not None:
            candidate = self.mesh.place_servable(candidate)
        with self._lock:
            entry.previous = (entry.servable, entry.version)
            entry.servable = candidate
            entry.version = stamp
            # Bucket warmth is per register image: the sparsity bin may
            # differ, so let compile accounting re-observe what actually
            # compiles (usually nothing — shapes are shared).
            entry.compiled = set()
        if retune:
            self.autotune(name)
        return stamp

    def rollback(self, name: str) -> ServableVersion:
        """Instantly restore the version displaced by the last swap.

        O(1): the previous placed image was retained whole, so no
        re-freeze, no sparsity re-analysis, no H2D transfer and no
        compile happen here.  The restored weights get a FRESH monotonic
        id (ids never regress) carrying the prior version's digest /
        epoch / step — the digest is what identifies the weights.
        A second rollback undoes the first (the pair flips back).
        """
        entry = self._servables[name]
        with self._lock:
            if entry.previous is None:
                raise ValueError(
                    f"rollback({name!r}): no previous version (nothing "
                    f"was swapped)"
                )
            prev_servable, prev_stamp = entry.previous
            entry.previous = (entry.servable, entry.version)
            entry.servable = prev_servable
            entry.version = ServableVersion(
                version=entry.version.version + 1,
                epoch=prev_stamp.epoch,
                step=prev_stamp.step,
                digest=prev_stamp.digest,
            )
            entry.compiled = set()
            return entry.version

    # --- degraded modes (ARCHITECTURE.md §Faults) -------------------------

    def degrade_path(self, name: str) -> Optional[str]:
        """Move ``name`` one step down the degradation chain.

        Called by the service's circuit breaker after repeated dispatch
        failures on the current path: the entry's eval path falls back
        along :func:`repro.serve.paths.degraded_fallback` (sparse ->
        dense twin, fused -> matmul, ... -> dense) and its ingress spec
        is rebuilt for the fallback's literal form.  The tuned plan is
        dropped (its winners belong to the failing path) and bucket
        warmth resets — correctness over speed is the whole point of the
        degraded mode.  Outputs stay bit-identical to ``kernels/ref.py``
        by the multi-path equivalence contract.  Returns the new path
        name, or None when already at the bottom of the chain.
        """
        from repro.serve.paths import degraded_fallback

        entry = self._servables[name]
        with self._lock:
            nxt = degraded_fallback(entry.path_name)
            if nxt is None:
                return None
            eval_path = get_path(nxt)
            entry.path_name = nxt
            entry.ingress = eval_path.ingress_spec(
                entry.servable.config.patch,
                method=entry.booleanize_method,
                **entry.booleanize_kw,
            )
            entry.servable = dataclasses.replace(entry.servable, tuned=None)
            entry.compiled = set()
            entry.stats.fallback_path = nxt
            entry.stats.degrade_steps += 1
            return nxt

    def shrink_mesh(self) -> Optional[ServeMesh]:
        """Re-place every registered servable on a shrunk mesh after a
        device loss on the data axis.

        Halves the batch-shard count (model axis kept — clause shards
        hold model state; the data axis holds only request rows, so it
        is the one that can shed devices without re-freezing anything)
        and re-places each entry's register image via
        ``ServeMesh.place_servable`` — an O(model-size) device_put, no
        re-freeze, no sparsity re-analysis.  In-flight dispatches hold
        references to the old placed arrays and complete on the old
        mesh; the engine lock makes the cutover atomic, the same
        discipline as :meth:`swap`.  Bucket warmth resets (bucket
        shardings changed).  Returns the new mesh, or None when there is
        nothing to shrink (unmeshed, or data axis already 1).
        """
        with self._lock:
            if self.mesh is None:
                return None
            new = self.mesh.shrunk()
            if new is None:
                return None
            self.mesh = new
            for entry in self._servables.values():
                entry.servable = new.place_servable(entry.servable)
                entry.compiled = set()
                entry.stamped = None
                entry.stats.devices = new.devices
                entry.stats.data_shards = new.n_data
            return new

    # --- serving ----------------------------------------------------------

    def bucket_for(self, n: int) -> int:
        """Smallest power-of-two >= n, clamped to ``max_batch``.

        On a mesh, additionally clamped from below to the data-axis size
        so the padded batch always divides evenly over the batch shards
        (jit input shardings require exact divisibility).
        """
        if n < 1:
            raise ValueError("empty request")
        bucket = min(1 << (n - 1).bit_length(), self.max_batch)
        return max(bucket, self.data_shards)

    def autotune(
        self,
        name: str,
        buckets=None,
        *,
        forms=("literals", "raw"),
        repeats: Optional[int] = None,
        max_seconds: Optional[float] = None,
    ) -> TunedPlan:
        """Measure eval-path candidates per (form, bucket) and pin the
        winners on the registered servable (see ``serve/autotune.py``).

        Default buckets: the engine's bucket range endpoints
        (``bucket_for(1)`` and ``max_batch``) — :class:`TunedPlan` lookup
        maps intermediate buckets to their nearest tuned neighbor, so the
        endpoints cover the whole range at a fraction of the sweep cost;
        pass an explicit list to tune every bucket a workload hits.  The
        winning plan and the full measurement report land in
        :class:`ServeStats` (``stats.autotune``); the plan also rides on
        the servable (``servable(name).tuned``) for checkpointing.
        """
        entry = self._servables[name]
        if buckets is None:
            buckets = dict.fromkeys((self.bucket_for(1), self.max_batch))
        buckets = [self.bucket_for(int(b)) for b in buckets]
        plan, report = autotune_servable(
            entry.servable,
            entry.path_name,
            entry.ingress,
            buckets,
            forms,
            repeats=self.autotune_repeats if repeats is None else repeats,
            smesh=self.mesh,
            max_seconds=(
                self.autotune_max_seconds if max_seconds is None else max_seconds
            ),
        )
        with self._lock:
            entry.servable = dataclasses.replace(entry.servable, tuned=plan)
        entry.stats.autotune = {
            **report.as_dict(),
            "plan": [list(e) for e in plan.entries],
        }
        return plan

    def warmup(
        self, name: str, buckets=None, *, forms=("literals", "raw")
    ) -> Tuple[int, ...]:
        """Pre-compile buckets so request latency excludes jit compiles.

        By default warms BOTH request forms per bucket — the raw-form
        fused graph (ingress + eval) and the literal-form step — since
        they compile separately; single-form workloads can pass
        ``forms=('raw',)`` or ``('literals',)`` to skip the other half's
        compile cost.  Default buckets: every power-of-two up to
        ``max_batch``.  Sizes are normalized through :meth:`bucket_for`
        first, so ``buckets=[10]`` compiles (and reports) bucket 16.
        Only compile accounting is touched — request/latency/hit stats
        stay clean.  Returns the buckets newly compiled, in order.

        Models registered with ``autotune=True`` are tuned here first
        (once), so warmup compiles exactly the executables dispatch will
        hit — each bucket's *tuned* path, in both forms.  Dispatching any
        (form, bucket) the default warmup covered then never recompiles
        (the no-recompile contract, tests/test_autotune.py).
        """
        entry = self._servables[name]
        if unknown := set(forms) - {"literals", "raw"}:
            raise ValueError(f"unknown warmup forms: {sorted(unknown)}")
        if entry.autotune and entry.servable.tuned is None:
            self.autotune(name, forms=forms)
        if buckets is None:
            buckets = []
            b = 1
            while b < self.max_batch:
                buckets.append(b)
                b <<= 1
            buckets.append(self.max_batch)
        for b in buckets:
            if not 1 <= b <= self.max_batch:
                raise ValueError(
                    f"warmup bucket {b} outside [1, max_batch={self.max_batch}]"
                )
        compiled = []
        for b in dict.fromkeys(self.bucket_for(b) for b in buckets):
            fresh = False
            zeros_for = {"literals": self._zero_literals, "raw": self._zero_raw}
            for form, zeros in ((f, zeros_for[f]) for f in forms):
                if (form, b) in entry.compiled:
                    continue
                preds, sums, _, _ = self._submit_bucket(
                    entry, zeros(entry, b), form, record_hit=False
                )
                jax.block_until_ready([preds, sums])
                fresh = True
            if fresh:
                compiled.append(b)
        return tuple(compiled)

    def _zero_literals(self, entry: _Entry, b: int) -> np.ndarray:
        spec = entry.servable.config.patch
        if get_path(entry.path_name).input_form == PACKED:
            return np.zeros((b, spec.n_patches, spec.n_words), np.uint32)
        return np.zeros((b, spec.n_patches, spec.n_literals), np.uint8)

    def _zero_raw(self, entry: _Entry, b: int) -> np.ndarray:
        return np.zeros((b,) + raw_trailing_shape(entry.ingress), np.uint8)

    def _submit_bucket(
        self, entry: _Entry, arr: np.ndarray, form: str, record_hit: bool = True
    ):
        """Pad one <= max_batch chunk to its bucket and dispatch the jitted
        step WITHOUT blocking; returns ``(preds, sums, n, bucket)`` with
        lazy device arrays.  Records bucket hit/compile accounting."""
        n = arr.shape[0]
        bucket = self.bucket_for(n)
        if bucket != n:
            pad = np.zeros((bucket - n,) + arr.shape[1:], arr.dtype)
            arr = np.concatenate([arr, pad], axis=0)
        # The autotuned winner for this (form, bucket), or the registered
        # path at defaults.  Literal-form winners share the registered
        # path's input form (autotune admissibility), so ``arr`` is
        # always in the right form already.
        path_name, params = entry.resolve(form, bucket)
        if self.mesh is not None:
            # One placed (data-sharded) buffer; the jitted step runs as a
            # single program across the mesh and GSPMD/shard_map gathers
            # nothing until .result() reads the global output.
            x = self.mesh.place_batch(arr)
            if self.mesh.shard_clauses:
                preds, sums = classify_step_clause_sharded(
                    entry.servable, x,
                    smesh=self.mesh,
                    path_name=path_name,
                    ingress=entry.ingress if form == "raw" else None,
                )
            elif form == "raw":
                preds, sums = classify_raw_step(
                    entry.servable, x, path_name, entry.ingress, params
                )
            else:
                preds, sums = classify_step(
                    entry.servable, x, path_name, params=params
                )
        elif form == "raw":
            preds, sums = classify_raw_step(
                entry.servable, jnp.asarray(arr), path_name, entry.ingress, params
            )
        else:
            preds, sums = classify_step(
                entry.servable, jnp.asarray(arr), path_name, params=params
            )
        st = entry.stats
        if record_hit:
            st.bucket_hits[bucket] = st.bucket_hits.get(bucket, 0) + 1
        entry.compiled.add((form, bucket))
        if bucket not in st.compiled_buckets:
            st.compiled_buckets = st.compiled_buckets + (bucket,)
        return preds, sums, n, bucket

    def _validate_preprocessed(self, lits: np.ndarray, path, spec) -> None:
        """Reject wrong-form preprocessed literals instead of serving garbage.

        ``preprocessed=True`` requests must already be in the path's input
        form: dense uint8 ``[n, P, 2o]`` or packed uint32 ``[n, P, W]``.
        A dense array fed to a packed path (or vice versa) would silently
        produce garbage predictions — the dtypes happen to broadcast.
        """
        if path.input_form == PACKED:
            want_dtype, want_trail, form = (
                np.uint32, (spec.n_patches, spec.n_words),
                f"packed uint32 [n, P={spec.n_patches}, W={spec.n_words}]",
            )
        else:
            want_dtype, want_trail, form = (
                np.uint8, (spec.n_patches, spec.n_literals),
                f"dense uint8 [n, P={spec.n_patches}, 2o={spec.n_literals}]",
            )
        if lits.ndim != 3 or lits.shape[1:] != want_trail or lits.dtype != want_dtype:
            raise ValueError(
                f"preprocessed literals for eval path {path.name!r} must be "
                f"{form}; got {lits.dtype} {list(lits.shape)} "
                f"(use data.pipeline.preprocess_for_serving(..., "
                f"packed={path.input_form == PACKED}))"
            )

    def validate_raw(self, name: str, raw_images: np.ndarray) -> np.ndarray:
        """Check a raw pixel batch against the model's ingress geometry.

        Raises KeyError for unknown models and ValueError for empty or
        wrongly shaped requests; returns the batch as an ndarray.  Cheap —
        this is all the host-side work a raw request pays before the
        device graph.
        """
        entry = self._servables[name]
        raw = np.asarray(raw_images)
        if len(raw) == 0:
            raise ValueError("empty request")
        want = raw_trailing_shape(entry.ingress)
        if raw.shape[1:] != want:
            raise ValueError(
                f"raw images for {name!r} must be [n, {', '.join(map(str, want))}] "
                f"(method={entry.booleanize_method!r}); got {list(raw.shape)}"
            )
        return raw

    def preprocess(
        self, name: str, raw_images: np.ndarray, *, preprocessed: bool = False
    ) -> np.ndarray:
        """Run the HOST-side ingress for a registered model.

        Returns literals in the model's eval-path input form (dense uint8
        or packed uint32).  With ``preprocessed=True`` the input is only
        validated against that form.  Kept as the reference baseline the
        device-resident ingress is asserted bit-identical against, and
        for callers that want to preprocess once and submit
        ``preprocessed=True`` many times.
        """
        entry = self._servables[name]
        path = get_path(entry.path_name)
        if len(raw_images) == 0:
            raise ValueError("empty request")
        if preprocessed:
            lits = np.asarray(raw_images)
            self._validate_preprocessed(lits, path, entry.servable.config.patch)
            return lits
        # The registered ingress knobs apply to BOTH ingresses — a host
        # baseline run with default knobs against a device path with
        # custom ones would silently break the bit-identity contract.
        # (kernel_backend is an IngressSpec-only knob, not a booleanize
        # parameter.)
        host_kw = {
            k: v for k, v in entry.booleanize_kw.items()
            if k in ("threshold", "block_size", "c", "levels")
        }
        return preprocess_for_serving(
            raw_images,
            entry.servable.config.patch,
            method=entry.booleanize_method,
            packed=path.input_form == PACKED,
            **host_kw,
        )

    def dispatch(
        self,
        name: str,
        images: np.ndarray,
        *,
        preprocessed: bool = False,
        ingress: str = "device",
    ) -> InFlightClassify:
        """Submit one request batch and return without waiting on device.

        ``images``: raw uint8 pixels ``[n, Y, X]`` (default; the fused
        device ingress), or — with ``preprocessed`` — literals already in
        the path's input form.  ``ingress='host'`` routes raw pixels
        through the legacy host pipeline instead.  Requests larger than
        ``max_batch`` are dispatched in ``max_batch`` slices.
        """
        if ingress not in ("device", "host"):
            raise ValueError(f"ingress must be 'device' or 'host', got {ingress!r}")
        entry = self._servables[name]
        if self.faults is not None:
            # Chaos seam: may raise InjectedEngineError before any host or
            # device work, standing in for an XLA/runtime dispatch failure.
            self.faults.on_engine_dispatch(name)
        t0 = time.perf_counter()
        if preprocessed:
            arr = self.preprocess(name, images, preprocessed=True)
            form = "literals"
        elif ingress == "host":
            arr = self.preprocess(name, images)
            form = "literals"
        else:
            arr = self.validate_raw(name, images)
            form = "raw"
        t1 = time.perf_counter()
        n = arr.shape[0]
        # The lock pins ONE (servable, version) across every slice of this
        # request: a concurrent swap either lands before (whole request on
        # the new version) or after (whole request on the old, which stays
        # referenced by the submitted executables until .result()).
        with self._lock:
            ver = entry.version.version
            parts = [
                self._submit_bucket(entry, arr[i : i + self.max_batch], form)
                for i in range(0, n, self.max_batch)
            ]
        return InFlightClassify(entry, parts, n, t0, t1, version=ver)

    def classify(
        self,
        name: str,
        images: np.ndarray,
        *,
        preprocessed: bool = False,
        ingress: str = "device",
    ) -> ClassifyResult:
        """Classify one request batch (blocking ``dispatch().result()``)."""
        return self.dispatch(
            name, images, preprocessed=preprocessed, ingress=ingress
        ).result()
