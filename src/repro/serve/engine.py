"""Batched ConvCoTM serving engine.

The software counterpart of the chip's continuous classification mode
(Sec. IV-C): models are frozen once into :class:`ServableModel` register
images, registered under a dataset key (MNIST / Fashion-MNIST /
Kuzushiji-MNIST, ...), and request batches stream through a jitted
classify step.

Batch bucketing
---------------
jit recompiles per input shape, so arbitrary request sizes would compile
without bound.  Requests are padded up to the nearest power-of-two bucket
(clamped to ``max_batch``) and results sliced back — at most
``log2(max_batch) + 1`` compilations per (model, path) ever, after which
every request hits a warm executable.  Padding rows are all-zero literal
words: they produce garbage predictions that are sliced off, and cannot
perturb real rows (no cross-batch interaction in the datapath).

Per-request latency and per-bucket hit/compile counts are recorded so the
throughput can be compared against the paper's 60.3k classifications/s
(measured numbers in EXPERIMENTS.md §Serve).

This is the synchronous library layer: one ``classify`` call per request
batch.  Online serving — request queue, admission control, latency-aware
microbatching across concurrent submitters, multi-model fairness — lives
one layer up in :mod:`repro.serve.service` (``ServingService``), which
wraps this engine and reuses :meth:`ServingEngine.preprocess` so service
results are bit-identical to direct ``classify`` calls.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clauses as cl
from repro.core.cotm import CoTMConfig, CoTMModel
from repro.data.pipeline import preprocess_for_serving
from repro.serve.paths import PACKED, get_path, run_path
from repro.serve.servable import ServableModel, freeze

__all__ = ["ClassifyResult", "ServeStats", "ServingEngine", "classify_step"]


@dataclasses.dataclass
class ClassifyResult:
    """One request's outcome."""

    predictions: np.ndarray   # int32 [n]
    class_sums: np.ndarray    # int32 [n, m]
    latency_s: float          # wall clock incl. host preprocessing
    bucket: int               # padded batch size actually executed


@dataclasses.dataclass
class ServeStats:
    """Running per-model accounting."""

    requests: int = 0
    images: int = 0
    total_latency_s: float = 0.0
    bucket_hits: Dict[int, int] = dataclasses.field(default_factory=dict)
    compiled_buckets: Tuple[int, ...] = ()

    @property
    def classifications_per_s(self) -> float:
        return self.images / self.total_latency_s if self.total_latency_s else 0.0

    @property
    def mean_latency_us(self) -> float:
        return self.total_latency_s / self.requests * 1e6 if self.requests else 0.0

    def as_dict(self) -> Dict:
        return {
            "requests": self.requests,
            "images": self.images,
            "classifications_per_s": self.classifications_per_s,
            "mean_latency_us": self.mean_latency_us,
            "bucket_hits": dict(self.bucket_hits),
            "compiled_buckets": list(self.compiled_buckets),
        }


@dataclasses.dataclass
class _Entry:
    servable: ServableModel
    booleanize_method: str
    path_name: str
    stats: ServeStats


def _classify_step(servable: ServableModel, lits: jax.Array, path_name: str):
    path = get_path(path_name)
    v = run_path(path, servable, lits)
    return cl.argmax_predict(v), v


#: The single jitted classify step: (servable, literals, path_name) ->
#: (predictions, class_sums).  Module-level so every engine instance (and
#: ``train.serve_step.make_tm_serve_fn``) shares one compile cache; jit
#: keys on (bucket shape, model config, path) — the bounded-recompile
#: contract.
classify_step = jax.jit(_classify_step, static_argnames=("path_name",))


class ServingEngine:
    """Multi-model batched classification service."""

    def __init__(self, max_batch: int = 256):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.max_batch = max_batch
        self._models: Dict[str, _Entry] = {}
        self._step = classify_step

    # --- registry ---------------------------------------------------------

    def register(
        self,
        name: str,
        model: CoTMModel | ServableModel,
        config: Optional[CoTMConfig] = None,
        *,
        booleanize_method: str = "threshold",
        path: Optional[str] = None,
    ) -> ServableModel:
        """Freeze (if needed) and register a model under a dataset key.

        Freezing happens here, exactly once — ``classify`` reuses the
        cached ``ServableModel`` arrays for every subsequent batch.
        """
        if isinstance(model, ServableModel):
            servable = model
        else:
            if config is None:
                raise ValueError("config required when registering a CoTMModel")
            servable = freeze(model, config)
        path_name = path or servable.config.eval_path
        get_path(path_name)  # fail fast on unknown paths
        self._models[name] = _Entry(
            servable=servable,
            booleanize_method=booleanize_method,
            path_name=path_name,
            stats=ServeStats(),
        )
        return servable

    def load_checkpoint(
        self,
        name: str,
        directory: str,
        config: CoTMConfig,
        *,
        step: Optional[int] = None,
        booleanize_method: str = "threshold",
        path: Optional[str] = None,
    ) -> ServableModel:
        """Restore a trained model from ``checkpoint/`` and register it."""
        from repro.checkpoint.checkpointer import restore_pytree

        template = CoTMModel(
            ta_state=jnp.zeros((config.n_clauses, config.n_literals), jnp.uint8),
            weights=jnp.zeros((config.n_classes, config.n_clauses), jnp.int32),
        )
        model, _, _ = restore_pytree(template, directory, step)
        return self.register(
            name, model, config, booleanize_method=booleanize_method, path=path
        )

    def models(self) -> Tuple[str, ...]:
        return tuple(sorted(self._models))

    def servable(self, name: str) -> ServableModel:
        return self._models[name].servable

    def stats(self, name: str) -> ServeStats:
        return self._models[name].stats

    # --- serving ----------------------------------------------------------

    def bucket_for(self, n: int) -> int:
        """Smallest power-of-two >= n, clamped to ``max_batch``."""
        if n < 1:
            raise ValueError("empty request")
        return min(1 << (n - 1).bit_length(), self.max_batch)

    def warmup(self, name: str, buckets=None) -> Tuple[int, ...]:
        """Pre-compile buckets so request latency excludes jit compiles.

        Default: every power-of-two bucket up to ``max_batch``.  Sizes are
        normalized through :meth:`bucket_for` first, so ``buckets=[10]``
        compiles (and reports) bucket 16.  Only compile accounting is
        touched — request/latency/hit stats stay clean.  Returns the
        buckets actually compiled, in order.
        """
        entry = self._models[name]
        path = get_path(entry.path_name)
        spec = entry.servable.config.patch
        if buckets is None:
            buckets = []
            b = 1
            while b < self.max_batch:
                buckets.append(b)
                b <<= 1
            buckets.append(self.max_batch)
        for b in buckets:
            if not 1 <= b <= self.max_batch:
                raise ValueError(
                    f"warmup bucket {b} outside [1, max_batch={self.max_batch}]"
                )
        compiled = []
        for b in dict.fromkeys(self.bucket_for(b) for b in buckets):
            if b in entry.stats.compiled_buckets:
                continue
            if path.input_form == PACKED:
                lits = np.zeros((b, spec.n_patches, spec.n_words), np.uint32)
            else:
                lits = np.zeros((b, spec.n_patches, spec.n_literals), np.uint8)
            self._run_bucket(entry, lits, record_hit=False)
            compiled.append(b)
        return tuple(compiled)

    def _run_bucket(
        self, entry: _Entry, lits: np.ndarray, record_hit: bool = True
    ) -> Tuple[np.ndarray, np.ndarray, int]:
        n = lits.shape[0]
        bucket = self.bucket_for(n)
        if bucket != n:
            pad = np.zeros((bucket - n,) + lits.shape[1:], lits.dtype)
            lits = np.concatenate([lits, pad], axis=0)
        preds, sums = self._step(entry.servable, jnp.asarray(lits), entry.path_name)
        preds, sums = jax.block_until_ready((preds, sums))
        if record_hit:
            entry.stats.bucket_hits[bucket] = entry.stats.bucket_hits.get(bucket, 0) + 1
        if bucket not in entry.stats.compiled_buckets:
            entry.stats.compiled_buckets = entry.stats.compiled_buckets + (bucket,)
        return np.asarray(preds)[:n], np.asarray(sums)[:n], bucket

    def _validate_preprocessed(self, lits: np.ndarray, path, spec) -> None:
        """Reject wrong-form preprocessed literals instead of serving garbage.

        ``preprocessed=True`` requests must already be in the path's input
        form: dense uint8 ``[n, P, 2o]`` or packed uint32 ``[n, P, W]``.
        A dense array fed to a packed path (or vice versa) would silently
        produce garbage predictions — the dtypes happen to broadcast.
        """
        if path.input_form == PACKED:
            want_dtype, want_trail, form = (
                np.uint32, (spec.n_patches, spec.n_words),
                f"packed uint32 [n, P={spec.n_patches}, W={spec.n_words}]",
            )
        else:
            want_dtype, want_trail, form = (
                np.uint8, (spec.n_patches, spec.n_literals),
                f"dense uint8 [n, P={spec.n_patches}, 2o={spec.n_literals}]",
            )
        if lits.ndim != 3 or lits.shape[1:] != want_trail or lits.dtype != want_dtype:
            raise ValueError(
                f"preprocessed literals for eval path {path.name!r} must be "
                f"{form}; got {lits.dtype} {list(lits.shape)} "
                f"(use data.pipeline.preprocess_for_serving(..., "
                f"packed={path.input_form == PACKED}))"
            )

    def preprocess(
        self, name: str, raw_images: np.ndarray, *, preprocessed: bool = False
    ) -> np.ndarray:
        """Run the host-side ingress for a registered model.

        Returns literals in the model's eval-path input form (dense uint8
        or packed uint32).  With ``preprocessed=True`` the input is only
        validated against that form.  This is the single ingress shared by
        :meth:`classify` and the async ``ServingService`` — both therefore
        produce bit-identical results for the same images.
        """
        entry = self._models[name]
        path = get_path(entry.path_name)
        if len(raw_images) == 0:
            raise ValueError("empty request")
        if preprocessed:
            lits = np.asarray(raw_images)
            self._validate_preprocessed(lits, path, entry.servable.config.patch)
            return lits
        return preprocess_for_serving(
            raw_images,
            entry.servable.config.patch,
            method=entry.booleanize_method,
            packed=path.input_form == PACKED,
        )

    def classify(
        self, name: str, raw_images: np.ndarray, *, preprocessed: bool = False
    ) -> ClassifyResult:
        """Classify one request batch against a registered model.

        ``raw_images``: uint8 images ``[n, Y, X]`` (booleanized host-side
        with the model's registered method), or — with ``preprocessed`` —
        literals already in the path's input form (validated against it).
        Requests larger than ``max_batch`` are served in ``max_batch``
        slices.
        """
        entry = self._models[name]
        t0 = time.perf_counter()
        lits = self.preprocess(name, raw_images, preprocessed=preprocessed)
        n = lits.shape[0]
        preds, sums, buckets = [], [], []
        for i in range(0, n, self.max_batch):
            p, v, bucket = self._run_bucket(entry, lits[i : i + self.max_batch])
            preds.append(p)
            sums.append(v)
            buckets.append(bucket)
        dt = time.perf_counter() - t0

        st = entry.stats
        st.requests += 1
        st.images += n
        st.total_latency_s += dt
        return ClassifyResult(
            predictions=np.concatenate(preds),
            class_sums=np.concatenate(sums),
            latency_s=dt,
            bucket=max(buckets),
        )
