"""Fault injection, degradation policy, and serving-health state.

The ASIC's dependability story is trivial: a fixed-function datapath at
27.8 MHz has no failure modes short of power loss.  The software serving
spine (ServingService -> MicrobatchScheduler -> ServingEngine ->
ServeMesh) has plenty — a dead dispatch thread leaves ``submit()``
futures pending forever, a malformed payload in a coalesced microbatch
poisons its batchmates, a lost device kills every subsequent dispatch —
and ``distributed/fault_tolerance.py`` covers training only.  This
module is the serving analogue (ARCHITECTURE.md §Faults):

``FaultPlan``
    A deterministic injection plan threaded through the service and
    engine seams: worker crash at dispatch *k*, fixed slow-dispatch
    delays, poisoned payload marking, engine exceptions mid-microbatch,
    simulated device loss on the mesh's data axis.  Counter-based and
    thread-safe, so chaos tests replay exactly.

``DegradationPolicy``
    The circuit-breaker knobs: how many consecutive dispatch failures
    trip a fallback along the dense-fallback chain in ``serve/paths.py``
    (sparse -> dense twin, fused -> matmul, ... -> dense), and how many
    worker restarts (with bounded backoff) are attempted before the
    service drains instead of crash-looping.

``ServiceHealth``
    The observable state machine — ``healthy`` / ``degraded`` /
    ``draining`` — with the last-fault cause, the fallback path in use,
    and fault counters; exposed through ``ServiceStats`` snapshots.

Structured errors (``WorkerCrashed``, ``PoisonedPayload``,
``DeviceLost``, ``ServiceExpired``) are what request futures resolve
with when their request cannot be served: the request-lifetime guarantee
is that every admitted future resolves — with a result or one of these —
never hangs (``tests/test_faults.py`` chaos suite).

``chaos_soak`` drives an adversarial open-loop load (via
``serve/loadgen.py``'s malformed/abandon knobs) against a service with
an injection plan and tallies how every future resolved.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Optional, Set, Tuple

__all__ = [
    "FaultError",
    "WorkerCrashed",
    "PoisonedPayload",
    "DeviceLost",
    "InjectedEngineError",
    "ServiceExpired",
    "FaultPlan",
    "DegradationPolicy",
    "ServiceHealth",
    "chaos_soak",
]


class FaultError(RuntimeError):
    """Structured serving fault: carries what broke (``kind``) and where
    (``model``, when known) so callers can triage without string
    parsing.  Every fault a request future resolves with derives from
    this (or is :class:`ServiceExpired`)."""

    kind = "fault"

    def __init__(self, message: str, *, model: Optional[str] = None):
        super().__init__(message)
        self.model = model


class WorkerCrashed(FaultError):
    """The dispatch worker died with this microbatch in flight.  The
    requests were never computed; the service restarts the worker with
    bounded backoff (``DegradationPolicy``) and keeps serving."""

    kind = "worker_crash"


class PoisonedPayload(FaultError):
    """A request payload marked poisoned (or failing only at dispatch)
    was isolated out of its microbatch; batchmates are unaffected."""

    kind = "poisoned_payload"


class DeviceLost(FaultError):
    """A mesh device (simulated) dropped out mid-dispatch; the service
    re-places servables on a shrunk mesh and retries."""

    kind = "device_loss"


class InjectedEngineError(FaultError):
    """A FaultPlan-injected engine failure mid-microbatch (stands in for
    a real XLA/runtime error at dispatch)."""

    kind = "engine_error"


class ServiceExpired(Exception):
    """The request's deadline passed before dispatch; it was shed from
    the queue without computing a dead answer."""

    def __init__(self, model: str, deadline_s: float, waited_s: float):
        super().__init__(
            f"request for {model!r} expired before dispatch "
            f"(deadline {deadline_s * 1e3:.1f} ms, waited "
            f"{waited_s * 1e3:.1f} ms)"
        )
        self.model = model
        self.deadline_s = deadline_s
        self.waited_s = waited_s


@dataclasses.dataclass
class FaultPlan:
    """Deterministic fault injection for the serving seams.

    Dispatch sequence numbers are 1-based and counted per seam:
    ``crash_at`` / ``device_loss_at`` / ``slow_dispatch_s`` fire on the
    *service* dispatch counter (one per microbatch dispatch attempt,
    quarantine retries excluded so a plan stays a script, not a
    feedback loop); ``engine_error_at`` fires on the *engine* dispatch
    counter (one per ``ServingEngine.dispatch`` call).  Payloads marked
    with :meth:`poison` fail at dispatch every time they are seen —
    poison is a property of the payload, which is exactly what lets the
    quarantine isolate it from its batchmates.

    All mutation is behind one lock: the seams run on the dispatch
    worker thread while tests poke the plan from the event loop.
    """

    crash_at: Tuple[int, ...] = ()          # service dispatches that crash the worker
    device_loss_at: Tuple[int, ...] = ()    # service dispatches that lose a device
    engine_error_at: Tuple[int, ...] = ()   # engine dispatches that raise
    slow_dispatch_s: float = 0.0            # added to every service dispatch

    def __post_init__(self):
        self._lock = threading.Lock()
        self._service_dispatches = 0
        self._engine_dispatches = 0
        self._poisoned: Set[int] = set()

    # --- seams ------------------------------------------------------------

    def on_service_dispatch(self, model: str) -> None:
        """Runs at the top of every service microbatch dispatch (on the
        dispatch worker thread).  May delay, crash the worker, or lose a
        device — in that order, so a plan can combine them."""
        with self._lock:
            self._service_dispatches += 1
            seq = self._service_dispatches
        if self.slow_dispatch_s > 0.0:
            time.sleep(self.slow_dispatch_s)
        if seq in self.crash_at:
            raise WorkerCrashed(
                f"injected worker crash at dispatch #{seq}", model=model
            )
        if seq in self.device_loss_at:
            raise DeviceLost(
                f"injected device loss at dispatch #{seq}", model=model
            )

    def on_engine_dispatch(self, model: str) -> None:
        """Runs inside ``ServingEngine.dispatch`` before any device work."""
        with self._lock:
            self._engine_dispatches += 1
            seq = self._engine_dispatches
        if seq in self.engine_error_at:
            raise InjectedEngineError(
                f"injected engine error at engine dispatch #{seq}", model=model
            )

    # --- poisoned payloads ------------------------------------------------

    def poison(self, payload) -> "FaultPlan":
        """Mark ``payload`` (an ndarray, by identity) as poisoned: any
        dispatch that includes it raises :class:`PoisonedPayload`.  The
        service keeps the submitted array object on the queued request,
        so identity survives admission."""
        with self._lock:
            self._poisoned.add(id(payload))
        return self

    def is_poisoned(self, payload) -> bool:
        with self._lock:
            return id(payload) in self._poisoned

    def check_payload(self, payload, model: str) -> None:
        if self.is_poisoned(payload):
            raise PoisonedPayload(
                "poisoned payload isolated at dispatch", model=model
            )

    # --- introspection ----------------------------------------------------

    @property
    def service_dispatches(self) -> int:
        with self._lock:
            return self._service_dispatches

    @property
    def engine_dispatches(self) -> int:
        with self._lock:
            return self._engine_dispatches


@dataclasses.dataclass(frozen=True)
class DegradationPolicy:
    """Circuit-breaker and supervision knobs (ARCHITECTURE.md §Faults).

    ``failure_threshold``  — consecutive dispatch failures for one model
                             before its eval path falls back one step
                             along the dense-fallback chain.
    ``max_worker_restarts``— dispatch-worker restarts before the service
                             gives up and drains (fails queued requests)
                             instead of crash-looping.
    ``restart_backoff_s``  — first restart delay; doubles per restart up
                             to ``restart_backoff_max_s``.
    """

    failure_threshold: int = 3
    max_worker_restarts: int = 5
    restart_backoff_s: float = 0.05
    restart_backoff_max_s: float = 1.0

    def __post_init__(self):
        if self.failure_threshold < 1:
            raise ValueError("failure_threshold must be >= 1")
        if self.max_worker_restarts < 0:
            raise ValueError("max_worker_restarts must be >= 0")
        if self.restart_backoff_s < 0 or self.restart_backoff_max_s < 0:
            raise ValueError("backoff delays must be >= 0")

    def backoff_s(self, restart_n: int) -> float:
        """Delay before restart ``restart_n`` (1-based), doubling and
        capped."""
        return min(
            self.restart_backoff_s * (2 ** max(restart_n - 1, 0)),
            self.restart_backoff_max_s,
        )


@dataclasses.dataclass
class ServiceHealth:
    """Snapshot of the service's degradation state machine.

    ``state`` moves ``healthy`` -> ``degraded`` (a fallback path or a
    shrunk mesh is in use, or a worker was restarted) -> ``draining``
    (stop() was called, or the worker-restart budget ran out and the
    service is shedding its queue).  Degraded is sticky until the
    operator swaps/re-registers: the breaker never flaps back on its
    own.  Counters are service-wide; per-model expiry/quarantine counts
    live on ``ServiceStats``.
    """

    state: str = "healthy"
    last_fault: Optional[str] = None       # cause string of the latest fault
    fallback_path: Optional[str] = None    # engine path in use when degraded
    worker_restarts: int = 0
    dispatch_failures: int = 0
    quarantined: int = 0                   # requests isolated out of batches
    expired: int = 0                       # requests shed past deadline
    device_losses: int = 0

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)

    def note_fault(self, cause: Exception) -> None:
        self.last_fault = f"{type(cause).__name__}: {cause}"

    def degrade(self, cause: Exception) -> None:
        self.note_fault(cause)
        if self.state == "healthy":
            self.state = "degraded"


async def chaos_soak(
    service,
    name: str,
    requests,
    rate: float,
    *,
    seed: int = 0,
    deadline_s: Optional[float] = None,
    malformed_frac: float = 0.0,
    abandon_frac: float = 0.0,
    preprocessed: bool = False,
    gather_timeout_s: float = 30.0,
) -> Dict:
    """Drive an adversarial open-loop load and tally how it resolved.

    One driver for the chaos tests and ``bench_service.py``: Poisson
    arrivals (``serve/loadgen.py``) with a fraction of malformed
    payloads and client abandons, against a service that may carry a
    :class:`FaultPlan`.  Every admitted future is awaited with a
    timeout — a timeout means a future HUNG, which is the one outcome
    the robustness layer must never produce — and the tally of results
    vs structured errors is returned alongside the service's health
    snapshot.
    """
    import asyncio

    from repro.serve.loadgen import poisson_open_loop

    report = await poisson_open_loop(
        service, name, requests, rate,
        seed=seed, preprocessed=preprocessed, deadline_s=deadline_s,
        malformed_frac=malformed_frac, abandon_frac=abandon_frac,
    )
    futures = [f for _, f in report.admitted] + [f for _, f in report.abandoned]
    tally = {
        "admitted": len(report.admitted),
        "abandoned": len(report.abandoned),
        "rejected": report.rejected,
        "malformed": report.malformed,
        "ok": 0,
        "expired": 0,
        "faulted": 0,
        "stopped": 0,
        "hung": 0,
    }
    outcomes = await asyncio.gather(
        *(asyncio.wait_for(asyncio.shield(f), gather_timeout_s) for f in futures),
        return_exceptions=True,
    )
    for out in outcomes:
        if isinstance(out, asyncio.TimeoutError):
            tally["hung"] += 1          # the forbidden outcome
        elif isinstance(out, ServiceExpired):
            tally["expired"] += 1
        elif isinstance(out, FaultError):
            tally["faulted"] += 1
        elif isinstance(out, Exception):
            tally["stopped"] += 1       # ServiceStopped / validation errors
        else:
            tally["ok"] += 1
    tally["health"] = service.health().as_dict()
    return tally
