"""Open-loop Poisson load generation for the serving service.

One shared arrival driver for ``benchmarks/bench_service.py`` and the
``launch.serve --service`` mode: requests fire on a precomputed
exponential schedule and never wait for earlier results — the way
independent users actually load a service (a closed loop would hide
queueing collapse behind its own self-throttling).
"""

from __future__ import annotations

import asyncio
from typing import List, Sequence, Tuple

import numpy as np

from repro.serve.service import ServiceOverloaded, ServingService

__all__ = ["poisson_open_loop"]


async def poisson_open_loop(
    service: ServingService,
    name: str,
    requests: Sequence[np.ndarray],
    rate: float,
    *,
    seed: int = 0,
    preprocessed: bool = False,
    host_ingress: bool = False,
) -> Tuple[List[Tuple[int, "asyncio.Future"]], int]:
    """Submit ``requests`` at Poisson rate ``rate`` (requests/s).

    Returns ``(admitted, rejected)`` where ``admitted`` pairs each
    accepted request's *original index* with its result future —
    rejections must not shift that pairing for callers that line results
    up against labels.  The caller gathers the futures (and normally
    drains the service) when the stream ends.

    ``host_ingress=True`` replays the legacy per-request host pipeline
    (the pre-device-ingress baseline the raw-path benchmarks compare
    against) via ``submit_host_nowait`` — admission still rejects
    synchronously, but the pipeline itself runs on the service's ingress
    thread so the baseline measurement does not also stall the
    coalescer's event loop.  The default raw path enqueues pixels with a
    shape check only.
    """
    if rate <= 0:
        raise ValueError("rate must be > 0")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, len(requests))
    loop = asyncio.get_running_loop()
    admitted: List[Tuple[int, "asyncio.Future"]] = []
    rejected = 0
    next_t = loop.time()
    for i, batch in enumerate(requests):
        next_t += gaps[i]
        # sleep(0) when behind schedule: still yields, so the dispatch
        # loop keeps draining while the generator catches up (open loop).
        await asyncio.sleep(max(next_t - loop.time(), 0.0))
        try:
            if host_ingress and not preprocessed:
                fut = service.submit_host_nowait(name, batch)
            else:
                fut = service.submit_nowait(name, batch, preprocessed=preprocessed)
            admitted.append((i, fut))
        except ServiceOverloaded:
            rejected += 1
    return admitted, rejected
