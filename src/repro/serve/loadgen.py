"""Open-loop Poisson load generation for the serving service.

One shared arrival driver for ``benchmarks/bench_service.py``, the
``launch.serve --service`` mode, and the chaos suite
(``serve/faults.py``): requests fire on a precomputed exponential
schedule and never wait for earlier results — the way independent users
actually load a service (a closed loop would hide queueing collapse
behind its own self-throttling).

Adversarial knobs (ARCHITECTURE.md §Faults): a fraction of requests can
be **malformed** (shape-corrupted, so admission-time validation must
reject them without poisoning anyone else) and a fraction can be
**abandoned** (submitted with a deadline the client then walks away
from — the service must still resolve those futures, with a result or
``ServiceExpired``, never leak them).  Which requests are malformed /
abandoned is drawn from the seeded RNG, so a chaos run replays exactly.
"""

from __future__ import annotations

import asyncio
import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.service import ServiceOverloaded, ServingService

__all__ = ["LoadReport", "poisson_open_loop"]


@dataclasses.dataclass
class LoadReport:
    """What one open-loop run submitted and how admission went.

    ``admitted`` pairs each accepted request's *original index* with its
    result future; ``abandoned`` holds the futures the simulated clients
    walked away from (the chaos driver still gathers them — an abandoned
    future must resolve like any other); ``malformed`` counts corrupted
    submissions rejected at validation.  Iterating yields
    ``(admitted, rejected)``, so legacy two-tuple unpacking keeps
    working.
    """

    admitted: List[Tuple[int, "asyncio.Future"]]
    rejected: int = 0
    malformed: int = 0
    abandoned: List[Tuple[int, "asyncio.Future"]] = dataclasses.field(
        default_factory=list
    )

    def __iter__(self):
        return iter((self.admitted, self.rejected))


async def poisson_open_loop(
    service: ServingService,
    name: str,
    requests: Sequence[np.ndarray],
    rate: float,
    *,
    seed: int = 0,
    preprocessed: bool = False,
    host_ingress: bool = False,
    deadline_s: Optional[float] = None,
    malformed_frac: float = 0.0,
    abandon_frac: float = 0.0,
) -> LoadReport:
    """Submit ``requests`` at Poisson rate ``rate`` (requests/s).

    Returns a :class:`LoadReport` (unpacks as the legacy
    ``(admitted, rejected)`` pair).  The caller gathers the futures (and
    normally drains the service) when the stream ends.

    ``host_ingress=True`` replays the legacy per-request host pipeline
    (the pre-device-ingress baseline the raw-path benchmarks compare
    against) via ``submit_host_nowait`` — admission still rejects
    synchronously, but the pipeline itself runs on the service's ingress
    thread so the baseline measurement does not also stall the
    coalescer's event loop.  The default raw path enqueues pixels with a
    shape check only.

    ``deadline_s`` rides on every submission (requests shed past it fail
    with ``ServiceExpired``).  ``malformed_frac`` corrupts that fraction
    of requests (last axis truncated — wrong shape) before submission;
    they must be rejected at validation (counted, not admitted).
    ``abandon_frac`` marks that fraction of *admitted* requests as
    client-abandoned: their futures land in ``report.abandoned`` instead
    of ``report.admitted``, modeling a client that stops waiting once
    its deadline passes.
    """
    if rate <= 0:
        raise ValueError("rate must be > 0")
    if not 0.0 <= malformed_frac <= 1.0:
        raise ValueError("malformed_frac must be in [0, 1]")
    if not 0.0 <= abandon_frac <= 1.0:
        raise ValueError("abandon_frac must be in [0, 1]")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, len(requests))
    malformed_mask = rng.random(len(requests)) < malformed_frac
    abandon_mask = rng.random(len(requests)) < abandon_frac
    loop = asyncio.get_running_loop()
    report = LoadReport(admitted=[])
    next_t = loop.time()
    for i, batch in enumerate(requests):
        next_t += gaps[i]
        # sleep(0) when behind schedule: still yields, so the dispatch
        # loop keeps draining while the generator catches up (open loop).
        await asyncio.sleep(max(next_t - loop.time(), 0.0))
        if malformed_mask[i]:
            # Corrupt the trailing axis: fails the cheap shape validation
            # at admission, exactly like a buggy client would.
            batch = np.asarray(batch)[..., :-1]
        try:
            if host_ingress and not preprocessed:
                fut = service.submit_host_nowait(
                    name, batch, deadline_s=deadline_s
                )
            else:
                fut = service.submit_nowait(
                    name, batch,
                    preprocessed=preprocessed, deadline_s=deadline_s,
                )
        except ServiceOverloaded:
            report.rejected += 1
            continue
        except (ValueError, TypeError):
            # Malformed submissions are rejected at validation; anything
            # the generator corrupted SHOULD land here (a corrupted
            # request that slipped through would poison its microbatch).
            report.malformed += 1
            continue
        if abandon_mask[i]:
            report.abandoned.append((i, fut))
        else:
            report.admitted.append((i, fut))
    return report
