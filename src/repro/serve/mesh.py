"""Sharded serving across a device mesh.

The chip sustains 60.3k classifications/s because 128 clauses evaluate in
parallel every cycle; the flexible-substrate follow-up (Qin et al.)
replicates the same TM datapath across independent tiles.  The software
analogue is a :class:`ServeMesh`: each registered
:class:`~repro.serve.servable.ServableModel` is placed across a
``("data", "model")`` :class:`jax.sharding.Mesh` and request batches are
sharded along the **data** axis inside the engine's existing bucketed jit
steps, so ``classify_step`` / ``classify_raw_step`` execute one program
across N devices and return a single gathered result.

Two placement contracts, both **bit-identical** to the single-device
engine (asserted in ``tests/test_serve_mesh.py``):

  * **replicated** (the default): the frozen model image lives on every
    device (the 45 056-bit register file is tiny — replication costs
    ~5.6 KiB/device) and only the batch is sharded over "data".  The
    datapath has no cross-batch interaction, so each device classifies
    its batch shard independently and the gathered result equals the
    unsharded run bit for bit.  GSPMD partitions the existing jitted
    steps from the input shardings alone.
  * **clause-sharded** (``shard_clauses=True``, for large-clause
    configs): the clause axis ``C`` of ``include``/``include_packed``/
    ``nonempty`` (and the ``C`` column axis of ``weights [m, C]``) is
    additionally split over "model" via the ``"clause"`` logical rule in
    ``sharding/partition.py``.  Evaluation runs as an explicit
    ``shard_map`` (:func:`repro.distributed.collectives.shard_map_compat`):
    each device evaluates its clause shard and computes partial class
    sums with its weight slice; an exact int32
    :func:`~repro.distributed.collectives.psum_tree` over "model"
    combines them — integer addition reorders associatively, so Eq. (3)
    class sums stay bit-identical.

Batch divisibility: jit input shardings require the batch axis to divide
evenly over "data", so the engine's power-of-two buckets are clamped from
below to the data-axis size (which must itself be a power of two
<= ``max_batch``) — every bucket then splits evenly and per-device bucket
accounting is ``bucket // n_data``.

Validated on CPU with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(see ARCHITECTURE.md §ServeMesh and the device-count scaling table in
EXPERIMENTS.md §Serve/mesh).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import clauses as cl
from repro.core.ingress import IngressSpec
from repro.distributed.collectives import psum_tree, shard_map_compat
from repro.serve.servable import ServableModel
from repro.sharding import partition

__all__ = ["ServeMesh", "make_serve_mesh", "classify_step_clause_sharded"]


@dataclasses.dataclass(frozen=True)
class ServeMesh:
    """A serving placement: device mesh + sharding mode.

    Hashable (the jit static key of the clause-sharded step).  ``mesh``
    must carry a "data" axis; ``shard_clauses=True`` additionally
    requires a "model" axis, over which every registered model's clause
    pool is split (``n_clauses`` must divide evenly — validated at
    placement).
    """

    mesh: Mesh
    shard_clauses: bool = False

    def __post_init__(self):
        names = tuple(self.mesh.axis_names)
        if "data" not in names:
            raise ValueError(f'ServeMesh requires a "data" axis; mesh has {names}')
        if self.shard_clauses and "model" not in names:
            raise ValueError(
                f'shard_clauses=True requires a "model" axis; mesh has {names}'
            )

    # --- geometry ---------------------------------------------------------

    @property
    def devices(self) -> int:
        return self.mesh.size

    @property
    def n_data(self) -> int:
        """Batch shards (the data-axis size)."""
        return self.mesh.shape["data"]

    @property
    def n_model(self) -> int:
        """Clause shards (1 when the mesh has no "model" axis)."""
        return self.mesh.shape.get("model", 1)

    def shrunk(self) -> Optional["ServeMesh"]:
        """The next-smaller placement after losing devices on the data
        axis: half the batch shards, model axis (and clause sharding)
        kept.  None when the data axis is already minimal — the caller
        (``ServingEngine.shrink_mesh``) then has nothing left to shed.
        Rebuilt through :func:`make_serve_mesh`, so the surviving grid
        comes from the same ``launch/mesh.py`` device selection as the
        original placement.
        """
        if self.n_data <= 1:
            return None
        return make_serve_mesh(
            self.n_data // 2, self.n_model, shard_clauses=self.shard_clauses
        )

    # --- placement --------------------------------------------------------

    def batch_sharding(self, ndim: int) -> NamedSharding:
        """Leading-axis-over-"data" sharding for an ``ndim``-d batch."""
        return partition.sharding(("batch",) + (None,) * (ndim - 1), self.mesh)

    def place_batch(self, arr: np.ndarray) -> jax.Array:
        """One H2D placement of a padded bucket: rows spread over "data".

        The batch size must divide by :attr:`n_data` — the engine's
        bucket clamp guarantees this for every dispatched bucket.
        """
        if arr.shape[0] % self.n_data:
            raise ValueError(
                f"batch {arr.shape[0]} does not divide over {self.n_data} "
                f"data shards"
            )
        return jax.device_put(arr, self.batch_sharding(arr.ndim))

    def place_servable(self, servable: ServableModel) -> ServableModel:
        """Place a frozen model's register image onto the mesh.

        Replicated mode puts every field on all devices — including the
        sparsity analysis, whose active-clause arrays are as replicable
        as the full register image; clause-sharded mode splits the clause
        axis over "model" (weights on their ``C`` column axis) using the
        ``"clause"`` logical rule.  The active-clause set is NOT
        shard-uniform, so clause-sharded placement drops ``sparsity``
        (sparse eval paths then resolve to their dense fallbacks inside
        the shard_map — see ``serve/paths.py``).  A ``tuned`` plan is
        static metadata and survives either placement.  The lifecycle
        ``version`` stamp is stripped: a placed image is a *dispatch*
        image, and version must never enter jit static keys (the engine
        tracks the stamp on its registry entry — ARCHITECTURE.md
        §Lifecycle).
        """
        if servable.version is not None:
            servable = dataclasses.replace(servable, version=None)
        if not self.shard_clauses:
            rep = NamedSharding(self.mesh, P())
            return dataclasses.replace(
                servable,
                include=jax.device_put(servable.include, rep),
                include_packed=jax.device_put(servable.include_packed, rep),
                nonempty=jax.device_put(servable.nonempty, rep),
                weights=jax.device_put(servable.weights, rep),
                sparsity=(
                    None if servable.sparsity is None
                    else jax.device_put(servable.sparsity, rep)
                ),
            )
        n_clauses = servable.include.shape[0]
        if n_clauses % self.n_model:
            raise ValueError(
                f"n_clauses={n_clauses} does not divide over {self.n_model} "
                f'"model" shards (clause sharding needs an even split)'
            )

        def put(x, logical):
            return jax.device_put(x, partition.sharding(logical, self.mesh))

        return dataclasses.replace(
            servable,
            include=put(servable.include, ("clause", None)),
            include_packed=put(servable.include_packed, ("clause", None)),
            nonempty=put(servable.nonempty, ("clause",)),
            weights=put(servable.weights, (None, "clause")),
            sparsity=None,
        )


def make_serve_mesh(
    data: int = 1, model: int = 1, *, shard_clauses: Optional[bool] = None
) -> ServeMesh:
    """Build a :class:`ServeMesh` over the first ``data * model`` local
    devices (``launch/mesh.py`` owns the device grid).  ``shard_clauses``
    defaults to ``model > 1`` — a mesh with a non-trivial model axis is
    only useful clause-sharded."""
    from repro.launch.mesh import make_serve_device_mesh

    if shard_clauses is None:
        shard_clauses = model > 1
    return ServeMesh(make_serve_device_mesh(data, model), shard_clauses=shard_clauses)


def _classify_clause_sharded(
    servable: ServableModel,
    arr: jax.Array,
    smesh: ServeMesh,
    path_name: str,
    ingress: Optional[IngressSpec],
):
    """Explicit per-shard program: each device evaluates its clause shard
    of its batch shard and psums partial class sums over "model"."""
    from repro.serve.paths import PACKED, get_path, resolve_path

    # Clause-sharded servables carry no sparsity analysis (placement
    # drops it), so sparse path names resolve to their dense fallbacks.
    path = resolve_path(get_path(path_name), servable)
    mesh = smesh.mesh
    if ingress is not None:
        # The ingress must produce literals in the EVALUATED path's form
        # (which can differ from the registered spec when the autotuner
        # measures cross-form candidates on the raw form).
        ingress = dataclasses.replace(ingress, packed=path.input_form == PACKED)
        # Raw form: the ingress runs OUTSIDE the shard_map, once per
        # batch shard under GSPMD (pinned to the "data" sharding) — not
        # replicated across every model-axis device holding that shard.
        # Only clause evaluation depends on the "model" axis.
        arr = jax.lax.with_sharding_constraint(
            path.ingress_fn(ingress, arr),
            smesh.batch_sharding(3),           # literals [B, P, 2o|W]
        )
    clause = partition.spec(("clause", None), mesh)
    batch = partition.spec(("batch",) + (None,) * (arr.ndim - 1), mesh)

    def body(inc, incp, ne, w, x):
        v = path.fn(x, inc, incp, ne, w)          # [B_local, m] partial sums
        return psum_tree(v, "model")

    v = shard_map_compat()(
        body,
        mesh=mesh,
        in_specs=(
            clause,                                # include [C, 2o]
            clause,                                # include_packed [C, W]
            partition.spec(("clause",), mesh),     # nonempty [C]
            partition.spec((None, "clause"), mesh),  # weights [m, C]
            batch,
        ),
        out_specs=partition.spec(("batch", None), mesh),
    )(servable.include, servable.include_packed, servable.nonempty,
      servable.weights, arr)
    return cl.argmax_predict(v), v


#: The clause-sharded classify step: (placed servable, placed batch) ->
#: (predictions, class_sums), jit-cached per (bucket shape, model config,
#: path, ServeMesh, IngressSpec) — ``ingress=None`` is the literal form,
#: an IngressSpec the raw form (ingress once per batch shard under GSPMD
#: outside the shard_map, then clause-shard evaluation + psum inside it).
classify_step_clause_sharded = jax.jit(
    _classify_clause_sharded, static_argnames=("smesh", "path_name", "ingress")
)
