"""Registry of ConvCoTM evaluation paths.

Every path computes Eq. (3) class sums ``int32 [B, m]`` from one image
batch's literals and a :class:`~repro.serve.servable.ServableModel`'s
frozen fields.  Paths declare their preferred *input form* so callers
(``core.cotm.infer``, the serving engine) convert literals exactly once:

  * ``dense``  — uint8 0/1 literals ``[B, P, 2o]``;
  * ``packed`` — uint32 words ``[B, P, W]`` (LSB-first, see
    ``core.patches.pack_bits``).

Replaces the stringly-typed ``eval_path`` if/elif chain that used to live
in ``core/cotm.py``: new paths register here and are immediately usable
by ``CoTMConfig(eval_path=...)``, the engine, benchmarks and tests.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import jax

from repro.core import clauses as cl

__all__ = ["EvalPath", "register_path", "get_path", "available_paths", "run_path"]

#: fn(literals, include, include_packed, nonempty, weights) -> int32 [B, m]
PathFn = Callable[[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array], jax.Array]

DENSE = "dense"
PACKED = "packed"


@dataclasses.dataclass(frozen=True)
class EvalPath:
    """A registered evaluation path (name, preferred literal form, fn)."""

    name: str
    input_form: str          # DENSE | PACKED
    fn: PathFn

    def __post_init__(self):
        if self.input_form not in (DENSE, PACKED):
            raise ValueError(f"input_form must be '{DENSE}' or '{PACKED}'")


_REGISTRY: dict[str, EvalPath] = {}


def register_path(name: str, input_form: str) -> Callable[[PathFn], PathFn]:
    """Decorator: register ``fn`` as evaluation path ``name``."""

    def deco(fn: PathFn) -> PathFn:
        if name in _REGISTRY:
            raise ValueError(f"eval path {name!r} already registered")
        _REGISTRY[name] = EvalPath(name=name, input_form=input_form, fn=fn)
        return fn

    return deco


def get_path(name: str) -> EvalPath:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown eval path {name!r}; registered: {available_paths()}"
        ) from None


def available_paths() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def run_path(path: EvalPath, servable, literals: jax.Array) -> jax.Array:
    """Class sums int32 [B, m]; ``literals`` must be in ``path.input_form``."""
    return path.fn(
        literals,
        servable.include,
        servable.include_packed,
        servable.nonempty,
        servable.weights,
    )


# --- the built-in paths ----------------------------------------------------

@register_path("dense", DENSE)
def _dense(lits, include, include_packed, nonempty, weights):
    fired = cl.eval_clauses_dense(lits, include)
    return cl.class_sums(fired, weights)


@register_path("matmul", DENSE)
def _matmul(lits, include, include_packed, nonempty, weights):
    fired = cl.eval_clauses_matmul(lits, include, nonempty)
    return cl.class_sums(fired, weights)


@register_path("bitpacked", PACKED)
def _bitpacked(lits, include, include_packed, nonempty, weights):
    fired = cl.eval_clauses_bitpacked(lits, include_packed, nonempty)
    return cl.class_sums(fired, weights)


@register_path("kernel", PACKED)
def _kernel(lits, include, include_packed, nonempty, weights):
    from repro.kernels import ops as kops

    fired = kops.clause_eval(lits, include_packed, nonempty)
    return cl.class_sums(fired, weights)


@register_path("fused", PACKED)
def _fused(lits, include, include_packed, nonempty, weights):
    from repro.kernels import ops as kops

    return kops.fused_infer(lits, include_packed, nonempty, weights)
