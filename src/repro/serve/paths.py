"""Registry of ConvCoTM evaluation paths.

Every path computes Eq. (3) class sums ``int32 [B, m]`` from one image
batch's literals and a :class:`~repro.serve.servable.ServableModel`'s
frozen fields.  Paths declare their preferred *literal input form* so
callers (``core.cotm.infer``, the serving engine) convert literals
exactly once:

  * ``dense``  — uint8 0/1 literals ``[B, P, 2o]``;
  * ``packed`` — uint32 words ``[B, P, W]`` (LSB-first, see
    ``core.patches.pack_bits``).

Beyond literals, every path also owns its full **raw -> class sums**
graph: :data:`RAW` names the third request form (raw pixel batches,
uint8 ``[B, H, W]``), and each :class:`EvalPath` carries an
``ingress_fn`` — ``(IngressSpec, raw) -> literals`` in the path's input
form, pure jnp — so :func:`run_path_raw` traces booleanize -> patches ->
literals -> pack -> clause eval -> class sums into ONE jitted graph with
a single H2D copy (the serving engine's ``classify_raw_step``).  The
default ``ingress_fn`` is :func:`repro.core.ingress.apply_ingress`;
kernel-backed paths may substitute one that drops into the Pallas
ingress kernel.

Replaces the stringly-typed ``eval_path`` if/elif chain that used to live
in ``core/cotm.py``: new paths register here and are immediately usable
by ``CoTMConfig(eval_path=...)``, the engine, benchmarks and tests.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax

from repro.core import clauses as cl
from repro.core.ingress import IngressSpec, apply_ingress

__all__ = [
    "EvalPath",
    "register_path",
    "get_path",
    "available_paths",
    "run_path",
    "run_path_raw",
    "DENSE",
    "PACKED",
    "RAW",
]

#: fn(literals, include, include_packed, nonempty, weights) -> int32 [B, m]
PathFn = Callable[[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array], jax.Array]

#: ingress_fn(spec, raw) -> literals in the path's input form (pure jnp)
IngressFn = Callable[[IngressSpec, jax.Array], jax.Array]

DENSE = "dense"
PACKED = "packed"
#: The raw request form: uint8 pixel batches, converted on device by the
#: path's ``ingress_fn`` inside the same jitted graph as evaluation.
RAW = "raw"


@dataclasses.dataclass(frozen=True)
class EvalPath:
    """A registered evaluation path (name, literal form, eval + ingress fns)."""

    name: str
    input_form: str          # DENSE | PACKED
    fn: PathFn
    ingress_fn: IngressFn = apply_ingress

    def __post_init__(self):
        if self.input_form not in (DENSE, PACKED):
            raise ValueError(f"input_form must be '{DENSE}' or '{PACKED}'")

    def ingress_spec(self, patch, method: str = "threshold", **kw) -> IngressSpec:
        """The :class:`IngressSpec` matching this path's literal form."""
        return IngressSpec(
            patch=patch, method=method, packed=self.input_form == PACKED, **kw
        )


_REGISTRY: dict[str, EvalPath] = {}


def register_path(
    name: str, input_form: str, *, ingress_fn: Optional[IngressFn] = None
) -> Callable[[PathFn], PathFn]:
    """Decorator: register ``fn`` as evaluation path ``name``.

    ``ingress_fn`` overrides the default device ingress for this path
    (same contract: ``(IngressSpec, raw) -> literals`` in ``input_form``,
    jit-composable).
    """

    def deco(fn: PathFn) -> PathFn:
        if name in _REGISTRY:
            raise ValueError(f"eval path {name!r} already registered")
        _REGISTRY[name] = EvalPath(
            name=name,
            input_form=input_form,
            fn=fn,
            ingress_fn=ingress_fn or apply_ingress,
        )
        return fn

    return deco


def get_path(name: str) -> EvalPath:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown eval path {name!r}; registered: {available_paths()}"
        ) from None


def available_paths() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def run_path(path: EvalPath, servable, literals: jax.Array) -> jax.Array:
    """Class sums int32 [B, m]; ``literals`` must be in ``path.input_form``."""
    return path.fn(
        literals,
        servable.include,
        servable.include_packed,
        servable.nonempty,
        servable.weights,
    )


def run_path_raw(
    path: EvalPath, servable, raw: jax.Array, ingress: IngressSpec
) -> jax.Array:
    """Class sums int32 [B, m] straight from raw pixels (the :data:`RAW`
    form): the path's own ingress_fn then its eval fn, one traceable
    graph with no host materialization in between."""
    if ingress.packed != (path.input_form == PACKED):
        ingress = dataclasses.replace(ingress, packed=path.input_form == PACKED)
    return run_path(path, servable, path.ingress_fn(ingress, raw))


# --- the built-in paths ----------------------------------------------------

@register_path("dense", DENSE)
def _dense(lits, include, include_packed, nonempty, weights):
    fired = cl.eval_clauses_dense(lits, include)
    return cl.class_sums(fired, weights)


@register_path("matmul", DENSE)
def _matmul(lits, include, include_packed, nonempty, weights):
    fired = cl.eval_clauses_matmul(lits, include, nonempty)
    return cl.class_sums(fired, weights)


@register_path("bitpacked", PACKED)
def _bitpacked(lits, include, include_packed, nonempty, weights):
    fired = cl.eval_clauses_bitpacked(lits, include_packed, nonempty)
    return cl.class_sums(fired, weights)


@register_path("kernel", PACKED)
def _kernel(lits, include, include_packed, nonempty, weights):
    from repro.kernels import ops as kops

    fired = kops.clause_eval(lits, include_packed, nonempty)
    return cl.class_sums(fired, weights)


@register_path("fused", PACKED)
def _fused(lits, include, include_packed, nonempty, weights):
    from repro.kernels import ops as kops

    return kops.fused_infer(lits, include_packed, nonempty, weights)
