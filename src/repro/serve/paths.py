"""Registry of ConvCoTM evaluation paths.

Every path computes Eq. (3) class sums ``int32 [B, m]`` from one image
batch's literals and a :class:`~repro.serve.servable.ServableModel`'s
frozen fields.  Paths declare their preferred *literal input form* so
callers (``core.cotm.infer``, the serving engine) convert literals
exactly once:

  * ``dense``  — uint8 0/1 literals ``[B, P, 2o]``;
  * ``packed`` — uint32 words ``[B, P, W]`` (LSB-first, see
    ``core.patches.pack_bits``).

Beyond literals, every path also owns its full **raw -> class sums**
graph: :data:`RAW` names the third request form (raw pixel batches,
uint8 ``[B, H, W]``), and each :class:`EvalPath` carries an
``ingress_fn`` — ``(IngressSpec, raw) -> literals`` in the path's input
form, pure jnp — so :func:`run_path_raw` traces booleanize -> patches ->
literals -> pack -> clause eval -> class sums into ONE jitted graph with
a single H2D copy (the serving engine's ``classify_raw_step``).  The
default ``ingress_fn`` is :func:`repro.core.ingress.apply_ingress`;
kernel-backed paths may substitute one that drops into the Pallas
ingress kernel.

Sparse paths and fallbacks (ARCHITECTURE.md §Sparsity)
------------------------------------------------------
Paths marked ``needs_sparsity`` consume the active-clause image derived
at freeze time (``servable.sparsity``, see
:func:`repro.serve.servable.analyze_sparsity`): empty clauses are pruned
from the clause pool entirely, so work scales with the number of clauses
that *can* fire.  When a servable carries no sparsity analysis (e.g.
frozen inline under jit, or clause-sharded across a mesh where the
active set is not shard-uniform), :func:`resolve_path` substitutes the
path's declared ``fallback`` — a registered dense twin with the same
input form and bit-identical outputs — so every caller keeps working.

Tunable parameters
------------------
``tunable`` lists candidate static parameter sets (tuples of ``(name,
value)`` pairs, hashable so they can key jit) the autotuner
(``serve/autotune.py``) may sweep per (bucket, geometry) — grid/block
shapes and the CSRF toggle for the Pallas-backed kernels.  ``()`` (the
path's defaults) is always a candidate; non-default sets are only worth
sweeping where the Pallas kernels actually compile (TPU), and the
autotuner restricts itself accordingly.

Replaces the stringly-typed ``eval_path`` if/elif chain that used to live
in ``core/cotm.py``: new paths register here and are immediately usable
by ``CoTMConfig(eval_path=...)``, the engine, benchmarks and tests.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax

from repro.core import clauses as cl
from repro.core.ingress import IngressSpec, apply_ingress

__all__ = [
    "EvalPath",
    "Params",
    "register_path",
    "get_path",
    "available_paths",
    "resolve_path",
    "degraded_fallback",
    "run_path",
    "run_path_raw",
    "DENSE",
    "PACKED",
    "RAW",
]

#: fn(literals, include, include_packed, nonempty, weights, [sparsity,]
#:    **params) -> int32 [B, m]; the ``sparsity`` positional is passed to
#: ``needs_sparsity`` paths only.
PathFn = Callable[..., jax.Array]

#: ingress_fn(spec, raw) -> literals in the path's input form (pure jnp)
IngressFn = Callable[[IngressSpec, jax.Array], jax.Array]

#: A static parameter set: hashable ((name, value), ...) pairs.
Params = Tuple[Tuple[str, object], ...]

DENSE = "dense"
PACKED = "packed"
#: The raw request form: uint8 pixel batches, converted on device by the
#: path's ``ingress_fn`` inside the same jitted graph as evaluation.
RAW = "raw"

#: Block-shape / CSRF candidates for the Pallas-backed kernels (swept by
#: the autotuner on backends where the kernels compile).
_KERNEL_TUNABLE: Tuple[Params, ...] = (
    (),
    (("block_b", 16),),
    (("block_p", 128),),
    (("block_b", 16), ("block_p", 128)),
    (("csrf", False),),
)


@dataclasses.dataclass(frozen=True)
class EvalPath:
    """A registered evaluation path (name, literal form, eval + ingress fns).

    ``needs_sparsity`` paths receive ``servable.sparsity`` as an extra
    positional argument; ``fallback`` names the bit-identical dense twin
    used when no sparsity analysis is attached (must share
    ``input_form``).  ``tunable`` lists static parameter sets the
    autotuner may sweep (the empty set — path defaults — always works).
    """

    name: str
    input_form: str          # DENSE | PACKED
    fn: PathFn
    ingress_fn: IngressFn = apply_ingress
    needs_sparsity: bool = False
    fallback: Optional[str] = None
    tunable: Tuple[Params, ...] = ((),)

    def __post_init__(self):
        if self.input_form not in (DENSE, PACKED):
            raise ValueError(f"input_form must be '{DENSE}' or '{PACKED}'")
        if self.needs_sparsity and self.fallback is None:
            raise ValueError(
                f"sparse path {self.name!r} must declare a dense fallback"
            )

    def ingress_spec(self, patch, method: str = "threshold", **kw) -> IngressSpec:
        """The :class:`IngressSpec` matching this path's literal form."""
        return IngressSpec(
            patch=patch, method=method, packed=self.input_form == PACKED, **kw
        )


_REGISTRY: dict[str, EvalPath] = {}


def register_path(
    name: str,
    input_form: str,
    *,
    ingress_fn: Optional[IngressFn] = None,
    needs_sparsity: bool = False,
    fallback: Optional[str] = None,
    tunable: Tuple[Params, ...] = ((),),
) -> Callable[[PathFn], PathFn]:
    """Decorator: register ``fn`` as evaluation path ``name``.

    ``ingress_fn`` overrides the default device ingress for this path
    (same contract: ``(IngressSpec, raw) -> literals`` in ``input_form``,
    jit-composable).  ``fallback`` (required with ``needs_sparsity``)
    must already be registered with the same input form.
    """

    def deco(fn: PathFn) -> PathFn:
        if name in _REGISTRY:
            raise ValueError(f"eval path {name!r} already registered")
        if fallback is not None:
            fb = get_path(fallback)    # fail fast on unknown fallbacks
            if fb.input_form != input_form:
                raise ValueError(
                    f"fallback {fallback!r} input form {fb.input_form!r} != "
                    f"{input_form!r}"
                )
        _REGISTRY[name] = EvalPath(
            name=name,
            input_form=input_form,
            fn=fn,
            ingress_fn=ingress_fn or apply_ingress,
            needs_sparsity=needs_sparsity,
            fallback=fallback,
            tunable=tunable,
        )
        return fn

    return deco


def get_path(name: str) -> EvalPath:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown eval path {name!r}; registered: {available_paths()}"
        ) from None


def available_paths() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def resolve_path(path: EvalPath, servable) -> EvalPath:
    """The path actually evaluated for ``servable``: sparse paths without
    an attached sparsity analysis resolve to their dense fallback
    (bit-identical by the multi-path equivalence contract)."""
    if path.needs_sparsity and getattr(servable, "sparsity", None) is None:
        return get_path(path.fallback)
    return path


#: The degradation chain: where a path falls back to when its dispatches
#: keep failing (the circuit breaker in serve/faults.py).  One step per
#: trip — sparse paths first shed their sparsity machinery onto the
#: declared dense twin, kernel-backed paths shed the Pallas kernels onto
#: plain XLA math, and everything bottoms out at "dense", the simplest
#: reference-equal path.  Unlike :func:`resolve_path`'s jit-internal
#: substitution (same input form, inside one graph), this chain is
#: walked at the engine registry level, where the ingress spec is
#: rebuilt — so a step may change literal input form (fused -> matmul).
_DEGRADED_CHAIN = {
    "fused_sparse": "fused",
    "sparse": "bitpacked",
    "matmul_sparse": "matmul",
    "fused": "matmul",
    "kernel": "matmul",
    "bitpacked": "dense",
    "matmul": "dense",
    "dense": None,
}


def degraded_fallback(name: str) -> Optional[str]:
    """The next path down the degradation chain for ``name`` (None when
    already at the bottom).  Paths outside the built-in chain fall back
    to their declared ``fallback``, else straight to ``dense``."""
    if name in _DEGRADED_CHAIN:
        return _DEGRADED_CHAIN[name]
    path = get_path(name)
    return path.fallback or "dense"


def run_path(
    path: EvalPath, servable, literals: jax.Array, params: Params = ()
) -> jax.Array:
    """Class sums int32 [B, m]; ``literals`` must be in ``path.input_form``.

    ``params`` is a static parameter set from ``path.tunable`` (autotuner
    winners); ``()`` runs the path defaults.
    """
    resolved = resolve_path(path, servable)
    if resolved is not path:
        # Fallback substitution: tuned params belong to the sparse path,
        # not its dense twin — run the twin at its defaults.
        path, params = resolved, ()
    args = (
        literals,
        servable.include,
        servable.include_packed,
        servable.nonempty,
        servable.weights,
    )
    if path.needs_sparsity:
        args = args + (servable.sparsity,)
    return path.fn(*args, **dict(params))


def run_path_raw(
    path: EvalPath,
    servable,
    raw: jax.Array,
    ingress: IngressSpec,
    params: Params = (),
) -> jax.Array:
    """Class sums int32 [B, m] straight from raw pixels (the :data:`RAW`
    form): the path's own ingress_fn then its eval fn, one traceable
    graph with no host materialization in between."""
    if ingress.packed != (path.input_form == PACKED):
        ingress = dataclasses.replace(ingress, packed=path.input_form == PACKED)
    return run_path(path, servable, path.ingress_fn(ingress, raw), params)


# --- the built-in paths ----------------------------------------------------

@register_path("dense", DENSE)
def _dense(lits, include, include_packed, nonempty, weights):
    fired = cl.eval_clauses_dense(lits, include)
    return cl.class_sums(fired, weights)


@register_path("matmul", DENSE)
def _matmul(lits, include, include_packed, nonempty, weights):
    fired = cl.eval_clauses_matmul(lits, include, nonempty)
    return cl.class_sums(fired, weights)


@register_path("bitpacked", PACKED)
def _bitpacked(lits, include, include_packed, nonempty, weights):
    fired = cl.eval_clauses_bitpacked(lits, include_packed, nonempty)
    return cl.class_sums(fired, weights)


@register_path("kernel", PACKED, tunable=_KERNEL_TUNABLE)
def _kernel(lits, include, include_packed, nonempty, weights, **params):
    from repro.kernels import ops as kops

    fired = kops.clause_eval(lits, include_packed, nonempty, **params)
    return cl.class_sums(fired, weights)


@register_path("fused", PACKED, tunable=_KERNEL_TUNABLE)
def _fused(lits, include, include_packed, nonempty, weights, **params):
    from repro.kernels import ops as kops

    return kops.fused_infer(lits, include_packed, nonempty, weights, **params)


# --- clause-sparsity fast paths (active-clause pool; see module doc) -------

@register_path(
    "sparse", PACKED, needs_sparsity=True, fallback="bitpacked",
    tunable=_KERNEL_TUNABLE,
)
def _sparse(lits, include, include_packed, nonempty, weights, sparsity, **params):
    from repro.kernels import ops as kops

    fired = kops.clause_eval_sparse(lits, sparsity.exclude_packed, **params)
    return cl.class_sums(fired, sparsity.weights)


@register_path(
    "fused_sparse", PACKED, needs_sparsity=True, fallback="fused",
    tunable=_KERNEL_TUNABLE,
)
def _fused_sparse(lits, include, include_packed, nonempty, weights, sparsity, **params):
    from repro.kernels import ops as kops

    return kops.fused_infer_sparse(
        lits, sparsity.exclude_packed, sparsity.weights, **params
    )


@register_path("matmul_sparse", DENSE, needs_sparsity=True, fallback="matmul")
def _matmul_sparse(lits, include, include_packed, nonempty, weights, sparsity):
    from repro.kernels import ops as kops

    return kops.matmul_sparse_infer(lits, sparsity.include, sparsity.weights)
