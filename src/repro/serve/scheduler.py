"""Latency-aware microbatch scheduler for the async serving service.

The chip reaches 60.3k classifications/s *and* 25.4 us single-image
latency because its DMA/frame pipeline keeps the datapath occupied
without ever parking a frame: a lone image is classified immediately,
while back-to-back frames ride the double-buffered image registers.  The
software analogue is a microbatcher with one knob, ``max_delay_us``:

  * a request batch is dispatched **immediately** once the queued images
    for its model fill the coalescing window (``max_coalesce``, normally
    the engine's ``max_batch`` bucket — on a meshed engine the service
    scales an explicit window by the mesh's batch-shard count so a full
    window fills a full bucket on every device), so bursts ride full
    pow2 buckets;
  * otherwise it is dispatched when the *oldest* queued request has
    waited ``max_delay_us`` — the bound on latency added by coalescing,
    which is what keeps batch-1 traffic on a 25.4 us-scale SLO while
    still giving concurrent submitters a chance to share a bucket.

This module is a pure synchronous state machine: per-model FIFO queues,
round-robin model selection, admission control against a ``high_water``
image depth.  All time is passed in explicitly (monotonic seconds), so
the policy is unit-testable with a fake clock; :mod:`repro.serve.service`
drives it from an asyncio event loop and owns futures, threads and stats.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any, Deque, Dict, List, Optional

__all__ = ["PendingRequest", "QueueFull", "SchedulerConfig", "MicrobatchScheduler"]


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Microbatching policy knobs.

    ``max_delay_us``: longest a queued request may wait for coalescing
    before its batch is dispatched anyway (0 = dispatch as soon as the
    worker looks at the queue — pure latency mode).
    ``high_water``: per-model admission limit in *images*; a submission
    that would push the queue past it is rejected (unless the queue is
    empty, so a single oversized request can always be served — the
    engine slices it internally).
    """

    max_delay_us: float = 200.0
    high_water: int = 4096

    def __post_init__(self):
        if self.max_delay_us < 0:
            raise ValueError("max_delay_us must be >= 0")
        if self.high_water < 1:
            raise ValueError("high_water must be >= 1")


class QueueFull(Exception):
    """Admission rejected: queued images would exceed the high-water mark."""

    def __init__(self, model: str, depth: int, high_water: int):
        super().__init__(
            f"queue for {model!r} holds {depth} images "
            f"(high_water={high_water})"
        )
        self.model = model
        self.depth = depth
        self.high_water = high_water


@dataclasses.dataclass
class PendingRequest:
    """One queued request: its image payload plus bookkeeping.

    ``literals`` holds either raw pixel batches (``preprocessed=False``,
    the default — the engine's device-resident ingress converts them
    inside the classify graph) or literals already in the model's
    eval-path input form (``preprocessed=True``); either way all
    requests of one form concatenate along the batch axis, so coalescing
    stays a plain ``np.concatenate``.  ``payload`` is opaque to the
    scheduler — the service stores the asyncio future that resolves the
    request there.

    ``version`` is the model's lifecycle version id at admission
    (0 = unversioned): :meth:`MicrobatchScheduler.pop_batch` never
    coalesces across a version boundary, so one microbatch is always
    attributable to a single model version even when a hot swap lands
    between two queued requests (ARCHITECTURE.md §Lifecycle).

    ``deadline_t`` is the request's absolute expiry (monotonic seconds,
    None = no deadline): a request still queued past it is shed by
    :meth:`MicrobatchScheduler.expire` *before* dispatch — the service
    reports it as ``ServiceExpired`` instead of computing a dead answer
    (ARCHITECTURE.md §Faults).
    """

    model: str
    literals: Any           # np.ndarray [n, ...] raw pixels or literals
    n: int                  # images in this request
    enqueue_t: float        # monotonic seconds at admission
    payload: Any = None
    preprocessed: bool = False
    version: int = 0        # model version id at admission (0 = unversioned)
    deadline_t: Optional[float] = None   # absolute expiry (None = none)

    def expired(self, now: float) -> bool:
        return self.deadline_t is not None and now >= self.deadline_t


class MicrobatchScheduler:
    """Per-model FIFO queues with round-robin, deadline-driven dispatch."""

    def __init__(self, config: Optional[SchedulerConfig] = None, *,
                 max_coalesce: int = 256):
        if max_coalesce < 1:
            raise ValueError("max_coalesce must be >= 1")
        self.config = config or SchedulerConfig()
        self.max_coalesce = max_coalesce
        self._queues: Dict[str, Deque[PendingRequest]] = {}
        self._depths: Dict[str, int] = {}
        # Round-robin cursor: models are served in registration order
        # starting after the last-served model, so a hot tenant cannot
        # starve the others.
        self._last_served: Optional[str] = None

    # --- admission --------------------------------------------------------

    def check_admission(self, model: str, n: int) -> None:
        """Raise :class:`QueueFull` if ``n`` more images would exceed the
        high-water mark.  Exposed separately so callers can shed load
        *before* paying the host-side ingress for a doomed request."""
        depth = self._depths.get(model, 0)
        if depth > 0 and depth + n > self.config.high_water:
            raise QueueFull(model, depth, self.config.high_water)

    def submit(self, req: PendingRequest) -> None:
        """Enqueue or raise :class:`QueueFull` (admission control)."""
        self.check_admission(req.model, req.n)
        self._queues.setdefault(req.model, collections.deque()).append(req)
        self._depths[req.model] = self._depths.get(req.model, 0) + req.n

    def depth(self, model: str) -> int:
        """Queued images for one model."""
        return self._depths.get(model, 0)

    def total_depth(self) -> int:
        """Queued images across all models."""
        return sum(self._depths.values())

    def models_with_work(self) -> List[str]:
        return [m for m, q in self._queues.items() if q]

    # --- dispatch policy --------------------------------------------------

    def _deadline(self, model: str) -> float:
        """When the oldest queued request's coalescing window expires."""
        return self._queues[model][0].enqueue_t + self.config.max_delay_us * 1e-6

    def _ready(self, model: str, now: float) -> bool:
        return (
            self._depths[model] >= self.max_coalesce
            or now >= self._deadline(model)
        )

    def _rotation(self) -> List[str]:
        """Models with work, round-robin order after the last served.

        Rotates over the stable (insertion-ordered) model list *before*
        filtering for work, so the cursor survives the last-served
        model's queue going empty.
        """
        names = list(self._queues)
        if self._last_served in names:
            i = names.index(self._last_served) + 1
            names = names[i:] + names[:i]
        return [m for m in names if self._queues[m]]

    def next_ready(self, now: float, *, force: bool = False) -> Optional[str]:
        """The model whose batch should be dispatched now, if any.

        ``force`` ignores deadlines (drain mode: flush everything).
        """
        for m in self._rotation():
            if force or self._ready(m, now):
                return m
        return None

    def earliest_deadline(self) -> Optional[float]:
        """When the next batch becomes dispatchable by deadline alone
        (None when no work is queued)."""
        work = self.models_with_work()
        if not work:
            return None
        return min(self._deadline(m) for m in work)

    def earliest_expiry(self) -> Optional[float]:
        """The soonest queued-request deadline (None when no queued
        request carries one) — the service folds this into its wait so a
        request expires on time, not at the next coalescing wakeup."""
        ts = [
            r.deadline_t
            for q in self._queues.values()
            for r in q
            if r.deadline_t is not None
        ]
        return min(ts) if ts else None

    def pop_batch(self, model: str) -> List[PendingRequest]:
        """Dequeue whole requests for one microbatch, FIFO order.

        Takes requests until adding the next would exceed
        ``max_coalesce`` images; always takes at least one (an oversized
        single request passes through — the engine serves it in
        ``max_batch`` slices).  Stops at a version boundary: requests
        admitted under different model versions never share a microbatch
        (the leftover tail is dispatched on the next rotation, so a swap
        costs at most one extra microbatch, never a dropped request).
        Advances the round-robin cursor.
        """
        q = self._queues[model]
        if not q:
            raise ValueError(f"no pending requests for {model!r}")
        batch = [q.popleft()]
        n = batch[0].n
        while (
            q
            and n + q[0].n <= self.max_coalesce
            and q[0].version == batch[0].version
        ):
            r = q.popleft()
            batch.append(r)
            n += r.n
        self._depths[model] -= n
        self._last_served = model
        return batch

    def expire(self, now: float) -> List[PendingRequest]:
        """Remove and return every queued request whose deadline passed.

        Queue order and depth accounting stay consistent for the
        survivors; the caller (the service) owns failing the shed
        requests' futures with ``ServiceExpired``.  Requests without a
        deadline never expire.
        """
        shed: List[PendingRequest] = []
        for m, q in self._queues.items():
            if not any(r.expired(now) for r in q):
                continue
            keep = collections.deque()
            for r in q:
                if r.expired(now):
                    shed.append(r)
                else:
                    keep.append(r)
            self._queues[m] = keep
            self._depths[m] -= sum(r.n for r in shed if r.model == m)
        return shed

    def drain_all(self) -> List[PendingRequest]:
        """Remove and return every queued request (hard stop)."""
        out: List[PendingRequest] = []
        for m, q in self._queues.items():
            out.extend(q)
            q.clear()
            self._depths[m] = 0
        return out
