"""ServableModel: the frozen, serving-ready image of a trained ConvCoTM.

The ASIC holds all clause weights and TA action signals resident in
registers (the 45 056-bit model image, Sec. IV-B) and streams only image
data through the datapath.  This is the software equivalent: ``freeze``
derives every model-side quantity inference needs — include bits, packed
include words, the nonempty mask, int8-clamped weights — exactly once,
so per-batch work touches literals only.  ``core.cotm.infer`` used to
recompute all of these on every call.

A ``ServableModel`` is a pytree (config is static metadata), so it jits,
shards and checkpoints like any other model state.

Clause sparsity (ARCHITECTURE.md §Sparsity)
-------------------------------------------
A trained (or boundary-initialized) clause pool is sparse in two ways
the dense paths ignore:

  * **empty clauses** — zero includes; the ASIC's ``Empty`` signal forces
    their output low (Sec. IV-D), so evaluating their literal products is
    pure waste.  Gorji et al. (clause indexing, PAPERS.md) report 13x
    inference speedups from skipping clauses that cannot match.
  * **include density** — each clause tests only its included literals;
    the packed word ops already exploit this at word granularity, and the
    per-clause include counts let the autotuner and roofline model reason
    about it.

:func:`analyze_sparsity` derives, once per model, the **active-clause
register image**: the indices of nonempty clauses, their include masks
(dense, packed, and the complementary packed *exclude* masks the sparse
kernels consume), per-clause include popcounts, and the weight columns
restricted to active clauses.  Class sums over active clauses equal
class sums over all clauses bit for bit — empty clauses contribute
``w * 0`` — so every sparse path stays bit-identical to ``kernels/ref.py``.

The analysis needs concrete values (the active count becomes an array
*shape*), so it runs eagerly — ``ServingEngine.register`` attaches it;
``freeze`` under jit leaves ``sparsity=None`` and sparse paths fall back
to their dense twins (``serve/paths.py``).

Versioning (ARCHITECTURE.md §Lifecycle)
---------------------------------------
:class:`ServableVersion` is the identity stamp of one served model
version: an engine-assigned monotonic id plus the training provenance
(epoch / step) and a content :func:`servable_digest` of the register
image.  It rides on :class:`ServableModel` as the ``version`` field so
checkpoints and hand-offs carry it, but it is **not** part of the jit
story: ``ServingEngine`` strips the stamp (``version=None``) from the
image it dispatches, so hot-swapping versions of one model never
changes the static jit key and a same-geometry swap compiles nothing.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clauses as cl
from repro.core.patches import pack_bits

__all__ = [
    "ClauseSparsity",
    "ServableModel",
    "ServableVersion",
    "active_pad",
    "analyze_sparsity",
    "freeze",
    "servable_digest",
]


@dataclasses.dataclass(frozen=True)
class ServableVersion:
    """Identity stamp of one served model version.

    ``version`` is the engine-assigned monotonic id per serving slot
    (register -> 1, every swap/rollback increments); ``epoch``/``step``
    are the training cursor the weights came from; ``digest`` is the
    content hash of the register image (:func:`servable_digest`), which
    is what identifies *weights* across rollbacks — a rollback installs
    a fresh monotonic id carrying the prior version's digest.
    """

    version: int = 0
    epoch: int = 0
    step: int = 0
    digest: str = ""

    def as_dict(self) -> Dict:
        return {
            "version": self.version,
            "epoch": self.epoch,
            "step": self.step,
            "digest": self.digest,
        }

    @classmethod
    def from_dict(cls, d) -> "ServableVersion":
        """Parse a checkpoint-manifest stamp; malformed or legacy input
        (pre-version checkpoints have no stamp at all) synthesizes the
        v0 stamp instead of crashing restore."""
        if not isinstance(d, dict):
            return cls()
        try:
            return cls(
                version=int(d.get("version", 0)),
                epoch=int(d.get("epoch", 0)),
                step=int(d.get("step", 0)),
                digest=str(d.get("digest", "")),
            )
        except (TypeError, ValueError):
            return cls()


def servable_digest(servable: "ServableModel") -> str:
    """Content hash (12 hex chars) of a frozen model's functional identity.

    Hashes the include bits, the clamped weights and the config repr —
    everything class sums depend on (``include_packed``/``nonempty``
    derive from ``include``; sparsity/tuned are derived or advisory).
    Two servables with equal digests classify bit-identically.
    """
    h = hashlib.sha256()
    h.update(repr(servable.config).encode())
    h.update(np.asarray(servable.include).tobytes())
    h.update(np.asarray(servable.weights).tobytes())
    return h.hexdigest()[:12]


@dataclasses.dataclass(frozen=True)
class ClauseSparsity:
    """The active-clause register image (empty clauses pruned at freeze).

    All clause-axis arrays have ``C_a = n_active`` rows — a concrete,
    data-dependent shape, which is why this is derived eagerly and not
    under jit.  ``exclude_packed`` is the full 32-bit complement of
    ``include_packed`` (pad bits beyond 2o are set), so the sparse
    kernels' satisfied-word test ``~(lit | exclude) == 0`` needs no
    extra valid-bit masking.
    """

    active_idx: jax.Array       # int32 [C_a] indices into the full clause pool
    include: jax.Array          # uint8 0/1 [C_a, 2o] active include masks
    include_packed: jax.Array   # uint32 [C_a, W] packed include masks
    exclude_packed: jax.Array   # uint32 [C_a, W] ~include (pad bits set)
    include_counts: jax.Array   # int32 [C_a] include popcount per clause
    weights: jax.Array          # int8 [m, C_a] active weight columns

    @property
    def n_active(self) -> int:
        return self.include.shape[0]

    @property
    def include_density(self) -> float:
        """Mean include fraction over active clauses (0 when none)."""
        if self.n_active == 0 or self.include.shape[1] == 0:
            return 0.0
        return float(np.asarray(self.include_counts).sum()) / (
            self.n_active * self.include.shape[1]
        )


ClauseSparsity = jax.tree_util.register_dataclass(
    ClauseSparsity,
    data_fields=[
        "active_idx",
        "include",
        "include_packed",
        "exclude_packed",
        "include_counts",
        "weights",
    ],
    meta_fields=[],
)


@dataclasses.dataclass(frozen=True)
class ServableModel:
    """Frozen inference artifact (the register-file image).

    ``sparsity`` (optional) is the active-clause image from
    :func:`analyze_sparsity`; ``tuned`` (optional, static metadata) is the
    per-bucket kernel plan from ``serve/autotune.py`` — both ride along
    through placement, jit and checkpointing.  ``version`` (optional,
    static metadata) is the :class:`ServableVersion` lifecycle stamp;
    the serving engine strips it from the dispatched image (see the
    module docstring) so it never perturbs jit cache keys.
    """

    include: jax.Array         # uint8 0/1 [C, 2o] TA action signals
    include_packed: jax.Array  # uint32 [C, W] packed include masks
    nonempty: jax.Array        # bool [C] empty-clause mask (Sec. IV-D)
    weights: jax.Array         # int8 [m, C] clamped clause weights
    config: "repro.core.cotm.CoTMConfig"
    sparsity: Optional[ClauseSparsity] = None
    tuned: Optional["repro.serve.autotune.TunedPlan"] = None
    version: Optional[ServableVersion] = None

    @property
    def n_clauses(self) -> int:
        return self.include.shape[0]

    @property
    def n_classes(self) -> int:
        return self.weights.shape[0]


ServableModel = jax.tree_util.register_dataclass(
    ServableModel,
    data_fields=["include", "include_packed", "nonempty", "weights", "sparsity"],
    meta_fields=["config", "tuned", "version"],
)


def freeze(model, config) -> ServableModel:
    """Prepare a trained ``CoTMModel`` for serving (one-time, per model).

    Works under jit (``core.cotm.infer`` freezes inline at trace time) and
    eagerly (the serving engine freezes at registration and reuses the
    arrays for every batch thereafter).  Sparsity analysis requires
    concrete values; attach it eagerly with :func:`analyze_sparsity`
    (``ServingEngine.register`` does).
    """
    from repro.core.cotm import WEIGHT_MAX, WEIGHT_MIN

    include = model.include
    return ServableModel(
        include=include,
        include_packed=pack_bits(include),
        nonempty=cl.clause_nonempty(include),
        weights=jnp.clip(model.weights, WEIGHT_MIN, WEIGHT_MAX).astype(jnp.int8),
        config=config,
    )


def active_pad(n_active: int, n_clauses: int) -> int:
    """Pow2-binned active-row count: next power of two >= ``n_active``,
    clamped to the clause-pool size (0 stays 0).

    Sparsity array shapes are part of every jit cache key the servable
    touches, so two trained versions with different active counts would
    compile fresh executables on every hot swap.  Binning the padded row
    count to powers of two bounds the distinct shapes (and with them the
    jit cache growth of a swap storm) at ``log2(n_clauses) + 1`` per
    model — ``ServingEngine.swap`` pads with this policy.
    """
    if n_active <= 0:
        return 0
    return min(1 << (n_active - 1).bit_length(), n_clauses)


def analyze_sparsity(
    servable: ServableModel, *, pad_to: Optional[int | str] = None
) -> ServableModel:
    """Attach the active-clause image to a frozen servable (eager only).

    Idempotent; returns a new :class:`ServableModel` with ``sparsity``
    filled.  A model with NO active clauses yields zero-row arrays — the
    sparse paths still produce the correct all-zero class sums (asserted
    in tests/test_sparse.py's degenerate-servable cases).

    ``pad_to`` (optional, >= the true active count, or the string
    ``"pow2"`` for the :func:`active_pad` bin) pads the analysis to a
    fixed row count with **provably inert** synthetic clauses: an
    all-zero include row packs to an all-ones exclude word (satisfied by
    every input, so it fires) carrying an all-zero weight column — its
    class-sum contribution is exactly 0 on every sparse path, so padded
    and unpadded analyses are bit-identical.  ``ServingEngine.swap``
    pads to the pow2 bins so swap storms reuse warm executables instead
    of compiling one shape per trained version.
    """
    if servable.sparsity is not None:
        return servable
    include = np.asarray(servable.include)
    nonempty = np.asarray(servable.nonempty).astype(bool)
    weights = np.asarray(servable.weights)
    active = np.flatnonzero(nonempty).astype(np.int32)
    if pad_to == "pow2":
        pad_to = active_pad(len(active), servable.n_clauses)
    inc_a = include[active]                                  # [C_a, 2o]
    # Packing is per-clause-row, so the active subset's packed words are a
    # row slice of the freeze-time packing — no second pack_bits pass
    # (the pack-once contract in tests/test_serve.py covers this).
    incp_a = np.asarray(servable.include_packed)[active]
    counts = inc_a.sum(axis=-1).astype(np.int32)
    if pad_to is not None:
        if pad_to < len(active):
            raise ValueError(
                f"pad_to={pad_to} < {len(active)} active clauses — padding "
                f"can only grow the analysis"
            )
        pad = pad_to - len(active)
        if pad:
            inc_a = np.concatenate(
                [inc_a, np.zeros((pad,) + inc_a.shape[1:], inc_a.dtype)]
            )
            incp_a = np.concatenate(
                [incp_a, np.zeros((pad,) + incp_a.shape[1:], incp_a.dtype)]
            )
            counts = np.concatenate([counts, np.zeros(pad, np.int32)])
            weights_a = np.concatenate(
                [weights[:, active], np.zeros((weights.shape[0], pad), weights.dtype)],
                axis=1,
            )
            # -1 marks synthetic rows; no kernel consumes active_idx.
            active = np.concatenate([active, np.full(pad, -1, np.int32)])
        else:
            weights_a = weights[:, active]
    else:
        weights_a = weights[:, active]
    sparsity = ClauseSparsity(
        active_idx=jnp.asarray(active),
        include=jnp.asarray(inc_a.astype(np.uint8)),
        include_packed=jnp.asarray(incp_a),
        exclude_packed=jnp.asarray(~incp_a),                 # pad bits -> 1
        include_counts=jnp.asarray(counts),
        weights=jnp.asarray(weights_a),
    )
    return dataclasses.replace(servable, sparsity=sparsity)
