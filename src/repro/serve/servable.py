"""ServableModel: the frozen, serving-ready image of a trained ConvCoTM.

The ASIC holds all clause weights and TA action signals resident in
registers (the 45 056-bit model image, Sec. IV-B) and streams only image
data through the datapath.  This is the software equivalent: ``freeze``
derives every model-side quantity inference needs — include bits, packed
include words, the nonempty mask, int8-clamped weights — exactly once,
so per-batch work touches literals only.  ``core.cotm.infer`` used to
recompute all of these on every call.

A ``ServableModel`` is a pytree (config is static metadata), so it jits,
shards and checkpoints like any other model state.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import clauses as cl
from repro.core.patches import pack_bits

__all__ = ["ServableModel", "freeze"]


@dataclasses.dataclass(frozen=True)
class ServableModel:
    """Frozen inference artifact (the register-file image)."""

    include: jax.Array         # uint8 0/1 [C, 2o] TA action signals
    include_packed: jax.Array  # uint32 [C, W] packed include masks
    nonempty: jax.Array        # bool [C] empty-clause mask (Sec. IV-D)
    weights: jax.Array         # int8 [m, C] clamped clause weights
    config: "repro.core.cotm.CoTMConfig"

    @property
    def n_clauses(self) -> int:
        return self.include.shape[0]

    @property
    def n_classes(self) -> int:
        return self.weights.shape[0]


ServableModel = jax.tree_util.register_dataclass(
    ServableModel,
    data_fields=["include", "include_packed", "nonempty", "weights"],
    meta_fields=["config"],
)


def freeze(model, config) -> ServableModel:
    """Prepare a trained ``CoTMModel`` for serving (one-time, per model).

    Works under jit (``core.cotm.infer`` freezes inline at trace time) and
    eagerly (the serving engine freezes at registration and reuses the
    arrays for every batch thereafter).
    """
    from repro.core.cotm import WEIGHT_MAX, WEIGHT_MIN

    include = model.include
    return ServableModel(
        include=include,
        include_packed=pack_bits(include),
        nonempty=cl.clause_nonempty(include),
        weights=jnp.clip(model.weights, WEIGHT_MIN, WEIGHT_MAX).astype(jnp.int8),
        config=config,
    )
