"""Async serving service: request queue + microbatcher over a ServingEngine.

:class:`~repro.serve.engine.ServingEngine` is a synchronous library call;
this module is the *service* around it — the software counterpart of the
chip's full serving story (Sec. IV-C), where the 60.3k classifications/s
figure includes the DMA/frame system overhead, not just the datapath:

  * a bounded request queue with admission control: submissions that
    would push a model's queue past the high-water mark are rejected
    with :class:`ServiceOverloaded` carrying a ``retry_after_s`` hint
    (backpressure instead of unbounded latency collapse);
  * a latency-aware microbatcher (:mod:`repro.serve.scheduler`) that
    coalesces concurrent requests into the engine's pow2 buckets under a
    ``max_delay_us`` deadline — lone requests stay on a 25.4 us-scale
    SLO budget, bursts ride full buckets;
  * multi-model tenancy with round-robin fairness across the registered
    servables;
  * graceful drain (``stop(drain=True)`` flushes every queued request
    before shutdown) and per-model :class:`ServiceStats` snapshots
    (queue depth, batch-occupancy histogram, p50/p99 latency, and the
    ingress vs device latency split).

Raw-pixel fast path
-------------------
Requests are enqueued as **raw pixel batches** by default: admission
checks and a cheap shape validation are all the host-side work a request
pays, and the booleanize -> patches -> literals -> pack ingress runs
inside the engine's single jitted raw classify graph per microbatch —
amortized over every coalesced request instead of paid per submission.
``preprocessed=True`` literals and a legacy ``host_ingress=True`` mode
(the PR-3 per-request host pipeline, kept as the benchmark baseline)
remain available; mixed-form microbatches execute as one engine dispatch
per form.

Pipelined dispatch
------------------
The dispatch worker thread only *pads and submits* each microbatch
(``engine.dispatch`` — JAX dispatch is asynchronous) and hands the
in-flight handle to a completion thread that blocks on device results
and resolves the request futures.  Up to ``max_inflight`` microbatches
overlap this way — the asyncio analogue of the ASIC's double-buffered
image registers (frame k classifies while frame k+1 streams in), now
actually overlapping device compute with coalescing AND with the next
batch's dispatch.

Results are **bit-identical** to direct ``engine.classify`` calls no
matter how requests were coalesced: every form runs the engine's own
graphs and the datapath has no cross-batch interaction (padding rows
cannot perturb real rows — see ``serve/engine.py``), so concatenating
requests and slicing the results back is exact.  ``tests/test_service.py``
and ``tests/test_ingress.py`` assert this under concurrent submitters,
drain-under-load, and across raw/preprocessed submission forms.

Request-lifetime guarantees (ARCHITECTURE.md §Faults)
-----------------------------------------------------
Every admitted future RESOLVES — with a result or a structured error,
never a hang — under any fault ``serve/faults.py`` can inject
(``tests/test_faults.py`` chaos suite).  The hardening layers:

  * **deadlines**: ``submit(deadline_s=...)`` requests still queued past
    their deadline are shed *before* dispatch and fail with
    ``ServiceExpired`` (no compute spent on a dead answer);
  * **worker supervision**: a dead dispatch worker fails its in-flight
    microbatch with ``WorkerCrashed`` and is replaced under bounded
    exponential backoff (``DegradationPolicy``); past the restart budget
    the service drains instead of crash-looping;
  * **input quarantine**: when a coalesced microbatch fails at dispatch,
    its members are retried individually — a poisoned/malformed request
    fails alone, batchmates complete bit-identically;
  * **degraded modes**: a circuit breaker trips repeated per-model
    dispatch failures into ``engine.degrade_path`` (one step down the
    dense-fallback chain, still bit-identical to ``kernels/ref.py``);
    a ``DeviceLost`` re-places servables on a shrunk mesh
    (``engine.shrink_mesh``) and retries.  ``ServiceHealth`` snapshots
    (healthy / degraded / draining, last fault, fallback path) ride on
    every :meth:`ServingService.stats` call.

Typical lifecycle::

    engine = ServingEngine(max_batch=256)
    engine.register("mnist", model, cfg, booleanize_method="threshold")
    service = ServingService(engine, ServiceConfig(max_delay_us=200.0))
    await service.start()
    result = await service.submit("mnist", images)     # or submit_nowait
    print(service.stats("mnist"))
    await service.stop(drain=True)
"""

from __future__ import annotations

import asyncio
import collections
import dataclasses
import functools
from concurrent.futures import BrokenExecutor, ThreadPoolExecutor
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.serve.engine import InFlightClassify, ServingEngine
from repro.serve.faults import (
    DegradationPolicy,
    DeviceLost,
    PoisonedPayload,
    ServiceExpired,
    ServiceHealth,
    WorkerCrashed,
)
from repro.serve.scheduler import (
    MicrobatchScheduler,
    PendingRequest,
    QueueFull,
    SchedulerConfig,
)

__all__ = [
    "ServiceConfig",
    "ServiceOverloaded",
    "ServiceResult",
    "ServiceStats",
    "ServiceStopped",
    "ServingService",
]


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Service knobs (the SLO surface).

    ``max_delay_us``  — microbatch coalescing deadline (see scheduler).
    ``high_water``    — per-model queued-image admission limit.
    ``max_coalesce``  — images per microbatch **per data shard**; scaled
                        by the engine's mesh data-axis size so a full
                        microbatch fills a full bucket on every device.
                        None = engine ``max_batch`` (already the global
                        largest bucket — used as-is).
    ``max_inflight``  — microbatches allowed between dispatch and device
                        completion (2 = double buffering).
    ``latency_window``— per-model ring buffer of request latencies the
                        p50/p99 snapshot is computed over.
    """

    max_delay_us: float = 200.0
    high_water: int = 4096
    max_coalesce: Optional[int] = None
    max_inflight: int = 2
    latency_window: int = 8192

    def __post_init__(self):
        # max_delay_us / high_water are re-validated by SchedulerConfig.
        if self.max_coalesce is not None and self.max_coalesce < 1:
            raise ValueError("max_coalesce must be >= 1 (or None)")
        if self.max_inflight < 1:
            raise ValueError("max_inflight must be >= 1")
        if self.latency_window < 1:
            raise ValueError("latency_window must be >= 1")


class ServiceOverloaded(Exception):
    """Admission rejected; retry after ``retry_after_s`` (backpressure)."""

    def __init__(self, model: str, depth: int, retry_after_s: float):
        super().__init__(
            f"queue for {model!r} at high-water ({depth} images queued); "
            f"retry after {retry_after_s * 1e3:.1f} ms"
        )
        self.model = model
        self.depth = depth
        self.retry_after_s = retry_after_s


class ServiceStopped(RuntimeError):
    """The service is not accepting requests (not started, or stopping)."""


@dataclasses.dataclass
class ServiceResult:
    """One request's outcome, sliced back out of its microbatch.

    ``version`` is the monotonic id of the model version whose weights
    computed this result (captured atomically at engine dispatch, so a
    concurrent hot swap cannot mislabel it); ``batch_id`` identifies the
    microbatch it rode in — all members of one microbatch share a
    ``batch_id`` and, by the scheduler's version-boundary rule plus the
    dispatch-time swap guard, a single ``version``.
    """

    predictions: np.ndarray   # int32 [n]
    class_sums: np.ndarray    # int32 [n, m]
    latency_s: float          # enqueue -> result (queue wait + compute)
    bucket: int               # pow2 bucket the microbatch executed in
    batch_requests: int       # requests coalesced into that microbatch
    batch_images: int         # images in that microbatch
    version: int = 0          # model version id that computed it
    batch_id: int = 0         # service-wide microbatch sequence number


@dataclasses.dataclass
class ServiceStats:
    """Per-model service-level snapshot (engine stats stay separate)."""

    submitted: int = 0        # admission attempts (includes rejected)
    rejected: int = 0
    completed: int = 0        # requests resolved
    images: int = 0           # images classified through the service
    batches: int = 0          # microbatches executed
    expired: int = 0          # requests shed past their deadline
    quarantined: int = 0      # requests isolated out of failed microbatches
    queue_depth: int = 0      # images queued at snapshot time
    # bucket -> {"batches": ..., "images": ...}; occupancy of bucket b is
    # images / (batches * b).
    occupancy_hist: Dict[int, Dict[str, int]] = dataclasses.field(
        default_factory=dict
    )
    mean_occupancy: float = 0.0
    p50_latency_us: float = 0.0
    p99_latency_us: float = 0.0
    # Where microbatch time goes, per image: host-side ingress/validation
    # vs device execution (the serving bottleneck, made visible).
    ingress_us_per_image: float = 0.0
    device_us_per_image: float = 0.0
    # Service-wide ServiceHealth snapshot (serve/faults.py): state,
    # last fault, fallback path, restart/fault counters.
    health: Dict = dataclasses.field(default_factory=dict)

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class _ModelStats:
    """Mutable accumulator behind ServiceStats snapshots."""

    submitted: int = 0
    rejected: int = 0
    completed: int = 0
    images: int = 0
    batches: int = 0
    expired: int = 0
    quarantined: int = 0
    busy_s: float = 0.0
    ingress_s: float = 0.0
    device_s: float = 0.0
    occupancy_hist: Dict[int, Dict[str, int]] = dataclasses.field(
        default_factory=dict
    )
    latencies: Optional[object] = None   # collections.deque, set on init


class ServingService:
    """Asyncio request queue + pipelined microbatcher around a ServingEngine.

    ``faults`` threads a :class:`~repro.serve.faults.FaultPlan` through
    the dispatch seams (chaos tests only — None in production);
    ``policy`` sets the circuit-breaker / worker-supervision knobs
    (:class:`~repro.serve.faults.DegradationPolicy`).
    """

    def __init__(
        self,
        engine: ServingEngine,
        config: Optional[ServiceConfig] = None,
        *,
        faults=None,
        policy: Optional[DegradationPolicy] = None,
    ):
        self.engine = engine
        self.config = config or ServiceConfig()
        self.policy = policy or DegradationPolicy()
        self._faults = faults
        self._health = ServiceHealth()
        # Circuit breaker: consecutive dispatch failures per model; reset
        # by any successful dispatch, tripped into engine.degrade_path at
        # policy.failure_threshold.
        self._consec_failures: Dict[str, int] = {}
        # Explicit max_coalesce is per data shard: on a meshed engine a
        # "full" microbatch must fill a full bucket on EVERY device, so
        # the window scales with the batch-shard count — but never past
        # the engine's largest bucket (one microbatch must stay one
        # dispatch, not a chain of max_batch slices).  An unmeshed
        # window explicitly set above max_batch is left alone (legacy
        # oversized-window behavior).  The None default (engine
        # ``max_batch``) is already the global largest bucket.
        if self.config.max_coalesce is None:
            max_coalesce = engine.max_batch
        else:
            max_coalesce = min(
                self.config.max_coalesce * engine.data_shards,
                max(engine.max_batch, self.config.max_coalesce),
            )
        self._sched = MicrobatchScheduler(
            SchedulerConfig(
                max_delay_us=self.config.max_delay_us,
                high_water=self.config.high_water,
            ),
            max_coalesce=max_coalesce,
        )
        self._mstats: Dict[str, _ModelStats] = {}
        self._task: Optional[asyncio.Task] = None
        self._executor: Optional[ThreadPoolExecutor] = None
        self._completer: Optional[ThreadPoolExecutor] = None
        self._ingress: Optional[ThreadPoolExecutor] = None
        self._arrival: Optional[asyncio.Event] = None
        self._inflight: Optional[asyncio.Semaphore] = None
        self._completions: Set[asyncio.Task] = set()
        self._accepting = False
        self._stopping = False
        self._draining = False
        self._batch_seq = 0          # microbatch sequence (ServiceResult.batch_id)

    # --- lifecycle --------------------------------------------------------

    @property
    def running(self) -> bool:
        return self._task is not None

    async def start(self) -> None:
        """Start the dispatch loop; must run inside an event loop."""
        if self._task is not None:
            raise RuntimeError("service already started")
        self._accepting = True
        self._stopping = False
        self._draining = False
        self._arrival = asyncio.Event()
        self._inflight = asyncio.Semaphore(self.config.max_inflight)
        self._executor = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="serve-dispatch"
        )
        self._completer = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="serve-complete"
        )
        self._ingress = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="serve-ingress"
        )
        self._task = asyncio.create_task(self._run(), name="serving-service")

    async def stop(self, *, drain: bool = True) -> None:
        """Shut down.  ``drain=True`` serves every queued request first
        (their futures resolve normally); ``drain=False`` fails queued
        requests with :class:`ServiceStopped` (already-dispatched
        microbatches still complete).  Idempotent."""
        task = self._task
        if task is None:
            return
        self._accepting = False
        self._stopping = True
        self._health.state = "draining"
        if drain:
            self._draining = True
        else:
            for r in self._sched.drain_all():
                if not r.payload.done():
                    r.payload.set_exception(
                        ServiceStopped("service stopped before dispatch")
                    )
        self._arrival.set()
        await task
        # In-flight microbatches resolve on the completion thread; wait
        # for all of them before tearing the executors down.
        while self._completions:
            await asyncio.gather(*tuple(self._completions))
        # Concurrent stop() calls all await the same task; only the first
        # to get here tears down.  The joins run off-loop: shutdown(wait=
        # True) blocks until each worker thread exits, and other tenants'
        # traffic (a second service on this loop, heartbeats) must keep
        # flowing while this one drains (tests/test_service.py pins this).
        if self._task is task:
            self._task = None
            for ex in (self._executor, self._completer, self._ingress):
                await asyncio.to_thread(ex.shutdown, True)
            self._executor = None
            self._completer = None
            self._ingress = None

    # --- lifecycle: hot swap (ARCHITECTURE.md §Lifecycle) -----------------

    async def swap(self, name: str, model, config=None, **kwargs):
        """Hot-swap ``name``'s weights under live load (awaitable).

        Runs ``engine.swap`` OFF the event loop (``asyncio.to_thread``):
        the swap acquires the engine lock, which the dispatch worker
        thread holds across each microbatch — blocking the loop on it
        would stall every tenant's coalescing (and, with the dispatch
        executor busy, deadlock the loop against its own worker; same
        off-loop rule as ``stop``'s executor joins).  Queued requests
        admitted before the swap complete on their admission version;
        the service keeps accepting throughout.  Returns the installed
        :class:`~repro.serve.servable.ServableVersion`.
        """
        return await asyncio.to_thread(
            self.engine.swap, name, model, config, **kwargs
        )

    async def rollback(self, name: str):
        """Restore the previously served version (awaitable; off-loop
        for the same lock-discipline reasons as :meth:`swap`)."""
        return await asyncio.to_thread(self.engine.rollback, name)

    # --- submission -------------------------------------------------------

    def submit_nowait(
        self,
        name: str,
        images: np.ndarray,
        *,
        preprocessed: bool = False,
        deadline_s: Optional[float] = None,
    ) -> "asyncio.Future[ServiceResult]":
        """Admit a request and return the future of its result.

        Raw images (the default) are only shape-validated here — the
        booleanize/patch/pack ingress runs on device inside the
        microbatch's fused classify graph.  ``preprocessed=True``
        validates already-converted literals; the legacy per-request
        host pipeline is :meth:`submit_host_nowait`.

        ``deadline_s`` bounds the request's lifetime: still queued that
        many seconds after admission, it is shed *before* dispatch and
        its future fails with :class:`~repro.serve.faults.ServiceExpired`
        (no compute is spent on an answer nobody is waiting for).

        Raises :class:`ServiceStopped` when not accepting,
        :class:`ServiceOverloaded` past the high-water mark, and
        propagates the engine's validation errors (unknown model, empty
        request, wrong literal form or raw shape).  The returned future
        resolves with a :class:`ServiceResult` once the request's
        microbatch executes.
        """
        if self._task is None or not self._accepting:
            raise ServiceStopped("service is not accepting requests")
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError("deadline_s must be > 0 (or None)")
        # Admission first, on the image count alone: a rejected request
        # must not pay any per-image work (backpressure has to shed load,
        # not just refuse it after the expensive part).
        self._check_admission(name, len(images))
        if preprocessed:
            arr = self.engine.preprocess(name, images, preprocessed=True)
        else:
            arr = self.engine.validate_raw(name, images)
        ms = self._model_stats(name)
        ms.submitted += 1
        loop = asyncio.get_running_loop()
        now = loop.time()
        req = PendingRequest(
            model=name,
            literals=arr,
            n=int(arr.shape[0]),
            enqueue_t=now,
            payload=loop.create_future(),
            preprocessed=preprocessed,
            # Admission-time version id: pop_batch never coalesces across
            # a version boundary, so a swap landing mid-queue splits the
            # queue into per-version microbatches instead of mixing them.
            version=self.engine.version_id(name),
            deadline_t=None if deadline_s is None else now + deadline_s,
        )
        # No await between _check_admission above and this enqueue, so the
        # scheduler's own re-check cannot fail here.
        self._sched.submit(req)
        self._arrival.set()
        return req.payload

    def _check_admission(self, name: str, n: int) -> None:
        """Depth pre-check; converts QueueFull to ServiceOverloaded and
        counts the rejection.  Only a non-empty queue can reject, so the
        model is necessarily registered by then (stats exist)."""
        try:
            self._sched.check_admission(name, n)
        except QueueFull as e:
            ms = self._model_stats(name)
            ms.submitted += 1
            ms.rejected += 1
            raise ServiceOverloaded(
                name, e.depth, self._retry_after(name, e.depth)
            ) from e

    def submit_host_nowait(
        self, name: str, images: np.ndarray, *,
        deadline_s: Optional[float] = None,
    ) -> "asyncio.Future[ServiceResult]":
        """Admit a raw request through the legacy HOST ingress, without
        blocking the event loop: admission is checked synchronously here
        (so open-loop generators still see immediate rejections), then
        the per-request booleanize/patch/pack pipeline runs on the
        dedicated ingress thread and the literals enqueue when it
        finishes.  The pre-device-ingress baseline the raw benchmarks
        compare against — serialized on one ingress thread exactly like
        the PR-3 ``submit`` path, but never stalling the coalescer.
        """
        if self._task is None or not self._accepting:
            raise ServiceStopped("service is not accepting requests")
        self._check_admission(name, len(images))
        self.engine.validate_raw(name, images)
        loop = asyncio.get_running_loop()
        out: asyncio.Future = loop.create_future()

        async def _ingress_then_enqueue():
            try:
                lits = await loop.run_in_executor(
                    self._ingress,
                    functools.partial(self.engine.preprocess, name, images),
                )
                # The authoritative admission re-check inside
                # submit_nowait can still reject if the queue filled
                # during the ingress; that surfaces on the future.
                res = await self.submit_nowait(
                    name, lits, preprocessed=True, deadline_s=deadline_s
                )
                if not out.done():
                    out.set_result(res)
            except Exception as e:
                if not out.done():
                    out.set_exception(e)

        loop.create_task(_ingress_then_enqueue())
        return out

    async def submit(
        self,
        name: str,
        images: np.ndarray,
        *,
        preprocessed: bool = False,
        host_ingress: bool = False,
        deadline_s: Optional[float] = None,
    ) -> ServiceResult:
        """Admit a request and await its result.

        The default raw path enqueues pixels directly (cheap shape check
        only; the ingress is fused into the device graph).  With
        ``host_ingress=True`` the legacy per-request host pipeline runs
        on a dedicated ingress thread first (:meth:`submit_host_nowait`),
        so it never blocks the event loop — kept for baseline
        comparisons.  ``deadline_s`` bounds the request's queue lifetime
        (see :meth:`submit_nowait`).
        """
        if host_ingress and not preprocessed:
            return await self.submit_host_nowait(
                name, images, deadline_s=deadline_s
            )
        return await self.submit_nowait(
            name, images, preprocessed=preprocessed, deadline_s=deadline_s
        )

    # --- stats ------------------------------------------------------------

    def stats(self, name: str) -> ServiceStats:
        """Snapshot one model's service-level stats.

        Raises KeyError for a model the engine doesn't know (same
        contract as ``engine.stats``); a registered model with no
        traffic yet snapshots as all zeros.
        """
        if name not in self._mstats:
            self.engine.servable(name)   # KeyError on unknown models
        ms = self._model_stats(name)
        lat = np.asarray(ms.latencies, np.float64) if ms.latencies else None
        occ_w = sum(
            h["batches"] * b for b, h in ms.occupancy_hist.items()
        )
        return ServiceStats(
            submitted=ms.submitted,
            rejected=ms.rejected,
            completed=ms.completed,
            images=ms.images,
            batches=ms.batches,
            expired=ms.expired,
            quarantined=ms.quarantined,
            queue_depth=self._sched.depth(name),
            occupancy_hist={
                b: dict(h) for b, h in sorted(ms.occupancy_hist.items())
            },
            mean_occupancy=ms.images / occ_w if occ_w else 0.0,
            p50_latency_us=(
                float(np.percentile(lat, 50) * 1e6) if lat is not None else 0.0
            ),
            p99_latency_us=(
                float(np.percentile(lat, 99) * 1e6) if lat is not None else 0.0
            ),
            ingress_us_per_image=(
                ms.ingress_s / ms.images * 1e6 if ms.images else 0.0
            ),
            device_us_per_image=(
                ms.device_s / ms.images * 1e6 if ms.images else 0.0
            ),
            health=self._health.as_dict(),
        )

    def health(self) -> ServiceHealth:
        """The service-wide degradation state machine (live object —
        snapshot with ``.as_dict()``)."""
        return self._health

    def _model_stats(self, name: str) -> _ModelStats:
        ms = self._mstats.get(name)
        if ms is None:
            ms = _ModelStats(
                latencies=collections.deque(maxlen=self.config.latency_window)
            )
            self._mstats[name] = ms
        return ms

    def _retry_after(self, name: str, depth: int) -> float:
        """Backpressure hint: time to work off the current queue at the
        observed service rate (coarse fallback before any batch ran)."""
        ms = self._model_stats(name)
        if ms.images and ms.busy_s:
            return depth * ms.busy_s / ms.images
        return max(self.config.max_delay_us * 1e-6, 1e-3)

    # --- dispatch loop ----------------------------------------------------

    async def _wait_arrival(self, timeout: Optional[float]) -> None:
        try:
            await asyncio.wait_for(self._arrival.wait(), timeout)
        except asyncio.TimeoutError:
            return
        self._arrival.clear()

    async def _run(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            now = loop.time()
            self._shed_expired(now)
            model = self._sched.next_ready(now, force=self._draining)
            if model is None:
                deadline = self._sched.earliest_deadline()
                # Wake for the sooner of "a batch becomes dispatchable"
                # and "a queued request expires", so ServiceExpired
                # resolves at the deadline, not at the next arrival.
                expiry = self._sched.earliest_expiry()
                if expiry is not None and (deadline is None or expiry < deadline):
                    deadline = expiry
                if deadline is None:
                    if self._stopping:
                        return
                    await self._wait_arrival(None)
                else:
                    await self._wait_arrival(max(deadline - now, 0.0))
                continue
            batch = self._sched.pop_batch(model)
            await self._execute(loop, model, batch)

    # --- request lifetime (ARCHITECTURE.md §Faults) -----------------------

    def _fail_expired(self, r: PendingRequest, now: float) -> None:
        ms = self._model_stats(r.model)
        ms.expired += 1
        self._health.expired += 1
        if not r.payload.done():
            deadline_s = (
                r.deadline_t - r.enqueue_t if r.deadline_t is not None else 0.0
            )
            r.payload.set_exception(
                ServiceExpired(r.model, deadline_s, now - r.enqueue_t)
            )

    def _shed_expired(self, now: float) -> None:
        """Fail every queued request whose deadline passed — before it
        costs a dispatch (the no-dead-answers rule)."""
        for r in self._sched.expire(now):
            self._fail_expired(r, now)

    @staticmethod
    def _form_groups(
        batch: List[PendingRequest],
    ) -> List[Tuple[bool, List[PendingRequest]]]:
        """Partition a microbatch by request form (raw vs preprocessed),
        preserving request order within each group — raw pixels and
        literals cannot share one concatenation."""
        groups: List[Tuple[bool, List[PendingRequest]]] = []
        for r in batch:
            if groups and groups[-1][0] == r.preprocessed:
                groups[-1][1].append(r)
            else:
                groups.append((r.preprocessed, [r]))
        # Merge non-adjacent same-form runs (order across groups does not
        # matter — each request is sliced back independently).
        merged: Dict[bool, List[PendingRequest]] = {}
        for flag, reqs in groups:
            merged.setdefault(flag, []).extend(reqs)
        return list(merged.items())

    async def _execute(
        self, loop, model: str, batch: List[PendingRequest]
    ) -> None:
        """Dispatch one coalesced microbatch (pad + submit, no device
        wait) on the dispatch thread, then hand completion to the
        completion thread so the loop keeps coalescing batch k+1 while
        batch k computes.

        Fault tiers (ARCHITECTURE.md §Faults): a dead worker fails the
        batch with ``WorkerCrashed`` and restarts the dispatch executor
        under backoff; a ``DeviceLost`` shrinks the mesh and retries the
        batch member-by-member; any other dispatch failure feeds the
        circuit breaker and quarantines — members retry individually so
        one poisoned request cannot take its batchmates down.
        """
        now = loop.time()
        live = [r for r in batch if not r.expired(now)]
        for r in batch:
            if r.expired(now):
                # Expired while pop_batch was deciding: still never
                # dispatched (the acceptance invariant).
                self._fail_expired(r, now)
        if not live:
            return
        batch = live
        await self._inflight.acquire()
        groups = self._form_groups(batch)
        self._batch_seq += 1
        batch_id = self._batch_seq

        def _dispatch() -> List[Tuple[List[PendingRequest], InFlightClassify]]:
            if self._faults is not None:
                # Chaos seams, on the worker thread: slow-dispatch delay,
                # injected crash / device loss, poisoned-payload check.
                self._faults.on_service_dispatch(model)
                for r in batch:
                    self._faults.check_payload(r.literals, model)
            out = []
            # One version across ALL form groups of this microbatch: the
            # guard (the engine lock) pins the entry so a concurrent swap
            # lands strictly before or strictly after the whole batch.
            with self.engine.swap_guard():
                for preprocessed, reqs in groups:
                    if len(reqs) == 1:
                        arr = reqs[0].literals
                    else:
                        arr = np.concatenate([r.literals for r in reqs], axis=0)
                    out.append(
                        (reqs, self.engine.dispatch(
                            model, arr, preprocessed=preprocessed
                        ))
                    )
            return out

        t0 = loop.time()
        try:
            inflights = await loop.run_in_executor(self._executor, _dispatch)
        except (WorkerCrashed, BrokenExecutor) as e:
            # The worker died with this batch in flight: the requests were
            # never computed — fail them with a structured error, then
            # replace the worker (bounded backoff) and keep serving.
            self._inflight.release()
            err = (
                e if isinstance(e, WorkerCrashed)
                else WorkerCrashed(f"dispatch worker died: {e}", model=model)
            )
            self._health.note_fault(err)
            for r in batch:
                if not r.payload.done():
                    r.payload.set_exception(err)
            await self._restart_worker(err)
            return
        except DeviceLost as e:
            # Simulated mesh-device loss: re-place every servable on a
            # shrunk mesh (off-loop — engine lock discipline, same as
            # swap) and retry the batch member-by-member on it.
            self._inflight.release()
            self._health.device_losses += 1
            self._health.degrade(e)
            await asyncio.to_thread(self.engine.shrink_mesh)
            await self._dispatch_isolated(loop, model, batch)
            return
        except Exception as e:
            self._inflight.release()
            await self._record_dispatch_failure(model, e)
            if len(batch) == 1:
                r = batch[0]
                ms = self._model_stats(model)
                ms.quarantined += 1
                self._health.quarantined += 1
                if not r.payload.done():
                    r.payload.set_exception(e)
                return
            # Quarantine: the failure could belong to ONE member of the
            # coalesced batch (poisoned/malformed input) — retry each
            # request alone so only the culprit fails.
            await self._dispatch_isolated(loop, model, batch)
            return
        self._consec_failures.pop(model, None)
        task = loop.create_task(
            self._complete(loop, model, batch, inflights, t0, batch_id),
            name=f"serve-complete-{model}",
        )
        self._completions.add(task)
        task.add_done_callback(self._completions.discard)

    async def _dispatch_isolated(
        self, loop, model: str, batch: List[PendingRequest]
    ) -> None:
        """Dispatch each member of a failed microbatch alone.

        The per-request failure domain: a member that fails again
        (poisoned payload, persistent engine error) fails ALONE with its
        structured error; every other member completes bit-identically
        to an uncoalesced submit.  Retries skip the FaultPlan's
        ``on_service_dispatch`` counter — an injection plan is a script
        over the primary dispatch sequence, not a feedback loop over its
        own retries — but still honor payload poison (a property of the
        request, not of the schedule).
        """
        for r in batch:
            if r.payload.done():
                continue
            now = loop.time()
            if r.expired(now):
                self._fail_expired(r, now)
                continue
            await self._inflight.acquire()
            self._batch_seq += 1
            batch_id = self._batch_seq

            def _one(req=r):
                if self._faults is not None:
                    self._faults.check_payload(req.literals, model)
                with self.engine.swap_guard():
                    return [(
                        [req],
                        self.engine.dispatch(
                            model, req.literals, preprocessed=req.preprocessed
                        ),
                    )]

            t0 = loop.time()
            try:
                inflights = await loop.run_in_executor(self._executor, _one)
            except Exception as e:
                self._inflight.release()
                ms = self._model_stats(model)
                ms.quarantined += 1
                self._health.quarantined += 1
                self._health.note_fault(e)
                if not r.payload.done():
                    r.payload.set_exception(e)
                continue
            task = loop.create_task(
                self._complete(loop, model, [r], inflights, t0, batch_id),
                name=f"serve-complete-{model}",
            )
            self._completions.add(task)
            task.add_done_callback(self._completions.discard)

    async def _record_dispatch_failure(self, model: str, e: Exception) -> None:
        """Feed the circuit breaker: at ``policy.failure_threshold``
        consecutive non-poison dispatch failures for one model, move its
        eval path one step down the degradation chain (bit-identical
        results, lower risk surface)."""
        self._health.dispatch_failures += 1
        self._health.note_fault(e)
        if isinstance(e, PoisonedPayload):
            return   # a per-request fault says nothing about the path
        k = self._consec_failures.get(model, 0) + 1
        self._consec_failures[model] = k
        if k < self.policy.failure_threshold:
            return
        self._consec_failures[model] = 0
        # Off-loop: degrade_path takes the engine lock (see swap()).
        nxt = await asyncio.to_thread(self.engine.degrade_path, model)
        if nxt is not None:
            self._health.degrade(e)
            self._health.fallback_path = nxt

    async def _restart_worker(self, cause: Exception) -> None:
        """Replace the dead dispatch executor under bounded backoff; past
        ``policy.max_worker_restarts`` the service drains (fails queued
        requests with ServiceStopped) instead of crash-looping."""
        self._health.worker_restarts += 1
        n = self._health.worker_restarts
        if n > self.policy.max_worker_restarts:
            self._health.state = "draining"
            self._health.note_fault(cause)
            self._accepting = False
            self._stopping = True
            for r in self._sched.drain_all():
                if not r.payload.done():
                    r.payload.set_exception(
                        ServiceStopped(
                            "worker-restart budget exhausted; service "
                            "draining"
                        )
                    )
            return
        self._health.degrade(cause)
        await asyncio.sleep(self.policy.backoff_s(n))
        old = self._executor
        self._executor = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="serve-dispatch"
        )
        if old is not None:
            # The dead worker's queue is abandoned, not joined — its
            # in-flight batch already failed above.
            old.shutdown(wait=False)

    async def _complete(
        self,
        loop,
        model: str,
        batch: List[PendingRequest],
        inflights: List[Tuple[List[PendingRequest], InFlightClassify]],
        t0: float,
        batch_id: int = 0,
    ) -> None:
        """Block on device results (completion thread) and slice them back
        to the member requests."""
        try:
            results = await loop.run_in_executor(
                self._completer,
                lambda: [(reqs, h.result()) for reqs, h in inflights],
            )
        except Exception as e:
            self._health.note_fault(e)
            for r in batch:
                if not r.payload.done():
                    r.payload.set_exception(e)
            return
        finally:
            self._inflight.release()
        t1 = loop.time()

        n = sum(r.n for r in batch)
        ms = self._model_stats(model)
        ms.batches += 1
        ms.images += n
        ms.busy_s += t1 - t0
        for reqs, res in results:
            ms.ingress_s += res.ingress_s
            ms.device_s += res.device_s
            ng = sum(r.n for r in reqs)
            # Histogram by *engine slice*: a group larger than max_batch
            # (one oversized request) executes as several buckets, and
            # occupancy must stay a <= 1 fraction of each executed bucket.
            for off in range(0, ng, self.engine.max_batch):
                m = min(self.engine.max_batch, ng - off)
                hist = ms.occupancy_hist.setdefault(
                    self.engine.bucket_for(m), {"batches": 0, "images": 0}
                )
                hist["batches"] += 1
                hist["images"] += m
            off = 0
            for r in reqs:
                out = ServiceResult(
                    predictions=res.predictions[off : off + r.n],
                    class_sums=res.class_sums[off : off + r.n],
                    latency_s=t1 - r.enqueue_t,
                    bucket=res.bucket,
                    batch_requests=len(batch),
                    batch_images=n,
                    version=res.version,
                    batch_id=batch_id,
                )
                off += r.n
                ms.completed += 1
                ms.latencies.append(out.latency_s)
                if not r.payload.done():
                    r.payload.set_result(out)
