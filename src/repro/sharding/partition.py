"""Logical-axis sharding rules.

Parameters and activations declare *logical* axes ("batch", "embed",
"mlp", ...); this module maps them onto the physical mesh axes of whichever
mesh is active — the single-pod (16, 16) ("data", "model") production mesh,
the multi-pod (2, 16, 16) ("pod", "data", "model") mesh, or the 1-device
CPU test mesh — so model code never mentions physical axes.

Rules (MaxText-style):
  batch   -> ("pod", "data")   data parallelism; the pod axis only ever
                               carries batch (gradient all-reduce is the
                               only inter-pod collective).
  fsdp    -> "data"            parameter / optimizer-state sharding
                               (ZeRO): the non-tensor-parallel dim of every
                               large parameter is sharded over "data".
  tensor  -> "model"           tensor parallelism (heads / mlp / vocab).
  expert  -> "model"           expert parallelism for MoE archs whose
                               expert count divides the model axis.
  seq     -> "model"           sequence sharding for long-context decode
                               KV caches (paged over the model axis).
  (None)  -> replicated.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "LOGICAL_RULES",
    "logical_to_physical",
    "spec",
    "sharding",
    "shard",
    "mesh_axis_size",
]

Axis = Union[str, None, Tuple[str, ...]]

LOGICAL_RULES = {
    "batch": ("pod", "data"),
    "fsdp": ("data",),
    "tensor": ("model",),
    "expert": ("model",),
    "seq": ("model",),
    "clause": ("model",),
    "replicated": (),
}

# Sharding profiles (perf iteration, EXPERIMENTS.md §Perf):
#   tp       — default: TP over "model", ZeRO over "data".
#   dp       — small archs (< ~1 B params): no tensor parallelism; params
#              ZeRO-sharded over BOTH axes, batch over ("pod","data").
#              Eliminates the per-layer activation all-reduces that
#              dominate small-model cells.
#   serve_tp — decode: weights DECODE-RESIDENT, sharded over "model" only
#              (no per-step fsdp all-gather; the ASIC's "model clock
#              stopped" discipline applied to the pod).
# "clause" (the TM clause pool axis, serve/mesh.py) maps to "model" in
# every profile: clause sharding is the TM's tensor parallelism.
PROFILES = {
    "tp": LOGICAL_RULES,
    "dp": {
        "batch": ("pod", "data"),
        "fsdp": ("data", "model"),
        "tensor": (),
        "expert": (),
        "seq": ("model",),
        "clause": ("model",),
        "replicated": (),
    },
    "serve_tp": {
        "batch": ("pod", "data"),
        "fsdp": (),
        "tensor": ("model",),
        "expert": ("model",),
        "seq": ("model",),
        "clause": ("model",),
        "replicated": (),
    },
}

_ACTIVE_PROFILE = "tp"


def set_profile(name: str) -> None:
    """Select the active sharding profile (launcher-scoped)."""
    global _ACTIVE_PROFILE
    if name not in PROFILES:
        raise KeyError(f"unknown sharding profile {name}")
    global LOGICAL_RULES
    _ACTIVE_PROFILE = name
    LOGICAL_RULES = PROFILES[name]


def get_profile() -> str:
    return _ACTIVE_PROFILE


def _mesh_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def logical_to_physical(axis: Axis, mesh: Mesh) -> Optional[Union[str, Tuple[str, ...]]]:
    """One logical axis -> physical mesh axes present in ``mesh``."""
    if axis is None:
        return None
    names = _mesh_axes(mesh)
    if isinstance(axis, tuple):
        out: list = []
        for a in axis:
            p = logical_to_physical(a, mesh)
            if p is None:
                continue
            out.extend(p if isinstance(p, tuple) else (p,))
        return tuple(out) if out else None
    phys = tuple(a for a in LOGICAL_RULES.get(axis, ()) if a in names)
    if not phys:
        return None
    return phys if len(phys) > 1 else phys[0]


def spec(logical: Sequence[Axis], mesh: Mesh) -> P:
    """Logical axis tuple -> PartitionSpec for ``mesh``."""
    return P(*(logical_to_physical(a, mesh) for a in logical))


def sharding(logical: Sequence[Axis], mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, spec(logical, mesh))


def shard(x: jax.Array, logical: Sequence[Axis], mesh: Mesh) -> jax.Array:
    """with_sharding_constraint with logical axes (no-op on 1-device mesh)."""
    if mesh.size == 1:
        return x
    return jax.lax.with_sharding_constraint(x, sharding(logical, mesh))


def sharding_for(shape: Tuple[int, ...], logical: Sequence[Axis], mesh: Mesh) -> NamedSharding:
    """NamedSharding for a concrete shape; logical axes whose mesh-axis
    product does not divide the dim size are dropped (jit input shardings
    require exact divisibility — e.g. a global_batch=1 long-context cell
    cannot shard its batch axis)."""
    from jax.sharding import PartitionSpec as PS

    base = spec(logical, mesh)
    fixed = []
    for dim, axes in zip(shape, base):
        if axes is None:
            fixed.append(None)
            continue
        ax_tuple = axes if isinstance(axes, tuple) else (axes,)
        size = 1
        for a in ax_tuple:
            size *= mesh.shape[a]
        fixed.append(axes if (size and dim % size == 0) else None)
    return NamedSharding(mesh, PS(*fixed))


def mesh_axis_size(mesh: Mesh, logical: str) -> int:
    """Product of the physical axis sizes a logical axis maps onto."""
    phys = logical_to_physical(logical, mesh)
    if phys is None:
        return 1
    if isinstance(phys, str):
        phys = (phys,)
    size = 1
    for a in phys:
        size *= mesh.shape[a]
    return size


@functools.lru_cache(maxsize=8)
def single_device_mesh() -> Mesh:
    """1-device mesh used by smoke tests and CPU examples."""
    import numpy as np

    return Mesh(np.array(jax.devices()[:1]), ("data",))
