"""Pure-JAX AdamW with ZeRO-sharded state and cosine LR schedule.

Optimizer moments are pytrees with the same structure (and logical
sharding) as the parameters — so the ``fsdp``/``tensor`` rules that shard a
weight also shard its m/v (ZeRO-1 falls out of the sharding rules; no
bespoke partitioner needed).  Master weights are kept in fp32 when params
are bf16.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig

__all__ = ["OptState", "init_opt_state", "adamw_update", "lr_schedule", "global_norm"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OptState:
    step: jax.Array            # int32 scalar
    m: Any                     # fp32, like params
    v: Any                     # fp32, like params
    master: Any                # fp32 master weights (None-like zeros if fp32)


def init_opt_state(params: Any) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    # copy=True: fp32 params must not alias master (donation safety).
    master = jax.tree.map(lambda p: jnp.array(p, jnp.float32, copy=True), params)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        m=zeros,
        v=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        master=master,
    )


def lr_schedule(step: jax.Array, tcfg: TrainConfig) -> jax.Array:
    """Linear warmup then cosine decay to 10% of peak."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(tcfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - tcfg.warmup_steps) / max(tcfg.total_steps - tcfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.55 + 0.45 * jnp.cos(jnp.pi * prog)
    return tcfg.learning_rate * warm * cos


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(leaf.astype(jnp.float32))) for leaf in leaves)
    )


def adamw_update(
    params: Any, grads: Any, opt: OptState, tcfg: TrainConfig
) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    """One AdamW step (grad clip + decoupled weight decay)."""
    step = opt.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, tcfg.grad_clip / (gnorm + 1e-9))
    lr = lr_schedule(step, tcfg)
    b1, b2, eps = tcfg.beta1, tcfg.beta2, tcfg.eps
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, mw):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        new_mw = mw - lr * (mhat / (jnp.sqrt(vhat) + eps) + tcfg.weight_decay * mw)
        return new_mw.astype(p.dtype), m, v, new_mw

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt.m)
    flat_v = jax.tree.leaves(opt.v)
    flat_mw = jax.tree.leaves(opt.master)
    outs = [upd(*t) for t in zip(flat_p, flat_g, flat_m, flat_v, flat_mw)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_opt = OptState(
        step=step,
        m=jax.tree.unflatten(treedef, [o[1] for o in outs]),
        v=jax.tree.unflatten(treedef, [o[2] for o in outs]),
        master=jax.tree.unflatten(treedef, [o[3] for o in outs]),
    )
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, new_opt, metrics
