"""Serving: prefill and decode steps for every family, plus sampling.

``prefill``  — full-sequence forward producing last-position logits (the
shape lowered for the ``prefill_32k`` cells).  For simplicity and HLO size
the prefill does not write the KV cache tensor-by-tensor; production
prefill-to-decode handoff re-runs the cached projections into the decode
cache layout (``prime_cache``), which is itself jittable.

``decode``   — single-token step against the cache (the ``decode_32k`` and
``long_500k`` cells lower this function).

The sampler applies the ASIC's monotone-saturation idea (Sec. IV-D CSRF)
to EOS handling: sequences whose EOS flag has latched are frozen and their
per-step work is masked out — the same "saturated OR needs no more
evaluation" reasoning, applied to batched decoding.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec as ed
from repro.models import transformer as tfm

__all__ = [
    "prefill",
    "decode",
    "sample_tokens",
    "make_serve_fns",
    "make_tm_serve_fn",
]


def prefill(
    params: Any, batch: Dict, cfg: ModelConfig, *, mesh=None
) -> jax.Array:
    """Returns last-position logits [B, vocab]."""
    if cfg.is_encoder_decoder:
        hidden = ed.encdec_forward(
            params, batch["frontend_embeds"], batch["dec_tokens"], cfg, mesh=mesh
        )
    else:
        hidden, _ = tfm.forward(
            params, batch.get("tokens"), cfg, mesh=mesh,
            frontend_embeds=batch.get("frontend_embeds"),
        )
    last = hidden[:, -1]
    from repro.models.layers import lm_logits, softcap

    logits = lm_logits(params["embed"], last, cfg).astype(jnp.float32)
    return softcap(logits, cfg.logit_softcap)


def decode(
    params: Any,
    tokens: jax.Array,
    cache: Any,
    pos: jax.Array,
    cfg: ModelConfig,
    *,
    cross_cache: Optional[Dict] = None,
    mesh=None,
) -> Tuple[jax.Array, Any]:
    """One decode step -> (logits [B, vocab], new cache)."""
    if cfg.is_encoder_decoder:
        return ed.encdec_decode_step(
            params, tokens, cache, cross_cache, pos, cfg, mesh=mesh
        )
    return tfm.decode_step(params, tokens, cache, pos, cfg, mesh=mesh)


def sample_tokens(
    key: jax.Array,
    logits: jax.Array,
    *,
    temperature: float = 0.0,
    eos_id: int = 2,
    done: Optional[jax.Array] = None,
    pad_id: int = 0,
) -> Tuple[jax.Array, jax.Array]:
    """Greedy/temperature sampling with latched EOS masking.

    Returns (tokens [B], done [B]); once done latches, the sequence emits
    pad tokens (frozen — the saturation early-exit).
    """
    if temperature > 0.0:
        nxt = jax.random.categorical(key, logits / temperature, axis=-1)
    else:
        nxt = jnp.argmax(logits, axis=-1)
    nxt = nxt.astype(jnp.int32)
    if done is None:
        done = jnp.zeros(nxt.shape, bool)
    nxt = jnp.where(done, pad_id, nxt)
    done = done | (nxt == eos_id)
    return nxt, done


def make_tm_serve_fn(servable, path: Optional[str] = None):
    """Jitted TM classify step closed over a frozen :class:`ServableModel`.

    The ConvCoTM analogue of ``make_serve_fns``: the model-side state is
    baked in (the register-file image), the returned function maps
    literals (in the path's input form) to ``(predictions, class_sums)``.
    Prefer :class:`repro.serve.ServingEngine` for batched traffic — this
    is the single-step building block (the engine's own jitted step,
    shared compile cache included).
    """
    from repro.serve.engine import classify_step
    from repro.serve.paths import get_path

    name = path or servable.config.eval_path
    get_path(name)  # fail fast on unknown paths
    return functools.partial(classify_step, servable, path_name=name)


def make_serve_fns(cfg: ModelConfig, mesh=None):
    """(prefill_fn, decode_fn) closed over cfg/mesh, ready for jit."""

    def prefill_fn(params, batch):
        return prefill(params, batch, cfg, mesh=mesh)

    def decode_fn(params, tokens, cache, pos, cross_cache=None):
        return decode(
            params, tokens, cache, pos, cfg, cross_cache=cross_cache, mesh=mesh
        )

    return prefill_fn, decode_fn
