"""Batch-parallel ConvCoTM training engine.

The training counterpart of ``repro.serve.engine.ServingEngine``: where
the serving engine freezes a model once and streams literals through a
jitted classify step, the ``TrainerEngine`` freezes the *dataset* once —
booleanize -> patches -> literals through the shared
``repro.data.pipeline`` ingress, device-resident for the whole run — and
streams the model through jitted epochs:

  * every epoch is ONE dispatch: a ``lax.scan`` over pre-batched gather
    indices, with the model buffers donated so XLA updates the TA/weight
    arrays in place instead of reallocating per step;
  * clause evaluation inside ``sample_deltas_literals`` uses the MXU
    matmul fast path (``config.train_eval='matmul'``), bit-identical to
    the dense reference broadcast;
  * with a mesh, per-device delta sums are combined with an exact integer
    ``shard_map`` psum (``repro.distributed.collectives.tree_psum_batch``)
    — batch-mode data parallelism whose result is bit-identical to the
    single-device sum;
  * the epoch shuffle comes from ``repro.data.pipeline.epoch_permutation``
    and the cursor is a checkpointable ``PipelineState``, so an engine run
    resumes exactly where ``batches()`` would.

Semantics contract: ``mode='batch'`` reproduces the naive
``update_batch`` python loop bit-for-bit given the same starting key and
cursor (the engine splits keys in the same ``key, k = split(key)`` chain);
``mode='scan'`` preserves exact sequential TMU semantics per batch and is
single-device only.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clauses as cl
from repro.core.cotm import CoTMConfig, CoTMModel, init_model
from repro.core.ingress import IngressSpec, device_ingress
from repro.core.train import _step_literals
from repro.data.pipeline import PipelineState, epoch_permutation

__all__ = ["TMDataset", "EpochReport", "TrainerEngine"]


@dataclasses.dataclass(frozen=True)
class TMDataset:
    """A dataset frozen for training: device-resident dense literals.

    Built once per dataset by :meth:`TrainerEngine.prepare` (the analogue
    of ``ServingEngine.register`` freezing a model once); every epoch
    gathers batches out of these arrays on device.
    """

    literals: jax.Array     # uint8 [N, P, 2o]
    labels: jax.Array       # int32 [N]

    @property
    def n(self) -> int:
        return self.literals.shape[0]


@dataclasses.dataclass
class EpochReport:
    """Per-epoch accounting returned by :meth:`TrainerEngine.fit`."""

    epoch: int
    samples: int
    seconds: float
    samples_per_s: float
    accuracy: Optional[float] = None


class TrainerEngine:
    """Jitted full-epoch ConvCoTM training over precomputed literals.

    Args:
      config: the ConvCoTM hyper-parameters (``config.train_eval`` picks
        the training clause-evaluation path, matmul by default).
      batch_size: samples per update step.
      mode: ``'batch'`` (vmap + summed deltas, the data-parallel mode) or
        ``'scan'`` (strict sequential per-sample application — exact TMU
        semantics, single-device only).
      mesh: optional ``jax.sharding.Mesh``; batch-mode delta sums then
        reduce with an exact integer shard_map psum over ``data_axis``
        (``batch_size`` must divide evenly by that axis' size).
      data_axis: mesh axis name carrying data parallelism.
      eval_batch: chunk size for :meth:`evaluate` (bounds the eval-time
        ``[B, P, C]`` intermediate).
    """

    def __init__(
        self,
        config: CoTMConfig,
        *,
        batch_size: int = 100,
        mode: str = "batch",
        mesh=None,
        data_axis: str = "data",
        eval_batch: int = 1024,
    ):
        if mode not in ("batch", "scan"):
            raise ValueError(f"unknown mode {mode!r}; expected 'batch' or 'scan'")
        if mode == "scan" and mesh is not None:
            raise ValueError(
                "mode='scan' is strictly sequential (exact TMU semantics) "
                "and cannot be data-parallel; use mode='batch' with a mesh"
            )
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        if mesh is not None:
            if data_axis not in mesh.axis_names:
                raise ValueError(
                    f"data_axis {data_axis!r} not in mesh axes {mesh.axis_names}"
                )
            axis_size = mesh.shape[data_axis]
            if batch_size % axis_size:
                raise ValueError(
                    f"batch_size={batch_size} must divide evenly over "
                    f"mesh axis {data_axis!r} (size {axis_size})"
                )
        if eval_batch < 1:
            raise ValueError("eval_batch must be >= 1")
        self.config = config
        self.batch_size = batch_size
        self.mode = mode
        self.mesh = mesh
        self.data_axis = data_axis
        self.eval_batch = eval_batch
        self._epoch_fn = self._build_epoch_fn()
        self._eval_fn = self._build_eval_fn()

    # --- dataset ingress --------------------------------------------------

    #: prepare() chunk size: bounds the peak footprint of the ingress
    #: gather; at most two shapes (full chunk + remainder) ever compile.
    INGRESS_CHUNK = 4096

    def prepare(
        self,
        images: np.ndarray,
        labels: np.ndarray,
        *,
        booleanize_method: str = "threshold",
        **booleanize_kw,
    ) -> TMDataset:
        """Freeze a dataset: device ingress -> dense literals, on device.

        Runs the same device-resident ingress the serving engine fuses
        into its classify graph (``core.ingress``: booleanize -> patches
        -> literals as ONE jitted dispatch per chunk, raw pixels H2D and
        nothing back) exactly once; epochs only gather from the result.
        Bit-identical to the old host-side ``preprocess_for_serving``
        route (asserted in ``tests/test_ingress.py``).
        """
        spec = IngressSpec(
            patch=self.config.patch,
            method=booleanize_method,
            packed=False,
            **booleanize_kw,
        )
        x = np.asarray(images)
        chunks = [
            device_ingress(spec, jnp.asarray(x[i : i + self.INGRESS_CHUNK]))
            for i in range(0, len(x), self.INGRESS_CHUNK)
        ]
        lits = chunks[0] if len(chunks) == 1 else jnp.concatenate(chunks, axis=0)
        return TMDataset(
            literals=lits.astype(jnp.uint8),
            labels=jax.device_put(jnp.asarray(np.asarray(labels), jnp.int32)),
        )

    def init_model(self, key: jax.Array) -> CoTMModel:
        return init_model(key, self.config)

    # --- jitted epoch -----------------------------------------------------

    def _build_epoch_fn(self):
        config, mode = self.config, self.mode
        mesh, data_axis = self.mesh, self.data_axis

        def epoch_fn(model, literals, labels, idx, keys):
            """idx int32 [S, B] gather indices; keys [S] step PRNG keys."""

            def step(mdl, xs):
                ix, k = xs
                mdl = _step_literals(
                    k, mdl, literals[ix], labels[ix], config, mode,
                    mesh=mesh, data_axis=data_axis,
                )
                return mdl, None

            model, _ = jax.lax.scan(step, model, (idx, keys))
            return model

        # Donating the model buffers lets XLA update the TA counters and
        # weights in place across the whole epoch.
        return jax.jit(epoch_fn, donate_argnums=(0,))

    def _build_eval_fn(self):
        def eval_fn(model, literals, labels):
            fired = cl.eval_clauses_matmul(literals, model.include)
            v = cl.class_sums(fired, model.weights)
            pred = cl.argmax_predict(v)
            return jnp.sum((pred == labels).astype(jnp.int32))

        return jax.jit(eval_fn)

    @staticmethod
    def _chain_keys(key: jax.Array, n: int) -> Tuple[jax.Array, jax.Array]:
        """n step keys via the naive loop's ``key, k = split(key)`` chain.

        Returns (advanced key, stacked step keys ``[n]``) — the exact key
        sequence a hand-written epoch loop would feed ``update_batch``,
        which is what makes engine-vs-naive runs bit-identical.
        """
        keys = []
        for _ in range(n):
            key, k = jax.random.split(key)
            keys.append(k)
        return key, jnp.stack(keys)

    def run_epoch(
        self,
        key: jax.Array,
        model: CoTMModel,
        ds: TMDataset,
        state: Optional[PipelineState] = None,
    ) -> Tuple[jax.Array, CoTMModel, PipelineState, int]:
        """Run (the rest of) one epoch as a single jitted scan.

        Resumes from ``state`` (mid-epoch cursors skip the already-trained
        steps of that epoch's permutation; a cursor exhausted on entry
        rolls forward and trains the next epoch, mirroring ``batches()``)
        and returns ``(advanced key, model, rolled-over cursor, samples
        trained)``.
        """
        state = state or PipelineState()
        b = self.batch_size
        n_steps = ds.n // b
        if n_steps == 0:
            raise ValueError(
                f"dataset has {ds.n} samples < batch_size={b}; an epoch "
                f"would train nothing — shrink batch_size or grow the dataset"
            )
        if state.step >= n_steps:
            state = PipelineState(state.epoch + 1, 0, state.seed)
        perm = epoch_permutation(state.seed, state.epoch, ds.n)
        steps = n_steps - state.step
        idx = perm[state.step * b : n_steps * b].reshape(steps, b)
        key, keys = self._chain_keys(key, steps)
        model = self._epoch_fn(
            model, ds.literals, ds.labels, jnp.asarray(idx, jnp.int32), keys
        )
        return key, model, PipelineState(state.epoch + 1, 0, state.seed), steps * b

    def evaluate(self, model: CoTMModel, ds: TMDataset) -> float:
        """Accuracy on a prepared dataset (matmul eval path on literals).

        Evaluates in ``eval_batch`` chunks — one full dataset dispatch
        would materialize an ``[N, P, C]`` fp32 violation-count tensor
        (~1.8 GB for a 10k split at paper geometry).  At most two shapes
        ever compile: the full chunk and the remainder.
        """
        b = self.eval_batch
        # Accumulate the per-chunk correct counts as DEVICE scalars and
        # convert exactly once at the end: an int() per chunk would force
        # a host sync inside the dispatch loop, serializing chunk k+1's
        # dispatch behind chunk k's compute (tmlint TM103; the one-sync
        # contract is pinned in tests/test_tm_engine.py).
        totals = [
            self._eval_fn(model, ds.literals[i : i + b], ds.labels[i : i + b])
            for i in range(0, ds.n, b)
        ]
        return int(sum(totals)) / ds.n

    def freeze_servable(
        self, model: CoTMModel, state: Optional[PipelineState] = None
    ):
        """Freeze a trained model into a stamp-carrying servable.

        The train -> serve hand-off of the lifecycle loop
        (ARCHITECTURE.md §Lifecycle): the returned
        :class:`~repro.serve.servable.ServableModel` carries a
        :class:`~repro.serve.servable.ServableVersion` whose epoch/step
        come from the training cursor and whose digest hashes the frozen
        register image.  The monotonic id is left 0 — the serving engine
        assigns it at ``register``/``swap``.  Freeze happens here exactly
        once per candidate version (the freeze-once-per-version contract);
        sparsity analysis stays the engine's job.
        """
        from repro.serve.servable import ServableVersion, freeze, servable_digest

        servable = freeze(model, self.config)
        state = state or PipelineState()
        stamp = ServableVersion(
            version=0,
            epoch=state.epoch,
            step=state.step,
            digest=servable_digest(servable),
        )
        return dataclasses.replace(servable, version=stamp)

    # --- driver -----------------------------------------------------------

    def fit(
        self,
        key: jax.Array,
        model: CoTMModel,
        train_ds: TMDataset,
        *,
        epochs: int,
        eval_ds: Optional[TMDataset] = None,
        state: Optional[PipelineState] = None,
        log=None,
    ) -> Tuple[jax.Array, CoTMModel, PipelineState, List[EpochReport]]:
        """Train ``epochs`` further epochs from the ``state`` cursor.

        Returns ``(advanced key, model, cursor, reports)``; pass the key,
        cursor and model (via ``repro.checkpoint``) back in to resume with
        the exact key chain an uninterrupted run would have used.
        """
        state = state or PipelineState()
        reports: List[EpochReport] = []
        for _ in range(epochs):
            t0 = time.perf_counter()
            key, model, state, n = self.run_epoch(key, model, train_ds, state)
            jax.block_until_ready(model.ta_state)
            dt = time.perf_counter() - t0
            rep = EpochReport(
                # the cursor now points at the next epoch; the one just
                # trained is state.epoch - 1 (also right for stale cursors)
                epoch=state.epoch - 1,
                samples=n,
                seconds=dt,
                samples_per_s=n / dt if dt > 0 else 0.0,
                accuracy=self.evaluate(model, eval_ds) if eval_ds else None,
            )
            reports.append(rep)
            if log is not None:
                acc = f"  acc {rep.accuracy:.4f}" if rep.accuracy is not None else ""
                log(
                    f"epoch {rep.epoch}:{acc}  "
                    f"({rep.samples_per_s:,.0f} samples/s, {rep.seconds:.2f}s)"
                )
        return key, model, state, reports
