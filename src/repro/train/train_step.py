"""Sharded train step: microbatched grad accumulation, remat, optional
int8+error-feedback gradient compression, AdamW.

The step is built per (arch, mesh) and jitted with NamedSharding
in/out_shardings by the launcher; inside, activations carry logical
sharding constraints (see models/*), so GSPMD emits:

  * reduce-scatter/all-gather for the fsdp-sharded params (ZeRO),
  * all-reduce of grads over ("pod", "data") — per *microbatch*, so the
    collective of microbatch i overlaps the forward of microbatch i+1
    (the standard accumulate-and-overlap schedule),
  * all-to-all for expert-parallel MoE dispatch.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.distributed.collectives import compressed_grad_sync
from repro.models import encdec as ed
from repro.models import transformer as tfm
from repro.train.optimizer import adamw_update, init_opt_state

__all__ = ["make_loss_fn", "make_train_step", "init_train_state", "TrainState"]


TrainState = Dict[str, Any]   # {"params", "opt", "residual"}


def make_loss_fn(cfg: ModelConfig, mesh=None, remat: bool = True) -> Callable:
    """batch dict -> scalar loss. Batch keys by family:

    decoder-only: {tokens [B, S]}; vlm adds {frontend_embeds [B, Sv, d]};
    enc-dec: {frontend_embeds [B, Se, d], dec_tokens [B, Sd]}.
    """

    def loss_fn(params, batch):
        if cfg.is_encoder_decoder:
            return ed.encdec_loss(
                params, batch["frontend_embeds"], batch["dec_tokens"], cfg,
                mesh=mesh, remat=remat,
            )
        return tfm.lm_loss(
            params, batch["tokens"], cfg, mesh=mesh,
            frontend_embeds=batch.get("frontend_embeds"), remat=remat,
        )

    return loss_fn


def init_train_state(params: Any, tcfg: TrainConfig) -> TrainState:
    state: TrainState = {"params": params, "opt": init_opt_state(params)}
    if tcfg.grad_compression:
        state["residual"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
    return state


def _split_microbatches(batch: Dict, k: int) -> Dict:
    def split(x):
        b = x.shape[0]
        if b % k:
            raise ValueError(f"batch {b} not divisible by microbatches {k}")
        return x.reshape(k, b // k, *x.shape[1:])

    return {key: split(v) for key, v in batch.items()}


def make_train_step(
    cfg: ModelConfig, tcfg: TrainConfig, mesh=None
) -> Callable[[TrainState, Dict], Tuple[TrainState, Dict]]:
    loss_fn = make_loss_fn(cfg, mesh=mesh, remat=tcfg.remat != "none")
    grad_fn = jax.value_and_grad(loss_fn)

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        params = state["params"]
        k = tcfg.microbatches
        if k > 1:
            mbs = _split_microbatches(batch, k)

            def body(carry, mb):
                loss_acc, grad_acc = carry
                loss, grads = grad_fn(params, mb)
                grad_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), grad_acc, grads
                )
                return (loss_acc + loss, grad_acc), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zero), mbs
            )
            loss = loss / k
            grads = jax.tree.map(lambda g: g / k, grads)
        else:
            loss, grads = grad_fn(params, batch)

        metrics = {"loss": loss}
        if tcfg.grad_compression:
            grads, new_residual = compressed_grad_sync(grads, state["residual"])
            metrics["residual_norm"] = jnp.sqrt(
                sum(
                    jnp.sum(jnp.square(r))
                    for r in jax.tree.leaves(new_residual)
                )
            )
        new_params, new_opt, opt_metrics = adamw_update(params, grads, state["opt"], tcfg)
        metrics.update(opt_metrics)
        new_state: TrainState = {"params": new_params, "opt": new_opt}
        if tcfg.grad_compression:
            new_state["residual"] = new_residual
        return new_state, metrics

    return train_step
