"""Optional-`hypothesis` shim for the property tests.

Imports the real library when installed.  Otherwise provides a minimal
fallback: ``@given`` draws ``max_examples`` pseudo-random examples from
the declared strategies with a fixed seed — deterministic, no shrinking,
but the invariants still get exercised instead of the whole module
failing at collection.

Usage (in test modules):  from _hypothesis_shim import given, settings, st
"""

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import functools
    import inspect
    import random

    HAVE_HYPOTHESIS = False
    _DEFAULT_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class st:  # noqa: N801 - mirrors `hypothesis.strategies as st`
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

    def settings(max_examples=_DEFAULT_EXAMPLES, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
                rng = random.Random(0)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **kwargs, **drawn)

            # Hide the drawn parameters from pytest's fixture resolution
            # (it must see only e.g. ``self``, not ``seed``/``steps``).
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(
                parameters=[
                    p for name, p in sig.parameters.items()
                    if name not in strategies
                ]
            )
            del wrapper.__wrapped__
            return wrapper

        return deco
