"""Regenerate adaptive_golden.npz: OpenCV adaptiveThreshold references.

The checked-in archive pins ``core.booleanize.adaptive_gaussian_booleanize``
(the paper's FMNIST/KMNIST booleanizer, Sec. III-D) to real
``cv2.adaptiveThreshold(..., ADAPTIVE_THRESH_GAUSSIAN_C, THRESH_BINARY,
block_size, c)`` outputs, so the JAX implementation is tested against
OpenCV ground truth rather than only against itself
(tests/test_booleanize_golden.py).

Requires opencv-python; run offline, the npz is committed:

    PYTHONPATH=src python tests/data/gen_adaptive_golden.py
"""

import os

import cv2
import numpy as np

CONFIGS = [(11, 2.0), (7, 3.0), (5, 2.0)]   # (block_size, c); 11/2 = paper default


def _images() -> np.ndarray:
    """A 28x28 probe set: random textures, a smooth shaded field, flat
    fields, and a sparse glyph-like stroke image."""
    out = [
        np.random.default_rng(seed).integers(0, 256, (28, 28)).astype(np.uint8)
        for seed in (0, 1)
    ]
    xs = np.linspace(0.0, 255.0, 28)
    grad = np.add.outer(xs, xs) / 2 + 30 * np.sin(np.add.outer(xs / 20, xs / 15))
    out.append(np.clip(grad, 0, 255).astype(np.uint8))
    out.append(np.zeros((28, 28), np.uint8))           # flat black
    out.append(np.full((28, 28), 200, np.uint8))       # flat bright
    glyph = np.zeros((28, 28), np.uint8)
    glyph[6:22, 13:16] = 230                            # vertical stroke
    glyph[6:9, 10:19] = 230                             # serif
    out.append(glyph)
    return np.stack(out)


def main():
    imgs = _images()
    arrays = {"images": imgs, "configs": np.asarray(CONFIGS, np.float64)}
    for bs, c in CONFIGS:
        refs = np.stack(
            [
                cv2.adaptiveThreshold(
                    im, 1, cv2.ADAPTIVE_THRESH_GAUSSIAN_C,
                    cv2.THRESH_BINARY, bs, c,
                )
                for im in imgs
            ]
        ).astype(np.uint8)
        arrays[f"ref_b{bs}_c{c:g}"] = refs
    path = os.path.join(os.path.dirname(__file__), "adaptive_golden.npz")
    np.savez_compressed(path, **arrays)
    print(f"wrote {path}: images {imgs.shape}, cv2 {cv2.__version__}")


if __name__ == "__main__":
    main()
