"""Per-bucket autotuner: plan semantics, deterministic re-registration,
bit-identity of tuned serving, bounded tuning time, and the
no-recompile-after-warmup contract (ARCHITECTURE.md §Autotune)."""

import time

import jax
import numpy as np

import repro.serve.engine as engine_mod
from repro.core.cotm import CoTMConfig, init_boundary_model
from repro.core.patches import PatchSpec
from repro.serve import ServingEngine, TunedPlan
from repro.serve.autotune import clear_measure_memo

# Tiny geometry so the full candidate sweep stays in CI-smoke territory.
SPEC = PatchSpec(image_x=8, image_y=8, window_x=4, window_y=4)
CFG = CoTMConfig(n_clauses=16, n_classes=4, patch=SPEC)
BUCKETS = (1, 4)


def _model(seed=0):
    return init_boundary_model(jax.random.PRNGKey(seed), CFG)


def _images(n, seed=0):
    rng = np.random.default_rng(seed)
    side = CFG.patch.image_y
    return rng.integers(0, 256, (n, side, side)).astype(np.uint8)


def _tuned_engine(**kw):
    eng = ServingEngine(max_batch=max(BUCKETS), autotune=True,
                        autotune_repeats=1, **kw)
    eng.register("m", _model(), CFG, path="fused")
    eng.autotune("m", buckets=BUCKETS)
    return eng


class TestTunedPlan:
    PLAN = TunedPlan().with_entry("raw", 1, "fused", ()).with_entry(
        "raw", 16, "matmul", (("block_b", 8),)
    )

    def test_exact_lookup(self):
        assert self.PLAN.lookup("raw", 16) == ("matmul", (("block_b", 8),))

    def test_nearest_below(self):
        assert self.PLAN.lookup("raw", 8) == ("fused", ())

    def test_smallest_above_when_nothing_below(self):
        plan = TunedPlan().with_entry("raw", 16, "matmul", ())
        assert plan.lookup("raw", 2) == ("matmul", ())

    def test_unknown_form_is_none(self):
        assert self.PLAN.lookup("literals", 4) is None

    def test_with_entry_replaces(self):
        plan = self.PLAN.with_entry("raw", 16, "dense", ())
        assert plan.lookup("raw", 16) == ("dense", ())
        assert len(plan.entries) == len(self.PLAN.entries)

    def test_json_round_trip(self):
        assert TunedPlan.from_json(self.PLAN.to_json()) == self.PLAN

    def test_hashable(self):
        assert hash(self.PLAN) == hash(TunedPlan(entries=self.PLAN.entries))


class TestAutotuneDeterminism:
    def test_two_registrations_same_plan(self):
        """The memoized measurements make re-registering the same model
        produce byte-identical plans despite wall-clock jitter."""
        a = _tuned_engine()
        b = _tuned_engine()
        assert a.servable("m").tuned == b.servable("m").tuned
        assert a.servable("m").tuned.entries  # non-trivial plan

    def test_plan_covers_requested_cells(self):
        eng = _tuned_engine()
        plan = eng.servable("m").tuned
        cells = {(f, b) for f, b, _, _ in plan.entries}
        assert {("literals", 1), ("literals", 4), ("raw", 1), ("raw", 4)} <= cells

    def test_pretuned_plan_skips_remeasure(self):
        """register(tuned=plan) restores a checkpointed plan verbatim —
        warmup must not re-run the tuner."""
        plan = _tuned_engine().servable("m").tuned
        eng = ServingEngine(max_batch=max(BUCKETS), autotune=True)
        eng.register("m", _model(), CFG, path="fused",
                     tuned=TunedPlan.from_json(plan.to_json()))
        eng.warmup("m", buckets=BUCKETS)
        assert eng.servable("m").tuned == plan
        assert eng.stats("m").autotune == {}   # nothing re-measured


class TestTunedBitIdentity:
    def test_tuned_matches_untuned(self):
        """Whatever the tuner picked per (form, bucket), results equal the
        untuned registered path — tuning can never change outputs."""
        ref = ServingEngine(max_batch=max(BUCKETS))
        ref.register("m", _model(), CFG, path="fused")
        eng = _tuned_engine()
        eng.warmup("m", buckets=BUCKETS)
        for n in (1, 3, 4):
            imgs = _images(n, seed=n)
            want = ref.classify("m", imgs)
            for kw in ({"ingress": "device"}, {"ingress": "host"}):
                got = eng.classify("m", imgs, **kw)
                np.testing.assert_array_equal(want.class_sums, got.class_sums)
                np.testing.assert_array_equal(want.predictions, got.predictions)


class TestWarmupCoversDispatch:
    def test_no_recompile_after_warmup(self):
        """Warmup compiles every (form, bucket) executable the engine can
        dispatch — including tuned winners — so serving afterwards never
        grows the jit caches (the regression this test pins down)."""
        from tools.recompile_guard import no_recompiles

        eng = _tuned_engine()
        eng.warmup("m", buckets=BUCKETS)
        # Touch both forms once so the lazily-built raw jit exists.
        eng.classify("m", _images(2))
        eng.classify("m", _images(2), ingress="host")
        with no_recompiles(
            engine_mod.classify_step, (engine_mod, "_raw_step_jit")
        ):
            for n in (1, 2, 3, 4):
                imgs = _images(n, seed=n)
                eng.classify("m", imgs)
                eng.classify("m", imgs, ingress="host")
                lits = eng.preprocess("m", imgs)
                eng.classify("m", lits, preprocessed=True)

    def test_compiled_buckets_reported(self):
        eng = _tuned_engine()
        eng.warmup("m", buckets=BUCKETS)
        assert set(eng.stats("m").compiled_buckets) == set(BUCKETS)


class TestBoundedTuning:
    def test_autotune_time_bounded_at_tiny_geometry(self):
        """The CI contract: a cold full sweep at tiny geometry finishes
        well inside the tier-1 budget, and the report accounts for it."""
        clear_measure_memo()
        t0 = time.perf_counter()
        eng = _tuned_engine()
        elapsed = time.perf_counter() - t0
        report = eng.stats("m").autotune
        assert report["total_s"] <= elapsed
        assert elapsed < 120.0, f"autotune took {elapsed:.1f}s at tiny geometry"

    def test_max_seconds_budget_skips_but_still_plans(self):
        """With an exhausted budget the tuner keeps the first measured
        candidate per cell, records skips, and still emits a full plan."""
        clear_measure_memo()
        eng = ServingEngine(max_batch=max(BUCKETS), autotune=True,
                            autotune_repeats=1, autotune_max_seconds=0.0)
        eng.register("m", _model(), CFG, path="fused")
        eng.autotune("m", buckets=BUCKETS)
        plan = eng.servable("m").tuned
        cells = {(f, b) for f, b, _, _ in plan.entries}
        assert {("literals", 1), ("raw", 4)} <= cells
        rows = eng.stats("m").autotune["rows"]
        assert any(r["skipped"] for r in rows)
        clear_measure_memo()     # do not poison later tests' memo
