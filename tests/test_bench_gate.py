"""tools/check_bench_trajectory.py: the CI perf gate's decision logic —
regression detection, threshold/skip escape hatches, and tolerance to
malformed artifact rows (none of which the gate had tests for before)."""

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
GATE = REPO / "tools" / "check_bench_trajectory.py"

sys.path.insert(0, str(REPO))
from benchmarks.trajectory import (  # noqa: E402
    compare,
    distill_serve_rows,
    median_drop,
    previous_row,
    upsert_row,
)


def _serve_row(path="fused", bucket=8, cls_per_s=1000.0, **extra):
    fields = {
        "kind": "serve_engine",
        "path": path,
        "bucket": bucket,
        "cls_per_s": cls_per_s,
    }
    fields.update(extra)
    return {"fields": fields}


def _bench_payload(cls_per_s, geometry="tiny"):
    return {
        "geometry": geometry,
        "rows": [
            _serve_row("fused", 8, cls_per_s),
            _serve_row("sparse", 8, cls_per_s),
        ],
    }


def _trajectory(cls_per_s=1000.0, paper_cls_per_s=None):
    geometries = {
        "tiny": {
            "best_cls_per_s": {
                "fused|b8": cls_per_s,
                "sparse|b8": cls_per_s,
            }
        }
    }
    if paper_cls_per_s is not None:
        geometries["paper"] = {
            "best_cls_per_s": {
                "fused|b8": paper_cls_per_s,
                "sparse|b8": paper_cls_per_s,
            }
        }
    return {
        "schema": 1,
        "rows": [
            {
                "pr": "PRX",
                "generated_at": "2026-01-01T00:00:00Z",
                "geometries": geometries,
            }
        ],
    }


def run_gate(tmp_path, bench, traj, env_extra=None):
    bench_p = tmp_path / "BENCH_serve.json"
    bench_p.write_text(json.dumps(bench))
    traj_p = tmp_path / "BENCH_trajectory.json"
    traj_p.write_text(json.dumps(traj))
    env = {k: v for k, v in os.environ.items() if not k.startswith("BENCH_GATE")}
    env.update(env_extra or {})
    return subprocess.run(
        [
            sys.executable,
            str(GATE),
            "--bench",
            str(bench_p),
            "--trajectory",
            str(traj_p),
        ],
        cwd=REPO,
        env=env,
        capture_output=True,
        text=True,
    )


class TestGateDecision:
    def test_regression_fails(self, tmp_path):
        # 50% drop on every key >> 15% threshold
        proc = run_gate(tmp_path, _bench_payload(500.0), _trajectory(1000.0))
        assert proc.returncode == 1, proc.stdout + proc.stderr
        assert "FAIL" in proc.stdout

    def test_within_threshold_passes(self, tmp_path):
        proc = run_gate(tmp_path, _bench_payload(950.0), _trajectory(1000.0))
        assert proc.returncode == 0, proc.stdout + proc.stderr
        assert "PASS" in proc.stdout

    def test_improvement_passes(self, tmp_path):
        proc = run_gate(tmp_path, _bench_payload(2000.0), _trajectory(1000.0))
        assert proc.returncode == 0

    def test_skip_env_bypasses_regression(self, tmp_path):
        proc = run_gate(
            tmp_path,
            _bench_payload(1.0),
            _trajectory(1000.0),
            env_extra={"BENCH_GATE_SKIP": "1"},
        )
        assert proc.returncode == 0
        assert "skipped" in proc.stdout

    def test_threshold_env_overrides_default(self, tmp_path):
        # 50% drop passes a 60% threshold, fails the default 15%
        proc = run_gate(
            tmp_path,
            _bench_payload(500.0),
            _trajectory(1000.0),
            env_extra={"BENCH_GATE_THRESHOLD": "0.6"},
        )
        assert proc.returncode == 0, proc.stdout + proc.stderr

    def test_other_geometry_skips(self, tmp_path):
        proc = run_gate(
            tmp_path, _bench_payload(1.0, geometry="medium"), _trajectory(1000.0)
        )
        assert proc.returncode == 0
        assert "gate only runs at tiny" in proc.stdout

    def test_paper_regression_warns_but_passes(self, tmp_path):
        # 50% drop at paper geometry: warn-only, never exit 1
        proc = run_gate(
            tmp_path,
            _bench_payload(500.0, geometry="paper"),
            _trajectory(1000.0, paper_cls_per_s=1000.0),
        )
        assert proc.returncode == 0, proc.stdout + proc.stderr
        assert "WARNING" in proc.stdout
        assert "warn-only" in proc.stdout
        assert "FAIL" not in proc.stdout

    def test_paper_within_threshold_reports_ok(self, tmp_path):
        proc = run_gate(
            tmp_path,
            _bench_payload(950.0, geometry="paper"),
            _trajectory(1000.0, paper_cls_per_s=1000.0),
        )
        assert proc.returncode == 0, proc.stdout + proc.stderr
        assert "paper geometry OK" in proc.stdout
        assert "WARNING" not in proc.stdout

    def test_paper_without_committed_paper_row_skips(self, tmp_path):
        # Committed row has only tiny numbers: no shared paper keys
        proc = run_gate(
            tmp_path, _bench_payload(1.0, geometry="paper"), _trajectory(1000.0)
        )
        assert proc.returncode == 0
        assert "no shared" in proc.stdout

    def test_no_committed_row_skips(self, tmp_path):
        proc = run_gate(
            tmp_path, _bench_payload(1.0), {"schema": 1, "rows": []}
        )
        assert proc.returncode == 0
        assert "no committed trajectory row" in proc.stdout

    def test_no_shared_keys_skips(self, tmp_path):
        traj = _trajectory(1000.0)
        traj["rows"][0]["geometries"]["tiny"]["best_cls_per_s"] = {
            "bitpacked|b64": 1.0
        }
        proc = run_gate(tmp_path, _bench_payload(500.0), traj)
        assert proc.returncode == 0
        assert "no shared" in proc.stdout

    def test_malformed_rows_do_not_crash_the_gate(self, tmp_path):
        bench = _bench_payload(950.0)
        bench["rows"] += [
            {"fields": {"kind": "serve_engine", "path": "fused"}},  # no bucket
            {"fields": {"kind": "serve_engine", "path": "x", "bucket": 8,
                        "cls_per_s": "not-a-number"}},
            {"fields": "not-a-dict"},
            {"no_fields_at_all": True},
        ]
        proc = run_gate(tmp_path, bench, _trajectory(1000.0))
        assert proc.returncode == 0, proc.stdout + proc.stderr
        assert "PASS" in proc.stdout


class TestTrajectoryHelpers:
    def test_distill_takes_best_per_key_and_skips_malformed(self, capsys):
        rows = [
            _serve_row("fused", 8, 100.0),
            _serve_row("fused", 8, 250.0),           # best wins
            _serve_row("fused", 8, 200.0),
            {"fields": {"kind": "other", "x": 1}},    # not serve_engine
            {"fields": {"kind": "serve_engine"}},     # malformed: skipped
            "not-even-a-dict",
        ]
        best = distill_serve_rows(rows)
        assert best == {"fused|b8": 250.0}
        assert "skipped 1 malformed" in capsys.readouterr().err

    def test_compare_marks_only_threshold_breaches(self):
        prev = {"a|b1": 100.0, "b|b1": 100.0, "only_prev|b1": 5.0}
        cur = {"a|b1": 90.0, "b|b1": 50.0, "only_cur|b1": 7.0}
        out = compare(prev, cur, threshold=0.15)
        assert [r["key"] for r in out] == ["a|b1", "b|b1"]  # shared keys only
        by_key = {r["key"]: r for r in out}
        assert not by_key["a|b1"]["regressed"]   # 10% drop
        assert by_key["b|b1"]["regressed"]       # 50% drop
        assert median_drop(out) == pytest.approx(0.3)

    def test_upsert_replaces_same_pr_row(self):
        traj = {"schema": 1, "rows": [{"pr": "PR1", "v": 1}]}
        traj = upsert_row(traj, {"pr": "PR1", "v": 2})
        traj = upsert_row(traj, {"pr": "PR2", "v": 3})
        assert [r["pr"] for r in traj["rows"]] == ["PR1", "PR2"]
        assert traj["rows"][0]["v"] == 2

    def test_previous_row_skips_own_pr(self):
        traj = {"schema": 1, "rows": [{"pr": "PR1"}, {"pr": "PR2"}]}
        assert previous_row(traj)["pr"] == "PR2"
        assert previous_row(traj, before_pr="PR2")["pr"] == "PR1"
        assert previous_row({"rows": []}) is None
