"""Golden-value tests: adaptive Gaussian booleanization vs OpenCV.

``adaptive_gaussian_booleanize`` implements the paper's FMNIST/KMNIST
preprocessing (Sec. III-D): ``cv2.adaptiveThreshold(...,
ADAPTIVE_THRESH_GAUSSIAN_C, THRESH_BINARY, block_size, c)``.  The other
booleanize tests only check the JAX code against itself; here it is
pinned to real OpenCV outputs checked into ``tests/data/``
(regenerate with ``tests/data/gen_adaptive_golden.py`` — cv2 is not a
test-time dependency).

Exactness caveat: OpenCV computes the Gaussian local mean in 8-bit
fixed point (its uint8 GaussianBlur path) and rounds it to uint8 before
comparing; the JAX path keeps the separable convolution in float32.
The two can therefore disagree only for pixels whose value falls within
a few gray levels of the decision boundary ``local_mean - c`` —
empirically the fixed-point mean deviates by up to ~2.5 levels, so the
tests assert bit-equality outside a 3-level band plus a small bounded
mismatch rate overall.  The largest divergence class is the dark halo
around bright strokes on black backgrounds (mean ~ c, so 0-pixels sit
almost exactly on the boundary) — glyph-like images are deliberately in
the probe set to pin that behavior.
"""

import os

import numpy as np
import pytest

from repro.core.booleanize import (
    adaptive_gaussian_booleanize,
    booleanize,
    gaussian_kernel1d,
)

GOLDEN = os.path.join(os.path.dirname(__file__), "data", "adaptive_golden.npz")


@pytest.fixture(scope="module")
def golden():
    return np.load(GOLDEN)


def _local_mean_reference(img: np.ndarray, block_size: int) -> np.ndarray:
    """Independent numpy Gaussian local mean (separable, edge-replicated)
    used to locate the decision boundary — deliberately not the JAX code
    under test."""
    k = gaussian_kernel1d(block_size).astype(np.float64)
    pad = block_size // 2
    x = img.astype(np.float64)
    x = np.pad(x, ((pad, pad), (0, 0)), mode="edge")
    x = np.apply_along_axis(lambda col: np.convolve(col, k, "valid"), 0, x)
    x = np.pad(x, ((0, 0), (pad, pad)), mode="edge")
    return np.apply_along_axis(lambda row: np.convolve(row, k, "valid"), 1, x)


def _configs(golden):
    return [(int(bs), float(c)) for bs, c in golden["configs"]]


class TestAdaptiveGolden:
    def test_matches_opencv_away_from_quantization_boundary(self, golden):
        """Bit-exact agreement with cv2.adaptiveThreshold wherever the
        pixel is not within OpenCV's fixed-point quantization band (3
        gray levels) of the threshold."""
        images = golden["images"]
        for bs, c in _configs(golden):
            refs = golden[f"ref_b{bs}_c{c:g}"]
            got = np.asarray(adaptive_gaussian_booleanize(images, bs, c))
            assert got.shape == refs.shape and got.dtype == np.uint8
            for img, ref, out in zip(images, refs, got):
                mean = _local_mean_reference(img, bs)
                boundary = np.abs(img.astype(np.float64) - (mean - c)) < 3.0
                disagree = ref != out
                assert not np.any(disagree & ~boundary), (
                    f"b{bs}/c{c}: disagreement away from the rounding "
                    f"boundary at {np.argwhere(disagree & ~boundary)[:4]}"
                )

    def test_mismatch_rate_bounded(self, golden):
        """Boundary-pixel disagreements stay rare (<3.5% per image; the
        worst case is the stroke-halo glyph image, see module doc)."""
        images = golden["images"]
        for bs, c in _configs(golden):
            refs = golden[f"ref_b{bs}_c{c:g}"]
            got = np.asarray(adaptive_gaussian_booleanize(images, bs, c))
            per_image = (refs != got).reshape(len(images), -1).mean(axis=1)
            assert per_image.max() <= 0.035, (bs, c, per_image)

    def test_flat_fields_are_exact(self, golden):
        """Constant images sit c away from the boundary: must be exact
        (all-ones for any c > 0, OpenCV semantics)."""
        images = golden["images"]
        flat = [i for i, im in enumerate(images) if im.min() == im.max()]
        assert flat, "golden set must include flat images"
        for bs, c in _configs(golden):
            refs = golden[f"ref_b{bs}_c{c:g}"]
            got = np.asarray(adaptive_gaussian_booleanize(images, bs, c))
            for i in flat:
                np.testing.assert_array_equal(got[i], refs[i])
                np.testing.assert_array_equal(refs[i], np.ones_like(refs[i]))

    def test_dispatch_method_adaptive_matches_direct(self, golden):
        """booleanize(method='adaptive') is the same code path the
        serving ingress uses for FMNIST/KMNIST entries."""
        images = golden["images"]
        bs, c = _configs(golden)[0]
        np.testing.assert_array_equal(
            np.asarray(booleanize(images, method="adaptive", block_size=bs, c=c)),
            np.asarray(adaptive_gaussian_booleanize(images, bs, c)),
        )
