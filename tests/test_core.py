"""Unit tests for the ConvCoTM core (booleanize, patches, clauses, io)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import clauses as cl
from repro.core.booleanize import (
    adaptive_gaussian_booleanize,
    thermometer_encode,
    threshold_booleanize,
)
from repro.core.cotm import CoTMConfig, infer, init_model
from repro.core.model_io import model_size_bytes, pack_model, unpack_model
from repro.core.patches import (
    PatchSpec,
    extract_patch_features,
    make_literals,
    pack_bits,
    unpack_bits,
)

KEY = jax.random.PRNGKey(0)


class TestBooleanize:
    def test_threshold_paper_rule(self):
        img = jnp.array([[0, 75, 76, 255]], jnp.uint8)
        out = threshold_booleanize(img, 75)
        np.testing.assert_array_equal(np.asarray(out), [[0, 0, 1, 1]])

    def test_adaptive_gaussian_shapes_and_range(self):
        imgs = jax.random.randint(KEY, (3, 28, 28), 0, 256).astype(jnp.uint8)
        out = adaptive_gaussian_booleanize(imgs)
        assert out.shape == (3, 28, 28)
        assert set(np.unique(np.asarray(out))) <= {0, 1}

    def test_adaptive_flat_image_is_ones(self):
        # pixel > mean - c  with flat image -> all ones (c > 0).
        imgs = jnp.full((1, 16, 16), 100, jnp.uint8)
        out = adaptive_gaussian_booleanize(imgs, c=2.0)
        assert np.asarray(out).all()

    def test_thermometer_monotone(self):
        img = jnp.array([[0, 100, 200, 255]], jnp.uint8)
        out = np.asarray(thermometer_encode(img, 4))
        # thermometer code: once a bit is 0, all higher bits are 0.
        for row in out.reshape(-1, 4):
            assert all(row[i] >= row[i + 1] for i in range(3))


class TestPatches:
    def test_paper_geometry(self):
        spec = PatchSpec()
        assert spec.n_patches == 361          # 19 x 19 (Sec. IV-C)
        assert spec.n_features == 136         # Eq. (5)
        assert spec.n_literals == 272
        assert spec.n_words == 9

    def test_position_thermometer_table1(self):
        spec = PatchSpec()
        img = jnp.zeros((1, 28, 28), jnp.uint8)
        feats = np.asarray(extract_patch_features(img, spec))[0]
        pos_bits = feats[:, 100:]             # [361, 36] = y(18) + x(18)
        # patch 0 = (y=0, x=0): all-zero position code (Table I row 0).
        assert pos_bits[0].sum() == 0
        # patch 18 = (y=0, x=18): x code all ones, y code zero.
        assert pos_bits[18][:18].sum() == 0 and pos_bits[18][18:].sum() == 18
        # patch 19 = (y=1, x=0): y thermometer has exactly 1 bit.
        assert pos_bits[19][:18].sum() == 1 and pos_bits[19][18:].sum() == 0
        # last patch (18,18): everything set.
        assert pos_bits[360].sum() == 36

    def test_window_content_matches_slice(self):
        spec = PatchSpec()
        img = (jax.random.uniform(KEY, (1, 28, 28)) > 0.5).astype(jnp.uint8)
        feats = np.asarray(extract_patch_features(img, spec))[0]
        npimg = np.asarray(img)[0]
        for pid, (y, x) in [(0, (0, 0)), (18, (0, 18)), (19, (1, 0)), (200, (10, 10))]:
            want = npimg[y : y + 10, x : x + 10].reshape(-1)
            np.testing.assert_array_equal(feats[pid][:100], want)

    def test_literals_are_x_and_not_x(self):
        feats = (jax.random.uniform(KEY, (2, 5, 7)) > 0.5).astype(jnp.uint8)
        lits = np.asarray(make_literals(feats))
        np.testing.assert_array_equal(lits[..., :7], np.asarray(feats))
        np.testing.assert_array_equal(lits[..., 7:], 1 - np.asarray(feats))

    @pytest.mark.parametrize("n", [1, 31, 32, 33, 272, 500])
    def test_pack_unpack_roundtrip(self, n):
        bits = (jax.random.uniform(jax.random.PRNGKey(n), (3, n)) > 0.5).astype(
            jnp.uint8
        )
        packed = pack_bits(bits)
        assert packed.dtype == jnp.uint32
        out = unpack_bits(packed, n)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(bits))


class TestClauses:
    def _setup(self, b=3, p=17, c=40, o=60, density=0.95, seed=1):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        lits = (jax.random.uniform(k1, (b, p, 2 * o)) > 0.5).astype(jnp.uint8)
        inc = (jax.random.uniform(k2, (c, 2 * o)) > density).astype(jnp.uint8)
        inc = inc.at[0].set(0)  # empty clause
        return lits, inc

    def test_eval_paths_agree(self):
        lits, inc = self._setup()
        ne = cl.clause_nonempty(inc)
        dense = cl.eval_clauses_dense(lits, inc)
        bp = cl.eval_clauses_bitpacked(pack_bits(lits), pack_bits(inc), ne)
        mm = cl.eval_clauses_matmul(lits, inc, ne)
        np.testing.assert_array_equal(np.asarray(dense), np.asarray(bp))
        np.testing.assert_array_equal(np.asarray(dense), np.asarray(mm))

    def test_empty_clause_semantics(self):
        lits, inc = self._setup()
        infer_out = cl.patch_clause_outputs(lits, inc, training=False)
        train_out = cl.patch_clause_outputs(lits, inc, training=True)
        assert not np.asarray(infer_out)[:, :, 0].any()   # empty -> 0 inference
        assert np.asarray(train_out)[:, :, 0].all()       # empty -> 1 training

    def test_sequential_or_matches_any(self):
        lits, inc = self._setup()
        per_patch = cl.patch_clause_outputs(lits, inc)
        fired = cl.eval_clauses_dense(lits, inc)
        np.testing.assert_array_equal(
            np.asarray(fired), np.asarray(per_patch).any(axis=1).astype(np.uint8)
        )

    def test_class_sums_int8_weights(self):
        fired = jnp.array([[1, 0, 1]], jnp.uint8)
        w = jnp.array([[10, -5, 3], [-128 + 1, 127, 127]], jnp.int32)
        v = np.asarray(cl.class_sums(fired, w))
        np.testing.assert_array_equal(v, [[13, 0]])

    def test_argmax_tie_lowest_class(self):
        v = jnp.array([[5, 9, 9, 1]])
        assert int(cl.argmax_predict(v)[0]) == 1


class TestInit:
    def test_boundary_model_splits_key(self):
        """init_boundary_model must not reuse one key for both the weight
        signs and the TA randint (the streams were correlated); the TA
        states may no longer equal a raw-key randint draw."""
        from repro.core.cotm import TA_HALF, init_boundary_model

        cfg = CoTMConfig(n_clauses=32)
        key = jax.random.PRNGKey(9)
        model = init_boundary_model(key, cfg, spread=10)
        reused = np.asarray(
            jax.random.randint(
                key, model.ta_state.shape, TA_HALF - 10, TA_HALF + 10
            ).astype(jnp.uint8)
        )
        assert not np.array_equal(np.asarray(model.ta_state), reused)
        # invariants unchanged: states straddle the boundary, weights ±1
        ta = np.asarray(model.ta_state)
        assert ta.min() >= TA_HALF - 10 and ta.max() < TA_HALF + 10
        assert set(np.unique(np.asarray(model.weights))) == {-1, 1}


class TestModelIO:
    def test_register_image_size_matches_paper(self):
        cfg = CoTMConfig()
        assert cfg.model_bits == 45056                  # Sec. IV-B
        assert model_size_bytes(cfg) == 5632

    def test_roundtrip_preserves_inference(self):
        cfg = CoTMConfig(n_clauses=32, T=15, s=3.0)
        model = init_model(KEY, cfg)
        # random TA states around the boundary
        ta = jax.random.randint(KEY, model.ta_state.shape, 0, 256).astype(jnp.uint8)
        model.ta_state = ta
        blob = pack_model(model, cfg)
        model2 = unpack_model(blob, cfg)
        imgs = (jax.random.uniform(KEY, (8, 28, 28)) > 0.6).astype(jnp.uint8)
        p1, v1 = infer(model, imgs, cfg)
        p2, v2 = infer(model2, imgs, cfg)
        np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
        np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))

    def test_weight_range_enforced(self):
        cfg = CoTMConfig(n_clauses=8)
        model = init_model(KEY, cfg)
        model.weights = model.weights.at[0, 0].set(300)
        with pytest.raises(ValueError):
            pack_model(model, cfg)
