"""Distributed substrate: optimizer, compression, checkpoint, fault
tolerance, sharding rules."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.checkpoint.checkpointer import (
    Checkpointer,
    latest_step,
    restore_pytree,
    save_pytree,
)
from repro.configs.base import TrainConfig
from repro.distributed.collectives import (
    compressed_grad_sync,
    dequantize_int8,
    quantize_int8,
    tree_psum_batch,
)
from repro.distributed.fault_tolerance import (
    HeartbeatMonitor,
    StragglerPolicy,
    run_with_restarts,
)
from repro.train.optimizer import adamw_update, init_opt_state, lr_schedule


class TestOptimizer:
    def test_adamw_minimizes_quadratic(self):
        tcfg = TrainConfig(learning_rate=0.1, weight_decay=0.0, warmup_steps=1,
                           total_steps=200)
        params = {"w": jnp.array([5.0, -3.0])}
        opt = init_opt_state(params)

        def loss(p):
            return jnp.sum(p["w"] ** 2)

        for _ in range(150):
            g = jax.grad(loss)(params)
            params, opt, _ = adamw_update(params, g, opt, tcfg)
        assert float(loss(params)) < 1e-2

    def test_lr_schedule_shape(self):
        tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
        lrs = [float(lr_schedule(jnp.int32(s), tcfg)) for s in [0, 5, 10, 50, 100]]
        assert lrs[0] == 0.0
        assert lrs[1] == pytest.approx(5e-4, rel=1e-3)
        assert lrs[2] == pytest.approx(1e-3, rel=1e-2)
        assert lrs[3] < lrs[2]
        assert lrs[4] == pytest.approx(1e-4, rel=0.05)

    def test_grad_clip_caps_update(self):
        tcfg = TrainConfig(learning_rate=1.0, grad_clip=1.0, warmup_steps=0,
                           weight_decay=0.0, total_steps=10)
        params = {"w": jnp.zeros(4)}
        opt = init_opt_state(params)
        g = {"w": jnp.full(4, 1e6)}
        _, opt2, m = adamw_update(params, g, opt, tcfg)
        assert float(m["grad_norm"]) == pytest.approx(2e6, rel=1e-3)
        # post-clip first moment norm bounded by clip value
        assert float(jnp.linalg.norm(opt2.m["w"])) <= 1.0 * (1 - tcfg.beta1) * 1.01


class TestCompression:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10_000), scale=st.floats(1e-6, 1e3))
    def test_quantize_roundtrip_error_bound(self, seed, scale):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal(1000) * scale, jnp.float32)
        q, s = quantize_int8(x)
        y = dequantize_int8(q, s, x.shape, jnp.float32)
        # per-block error <= scale/2 = max|block|/254
        err = np.abs(np.asarray(x - y))
        bound = np.asarray(s).max() / 2 + 1e-9
        assert err.max() <= bound

    def test_error_feedback_accumulates(self):
        """EF compression is unbiased over steps: sum of dequantized grads
        + final residual == sum of true grads (telescoping)."""
        rng = np.random.default_rng(0)
        grads = [
            {"w": jnp.asarray(rng.standard_normal(256) * 1e-3, jnp.float32)}
            for _ in range(10)
        ]
        residual = {"w": jnp.zeros(256, jnp.float32)}
        total_sent = jnp.zeros(256, jnp.float32)
        for g in grads:
            sent, residual = compressed_grad_sync(g, residual)
            total_sent = total_sent + sent["w"]
        total_true = sum(np.asarray(g["w"]) for g in grads)
        np.testing.assert_allclose(
            np.asarray(total_sent + residual["w"]), total_true, rtol=1e-5, atol=1e-6
        )

    def test_int8_psum_multidevice_subprocess(self):
        """Run the explicit int8 all-reduce on an 8-virtual-device CPU mesh
        (subprocess: device count must be set before jax init)."""
        code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.distributed.collectives import int8_psum_shard_map
mesh = jax.make_mesh((2, 4), ("pod", "data"))
x = jnp.asarray(np.random.default_rng(0).standard_normal((64, 64)), jnp.float32)
out = int8_psum_shard_map(x, mesh, axis="pod")
want = 2.0 * x  # replicated input summed over 2 pods
err = float(jnp.max(jnp.abs(out - want)))
rel = err / float(jnp.max(jnp.abs(want)))
assert rel < 0.02, rel
print("OK", rel)
"""
        r = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            timeout=300,
        )
        assert r.returncode == 0, r.stderr[-2000:]
        assert "OK" in r.stdout


class TestDeltaPsum:
    def _deltas(self, b=16, seed=0):
        rng = np.random.default_rng(seed)
        return (
            jnp.asarray(rng.integers(-2, 3, (b, 12, 34)), jnp.int32),
            jnp.asarray(rng.integers(-1, 2, (b, 10, 12)), jnp.int32),
        )

    def test_plain_sum_without_mesh(self):
        ta, w = self._deltas()
        sa, sw = tree_psum_batch((ta, w))
        np.testing.assert_array_equal(np.asarray(sa), np.asarray(ta).sum(0))
        np.testing.assert_array_equal(np.asarray(sw), np.asarray(w).sum(0))

    def test_single_device_mesh_matches_plain_sum(self):
        from jax.sharding import Mesh

        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        ta, w = self._deltas(seed=1)
        sa, sw = tree_psum_batch((ta, w), mesh=mesh, axis="data")
        np.testing.assert_array_equal(np.asarray(sa), np.asarray(ta).sum(0))
        np.testing.assert_array_equal(np.asarray(sw), np.asarray(w).sum(0))

    def test_tm_delta_psum_multidevice_subprocess(self):
        """The exact integer delta reduction on an 8-virtual-device CPU
        mesh is bit-identical to the single-device sum — the TM
        data-parallel training contract."""
        code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.distributed.collectives import tree_psum_batch
mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
rng = np.random.default_rng(0)
ta = jnp.asarray(rng.integers(-2, 3, (64, 12, 34)), jnp.int32)
w = jnp.asarray(rng.integers(-1, 2, (64, 10, 12)), jnp.int32)
sa, sw = jax.jit(lambda t: tree_psum_batch(t, mesh=mesh, axis="data"))((ta, w))
np.testing.assert_array_equal(np.asarray(sa), np.asarray(ta).sum(0))
np.testing.assert_array_equal(np.asarray(sw), np.asarray(w).sum(0))
print("OK")
"""
        r = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            timeout=300,
        )
        assert r.returncode == 0, r.stderr[-2000:]
        assert "OK" in r.stdout


class TestCheckpoint:
    def _tree(self, seed=0):
        rng = np.random.default_rng(seed)
        return {
            "params": {"w": jnp.asarray(rng.standard_normal((8, 4)), jnp.bfloat16)},
            "opt": {"step": jnp.int32(7), "m": jnp.asarray(rng.standard_normal(3))},
        }

    def test_roundtrip(self, tmp_path):
        tree = self._tree()
        save_pytree(tree, str(tmp_path), 7, extra={"epoch": 2})
        out, step, extra = restore_pytree(tree, str(tmp_path))
        assert step == 7 and extra == {"epoch": 2}
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_only_committed_restored(self, tmp_path):
        tree = self._tree()
        save_pytree(tree, str(tmp_path), 5)
        # fake a torn write at step 9
        torn = tmp_path / "step_00000009"
        torn.mkdir()
        (torn / "manifest.json").write_text("{}")
        assert latest_step(str(tmp_path)) == 5

    def test_async_checkpointer_gc(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=2)
        tree = self._tree()
        for s in [1, 2, 3, 4]:
            ck.save(tree, s)
        ck.wait()
        steps = sorted(
            int(d[5:]) for d in os.listdir(tmp_path) if d.startswith("step_")
        )
        assert steps == [3, 4]

    def test_restore_rejects_shape_change(self, tmp_path):
        tree = self._tree()
        save_pytree(tree, str(tmp_path), 1)
        bad = {
            "params": {"w": jnp.zeros((9, 4), jnp.bfloat16)},
            "opt": tree["opt"],
        }
        with pytest.raises(ValueError):
            restore_pytree(bad, str(tmp_path))

    def test_async_save_failure_surfaces_on_wait(self, tmp_path, monkeypatch):
        """Regression: the save thread used to swallow exceptions — wait()
        reported success and a restart silently resumed from an older
        step.  The failure must re-raise on the next wait()."""
        import repro.checkpoint.checkpointer as ckpt_mod

        ck = Checkpointer(str(tmp_path))
        tree = self._tree()
        ck.save(tree, 1)
        ck.wait()                                 # healthy save is clean

        def boom(*a, **kw):
            raise OSError("disk full")

        monkeypatch.setattr(ckpt_mod, "save_pytree", boom)
        ck.save(tree, 2)
        with pytest.raises(OSError, match="disk full"):
            ck.wait()
        # the error is consumed exactly once; the checkpointer stays usable
        ck.wait()
        monkeypatch.undo()
        ck.save(tree, 3)
        ck.wait()
        assert latest_step(str(tmp_path)) == 3

    def test_async_save_failure_surfaces_on_next_save(self, tmp_path, monkeypatch):
        """save() joins the previous save first, so a failed save also
        surfaces there — before the next checkpoint is dispatched."""
        import repro.checkpoint.checkpointer as ckpt_mod

        ck = Checkpointer(str(tmp_path))
        tree = self._tree()
        monkeypatch.setattr(
            ckpt_mod, "save_pytree",
            lambda *a, **kw: (_ for _ in ()).throw(RuntimeError("torn write")),
        )
        ck.save(tree, 1)
        with pytest.raises(RuntimeError, match="torn write"):
            ck.save(tree, 2)

    def test_malformed_step_dirs_skipped(self, tmp_path):
        """Regression: a stray non-numeric step_* directory crashed
        latest_step and Checkpointer._gc on int()."""
        tree = self._tree()
        save_pytree(tree, str(tmp_path), 3)
        for junk in ["step_backup", "step_1a2b", "step_"]:
            d = tmp_path / junk
            d.mkdir()
            (d / "COMMITTED").write_text("")      # committed but malformed
        assert latest_step(str(tmp_path)) == 3

        ck = Checkpointer(str(tmp_path), keep=2)
        for s in [4, 5, 6]:
            ck.save(tree, s)
        ck.wait()                                 # _gc must not crash
        steps = sorted(
            d for d in os.listdir(tmp_path) if d.startswith("step_")
        )
        assert steps == [
            "step_", "step_00000005", "step_00000006", "step_1a2b",
            "step_backup",
        ]


class TestFaultTolerance:
    def test_straggler_policy_escalates(self):
        p = StragglerPolicy(factor=3.0, window=16, tolerance=3)
        for _ in range(16):
            assert p.observe(1.0) == "ok"
        assert p.observe(10.0) == "straggler"
        assert p.observe(10.0) == "straggler"
        assert p.observe(10.0) == "reshard"
        # in-window recovery (before reshard) still resets strikes
        p2 = StragglerPolicy(factor=3.0, window=16, tolerance=3)
        for _ in range(16):
            p2.observe(1.0)
        assert p2.observe(10.0) == "straggler"
        assert p2.observe(1.0) == "ok"
        assert p2.observe(10.0) == "straggler"

    def test_straggler_policy_resets_after_reshard(self):
        """Regression: 'reshard' used to latch — every later straggler
        event escalated straight back to 'reshard' and pre-reshard
        (straggler-inflated) durations kept polluting the median.  The
        intervention now clears strikes AND history."""
        p = StragglerPolicy(factor=3.0, window=16, tolerance=3)
        for _ in range(16):
            p.observe(1.0)
        assert [p.observe(10.0) for _ in range(3)] == [
            "straggler", "straggler", "reshard"
        ]
        # History cleared: the policy re-warms on post-reshard step times
        # (2.0 s/step on the rebuilt, smaller mesh) instead of judging
        # them against the old 1.0 s median.
        assert p.median is None
        for _ in range(16):
            assert p.observe(2.0) == "ok"
        assert p.median == pytest.approx(2.0)
        # A second full escalate->reshard cycle behaves like the first:
        # one event is 'straggler', not an instant 'reshard'.
        assert p.observe(20.0) == "straggler"
        assert p.observe(20.0) == "straggler"
        assert p.observe(20.0) == "reshard"
        assert p.median is None

    def test_heartbeat_dead_hosts(self):
        hb = HeartbeatMonitor(timeout=10.0)
        hb.beat("h0", now=0.0)
        hb.beat("h1", now=0.0)
        hb.beat("h0", now=8.0)
        assert hb.dead_hosts(now=12.0) == ["h1"]
        assert not hb.healthy(now=12.0)

    def test_run_with_restarts_recovers(self):
        log = {"saved": [], "failed_at": []}
        state = {"ckpt": 0}

        def step_fn(step):
            if step == 5 and not log["failed_at"]:
                log["failed_at"].append(step)
                raise RuntimeError("node lost")

        def save_fn(step):
            state["ckpt"] = step
            log["saved"].append(step)

        def restore_fn():
            return state["ckpt"]

        stats = run_with_restarts(
            step_fn, start_step=0, total_steps=10, save_fn=save_fn,
            restore_fn=restore_fn, checkpoint_every=2, max_restarts=2,
        )
        assert stats.restarts == 1
        assert stats.resumed_from == [4]
        assert state["ckpt"] == 10

    def test_run_with_restarts_gives_up(self):
        def step_fn(step):
            raise RuntimeError("always broken")

        with pytest.raises(RuntimeError):
            run_with_restarts(
                step_fn, start_step=0, total_steps=3,
                save_fn=lambda s: None, restore_fn=lambda: 0,
                checkpoint_every=10, max_restarts=2,
            )

    def test_restart_budget_resets_after_checkpointed_progress(self):
        """Regression: the restart budget counted failures over the whole
        job lifetime, so a long-lived run died on its (max_restarts+1)-th
        transient failure even with checkpointed progress in between.
        The budget now bounds *consecutive* failures: 4 transient
        failures spread across a 40-step run survive max_restarts=1."""
        state = {"ckpt": 0}
        failed_at = set()

        def step_fn(step):
            if step in (5, 15, 25, 35) and step not in failed_at:
                failed_at.add(step)
                raise RuntimeError(f"transient failure at {step}")

        def save_fn(step):
            state["ckpt"] = step

        stats = run_with_restarts(
            step_fn, start_step=0, total_steps=40, save_fn=save_fn,
            restore_fn=lambda: state["ckpt"], checkpoint_every=2,
            max_restarts=1,
        )
        assert stats.restarts == 4           # lifetime total still reported
        assert stats.resumed_from == [4, 14, 24, 34]
        assert state["ckpt"] == 40

    def test_restart_budget_still_bounds_crash_loops(self):
        """A failure loop with NO checkpointed progress between failures
        must still give up after max_restarts, even when an earlier save
        reset the budget."""
        state = {"ckpt": 0}
        calls = {"n": 0}

        def step_fn(step):
            if step >= 6:                    # permanent breakage at step 6
                calls["n"] += 1
                raise RuntimeError("stuck")

        def save_fn(step):
            state["ckpt"] = step

        with pytest.raises(RuntimeError, match="stuck"):
            run_with_restarts(
                step_fn, start_step=0, total_steps=10, save_fn=save_fn,
                restore_fn=lambda: state["ckpt"], checkpoint_every=2,
                max_restarts=3,
            )
        assert calls["n"] == 4               # 3 retries + the final raise
        assert state["ckpt"] == 6            # progress up to the breakage

    def test_heartbeat_expect_declares_silent_from_birth_hosts_dead(self):
        """Regression: only hosts that beat at least once were tracked,
        so a host that died during bring-up (never beat) was reported
        healthy forever.  expect() starts every roster host's silence
        clock, so silent-from-birth hosts age into dead_hosts."""
        hb = HeartbeatMonitor(timeout=10.0)
        hb.expect(["h0", "h1", "h2"], now=0.0)
        hb.beat("h0", now=8.0)
        hb.beat("h1", now=8.0)
        # h2 never beat: dead once the timeout elapses from expect().
        assert hb.dead_hosts(now=12.0) == ["h2"]
        assert not hb.healthy(now=12.0)
        # expect() never regresses a clock: re-expecting the roster keeps
        # h0/h1's latest beats (silence 9 s at t=17, still alive) AND
        # keeps h2 dead (its clock stays at the original expect, not the
        # re-expect).
        hb.expect(["h0", "h1", "h2"], now=12.0)
        assert hb.dead_hosts(now=17.0) == ["h2"]

    def test_run_with_restarts_restore_failure_consumes_budget(self):
        """Regression: restore_fn raising escaped the restart loop
        without consuming budget — a corrupt checkpoint turned one step
        failure into an instant job abort regardless of max_restarts.
        Recovery failures now retry under the same budget."""
        state = {"ckpt": 0, "restores": 0}
        failed = set()

        def step_fn(step):
            if step == 3 and step not in failed:
                failed.add(step)
                raise RuntimeError("node lost")

        def restore_fn():
            state["restores"] += 1
            if state["restores"] == 1:       # first restore hits a bad ckpt
                raise IOError("checkpoint unreachable")
            return state["ckpt"]

        stats = run_with_restarts(
            step_fn, start_step=0, total_steps=6,
            save_fn=lambda s: state.__setitem__("ckpt", s),
            restore_fn=restore_fn, checkpoint_every=2, max_restarts=3,
        )
        # step failure + failed restore both consumed budget; the retry
        # restored and the run completed.
        assert stats.restarts == 2
        assert stats.resumed_from == [2]
        assert state["ckpt"] == 6

    def test_run_with_restarts_persistent_restore_failure_exhausts_budget(self):
        """A restore that NEVER succeeds must exhaust max_restarts and
        surface the recovery error, not loop forever."""
        calls = {"restores": 0}

        def step_fn(step):
            raise RuntimeError("node lost")

        def restore_fn():
            calls["restores"] += 1
            raise IOError("checkpoint gone")

        with pytest.raises(IOError, match="checkpoint gone"):
            run_with_restarts(
                step_fn, start_step=0, total_steps=5,
                save_fn=lambda s: None, restore_fn=restore_fn,
                checkpoint_every=10, max_restarts=3,
            )
        # budget: 1 step failure + up to max_restarts recovery attempts
        assert calls["restores"] == 3

    def test_run_with_restarts_on_restart_failure_consumes_budget(self):
        """on_restart (mesh teardown) raising is a recovery failure too:
        budgeted and retried, not an escape hatch."""
        state = {"ckpt": 0}
        hooks = {"calls": 0}
        failed = set()

        def step_fn(step):
            if step == 2 and step not in failed:
                failed.add(step)
                raise RuntimeError("node lost")

        def on_restart(e):
            hooks["calls"] += 1
            if hooks["calls"] == 1:
                raise RuntimeError("mesh teardown failed")

        stats = run_with_restarts(
            step_fn, start_step=0, total_steps=4,
            save_fn=lambda s: state.__setitem__("ckpt", s),
            restore_fn=lambda: state["ckpt"], checkpoint_every=2,
            max_restarts=3, on_restart=on_restart,
        )
        assert stats.restarts == 2
        assert hooks["calls"] == 2
        assert state["ckpt"] == 4


class TestShardingRules:
    def test_logical_rules_resolve_per_mesh(self):
        from jax.sharding import PartitionSpec as P

        from repro.sharding.partition import sharding_for, single_device_mesh, spec

        mesh = single_device_mesh()  # only a "data" axis
        s = spec(("batch", None, "tensor"), mesh)
        assert s == P("data", None, None)   # tensor axis absent -> dropped

    def test_sharding_for_divisibility(self):
        from jax.sharding import PartitionSpec as P

        from repro.sharding.partition import sharding_for, single_device_mesh

        mesh = single_device_mesh()
        sh = sharding_for((3, 5), ("batch", None), mesh)  # 3 % 1 == 0 ok
        assert sh.spec == P("data", None)

    def test_pspec_tree_drops_nondivisible(self):
        from jax.sharding import PartitionSpec as P

        from repro.models.base import ParamDecl, pspec_tree
        from repro.sharding.partition import single_device_mesh

        mesh = single_device_mesh()
        decls = {"w": ParamDecl((7, 8), ("fsdp", "tensor"))}
        # data axis size 1 divides everything; spec keeps fsdp -> 'data'
        tree = pspec_tree(decls, mesh)
        assert tree["w"] == P("data", None)
