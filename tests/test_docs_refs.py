"""The docs cross-reference gate, run as a tier-1 test so dangling
markdown/anchor citations fail locally, not just in CI."""

import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CHECKER = os.path.join(REPO, "tools", "check_docs_refs.py")


def test_no_dangling_docs_references():
    r = subprocess.run(
        [sys.executable, CHECKER], capture_output=True, text=True, cwd=REPO
    )
    assert r.returncode == 0, f"\n{r.stdout}{r.stderr}"


def test_required_experiment_anchors_exist():
    """The anchors the codebase cites must stay present (§Perf,
    §Perf/kernel, §Serve, §Roofline in EXPERIMENTS.md; §Substrate in
    ARCHITECTURE.md) — belt and braces on top of the generic scan."""
    with open(os.path.join(REPO, "EXPERIMENTS.md"), encoding="utf-8") as f:
        experiments = f.read()
    for anchor in ("§Perf", "§Perf/kernel", "§Serve", "§Roofline"):
        assert any(
            ln.startswith("#") and anchor in ln
            for ln in experiments.splitlines()
        ), f"EXPERIMENTS.md lost its {anchor} heading"
    with open(os.path.join(REPO, "ARCHITECTURE.md"), encoding="utf-8") as f:
        assert any(
            ln.startswith("#") and "§Substrate" in ln
            for ln in f.read().splitlines()
        ), "ARCHITECTURE.md lost its §Substrate heading"
