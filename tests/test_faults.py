"""Serving-robustness chaos suite (ARCHITECTURE.md §Faults).

The request-lifetime guarantee under test: every admitted future
RESOLVES — with a result or a structured error, never a hang — under
every fault ``serve/faults.py`` can inject.  Alongside it, the
per-guarantee invariants: expired requests are never dispatched,
non-poisoned batchmates of a quarantined request stay bit-identical,
degraded-path results stay bit-identical to the ``kernels/ref.py``
oracle, and a crashed worker is restarted under bounded backoff.
"""

import asyncio

import jax
import numpy as np
import pytest

from repro.core import clauses as cl
from repro.core.cotm import CoTMConfig, init_boundary_model
from repro.core.patches import PatchSpec
from repro.data.pipeline import preprocess_for_serving
from repro.kernels.ref import fused_infer_ref
from repro.serve import (
    DegradationPolicy,
    DeviceLost,
    FaultPlan,
    InjectedEngineError,
    PoisonedPayload,
    ServiceConfig,
    ServiceExpired,
    ServiceHealth,
    ServiceStopped,
    ServingEngine,
    ServingService,
    WorkerCrashed,
    chaos_soak,
    degraded_fallback,
    make_serve_mesh,
    poisson_open_loop,
)

EDGE_SPEC = PatchSpec(image_x=11, image_y=11, window_x=5, window_y=5)
EDGE_CFG = CoTMConfig(n_clauses=37, n_classes=10, patch=EDGE_SPEC)


def _model(seed=0):
    return init_boundary_model(jax.random.PRNGKey(seed), EDGE_CFG)


def _images(n, seed=0):
    key = jax.random.PRNGKey(seed + 100)
    side = EDGE_CFG.patch.image_y
    return np.asarray(
        (jax.random.uniform(key, (n, side, side)) > 0.6)
    ).astype(np.uint8)


def _pair(
    *, faults=None, policy=None, max_batch=16, path=None, mesh=None, seed=0
):
    """A fault-injected service engine and an untouched reference engine
    over the same model — reference results never see the FaultPlan."""
    model = _model(seed=seed)
    engine = ServingEngine(max_batch=max_batch, mesh=mesh, faults=faults)
    engine.register("glyphs", model, EDGE_CFG, booleanize_method="none", path=path)
    ref = ServingEngine(max_batch=max_batch)
    ref.register("glyphs", model, EDGE_CFG, booleanize_method="none", path=path)
    return engine, ref


def _oracle_classify(ref_engine, imgs):
    """Classify through the kernels/ref.py oracle composition directly:
    host ingress -> fused_infer_ref on the frozen register image.  The
    independent ground truth degraded paths are asserted against."""
    servable = ref_engine.servable("glyphs")
    lits = preprocess_for_serving(
        imgs, EDGE_CFG.patch, method="none", packed=True
    )
    sums = np.asarray(
        fused_infer_ref(
            jax.numpy.asarray(lits),
            servable.include_packed,
            servable.nonempty,
            servable.weights,
        )
    )
    return np.asarray(cl.argmax_predict(sums)), sums


# --------------------------------------------------------------------------
# FaultPlan / DegradationPolicy / ServiceHealth units (no event loop)
# --------------------------------------------------------------------------


class TestFaultPrimitives:
    def test_fault_plan_counters_and_injection_order(self):
        p = FaultPlan(crash_at=(2,), device_loss_at=(3,), engine_error_at=(1,))
        p.on_service_dispatch("m")                      # seq 1: clean
        with pytest.raises(WorkerCrashed) as e:
            p.on_service_dispatch("m")                  # seq 2: crash
        assert e.value.kind == "worker_crash" and e.value.model == "m"
        with pytest.raises(DeviceLost):
            p.on_service_dispatch("m")                  # seq 3: device loss
        assert p.service_dispatches == 3
        with pytest.raises(InjectedEngineError):
            p.on_engine_dispatch("m")                   # engine seq 1
        p.on_engine_dispatch("m")                       # engine seq 2: clean
        assert p.engine_dispatches == 2

    def test_poison_is_payload_identity(self):
        p = FaultPlan()
        a, b = _images(1), _images(1)
        p.poison(a)
        assert p.is_poisoned(a) and not p.is_poisoned(b)
        # np.asarray of an existing ndarray is the same object, so poison
        # survives the service's validation path.
        assert p.is_poisoned(np.asarray(a))
        with pytest.raises(PoisonedPayload):
            p.check_payload(a, "m")
        p.check_payload(b, "m")

    def test_degradation_policy_backoff_doubles_and_caps(self):
        pol = DegradationPolicy(restart_backoff_s=0.1, restart_backoff_max_s=0.5)
        assert [pol.backoff_s(i) for i in (1, 2, 3, 4)] == [
            0.1, 0.2, 0.4, 0.5
        ]
        with pytest.raises(ValueError):
            DegradationPolicy(failure_threshold=0)
        with pytest.raises(ValueError):
            DegradationPolicy(max_worker_restarts=-1)

    def test_service_health_degrade_is_sticky(self):
        h = ServiceHealth()
        assert h.state == "healthy"
        h.degrade(RuntimeError("boom"))
        assert h.state == "degraded" and "boom" in h.last_fault
        h.state = "draining"
        h.degrade(RuntimeError("later"))     # degrade never un-drains
        assert h.state == "draining"

    def test_degraded_fallback_chain_ends_dense(self):
        for start in ("fused_sparse", "sparse", "matmul_sparse",
                      "fused", "kernel", "bitpacked", "matmul"):
            name, hops = start, 0
            while name is not None:
                name = degraded_fallback(name)
                hops += 1
                assert hops < 10
        assert degraded_fallback("dense") is None


# --------------------------------------------------------------------------
# Deadlines: expired requests are shed before dispatch
# --------------------------------------------------------------------------


class TestDeadlines:
    def test_expired_request_sheds_without_dispatch(self):
        faults = FaultPlan()
        engine, _ = _pair(faults=faults)
        # Coalescing window far beyond the deadline: the request would sit
        # queued for 1 s, so the 5 ms deadline must win.
        service = ServingService(engine, ServiceConfig(max_delay_us=1e6))

        async def run():
            await service.start()
            fut = service.submit_nowait("glyphs", _images(2), deadline_s=0.005)
            with pytest.raises(ServiceExpired) as e:
                await fut
            await service.stop(drain=True)
            return e.value

        err = asyncio.run(run())
        assert err.model == "glyphs"
        assert err.waited_s >= err.deadline_s == pytest.approx(0.005)
        # The acceptance invariant: it never reached a dispatch seam.
        assert faults.service_dispatches == 0
        st = service.stats("glyphs")
        assert st.expired == 1 and st.completed == 0
        assert st.health["expired"] == 1

    def test_unexpired_batchmate_completes_bit_identical(self):
        engine, ref = _pair()
        service = ServingService(engine, ServiceConfig(max_delay_us=40_000.0))
        imgs = _images(3, seed=7)

        async def run():
            await service.start()
            doomed = service.submit_nowait(
                "glyphs", _images(2, seed=1), deadline_s=0.004
            )
            ok = service.submit_nowait("glyphs", imgs, deadline_s=5.0)
            with pytest.raises(ServiceExpired):
                await doomed
            res = await ok
            await service.stop(drain=True)
            return res

        res = asyncio.run(run())
        want = ref.classify("glyphs", imgs)
        np.testing.assert_array_equal(res.predictions, want.predictions)
        np.testing.assert_array_equal(res.class_sums, want.class_sums)
        st = service.stats("glyphs")
        assert st.expired == 1 and st.completed == 1

    def test_deadline_validation(self):
        engine, _ = _pair()
        service = ServingService(engine)

        async def run():
            await service.start()
            with pytest.raises(ValueError, match="deadline_s"):
                service.submit_nowait("glyphs", _images(1), deadline_s=0.0)
            await service.stop(drain=False)

        asyncio.run(run())


# --------------------------------------------------------------------------
# Worker supervision: crash -> structured failure -> bounded restart
# --------------------------------------------------------------------------


class TestWorkerCrash:
    def test_crash_fails_inflight_and_restarts(self):
        faults = FaultPlan(crash_at=(1,))
        engine, ref = _pair(faults=faults)
        service = ServingService(
            engine,
            ServiceConfig(max_delay_us=100.0),
            faults=faults,
            policy=DegradationPolicy(restart_backoff_s=0.001),
        )
        imgs = _images(4, seed=3)

        async def run():
            await service.start()
            with pytest.raises(WorkerCrashed) as e:
                await service.submit("glyphs", _images(2))
            # The replaced worker serves the next request normally.
            res = await service.submit("glyphs", imgs)
            await service.stop(drain=True)
            return e.value, res

        err, res = asyncio.run(run())
        assert err.kind == "worker_crash"
        want = ref.classify("glyphs", imgs)
        np.testing.assert_array_equal(res.predictions, want.predictions)
        np.testing.assert_array_equal(res.class_sums, want.class_sums)
        h = service.health()
        assert h.worker_restarts == 1
        assert h.state == "draining"        # stop() was called at the end
        assert "WorkerCrashed" in h.last_fault

    def test_restart_budget_exhaustion_drains(self):
        faults = FaultPlan(crash_at=(1,))
        engine, _ = _pair(faults=faults)
        service = ServingService(
            engine,
            ServiceConfig(max_delay_us=100.0),
            faults=faults,
            policy=DegradationPolicy(max_worker_restarts=0),
        )

        async def run():
            await service.start()
            with pytest.raises(WorkerCrashed):
                await service.submit("glyphs", _images(1))
            # Budget (0) exhausted: the service stopped accepting.
            with pytest.raises(ServiceStopped):
                service.submit_nowait("glyphs", _images(1))
            await service.stop(drain=False)

        asyncio.run(run())
        assert service.health().state == "draining"


# --------------------------------------------------------------------------
# Quarantine: a poisoned member fails alone, batchmates bit-identical
# --------------------------------------------------------------------------


class TestQuarantine:
    def test_poisoned_member_isolated_from_coalesced_batch(self):
        faults = FaultPlan()
        engine, ref = _pair(faults=faults)
        # Wide-open window so all three submissions coalesce into one
        # microbatch before the first dispatch.
        service = ServingService(
            engine, ServiceConfig(max_delay_us=30_000.0), faults=faults
        )
        batches = [_images(2, seed=i) for i in range(3)]
        faults.poison(batches[1])

        async def run():
            await service.start()
            futs = [service.submit_nowait("glyphs", b) for b in batches]
            out = await asyncio.gather(*futs, return_exceptions=True)
            await service.stop(drain=True)
            return out

        out = asyncio.run(run())
        assert isinstance(out[1], PoisonedPayload)
        for i in (0, 2):
            want = ref.classify("glyphs", batches[i])
            np.testing.assert_array_equal(out[i].predictions, want.predictions)
            np.testing.assert_array_equal(out[i].class_sums, want.class_sums)
        st = service.stats("glyphs")
        assert st.quarantined >= 1
        assert st.completed == 2
        assert service.health().quarantined >= 1

    def test_single_poisoned_request_fails_structured(self):
        faults = FaultPlan()
        engine, _ = _pair(faults=faults)
        service = ServingService(
            engine, ServiceConfig(max_delay_us=100.0), faults=faults
        )
        bad = _images(1)
        faults.poison(bad)

        async def run():
            await service.start()
            with pytest.raises(PoisonedPayload) as e:
                await service.submit("glyphs", bad)
            await service.stop(drain=True)
            return e.value

        err = asyncio.run(run())
        assert err.kind == "poisoned_payload" and err.model == "glyphs"


# --------------------------------------------------------------------------
# Engine exceptions mid-microbatch: members all resolve
# --------------------------------------------------------------------------


class TestEngineException:
    def test_injected_engine_error_resolves_every_member(self):
        faults = FaultPlan(engine_error_at=(1,))
        engine, ref = _pair(faults=faults)
        service = ServingService(
            engine, ServiceConfig(max_delay_us=30_000.0), faults=faults
        )
        batches = [_images(2, seed=i) for i in range(2)]

        async def run():
            await service.start()
            futs = [service.submit_nowait("glyphs", b) for b in batches]
            out = await asyncio.gather(*futs, return_exceptions=True)
            await service.stop(drain=True)
            return out

        out = asyncio.run(run())
        # The first engine dispatch (the coalesced batch) raised; the
        # quarantine retried each member alone (fresh engine sequence
        # numbers — a plan is a script, not a feedback loop) and both
        # completed bit-identically.
        for b, res in zip(batches, out):
            assert not isinstance(res, Exception), res
            want = ref.classify("glyphs", b)
            np.testing.assert_array_equal(res.predictions, want.predictions)
            np.testing.assert_array_equal(res.class_sums, want.class_sums)
        assert service.health().dispatch_failures >= 1


# --------------------------------------------------------------------------
# Degraded modes: circuit breaker -> fallback path, bit-identical
# --------------------------------------------------------------------------


class TestDegradation:
    def test_engine_degrade_path_walks_chain_bit_identical(self):
        engine, ref = _pair(path="fused")
        imgs = _images(5, seed=11)
        want_preds, want_sums = _oracle_classify(ref, imgs)
        seen = ["fused"]
        while True:
            res = engine.classify("glyphs", imgs)
            np.testing.assert_array_equal(res.predictions, want_preds)
            np.testing.assert_array_equal(res.class_sums, want_sums)
            nxt = engine.degrade_path("glyphs")
            if nxt is None:
                break
            seen.append(nxt)
        assert seen[-1] == "dense"               # chain bottoms out dense
        assert seen == ["fused"] + [
            s for s in ["matmul", "dense"]
        ]
        st = engine.stats("glyphs")
        assert st.fallback_path == "dense"
        assert st.degrade_steps == len(seen) - 1

    def test_breaker_trips_to_fallback_and_serves_bit_identical(self):
        # Two consecutive engine errors (threshold=2) on single-request
        # microbatches trip the breaker; the fallback path then serves.
        faults = FaultPlan(engine_error_at=(1, 2))
        engine, ref = _pair(faults=faults, path="fused")
        service = ServingService(
            engine,
            ServiceConfig(max_delay_us=100.0),
            faults=faults,
            policy=DegradationPolicy(failure_threshold=2),
        )
        imgs = _images(3, seed=5)

        async def run():
            await service.start()
            errs = []
            for _ in range(2):
                try:
                    await service.submit("glyphs", _images(1))
                except InjectedEngineError as e:
                    errs.append(e)
            res = await service.submit("glyphs", imgs)
            state = service.health().state   # before stop() marks draining
            await service.stop(drain=True)
            return errs, res, state

        errs, res, state = asyncio.run(run())
        assert len(errs) == 2
        h = service.health()
        assert state == "degraded"
        assert h.fallback_path == degraded_fallback("fused") == "matmul"
        assert engine.stats("glyphs").fallback_path == "matmul"
        # Degraded results match the kernels/ref.py oracle bit for bit.
        want_preds, want_sums = _oracle_classify(ref, imgs)
        np.testing.assert_array_equal(res.predictions, want_preds)
        np.testing.assert_array_equal(res.class_sums, want_sums)


# --------------------------------------------------------------------------
# Device loss: shrink the mesh, retry, keep serving
# --------------------------------------------------------------------------


class TestDeviceLoss:
    def test_unmeshed_device_loss_retries_and_resolves(self):
        faults = FaultPlan(device_loss_at=(1,))
        engine, ref = _pair(faults=faults)
        service = ServingService(
            engine, ServiceConfig(max_delay_us=100.0), faults=faults
        )
        imgs = _images(2, seed=9)

        async def run():
            await service.start()
            res = await service.submit("glyphs", imgs)
            await service.stop(drain=True)
            return res

        res = asyncio.run(run())
        want = ref.classify("glyphs", imgs)
        np.testing.assert_array_equal(res.predictions, want.predictions)
        assert service.health().device_losses == 1

    @pytest.mark.skipif(
        jax.device_count() < 2, reason="needs >= 2 devices for a data mesh"
    )
    def test_meshed_device_loss_shrinks_and_stays_bit_identical(self):
        faults = FaultPlan(device_loss_at=(1,))
        engine, ref = _pair(faults=faults, mesh=make_serve_mesh(2))
        service = ServingService(
            engine, ServiceConfig(max_delay_us=100.0), faults=faults
        )
        imgs = _images(4, seed=13)

        async def run():
            await service.start()
            res = await service.submit("glyphs", imgs)
            await service.stop(drain=True)
            return res

        assert engine.stats("glyphs").data_shards == 2
        res = asyncio.run(run())
        # The loss shrank the data axis 2 -> 1 and the retry served on
        # the shrunk mesh, bit-identically.
        assert engine.stats("glyphs").data_shards == 1
        want = ref.classify("glyphs", imgs)
        np.testing.assert_array_equal(res.predictions, want.predictions)
        np.testing.assert_array_equal(res.class_sums, want.class_sums)
        assert service.health().device_losses == 1


# --------------------------------------------------------------------------
# Loadgen adversarial knobs
# --------------------------------------------------------------------------


class TestLoadgenKnobs:
    def test_malformed_requests_rejected_at_validation(self):
        engine, _ = _pair()
        service = ServingService(engine, ServiceConfig(max_delay_us=100.0))

        async def run():
            await service.start()
            report = await poisson_open_loop(
                service, "glyphs", [_images(1) for _ in range(8)],
                rate=2000.0, malformed_frac=1.0,
            )
            await service.stop(drain=True)
            return report

        report = asyncio.run(run())
        assert report.malformed == 8
        assert report.admitted == [] and report.abandoned == []
        # Nothing poisoned the service: it served zero requests cleanly.
        assert service.stats("glyphs").completed == 0

    def test_abandoned_futures_still_resolve(self):
        engine, _ = _pair()
        service = ServingService(engine, ServiceConfig(max_delay_us=100.0))

        async def run():
            await service.start()
            report = await poisson_open_loop(
                service, "glyphs", [_images(1) for _ in range(6)],
                rate=2000.0, abandon_frac=1.0, deadline_s=5.0,
            )
            # The clients walked away; the service must still resolve
            # every abandoned future.
            out = await asyncio.gather(
                *(f for _, f in report.abandoned), return_exceptions=True
            )
            await service.stop(drain=True)
            return report, out

        report, out = asyncio.run(run())
        assert len(report.abandoned) == 6 and report.admitted == []
        assert all(not isinstance(o, Exception) for o in out)

    def test_report_unpacks_as_legacy_pair(self):
        engine, _ = _pair()
        service = ServingService(engine, ServiceConfig(max_delay_us=100.0))

        async def run():
            await service.start()
            admitted, rejected = await poisson_open_loop(
                service, "glyphs", [_images(1) for _ in range(3)], rate=2000.0
            )
            await asyncio.gather(*(f for _, f in admitted))
            await service.stop(drain=True)
            return admitted, rejected

        admitted, rejected = asyncio.run(run())
        assert len(admitted) == 3 and rejected == 0


# --------------------------------------------------------------------------
# Chaos soak: every future resolves under combined faults
# --------------------------------------------------------------------------


def _soak(requests, *, faults, policy=None, **knobs):
    engine, _ = _pair(faults=faults)
    service = ServingService(
        engine,
        ServiceConfig(max_delay_us=500.0),
        faults=faults,
        policy=policy or DegradationPolicy(restart_backoff_s=0.001),
    )

    async def run():
        await service.start()
        tally = await chaos_soak(
            service, "glyphs", requests, rate=800.0, **knobs
        )
        await service.stop(drain=True)
        return tally

    return asyncio.run(run()), service


class TestChaosSoak:
    def test_fast_soak_no_future_hangs(self):
        faults = FaultPlan(
            crash_at=(2,), engine_error_at=(3,), slow_dispatch_s=0.0005
        )
        requests = [_images(2, seed=i) for i in range(24)]
        tally, service = _soak(
            requests, faults=faults,
            deadline_s=2.0, malformed_frac=0.15, abandon_frac=0.15,
        )
        # THE invariant: zero hung futures, and every submission is
        # accounted for in exactly one bucket.
        assert tally["hung"] == 0
        resolved = (
            tally["ok"] + tally["expired"] + tally["faulted"] + tally["stopped"]
        )
        assert resolved == tally["admitted"] + tally["abandoned"]
        assert (
            tally["admitted"] + tally["abandoned"]
            + tally["rejected"] + tally["malformed"]
        ) == len(requests)
        assert tally["malformed"] > 0          # knob actually engaged
        assert tally["health"]["worker_restarts"] >= 1

    @pytest.mark.slow
    def test_long_soak_under_combined_faults(self):
        faults = FaultPlan(
            crash_at=(3, 17), device_loss_at=(9,), engine_error_at=(5, 6, 30),
            slow_dispatch_s=0.0005,
        )
        requests = [_images(1 + i % 4, seed=i) for i in range(200)]
        tally, service = _soak(
            requests, faults=faults,
            deadline_s=5.0, malformed_frac=0.1, abandon_frac=0.2,
            gather_timeout_s=60.0,
        )
        assert tally["hung"] == 0
        resolved = (
            tally["ok"] + tally["expired"] + tally["faulted"] + tally["stopped"]
        )
        assert resolved == tally["admitted"] + tally["abandoned"]
        assert tally["ok"] > 0
        assert tally["health"]["worker_restarts"] >= 2
        assert tally["health"]["device_losses"] >= 1
