"""Device-resident ingress tests.

The single-graph raw->predictions contract: the fused device ingress
(``core.ingress``) must be bit-identical to the host pipeline
(``data.pipeline.preprocess_for_serving``) across every booleanize
method and both literal forms; the Pallas ingress-pack kernel must match
the jnp oracle; the engine's raw / host-ingress / preprocessed request
forms and the service's raw submissions must all agree bit for bit.
"""

import asyncio
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cotm import CoTMConfig, infer, init_boundary_model
from repro.core.ingress import IngressSpec, apply_booleanize, device_ingress
from repro.core.patches import PatchSpec
from repro.data.pipeline import preprocess_for_serving
from repro.kernels import ops, ref
from repro.serve import ServiceConfig, ServingEngine, ServingService

EDGE_SPEC = PatchSpec(image_x=11, image_y=11, window_x=5, window_y=5)
EDGE_CFG = CoTMConfig(n_clauses=37, n_classes=10, patch=EDGE_SPEC)
THERM_SPEC = PatchSpec(image_x=8, image_y=8, window_x=4, window_y=4, therm_bits=3)
STRIDE_SPEC = PatchSpec(
    image_x=12, image_y=12, window_x=4, window_y=4, stride_x=2, stride_y=2
)


def _raw(n, side=11, seed=0, binary=False):
    rng = np.random.default_rng(seed)
    if binary:
        return (rng.random((n, side, side)) > 0.6).astype(np.uint8)
    return rng.integers(0, 256, (n, side, side)).astype(np.uint8)


class TestDeviceIngressEquivalence:
    """apply_ingress == preprocess_for_serving, bit for bit."""

    CASES = [
        ("threshold", EDGE_SPEC, {}),
        ("adaptive", EDGE_SPEC, {"block_size": 5, "c": 2.0}),
        ("adaptive_gaussian", EDGE_SPEC, {"block_size": 5, "c": 2.0}),
        ("thermometer", THERM_SPEC, {"levels": 3}),
        ("none", EDGE_SPEC, {}),
    ]

    @pytest.mark.parametrize("packed", [False, True], ids=["dense", "packed"])
    @pytest.mark.parametrize(
        "method,spec,kw", CASES, ids=[c[0] for c in CASES]
    )
    def test_matches_host_pipeline(self, method, spec, kw, packed):
        raw = _raw(5, side=spec.image_y, seed=3, binary=(method == "none"))
        want = preprocess_for_serving(
            raw, spec, method=method, packed=packed, **kw
        )
        got = np.asarray(
            device_ingress(
                IngressSpec(patch=spec, method=method, packed=packed, **kw),
                jnp.asarray(raw),
            )
        )
        assert got.dtype == want.dtype and got.shape == want.shape
        np.testing.assert_array_equal(want, got, err_msg=f"{method}/packed={packed}")

    def test_adaptive_matches_golden_probe_images(self):
        """On the cv2-pinned golden probe set, the device booleanize stage
        equals the host adaptive path exactly (which test_booleanize_golden
        pins to OpenCV outside the fixed-point band) — so the golden
        anchoring transfers to the fused graph."""
        import os

        g = np.load(
            os.path.join(os.path.dirname(__file__), "data", "adaptive_golden.npz")
        )
        images = g["images"]
        for bs, c in [(int(b), float(c)) for b, c in g["configs"]]:
            spec = IngressSpec(
                patch=PatchSpec(), method="adaptive_gaussian",
                packed=False, block_size=bs, c=c,
            )
            from repro.core.booleanize import adaptive_gaussian_booleanize

            np.testing.assert_array_equal(
                np.asarray(adaptive_gaussian_booleanize(images, bs, c)),
                np.asarray(apply_booleanize(spec, jnp.asarray(images))),
            )
            # And end to end: full literals agree with the host pipeline.
            np.testing.assert_array_equal(
                preprocess_for_serving(
                    images, spec.patch, method="adaptive",
                    packed=False, block_size=bs, c=c,
                ),
                np.asarray(device_ingress(spec, jnp.asarray(images))),
            )

    def test_strided_geometry(self):
        raw = _raw(4, side=12, seed=9)
        spec = IngressSpec(patch=STRIDE_SPEC, method="threshold", packed=True)
        np.testing.assert_array_equal(
            preprocess_for_serving(raw, STRIDE_SPEC, method="threshold", packed=True),
            np.asarray(device_ingress(spec, jnp.asarray(raw))),
        )

    def test_spec_validation(self):
        with pytest.raises(ValueError, match="unknown booleanization"):
            IngressSpec(patch=EDGE_SPEC, method="bogus")
        with pytest.raises(ValueError, match="therm_bits"):
            IngressSpec(patch=EDGE_SPEC, method="thermometer", levels=3)


class TestIngressKernel:
    """The Pallas ingress-pack kernel vs the jnp oracle."""

    @pytest.mark.parametrize(
        "spec",
        [EDGE_SPEC, STRIDE_SPEC, PatchSpec(image_x=14, image_y=14, window_x=6, window_y=6)],
        ids=["edge", "strided", "mid"],
    )
    @pytest.mark.parametrize("b", [1, 5, 8])
    def test_interpret_matches_ref(self, spec, b):
        imgs = jnp.asarray(_raw(b, side=spec.image_y, seed=b, binary=True))
        want = ref.ingress_pack_ref(imgs, spec)
        got = ops.ingress_pack(imgs, spec, backend="interpret")
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))

    def test_kernel_backend_in_full_ingress(self):
        """IngressSpec(kernel_backend='interpret') routes the packed path
        through the Pallas kernel and still matches the jnp route."""
        raw = _raw(3, seed=2)
        jnp_spec = IngressSpec(patch=EDGE_SPEC, method="threshold", packed=True)
        pl_spec = dataclasses.replace(jnp_spec, kernel_backend="interpret")
        np.testing.assert_array_equal(
            np.asarray(device_ingress(jnp_spec, jnp.asarray(raw))),
            np.asarray(device_ingress(pl_spec, jnp.asarray(raw))),
        )

    def test_fused_infer_from_images(self):
        """The no-dense-literals-in-HBM chain (ingress kernel -> fused
        kernel) equals the oracle composition."""
        from repro.serve import freeze

        model = init_boundary_model(jax.random.PRNGKey(1), EDGE_CFG)
        sm = freeze(model, EDGE_CFG)
        imgs = jnp.asarray(_raw(4, seed=5, binary=True))
        want = ref.fused_infer_ref(
            ref.ingress_pack_ref(imgs, EDGE_SPEC),
            sm.include_packed, sm.nonempty, sm.weights,
        )
        got = ops.fused_infer_from_images(
            imgs, EDGE_SPEC, sm.include_packed, sm.nonempty, sm.weights,
            backend="interpret",
        )
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


class TestEngineRawPath:
    def _engine(self, path=None, method="threshold", max_batch=16):
        engine = ServingEngine(max_batch=max_batch)
        model = init_boundary_model(jax.random.PRNGKey(0), EDGE_CFG)
        engine.register("m", model, EDGE_CFG, booleanize_method=method, path=path)
        return engine, model

    @pytest.mark.parametrize("path", ["matmul", "fused"])
    def test_raw_device_matches_host_and_preprocessed(self, path):
        engine, model = self._engine(path=path)
        raw = _raw(5, seed=7)
        dev = engine.classify("m", raw)                       # device ingress
        host = engine.classify("m", raw, ingress="host")      # legacy pipeline
        lits = engine.preprocess("m", raw)
        pre = engine.classify("m", lits, preprocessed=True)
        np.testing.assert_array_equal(dev.class_sums, host.class_sums)
        np.testing.assert_array_equal(dev.class_sums, pre.class_sums)
        np.testing.assert_array_equal(dev.predictions, host.predictions)
        # ... and against the reference inference on booleanized images.
        from repro.data.pipeline import booleanize_split

        want_p, want_v = infer(
            model, jnp.asarray(booleanize_split(raw, "threshold")),
            dataclasses.replace(EDGE_CFG, eval_path=path),
        )
        np.testing.assert_array_equal(dev.predictions, np.asarray(want_p))
        np.testing.assert_array_equal(dev.class_sums, np.asarray(want_v))

    def test_latency_split_recorded(self):
        engine, _ = self._engine()
        res = engine.classify("m", _raw(4, seed=1))
        assert res.device_s > 0.0 and res.ingress_s >= 0.0
        assert res.latency_s == pytest.approx(res.ingress_s + res.device_s, rel=0.05)
        st = engine.stats("m")
        assert st.mean_device_us > 0.0
        assert st.total_latency_s == pytest.approx(st.ingress_s + st.device_s, rel=0.05)
        # Host ingress dominates its split; device path keeps ingress ~free.
        engine.classify("m", _raw(4, seed=2), ingress="host")
        st = engine.stats("m")
        assert st.ingress_s > 0.0

    def test_raw_shape_validated(self):
        engine, _ = self._engine()
        with pytest.raises(ValueError, match="raw images"):
            engine.classify("m", np.zeros((2, 9, 9), np.uint8))
        with pytest.raises(ValueError, match="empty request"):
            engine.classify("m", np.zeros((0, 11, 11), np.uint8))
        assert engine.stats("m").requests == 0

    def test_warmup_covers_raw_form(self):
        """After warmup, raw classifies add no new compiled buckets and
        both request forms execute."""
        engine, _ = self._engine(max_batch=8)
        assert engine.warmup("m") == (1, 2, 4, 8)
        st = engine.stats("m")
        assert set(st.compiled_buckets) == {1, 2, 4, 8}
        engine.classify("m", _raw(3, seed=4))                   # raw bucket 4
        lits = engine.preprocess("m", _raw(3, seed=4))
        engine.classify("m", lits, preprocessed=True)           # literal bucket 4
        st = engine.stats("m")
        assert set(st.compiled_buckets) == {1, 2, 4, 8}         # still warm
        assert engine.warmup("m") == ()                         # idempotent

    def test_booleanize_kw_applies_to_both_ingresses(self):
        """Custom booleanize knobs registered for the device IngressSpec
        must also drive the host baseline — a host run with default knobs
        would silently break the bit-identity contract."""
        engine = ServingEngine(max_batch=8)
        model = init_boundary_model(jax.random.PRNGKey(0), EDGE_CFG)
        engine.register(
            "hot", model, EDGE_CFG, booleanize_method="threshold",
            booleanize_kw={"threshold": 200},
        )
        engine.register("default", model, EDGE_CFG, booleanize_method="threshold")
        raw = _raw(4, seed=3)
        dev = engine.classify("hot", raw)
        host = engine.classify("hot", raw, ingress="host")
        np.testing.assert_array_equal(dev.class_sums, host.class_sums)
        # ... and the knob is real: literals differ from the default-75 entry.
        assert not np.array_equal(
            engine.preprocess("hot", raw), engine.preprocess("default", raw)
        )

    def test_dispatch_is_nonblocking_handle(self):
        """dispatch() returns an in-flight handle whose result() is
        idempotent and matches a blocking classify."""
        engine, _ = self._engine()
        raw = _raw(4, seed=11)
        handle = engine.dispatch("m", raw)
        r1 = handle.result()
        r2 = handle.result()
        assert r1 is r2
        want = engine.classify("m", raw)
        np.testing.assert_array_equal(r1.class_sums, want.class_sums)


class TestServiceRawPath:
    def _pair(self, max_batch=16):
        model = init_boundary_model(jax.random.PRNGKey(2), EDGE_CFG)
        engine = ServingEngine(max_batch=max_batch)
        engine.register("m", model, EDGE_CFG, booleanize_method="threshold")
        reference = ServingEngine(max_batch=max_batch)
        reference.register("m", model, EDGE_CFG, booleanize_method="threshold")
        return engine, reference

    def test_raw_submission_matches_preprocessed(self):
        """The service-level contract: raw-pixel submission, preprocessed
        submission and host_ingress submission all agree with each other
        and with direct engine classifies."""
        engine, reference = self._pair()
        service = ServingService(engine, ServiceConfig(max_delay_us=500.0))

        async def run():
            await service.start()
            raws = [_raw(n, seed=i) for i, n in enumerate([1, 3, 2, 5])]
            raw_res = await asyncio.gather(
                *(service.submit("m", r) for r in raws)
            )
            pre_res = await asyncio.gather(
                *(service.submit(
                    "m", reference.preprocess("m", r), preprocessed=True
                ) for r in raws)
            )
            host_res = await asyncio.gather(
                *(service.submit("m", r, host_ingress=True) for r in raws)
            )
            await service.stop(drain=True)
            return raws, raw_res, pre_res, host_res

        raws, raw_res, pre_res, host_res = asyncio.run(run())
        for r, a, b, c in zip(raws, raw_res, pre_res, host_res):
            want = reference.classify("m", r)
            for got in (a, b, c):
                np.testing.assert_array_equal(got.predictions, want.predictions)
                np.testing.assert_array_equal(got.class_sums, want.class_sums)

    def test_mixed_form_microbatch(self):
        """Raw and preprocessed requests coalesced into ONE microbatch
        execute as separate engine dispatches but resolve identically."""
        engine, reference = self._pair()
        service = ServingService(engine, ServiceConfig(max_delay_us=50_000.0))

        async def run():
            await service.start()
            raw = _raw(2, seed=0)
            lits = reference.preprocess("m", _raw(2, seed=1))
            futs = [
                service.submit_nowait("m", raw),
                service.submit_nowait("m", lits, preprocessed=True),
                service.submit_nowait("m", _raw(2, seed=2)),
            ]
            out = await asyncio.gather(*futs)
            await service.stop(drain=True)
            return out

        results = asyncio.run(run())
        assert all(r.batch_requests == 3 and r.batch_images == 6 for r in results)
        np.testing.assert_array_equal(
            results[0].predictions,
            reference.classify("m", _raw(2, seed=0)).predictions,
        )
        np.testing.assert_array_equal(
            results[1].predictions,
            reference.classify("m", _raw(2, seed=1)).predictions,
        )
        np.testing.assert_array_equal(
            results[2].predictions,
            reference.classify("m", _raw(2, seed=2)).predictions,
        )
        st = service.stats("m")
        assert st.batches == 1 and st.images == 6

    def test_service_stats_split(self):
        engine, _ = self._pair()
        service = ServingService(engine, ServiceConfig(max_delay_us=0.0))

        async def run():
            await service.start()
            await service.submit("m", _raw(3, seed=5))
            await service.stop(drain=True)

        asyncio.run(run())
        st = service.stats("m")
        assert st.device_us_per_image > 0.0
        assert st.ingress_us_per_image >= 0.0

    def test_raw_shape_error_propagates_without_enqueue(self):
        engine, _ = self._pair()
        service = ServingService(engine)

        async def run():
            await service.start()
            with pytest.raises(ValueError, match="raw images"):
                service.submit_nowait("m", np.zeros((2, 9, 9), np.uint8))
            await service.stop()

        asyncio.run(run())
        assert service.stats("m").submitted == 0


class TestTrainerIngress:
    def test_prepare_matches_host_pipeline(self):
        from repro.train.tm_engine import TrainerEngine

        cfg = dataclasses.replace(EDGE_CFG, n_clauses=16)
        eng = TrainerEngine(cfg, batch_size=4)
        raw = _raw(10, seed=6)
        labels = np.arange(10) % cfg.n_classes
        ds = eng.prepare(raw, labels, booleanize_method="threshold")
        want = preprocess_for_serving(
            raw, cfg.patch, method="threshold", packed=False
        )
        np.testing.assert_array_equal(np.asarray(ds.literals), want)

    def test_prepare_chunks_are_seamless(self, monkeypatch):
        from repro.train import tm_engine as te

        cfg = dataclasses.replace(EDGE_CFG, n_clauses=16)
        eng = te.TrainerEngine(cfg, batch_size=4)
        monkeypatch.setattr(te.TrainerEngine, "INGRESS_CHUNK", 4)
        raw = _raw(10, seed=8)
        ds = eng.prepare(raw, np.zeros(10, np.int64))
        want = preprocess_for_serving(raw, cfg.patch, method="threshold", packed=False)
        np.testing.assert_array_equal(np.asarray(ds.literals), want)
