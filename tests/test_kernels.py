"""Pallas kernel tests: shape/dtype sweeps + property tests vs ref.py.

All kernels run in interpret mode (CPU container; TPU is the target)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core.patches import pack_bits
from repro.kernels import ops, ref

def _mk(b, p, c, nlit, density, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    lits = (jax.random.uniform(k1, (b, p, nlit)) > 0.5).astype(jnp.uint8)
    inc = (jax.random.uniform(k2, (c, nlit)) > density).astype(jnp.uint8)
    inc = inc.at[0].set(0)
    ne = jnp.any(inc > 0, axis=1)
    w = jax.random.randint(k3, (10, c), -127, 128, jnp.int32)
    return pack_bits(lits), pack_bits(inc), ne, w


SHAPES = [
    (4, 361, 128, 272),   # the paper's configuration
    (1, 9, 16, 16),       # noisy-XOR scale
    (3, 50, 70, 100),     # ragged everything
    (8, 64, 256, 512),    # larger clause pool
    (2, 361, 1000, 272),  # Table III clause count
]


@pytest.mark.parametrize("b,p,c,nlit", SHAPES)
@pytest.mark.parametrize("csrf", [True, False])
def test_clause_eval_matches_ref(b, p, c, nlit, csrf):
    lp, ip, ne, _ = _mk(b, p, c, nlit, density=0.93, seed=b * 100 + c)
    want = ref.clause_eval_ref(lp, ip, ne)
    got = ops.clause_eval(lp, ip, ne, backend="interpret", csrf=csrf)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


@pytest.mark.parametrize("density", [0.0, 0.5, 0.999, 1.0])
def test_clause_eval_density_extremes(density):
    # density=1.0 -> every clause empty; 0.0 -> every literal included.
    lp, ip, ne, _ = _mk(2, 30, 64, 128, density=density, seed=7)
    want = ref.clause_eval_ref(lp, ip, ne)
    got = ops.clause_eval(lp, ip, ne, backend="interpret")
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


@pytest.mark.parametrize("block_b,block_c,block_p", [(8, 128, 64), (4, 128, 8), (8, 256, 128)])
def test_clause_eval_block_shape_sweep(block_b, block_c, block_p):
    lp, ip, ne, _ = _mk(5, 100, 130, 272, density=0.95, seed=3)
    want = ref.clause_eval_ref(lp, ip, ne)
    got = ops.clause_eval(
        lp, ip, ne, backend="interpret",
        block_b=block_b, block_c=block_c, block_p=block_p,
    )
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


@pytest.mark.parametrize("b,p,c,nlit", SHAPES[:3])
def test_class_sum_matches_ref(b, p, c, nlit):
    lp, ip, ne, w = _mk(b, p, c, nlit, density=0.93, seed=11)
    fired = ref.clause_eval_ref(lp, ip, ne)
    want = ref.class_sum_ref(fired, w)
    got = ops.class_sum(fired, w, backend="interpret")
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


def test_fused_infer():
    lp, ip, ne, w = _mk(4, 361, 128, 272, density=0.95, seed=13)
    want = ref.fused_infer_ref(lp, ip, ne, w)
    got = ops.fused_infer(lp, ip, ne, w, backend="interpret")
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


# 6 examples keep interpret-mode runtime ~10s in tier-1; the full 15-example
# sweep runs in the slow CI job via test_clause_eval_property_full below.
@settings(max_examples=6, deadline=None)
@given(
    b=st.integers(1, 6),
    p=st.integers(1, 40),
    c=st.integers(1, 150),
    o=st.integers(1, 80),
    density=st.floats(0.5, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_clause_eval_property(b, p, c, o, density, seed):
    """Padding contract + CSRF hold for arbitrary shapes/densities."""
    _check_clause_eval_property(b, p, c, o, density, seed)


def _check_clause_eval_property(b, p, c, o, density, seed):
    lp, ip, ne, _ = _mk(b, p, c, 2 * o, density=density, seed=seed % 10_000)
    want = ref.clause_eval_ref(lp, ip, ne)
    got = ops.clause_eval(lp, ip, ne, backend="interpret")
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 6),
    p=st.integers(1, 40),
    c=st.integers(1, 150),
    o=st.integers(1, 80),
    density=st.floats(0.5, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_clause_eval_property_full(b, p, c, o, density, seed):
    _check_clause_eval_property(b, p, c, o, density, seed)


def test_kernel_path_in_full_inference():
    """cotm.infer(eval_path='kernel') == 'dense' on the paper config."""
    from repro.core.cotm import CoTMConfig, init_model, infer
    import dataclasses

    cfg_d = CoTMConfig(n_clauses=64, eval_path="dense")
    cfg_k = dataclasses.replace(cfg_d, eval_path="kernel")
    key = jax.random.PRNGKey(5)
    model = init_model(key, cfg_d)
    model.ta_state = jax.random.randint(
        key, model.ta_state.shape, 100, 140
    ).astype(jnp.uint8)
    imgs = (jax.random.uniform(key, (4, 28, 28)) > 0.6).astype(jnp.uint8)
    p1, v1 = infer(model, imgs, cfg_d)
    p2, v2 = infer(model, imgs, cfg_k)
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))


@pytest.mark.parametrize("b,p,c,nlit", SHAPES[:2] + SHAPES[3:4])
@pytest.mark.parametrize("csrf", [True, False])
def test_fused_single_kernel_matches_ref(b, p, c, nlit, csrf):
    """The single-pallas_call fused kernel (OR register in VMEM scratch,
    in-register class-sum reduction) is bit-equal to the oracle."""
    lp, ip, ne, w = _mk(b, p, c, nlit, density=0.94, seed=b + c)
    want = ref.fused_infer_ref(lp, ip, ne, w)
    got = ops.fused_infer(lp, ip, ne, w, backend="interpret", csrf=csrf)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


@settings(max_examples=4, deadline=None)
@given(
    b=st.integers(1, 5),
    p=st.integers(1, 30),
    c=st.integers(1, 140),
    o=st.integers(1, 60),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_kernel_property(b, p, c, o, seed):
    _check_fused_kernel_property(b, p, c, o, seed)


def _check_fused_kernel_property(b, p, c, o, seed):
    lp, ip, ne, w = _mk(b, p, c, 2 * o, density=0.9, seed=seed % 10_000)
    want = ref.fused_infer_ref(lp, ip, ne, w)
    got = ops.fused_infer(lp, ip, ne, w, backend="interpret")
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 5),
    p=st.integers(1, 30),
    c=st.integers(1, 140),
    o=st.integers(1, 60),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_kernel_property_full(b, p, c, o, seed):
    _check_fused_kernel_property(b, p, c, o, seed)


class TestOracleRegistry:
    """kernels/registry.py: the runtime aggregation of the per-module
    PALLAS_ORACLES annotations that tmlint TM202 checks statically."""

    def test_every_kernel_has_a_callable_oracle(self):
        from repro.kernels import registry

        assert registry.KERNEL_ORACLES, "registry must not be empty"
        for kernel, oracle in registry.KERNEL_ORACLES.items():
            fn = registry.oracle_for(kernel)
            assert callable(fn)
            assert fn is getattr(ref, oracle)

    def test_registry_matches_module_annotations(self):
        from repro.kernels import registry
        from repro.kernels import class_sum, clause_eval, fused_infer, ingress

        merged = {}
        for mod in (class_sum, clause_eval, fused_infer, ingress):
            merged.update(mod.PALLAS_ORACLES)
        assert registry.KERNEL_ORACLES == merged

    def test_unknown_kernel_rejected(self):
        from repro.kernels import registry

        with pytest.raises(KeyError):
            registry.oracle_for("nonexistent_pallas")
