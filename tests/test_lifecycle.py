"""Zero-downtime model lifecycle: versioned servables, atomic hot swap
under load, and the train -> shadow -> promote loop.

The acceptance contract (ARCHITECTURE.md §Lifecycle): under open-loop
Poisson load at tiny geometry, a swap storm completes with zero failed
or dropped requests, every ``ServiceResult`` carries the monotonic id of
the version whose weights computed it, results are bit-identical to
direct ``engine.classify`` on the corresponding version, no microbatch
ever mixes two versions, swaps compile only the delta (pow2-binned
sparsity shapes — nothing, once a bin is warm), and ``rollback()``
restores the displaced version within one microbatch.

Also here: the stop/drain-vs-swap race soak with its off-loop regression
pins (the PR-7 ``stop`` lesson: engine-lock work never runs ON the event
loop), the scheduler version-boundary property test (hypothesis, or its
deterministic shim), and the servable checkpoint round-trip (stamp +
tuned-plan digests survive; legacy/malformed manifests load as v0).

Multi-device cases skip unless the process was started with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
multidevice job does exactly that).
"""

import asyncio
import collections
import dataclasses
import random
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.serve.engine as engine_mod
from _hypothesis_shim import given, settings, st
from repro.checkpoint.checkpointer import (
    restore_servable,
    save_pytree,
    save_servable,
)
from repro.core.cotm import CoTMConfig, CoTMModel, init_boundary_model
from repro.core.patches import PatchSpec
from repro.launch.lifecycle import LifecycleConfig, LifecycleDriver, shadow_slot
from repro.serve import (
    MicrobatchScheduler,
    PendingRequest,
    QueueFull,
    SchedulerConfig,
    ServableVersion,
    ServiceConfig,
    ServingEngine,
    ServingService,
    TunedPlan,
    freeze,
    make_serve_mesh,
    servable_digest,
)
from repro.serve.loadgen import poisson_open_loop
from repro.train.tm_engine import TrainerEngine

# n_clauses divisible by 8 so the clause-sharded mesh cases split evenly.
SPEC = PatchSpec(image_x=11, image_y=11, window_x=5, window_y=5)
CFG = CoTMConfig(n_clauses=40, n_classes=10, patch=SPEC)


def _model(seed=0):
    return init_boundary_model(jax.random.PRNGKey(seed), CFG)


def _weight_variant(base: CoTMModel, seed: int) -> CoTMModel:
    """Same clause structure (same include bits, hence the same sparsity
    shape and pow2 bin), different weights — the shape of a retrained
    candidate a swap storm actually installs."""
    rng = np.random.default_rng(seed)
    w = np.asarray(base.weights)
    delta = rng.integers(-3, 4, w.shape).astype(w.dtype)
    return CoTMModel(ta_state=base.ta_state, weights=jnp.asarray(w + delta))


def _images(n, seed=0):
    key = jax.random.PRNGKey(seed + 100)
    side = SPEC.image_y
    return np.asarray(
        (jax.random.uniform(key, (n, side, side)) > 0.6)
    ).astype(np.uint8)


def _ref(model, max_batch=16):
    """An independent reference engine over one fixed model version."""
    eng = ServingEngine(max_batch=max_batch)
    eng.register("m", model, CFG, booleanize_method="none")
    return eng


def _need_devices(n):
    if jax.device_count() < n:
        pytest.skip(
            f"needs {n} devices; run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=8"
        )


# --------------------------------------------------------------------------
# Version stamps on the synchronous engine
# --------------------------------------------------------------------------


class TestVersionStamps:
    def test_register_stamps_v1_with_content_digest(self):
        eng = ServingEngine(max_batch=8)
        model = _model()
        eng.register("m", model, CFG, booleanize_method="none")
        v = eng.version("m")
        assert v.version == 1 and eng.version_id("m") == 1
        assert v.digest == servable_digest(freeze(model, CFG))
        # The served image carries the stamp back out for checkpointing.
        assert eng.servable("m").version == v
        # ...and results are attributed to it.
        assert eng.classify("m", _images(3)).version == 1

    def test_swap_increments_and_serves_new_weights(self):
        eng = ServingEngine(max_batch=8)
        base = _model()
        eng.register("m", base, CFG, booleanize_method="none")
        var = _weight_variant(base, 1)
        stamp = eng.swap("m", var, CFG)
        assert stamp.version == 2 and eng.version_id("m") == 2
        assert stamp.digest != ""
        assert eng.version("m") == stamp
        imgs = _images(7)
        got = eng.classify("m", imgs)
        want = _ref(var).classify("m", imgs)
        assert got.version == 2
        np.testing.assert_array_equal(got.predictions, want.predictions)
        np.testing.assert_array_equal(got.class_sums, want.class_sums)

    def test_swap_rejects_geometry_change(self):
        eng = ServingEngine(max_batch=8)
        eng.register("m", _model(), CFG, booleanize_method="none")
        other_cfg = dataclasses.replace(CFG, n_clauses=48)
        other = init_boundary_model(jax.random.PRNGKey(9), other_cfg)
        with pytest.raises(ValueError, match="config mismatch"):
            eng.swap("m", other, other_cfg)

    def test_swap_unknown_slot_and_missing_config(self):
        eng = ServingEngine(max_batch=8)
        eng.register("m", _model(), CFG, booleanize_method="none")
        with pytest.raises(KeyError):
            eng.swap("ghost", _model(), CFG)
        with pytest.raises(ValueError, match="config required"):
            eng.swap("m", _model())

    def test_rollback_without_swap_raises(self):
        eng = ServingEngine(max_batch=8)
        eng.register("m", _model(), CFG, booleanize_method="none")
        with pytest.raises(ValueError, match="no previous version"):
            eng.rollback("m")

    def test_rollback_restores_weights_under_fresh_monotonic_id(self):
        eng = ServingEngine(max_batch=8)
        base = _model()
        eng.register("m", base, CFG, booleanize_method="none")
        v1 = eng.version("m")
        eng.swap("m", _weight_variant(base, 1), CFG)
        stamp = eng.rollback("m")
        # Ids never regress; the digest identifies the restored weights.
        assert stamp.version == 3
        assert stamp.digest == v1.digest
        imgs = _images(5)
        got = eng.classify("m", imgs)
        want = _ref(base).classify("m", imgs)
        assert got.version == 3
        np.testing.assert_array_equal(got.class_sums, want.class_sums)

    def test_double_rollback_flips_back(self):
        eng = ServingEngine(max_batch=8)
        base = _model()
        var = _weight_variant(base, 1)
        eng.register("m", base, CFG, booleanize_method="none")
        v2 = eng.swap("m", var, CFG)
        eng.rollback("m")                      # v3: base weights
        stamp = eng.rollback("m")              # v4: var weights again
        assert stamp.version == 4 and stamp.digest == v2.digest
        imgs = _images(4)
        np.testing.assert_array_equal(
            eng.classify("m", imgs).class_sums,
            _ref(var).classify("m", imgs).class_sums,
        )

    def test_reregister_of_live_slot_continues_id_sequence(self):
        eng = ServingEngine(max_batch=8)
        base = _model()
        eng.register("m", base, CFG, booleanize_method="none")
        eng.swap("m", _weight_variant(base, 1), CFG)   # v2
        eng.register("m", _model(7), CFG, booleanize_method="none")
        assert eng.version_id("m") == 3

    def test_inflight_request_completes_on_old_version(self):
        """A dispatch captures its (weights, version) atomically: a swap
        landing before .result() cannot retroactively change either."""
        eng = ServingEngine(max_batch=8)
        base = _model()
        eng.register("m", base, CFG, booleanize_method="none")
        imgs = _images(6)
        handle = eng.dispatch("m", imgs)
        eng.swap("m", _weight_variant(base, 2), CFG)
        res = handle.result()
        assert res.version == 1
        np.testing.assert_array_equal(
            res.class_sums, _ref(base).classify("m", imgs).class_sums
        )

    def test_trainer_freeze_stamp_provenance_flows_through_register(self):
        trainer = TrainerEngine(CFG, batch_size=8)
        model = _model()
        from repro.data.pipeline import PipelineState

        servable = trainer.freeze_servable(
            model, PipelineState(epoch=4, step=123)
        )
        assert servable.version is not None
        assert servable.version.epoch == 4 and servable.version.step == 123
        eng = ServingEngine(max_batch=8)
        eng.register("m", servable, booleanize_method="none")
        v = eng.version("m")
        # Engine assigns the id; provenance and digest ride through.
        assert v.version == 1 and v.epoch == 4 and v.step == 123
        assert v.digest == servable.version.digest


# --------------------------------------------------------------------------
# Swap compiles only the delta
# --------------------------------------------------------------------------


class TestSwapCompileDelta:
    def test_swap_storm_compiles_nothing_once_bin_is_warm(self):
        """Version is never a jit key and sparsity shapes are pow2-binned,
        so after one swap has warmed a bin, further swaps (and rollback)
        across weight variants compile exactly zero executables."""
        from tools.recompile_guard import no_recompiles

        eng = ServingEngine(max_batch=8)
        base = _model()
        eng.register("m", base, CFG, booleanize_method="none")
        eng.warmup("m", forms=("raw",))
        # First swap may introduce the pow2-binned sparsity shape; warm it.
        eng.swap("m", _weight_variant(base, 1), CFG)
        eng.warmup("m", forms=("raw",))
        imgs = _images(5)
        expected_version = 2
        with no_recompiles(
            engine_mod.classify_step, (engine_mod, "_raw_step_jit"), expect=0
        ):
            for seed in (2, 3, 4):
                eng.swap("m", _weight_variant(base, seed), CFG)
                expected_version += 1
                got = eng.classify("m", imgs)
                assert got.version == expected_version
            eng.rollback("m")
            expected_version += 1
            got = eng.classify("m", imgs)
            assert got.version == expected_version
        # The storm's last classifies stayed bit-identical per version:
        # rollback restored variant 3's weights.
        want = _ref(_weight_variant(base, 3)).classify("m", imgs)
        np.testing.assert_array_equal(got.class_sums, want.class_sums)


# --------------------------------------------------------------------------
# Service: version attribution, swap storms under open-loop load
# --------------------------------------------------------------------------


def _lifecycle_service(max_batch=16, max_delay_us=300.0, mesh=None):
    base = _model()
    engine = ServingEngine(max_batch=max_batch, mesh=mesh)
    engine.register("m", base, CFG, booleanize_method="none")
    service = ServingService(engine, ServiceConfig(max_delay_us=max_delay_us))
    return base, engine, service


class TestServiceLifecycle:
    def test_results_carry_version_and_batch_id(self):
        base, engine, service = _lifecycle_service()
        var = _weight_variant(base, 1)

        async def run():
            await service.start()
            r1 = await service.submit("m", _images(2, seed=1))
            stamp = await service.swap("m", var, CFG)
            r2 = await service.submit("m", _images(2, seed=2))
            await service.stop(drain=True)
            return r1, stamp, r2

        r1, stamp, r2 = asyncio.run(run())
        assert r1.version == 1 and r1.batch_id >= 1
        assert stamp.version == 2
        assert r2.version == 2 and r2.batch_id > r1.batch_id

    def test_swap_storm_under_open_loop_poisson_load(self):
        """The headline soak: zero dropped/failed requests, per-version
        bit-identity, single version per microbatch, non-decreasing
        version ids along admission order, zero recompiles."""
        from tools.recompile_guard import no_recompiles

        base, engine, service = _lifecycle_service(max_delay_us=200.0)
        var_a = _weight_variant(base, 1)
        var_b = _weight_variant(base, 2)
        var_c = _weight_variant(base, 3)
        # Warm every bucket and the pow2-binned sparsity shape before the
        # storm so the RecompileGuard measures the swaps, not cold start.
        engine.warmup("m", forms=("raw",))
        engine.swap("m", var_a, CFG)              # v2 (storm baseline)
        engine.warmup("m", forms=("raw",))
        model_by_version = {2: var_a, 3: var_b, 4: var_c, 5: var_b}
        refs = {
            v: _ref(m) for v, m in model_by_version.items()
        }

        rng = np.random.default_rng(0)
        requests = [
            _images(int(rng.integers(1, 5)), seed=1000 + i) for i in range(48)
        ]

        async def run():
            await service.start()
            load = asyncio.create_task(
                poisson_open_loop(service, "m", requests, rate=600.0, seed=7)
            )
            # Three lifecycle events land while the stream is in flight.
            await asyncio.sleep(0.015)
            await service.swap("m", var_b, CFG)           # v3
            await asyncio.sleep(0.015)
            await service.swap("m", var_c, CFG)           # v4
            await asyncio.sleep(0.015)
            await service.rollback("m")                   # v5 (= var_b)
            admitted, rejected = await load
            results = await asyncio.gather(*(f for _, f in admitted))
            # One deterministic post-rollback submission pins the final
            # endpoint even if the stream outran the lifecycle events.
            final = await service.submit("m", requests[0])
            await service.stop(drain=True)
            return admitted, rejected, results, final

        with no_recompiles(
            engine_mod.classify_step, (engine_mod, "_raw_step_jit"), expect=0
        ):
            admitted, rejected, results, final = asyncio.run(run())

        # Nothing dropped, nothing failed: every admitted request
        # resolved (gather would have raised), and none were shed.
        assert rejected == 0
        assert len(admitted) == len(requests)
        assert service.stats("m").completed == len(requests) + 1

        by_batch = collections.defaultdict(set)
        versions_in_order = []
        for (i, _), res in zip(admitted, results):
            assert res.version in model_by_version
            versions_in_order.append(res.version)
            by_batch[res.batch_id].add(res.version)
            want = refs[res.version].classify("m", requests[i])
            np.testing.assert_array_equal(res.predictions, want.predictions)
            np.testing.assert_array_equal(res.class_sums, want.class_sums)
        # One version per microbatch, ids non-decreasing in admission order.
        assert all(len(vs) == 1 for vs in by_batch.values())
        assert versions_in_order == sorted(versions_in_order)
        # The stream started on the storm baseline and the post-rollback
        # request landed on the restored (freshly stamped) version.
        assert versions_in_order[0] == 2
        assert final.version == 5
        np.testing.assert_array_equal(
            final.class_sums, refs[5].classify("m", requests[0]).class_sums
        )

    def test_rollback_restores_prior_version_within_one_microbatch(self):
        """The very next microbatch dispatched after rollback() runs on
        the restored weights — no re-freeze / re-analysis window during
        which stale weights keep serving."""
        base, engine, service = _lifecycle_service(max_delay_us=200.0)
        var = _weight_variant(base, 1)
        i1, i2, i3 = _images(2, seed=1), _images(2, seed=2), _images(2, seed=3)

        async def run():
            await service.start()
            r1 = await service.submit("m", i1)
            await service.swap("m", var, CFG)
            r2 = await service.submit("m", i2)
            await service.rollback("m")
            r3 = await service.submit("m", i3)
            await service.stop(drain=True)
            return r1, r2, r3

        r1, r2, r3 = asyncio.run(run())
        assert (r1.version, r2.version, r3.version) == (1, 2, 3)
        assert len({r1.batch_id, r2.batch_id, r3.batch_id}) == 3
        np.testing.assert_array_equal(
            r2.class_sums, _ref(var).classify("m", i2).class_sums
        )
        np.testing.assert_array_equal(
            r3.class_sums, _ref(base).classify("m", i3).class_sums
        )

    def test_requests_queued_across_swap_complete_on_dispatch_version(self):
        """Attribution is honest under queueing: a request still queued
        when a swap lands is computed by (and labeled with) the NEW
        version — the version boundary guarantees its microbatch never
        mixes with post-swap admissions, and the label always names the
        weights that actually ran."""
        base, engine, service = _lifecycle_service(max_delay_us=40_000.0)
        var = _weight_variant(base, 1)
        i1, i2 = _images(2, seed=1), _images(3, seed=2)

        async def run():
            await service.start()
            f1 = service.submit_nowait("m", i1)     # queued under v1
            await service.swap("m", var, CFG)       # lands mid-queue
            f2 = service.submit_nowait("m", i2)     # admitted under v2
            r1, r2 = await asyncio.gather(f1, f2)
            await service.stop(drain=True)
            return r1, r2

        r1, r2 = asyncio.run(run())
        # Both dispatched after the swap: v2 weights computed both, and
        # both say so.  Admission versions differ, so they rode separate
        # microbatches despite the wide-open coalescing deadline.
        assert r1.version == 2 and r2.version == 2
        assert r1.batch_id != r2.batch_id
        ref = _ref(var)
        np.testing.assert_array_equal(
            r1.class_sums, ref.classify("m", i1).class_sums
        )
        np.testing.assert_array_equal(
            r2.class_sums, ref.classify("m", i2).class_sums
        )

    def test_stop_drain_racing_inflight_swap_soak(self):
        """stop(drain=True) racing a concurrent swap: every admitted
        request resolves on a well-defined version, neither call
        deadlocks, and the teardown stays clean — across several
        race-offset iterations."""
        for it in range(4):
            base, engine, service = _lifecycle_service(max_delay_us=100.0)
            var = _weight_variant(base, it + 1)
            batches = [_images(2, seed=10 * it + j) for j in range(8)]

            async def run():
                await service.start()
                futs = [service.submit_nowait("m", b) for b in batches]
                # Vary which side wins the race per iteration.
                if it % 2:
                    swap_t = asyncio.create_task(service.swap("m", var, CFG))
                    stop_t = asyncio.create_task(service.stop(drain=True))
                else:
                    stop_t = asyncio.create_task(service.stop(drain=True))
                    swap_t = asyncio.create_task(service.swap("m", var, CFG))
                await asyncio.wait_for(
                    asyncio.gather(swap_t, stop_t), timeout=60
                )
                return await asyncio.wait_for(
                    asyncio.gather(*futs), timeout=60
                )

            results = asyncio.run(run())
            assert len(results) == len(batches)
            for b, r in zip(batches, results):
                assert r.version in (1, 2)
                ref_model = base if r.version == 1 else var
                np.testing.assert_array_equal(
                    r.class_sums, _ref(ref_model).classify("m", b).class_sums
                )

    def test_swap_and_rollback_run_off_the_event_loop(self, monkeypatch):
        """Regression pin (the PR-7 ``stop`` lesson): engine.swap/rollback
        acquire the engine lock the dispatch worker holds across each
        microbatch — awaiting them ON the loop thread would stall every
        tenant's coalescing, so the service must route them through
        asyncio.to_thread."""
        calls = []
        orig_swap = ServingEngine.swap
        orig_rollback = ServingEngine.rollback

        def rec_swap(self, *a, **k):
            calls.append(threading.current_thread())
            return orig_swap(self, *a, **k)

        def rec_rollback(self, *a, **k):
            calls.append(threading.current_thread())
            return orig_rollback(self, *a, **k)

        monkeypatch.setattr(ServingEngine, "swap", rec_swap)
        monkeypatch.setattr(ServingEngine, "rollback", rec_rollback)
        base, engine, service = _lifecycle_service()

        async def run():
            await service.start()
            await service.swap("m", _weight_variant(base, 1), CFG)
            await service.rollback("m")
            await service.stop(drain=True)
            return threading.current_thread()

        loop_thread = asyncio.run(run())
        assert len(calls) == 2
        assert all(t is not loop_thread for t in calls), (
            "engine.swap/rollback ran on the event-loop thread"
        )


# --------------------------------------------------------------------------
# Scheduler property test: random interleavings (hypothesis / shim)
# --------------------------------------------------------------------------


class TestSchedulerVersionProperty:
    @settings(max_examples=25)
    @given(
        seed=st.integers(min_value=0, max_value=10_000),
        high_water=st.integers(min_value=4, max_value=32),
        max_coalesce=st.integers(min_value=1, max_value=16),
        n_ops=st.integers(min_value=20, max_value=150),
    )
    def test_random_interleavings_preserve_invariants(
        self, seed, high_water, max_coalesce, n_ops
    ):
        """Random submit / version-bump / clock-advance / dispatch
        interleavings: FIFO per tenant, the high-water admission rule is
        exact, batches respect the coalescing window (modulo the
        oversized-single rule), and no batch ever spans two versions."""
        rng = random.Random(seed)
        sched = MicrobatchScheduler(
            SchedulerConfig(max_delay_us=100.0, high_water=high_water),
            max_coalesce=max_coalesce,
        )
        models = ["a", "b"]
        version = dict.fromkeys(models, 1)
        seq = dict.fromkeys(models, 0)
        expected = {m: collections.deque() for m in models}
        now = 0.0

        def check_batch(m, batch):
            for r in batch:
                s, v, n = expected[m].popleft()   # FIFO per tenant
                assert (r.payload, r.version, r.n) == (s, v, n)
            assert len({r.version for r in batch}) == 1
            total = sum(r.n for r in batch)
            assert total <= max_coalesce or len(batch) == 1

        for _ in range(n_ops):
            op = rng.random()
            m = rng.choice(models)
            if op < 0.5:
                n = rng.randint(1, 6)
                before = sched.depth(m)
                req = PendingRequest(
                    model=m, literals=None, n=n, enqueue_t=now,
                    payload=seq[m], version=version[m],
                )
                try:
                    sched.submit(req)
                except QueueFull:
                    # Rejected exactly when a non-empty queue would burst.
                    assert before > 0 and before + n > high_water
                    assert sched.depth(m) == before
                    continue
                assert before == 0 or before + n <= high_water
                expected[m].append((seq[m], version[m], n))
                seq[m] += 1
            elif op < 0.7:
                version[m] += 1                   # a hot swap lands
            elif op < 0.85:
                now += rng.uniform(0.0, 300e-6)   # deadlines expire
            else:
                ready = sched.next_ready(now, force=rng.random() < 0.5)
                if ready is not None:
                    check_batch(ready, sched.pop_batch(ready))
        # Drain: the remaining queue flushes under the same invariants.
        while sched.total_depth():
            m = sched.next_ready(now, force=True)
            check_batch(m, sched.pop_batch(m))
        assert all(not q for q in expected.values())


# --------------------------------------------------------------------------
# Servable checkpoints: version round-trip, legacy/malformed stamps
# --------------------------------------------------------------------------


class TestServableCheckpointRoundTrip:
    def _stamped(self, seed=0):
        servable = freeze(_model(seed), CFG)
        stamp = ServableVersion(
            version=5, epoch=3, step=1200, digest=servable_digest(servable)
        )
        plan = TunedPlan(
            entries=(("literals", 8, "matmul", ()),), digest=stamp.digest
        )
        return dataclasses.replace(servable, version=stamp, tuned=plan)

    def test_round_trip_preserves_stamp_and_plan_digests(self, tmp_path):
        servable = self._stamped()
        save_servable(servable, str(tmp_path), 7)
        got, step = restore_servable(CFG, str(tmp_path))
        assert step == 7
        assert got.version == servable.version
        assert got.tuned == servable.tuned
        assert got.tuned.digest == servable.version.digest
        assert got.sparsity is None            # derived, never stored
        for field in ("include", "include_packed", "nonempty", "weights"):
            np.testing.assert_array_equal(
                np.asarray(getattr(got, field)),
                np.asarray(getattr(servable, field)),
            )

    def test_restored_servable_reregisters_with_provenance(self, tmp_path):
        servable = self._stamped()
        save_servable(servable, str(tmp_path), 2)
        got, _ = restore_servable(CFG, str(tmp_path))
        eng = ServingEngine(max_batch=8)
        eng.register("m", got, booleanize_method="none")
        v = eng.version("m")
        assert v.version == 1                  # engine-assigned id
        assert (v.epoch, v.step) == (3, 1200)  # provenance carried
        assert v.digest == servable.version.digest
        assert eng.servable("m").tuned == servable.tuned

    def test_restored_servable_hot_swaps_with_digest_intact(self, tmp_path):
        servable = self._stamped(seed=4)
        save_servable(servable, str(tmp_path), 1)
        got, _ = restore_servable(CFG, str(tmp_path))
        eng = ServingEngine(max_batch=8)
        eng.register("m", _model(), CFG, booleanize_method="none")
        stamp = eng.swap("m", got)
        assert stamp.version == 2
        assert stamp.digest == servable.version.digest
        assert (stamp.epoch, stamp.step) == (3, 1200)

    def _bare_tree(self):
        s = freeze(_model(), CFG)
        return {
            "include": np.asarray(s.include),
            "include_packed": np.asarray(s.include_packed),
            "nonempty": np.asarray(s.nonempty),
            "weights": np.asarray(s.weights),
        }

    def test_legacy_checkpoint_without_stamp_loads_as_v0(self, tmp_path):
        save_pytree(self._bare_tree(), str(tmp_path), 3)
        got, step = restore_servable(CFG, str(tmp_path))
        assert step == 3
        assert got.version == ServableVersion()    # synthesized v0
        assert got.tuned is None

    def test_malformed_stamp_and_plan_load_as_v0(self, tmp_path):
        save_pytree(
            self._bare_tree(), str(tmp_path), 4,
            extra={
                "servable_version": {"version": "not-an-int", "epoch": []},
                "tuned_plan": "{this is not json",
            },
        )
        got, _ = restore_servable(CFG, str(tmp_path))
        assert got.version == ServableVersion()
        assert got.tuned is None

    def test_non_dict_stamp_loads_as_v0(self, tmp_path):
        save_pytree(
            self._bare_tree(), str(tmp_path), 5,
            extra={"servable_version": ["v", 1]},
        )
        got, _ = restore_servable(CFG, str(tmp_path))
        assert got.version == ServableVersion()

    def test_engine_load_checkpoint_handles_servable_flavor(self, tmp_path):
        """Regression pin: ``ServingEngine.load_checkpoint`` (the serve
        CLI's ``--ckpt-dir`` path) must restore ``save_servable``
        checkpoints — the lifecycle driver's promote artifacts — not just
        raw ``CoTMModel`` trees; it used to KeyError on the missing
        ``.ta_state`` leaf."""
        servable = self._stamped(seed=6)
        save_servable(servable, str(tmp_path), 9)
        eng = ServingEngine(max_batch=8)
        eng.load_checkpoint("m", str(tmp_path), CFG, booleanize_method="none")
        v = eng.version("m")
        assert v.version == 1                  # engine-assigned id
        assert (v.epoch, v.step) == (3, 1200)  # provenance carried
        assert v.digest == servable.version.digest
        assert eng.servable("m").tuned == servable.tuned
        imgs = _images(6, seed=6)
        ref = _ref(dataclasses.replace(servable, version=None, tuned=None))
        got = eng.classify("m", imgs)
        want = ref.classify("m", imgs)
        np.testing.assert_array_equal(got.predictions, want.predictions)
        np.testing.assert_array_equal(got.class_sums, want.class_sums)


# --------------------------------------------------------------------------
# Train -> shadow -> promote under load (the full lifecycle loop)
# --------------------------------------------------------------------------


class TestTrainShadowPromote:
    def test_cycle_under_open_loop_load(self, tmp_path):
        """One full lifecycle round against a live service: the candidate
        trains, shadows on mirrored traffic, promotes via an atomic swap
        — with zero failed/dropped requests, per-version bit-identity
        throughout, and an instant rollback afterwards."""
        rng = np.random.default_rng(0)
        tx = (rng.random((96, 11, 11)) > 0.5).astype(np.uint8)
        ty = rng.integers(0, CFG.n_classes, 96).astype(np.int32)
        vx = (rng.random((32, 11, 11)) > 0.5).astype(np.uint8)
        vy = rng.integers(0, CFG.n_classes, 32).astype(np.int32)

        trainer = TrainerEngine(CFG, batch_size=32)
        train_ds = trainer.prepare(tx, ty, booleanize_method="none")
        engine = ServingEngine(max_batch=16)
        model = _model()
        initial = trainer.freeze_servable(model)
        engine.register("m", initial, booleanize_method="none")
        service = ServingService(engine, ServiceConfig(max_delay_us=300.0))
        driver = LifecycleDriver(
            trainer, engine, "m",
            config=LifecycleConfig(
                min_agreement=0.0,           # promote regardless of drift
                allow_accuracy_drop=1.0,     # (random labels at tiny geometry)
                shadow_requests=32,
            ),
            ckpt_dir=str(tmp_path),
            booleanize_method="none",
        )
        requests = [
            vx[rng.integers(0, 32, int(rng.integers(1, 4)))] for _ in range(36)
        ]

        async def run():
            await service.start()
            load = asyncio.create_task(
                poisson_open_loop(service, "m", requests, rate=40.0, seed=3)
            )
            # The whole round (train + shadow + swap) runs off-loop while
            # the Poisson stream keeps flowing through the service.
            key = jax.random.PRNGKey(1)
            _, _, _, report = await asyncio.to_thread(
                driver.run_round, key, model, train_ds, vx, vy, epochs=1
            )
            admitted, rejected = await load
            results = await asyncio.gather(*(f for _, f in admitted))
            await service.stop(drain=True)
            return report, admitted, rejected, results

        report, admitted, rejected, results = asyncio.run(run())

        assert report.promoted and report.promoted_version == 2
        assert report.live_version == 1
        assert 0.0 <= report.agreement <= 1.0
        assert report.live_accuracy is not None
        assert shadow_slot("m") in engine.models()
        assert engine.version_id("m") == 2

        # Zero dropped/failed: every request admitted and resolved.
        assert rejected == 0 and len(admitted) == len(requests)
        ref_old = ServingEngine(max_batch=16)
        ref_old.register("m", initial, booleanize_method="none")
        ref_new = ServingEngine(max_batch=16)
        ref_new.register("m", engine.servable("m"), booleanize_method="none")
        refs = {1: ref_old, 2: ref_new}
        versions = []
        for (i, _), res in zip(admitted, results):
            assert res.version in refs
            versions.append(res.version)
            want = refs[res.version].classify("m", requests[i])
            np.testing.assert_array_equal(res.predictions, want.predictions)
            np.testing.assert_array_equal(res.class_sums, want.class_sums)
        assert versions == sorted(versions)

        # The promoted servable was checkpointed with its stamp.
        got, _ = restore_servable(CFG, str(tmp_path))
        assert got.version.version == 2
        assert got.version.digest == engine.version("m").digest

        # Rollback is instant and restores the initial weights.
        stamp = driver.rollback()
        assert stamp.version == 3
        assert stamp.digest == initial.version.digest
        imgs = _images(5)
        np.testing.assert_array_equal(
            engine.classify("m", imgs).class_sums,
            ref_old.classify("m", imgs).class_sums,
        )

    def test_gate_rejects_low_agreement_and_regressions(self):
        trainer = TrainerEngine(CFG, batch_size=8)
        engine = ServingEngine(max_batch=8)
        driver = LifecycleDriver(
            trainer, engine, "m",
            config=LifecycleConfig(min_agreement=0.9, allow_accuracy_drop=0.0),
        )
        from repro.launch.lifecycle import ShadowReport

        ok, reason = driver.gate(
            ShadowReport(n=8, agreement=0.5, live_version=1, candidate_digest="")
        )
        assert not ok and "agreement" in reason
        ok, reason = driver.gate(
            ShadowReport(
                n=8, agreement=1.0, live_version=1, candidate_digest="",
                live_accuracy=0.8, candidate_accuracy=0.6,
            )
        )
        assert not ok and "accuracy" in reason
        ok, _ = driver.gate(
            ShadowReport(
                n=8, agreement=0.95, live_version=1, candidate_digest="",
                live_accuracy=0.5, candidate_accuracy=0.5,
            )
        )
        assert ok


# --------------------------------------------------------------------------
# Multi-device: swap/rollback on an 8-virtual-device ServeMesh
# --------------------------------------------------------------------------


class TestLifecycleOnMesh:
    def _mesh_pair(self, data, model_ax, *, shard_clauses=None):
        smesh = make_serve_mesh(data, model_ax, shard_clauses=shard_clauses)
        eng = ServingEngine(max_batch=32, mesh=smesh)
        return eng

    def test_swap_and_rollback_on_replicated_mesh(self):
        _need_devices(8)
        eng = self._mesh_pair(8, 1)
        base = _model()
        var = _weight_variant(base, 5)
        eng.register("m", base, CFG, booleanize_method="none")
        stamp = eng.swap("m", var, CFG)
        assert stamp.version == 2
        imgs = _images(13)
        got = eng.classify("m", imgs)
        want = _ref(var, max_batch=32).classify("m", imgs)
        assert got.version == 2
        np.testing.assert_array_equal(got.predictions, want.predictions)
        np.testing.assert_array_equal(got.class_sums, want.class_sums)
        eng.rollback("m")
        got = eng.classify("m", imgs)
        assert got.version == 3
        np.testing.assert_array_equal(
            got.class_sums, _ref(base, max_batch=32).classify("m", imgs).class_sums
        )

    def test_swap_and_rollback_on_clause_sharded_mesh(self):
        _need_devices(8)
        eng = self._mesh_pair(1, 8)     # shard_clauses defaults True
        base = _model()
        var = _weight_variant(base, 6)
        eng.register("m", base, CFG, booleanize_method="none")
        stamp = eng.swap("m", var, CFG)
        assert stamp.version == 2
        imgs = _images(9)
        got = eng.classify("m", imgs)
        want = _ref(var, max_batch=32).classify("m", imgs)
        np.testing.assert_array_equal(got.predictions, want.predictions)
        np.testing.assert_array_equal(got.class_sums, want.class_sums)
        stamp = eng.rollback("m")
        assert stamp.version == 3
        np.testing.assert_array_equal(
            eng.classify("m", imgs).class_sums,
            _ref(base, max_batch=32).classify("m", imgs).class_sums,
        )

    def test_service_swap_under_load_on_mesh(self):
        _need_devices(8)
        smesh = make_serve_mesh(8, 1)
        base = _model()
        engine = ServingEngine(max_batch=32, mesh=smesh)
        engine.register("m", base, CFG, booleanize_method="none")
        service = ServingService(engine, ServiceConfig(max_delay_us=200.0))
        var = _weight_variant(base, 7)
        requests = [_images(3, seed=50 + j) for j in range(12)]

        async def run():
            await service.start()
            futs = [service.submit_nowait("m", b) for b in requests[:6]]
            await service.swap("m", var, CFG)
            futs += [service.submit_nowait("m", b) for b in requests[6:]]
            out = await asyncio.gather(*futs)
            await service.stop(drain=True)
            return out

        results = asyncio.run(run())
        refs = {1: _ref(base, max_batch=32), 2: _ref(var, max_batch=32)}
        versions = [r.version for r in results]
        assert versions == sorted(versions)
        assert set(versions) == {1, 2}
        for b, r in zip(requests, results):
            want = refs[r.version].classify("m", b)
            np.testing.assert_array_equal(r.predictions, want.predictions)
            np.testing.assert_array_equal(r.class_sums, want.class_sums)
