"""Accumulator contracts at the maximum supported geometry
(``repro.core.cotm.MAX_GEOMETRY``), pinned against int64 references.

tmverify's TM404 *proves* the int8 x int8 -> int32 class-sum and uint32
popcount chains cannot overflow at the envelope by interval analysis
over the jaxpr; these tests *witness* the same contracts numerically:
every eval-path result at envelope accumulator depth must equal the
same computation done in int64 (where overflow is impossible), on
adversarial extreme inputs as well as random draws.

The contracted (accumulated) axes sit at the envelope — clause pool
C = 1024, dense literals 2o = 8192 (W = 256 words), classes m = 64 —
while batch/patch axes stay small: they are parallel or OR-reduced and
never feed an accumulator, so depth, not breadth, is what these pins
exercise.  (No hypothesis in the container: the property is quantified
over seeded random draws plus the deterministic extreme cases.)
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.cotm import MAX_GEOMETRY, WEIGHT_MAX, WEIGHT_MIN
from repro.core.patches import pack_bits
from repro.kernels import ops, ref

C = MAX_GEOMETRY.n_clauses          # 1024
M = MAX_GEOMETRY.n_classes          # 64
L = MAX_GEOMETRY.n_literals         # 8192
W = L // 32                         # 256 uint32 words
B, P = 2, 4                         # parallel axes (see module docstring)

SEEDS = (0, 1, 2)


def draw(seed):
    """One adversarial draw: random bits plus extreme rows forced in."""
    rng = np.random.default_rng(seed)
    literals = rng.integers(0, 2, (B, P, L), dtype=np.uint8)
    include = rng.integers(0, 2, (C, L), dtype=np.uint8)
    weights = rng.integers(WEIGHT_MIN, WEIGHT_MAX + 1, (M, C), dtype=np.int8)
    # Extremes: an all-zero literal patch (maximum violations/popcounts),
    # an empty and a full clause, saturated weight rows both ways.
    literals[0, 0] = 0
    include[0] = 0
    include[1] = 1
    weights[0] = WEIGHT_MAX
    weights[1] = WEIGHT_MIN
    return literals, include, weights


def int64_class_sums(fired: np.ndarray, weights: np.ndarray) -> np.ndarray:
    return fired.astype(np.int64) @ weights.astype(np.int64).T


class TestInt8MatmulViolationPath:
    """matmul_sparse_infer: (1 - literals) @ include^T as int8 -> int32."""

    @pytest.mark.parametrize("seed", SEEDS)
    def test_matches_int64_reference(self, seed):
        literals, include, weights = draw(seed)
        got = np.asarray(ops.matmul_sparse_infer(
            jnp.asarray(literals), jnp.asarray(include), jnp.asarray(weights)
        ))

        viol64 = (1 - literals.astype(np.int64)) @ include.astype(np.int64).T
        assert viol64.max() <= L  # the accumulator depth this pin exercises
        fired64 = np.any(viol64 == 0, axis=1)
        want = int64_class_sums(fired64, weights)
        assert want.dtype == np.int64
        # int64 truth must fit int32 (the overflow-freedom property TM404
        # proves) and the int32 path must equal it exactly.
        assert np.abs(want).max() <= np.iinfo(np.int32).max
        np.testing.assert_array_equal(got, want.astype(np.int32))


class TestPackedPopcountPath:
    """Packed-word paths: sequential-OR / popcount chains over W = 256."""

    @pytest.mark.parametrize("seed", SEEDS)
    def test_sparse_eval_matches_int64_popcounts(self, seed):
        literals, include, _ = draw(seed)
        lit_packed = np.asarray(pack_bits(jnp.asarray(literals)))
        exclude = np.asarray(
            pack_bits(jnp.asarray((1 - include).astype(np.uint8)))
        )
        # pad bits of the exclude mask must be set (nothing beyond 2o can
        # be required): pack_bits zero-fills, so set them explicitly.
        pad_bits = W * 32 - L
        assert pad_bits == 0  # envelope 2o is word-aligned; guard anyway

        got = np.asarray(ops.clause_eval_sparse(
            jnp.asarray(lit_packed), jnp.asarray(exclude)
        ))

        # int64 reference: per-word popcounts of the uncovered literals,
        # summed over all W words (the kernels' int32 accumulator chain).
        miss = ~(lit_packed[:, :, None, :] | exclude[None, None])
        counts64 = np.zeros(miss.shape[:-1], np.int64)
        for w in range(W):
            counts64 += np.vectorize(lambda x: bin(x).count("1"))(
                miss[..., w].astype(np.uint32)
            ).astype(np.int64)
        assert counts64.max() <= L
        assert counts64.max() <= np.iinfo(np.int32).max
        fired64 = np.any(counts64 == 0, axis=1).astype(np.uint8)
        np.testing.assert_array_equal(got, fired64)

    def test_interpret_kernel_at_full_accumulator_depth(self):
        """The Pallas popcount kernel itself (interpret mode), with the
        accumulated word axis at the envelope (W = 256 -> counts up to
        8192) and one clause block: kernel int32 chain == int64 truth."""
        rng = np.random.default_rng(3)
        c_small, b_small, p_small = 128, 8, 8
        literals = rng.integers(0, 2, (b_small, p_small, L), dtype=np.uint8)
        include = rng.integers(0, 2, (c_small, L), dtype=np.uint8)
        literals[0, 0] = 0  # max-depth row: popcount == 8192 on full clauses
        include[0] = 1
        lit_packed = jnp.asarray(np.asarray(pack_bits(jnp.asarray(literals))))
        exclude = jnp.asarray(np.asarray(
            pack_bits(jnp.asarray((1 - include).astype(np.uint8)))
        ))
        got = np.asarray(ops.clause_eval_sparse(
            lit_packed, exclude, backend="interpret"
        ))
        want = np.asarray(ref.clause_eval_sparse_ref(lit_packed, exclude))
        np.testing.assert_array_equal(got, want)

    @pytest.mark.parametrize("seed", SEEDS)
    def test_class_sums_at_saturated_weights(self, seed):
        _, _, weights = draw(seed)
        rng = np.random.default_rng(seed + 100)
        fired = rng.integers(0, 2, (B, C), dtype=np.uint8)
        fired[0] = 1  # every clause fires: |v| can reach 127 * 1024
        got = np.asarray(ref.class_sum_ref(
            jnp.asarray(fired), jnp.asarray(weights)
        ))
        want = int64_class_sums(fired, weights)
        assert np.abs(want).max() <= np.iinfo(np.int32).max
        np.testing.assert_array_equal(got, want.astype(np.int32))
        # the documented envelope bound itself
        assert np.abs(want).max() <= WEIGHT_MAX * C
