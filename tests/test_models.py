"""Per-arch smoke tests (reduced configs) + numerical equivalence tests
for the recurrent/decode paths."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, TrainConfig, reduced_config
from repro.launch import specs as S
from repro.models import encdec as ed
from repro.models import transformer as tfm
from repro.models.base import init_params
from repro.train.train_step import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)
B, SEQ = 2, 32


def _batch_for(cfg, b=B, s=SEQ, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.is_encoder_decoder:
        return {
            "frontend_embeds": jnp.asarray(
                rng.standard_normal((b, s, cfg.d_model)), cfg.dtype
            ),
            "dec_tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (b, s // 2)), jnp.int32
            ),
        }
    out = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    if cfg.modality == "vision":
        out["tokens"] = out["tokens"][:, : s - 8]
        out["frontend_embeds"] = jnp.asarray(
            rng.standard_normal((b, 8, cfg.d_model)), cfg.dtype
        )
    return out


# The recurrent archs compile 40s+ train steps on CPU — slow-job only;
# their decode smoke tests (below) stay in tier-1.
_SLOW_TRAIN_SMOKE = {"xlstm-350m", "recurrentgemma-2b"}


@pytest.mark.parametrize(
    "arch",
    [
        pytest.param(a, marks=pytest.mark.slow) if a in _SLOW_TRAIN_SMOKE else a
        for a in sorted(ARCHS)
    ],
)
def test_arch_smoke_forward_and_train_step(arch):
    """One forward + one full train step on CPU: shapes + no NaNs."""
    cfg = reduced_config(ARCHS[arch])
    params = init_params(S.model_decls(cfg), KEY)
    batch = _batch_for(cfg)
    tcfg = TrainConfig(microbatches=2, total_steps=10, warmup_steps=2)
    step = make_train_step(cfg, tcfg)
    state = init_train_state(params, tcfg)
    state, metrics = jax.jit(step)(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch} loss NaN"
    assert loss > 0
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    before = jax.tree.leaves(params)[0]
    after = jax.tree.leaves(state["params"])[0]
    assert not np.array_equal(np.asarray(before), np.asarray(after))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_decode(arch):
    cfg = reduced_config(ARCHS[arch])
    params = init_params(S.model_decls(cfg), KEY)
    if cfg.is_encoder_decoder:
        fe = jnp.asarray(np.random.default_rng(0).standard_normal((B, 16, cfg.d_model)), cfg.dtype)
        enc = ed.encode(params, fe, cfg)
        cross = ed.prepare_cross_cache(params, enc, cfg)
        cache = ed.init_self_cache(B, cfg, 16)
        logits, cache = ed.encdec_decode_step(
            params, jnp.zeros((B, 1), jnp.int32), cache, cross, jnp.int32(0), cfg
        )
    else:
        cache = tfm.init_decode_cache(B, cfg, 16)
        logits, cache = tfm.decode_step(
            params, jnp.zeros((B, 1), jnp.int32), cache, jnp.int32(0), cfg
        )
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def _fp32(cfg):
    return dataclasses.replace(cfg, dtype=jnp.float32)


class TestDecodeMatchesForward:
    """The decode path (KV cache / recurrent state) must agree with the
    full-sequence forward at every position — the strongest correctness
    check for the serving stack."""

    @pytest.mark.parametrize(
        "arch",
        [
            "h2o-danube-1.8b",
            "codeqwen1.5-7b",
            pytest.param("recurrentgemma-2b", marks=pytest.mark.slow),
        ],
    )
    def test_stepwise_equals_forward(self, arch):
        cfg = _fp32(reduced_config(ARCHS[arch]))
        params = init_params(S.model_decls(cfg), KEY)
        s = 12
        toks = jnp.asarray(
            np.random.default_rng(1).integers(0, cfg.vocab_size, (B, s)), jnp.int32
        )
        hidden, _ = tfm.forward(params, toks, cfg, remat=False)
        from repro.models.layers import lm_logits

        full_logits = np.asarray(
            jax.vmap(lambda h: lm_logits(params["embed"], h, cfg))(hidden),
            np.float32,
        )  # [B, s, V]

        cache = tfm.init_decode_cache(B, cfg, s)
        step_logits = []
        for i in range(s):
            lg, cache = tfm.decode_step(
                params, toks[:, i : i + 1], cache, jnp.int32(i), cfg
            )
            step_logits.append(np.asarray(lg, np.float32))
        step_logits = np.stack(step_logits, axis=1)
        np.testing.assert_allclose(step_logits, full_logits, rtol=2e-3, atol=2e-3)

    def test_stepwise_equals_forward_xlstm_shallow(self):
        """xLSTM stepwise == forward on a shallow stack (deep stacks of
        exponential-gated recurrences amplify fp32 rounding chaotically —
        the single-block equivalences below are exact; here we bound the
        composed drift on 3 layers)."""
        cfg = dataclasses.replace(
            _fp32(reduced_config(ARCHS["xlstm-350m"])),
            n_layers=3, block_pattern=("mlstm", "slstm"),
        )
        params = init_params(S.model_decls(cfg), KEY)
        s = 10
        toks = jnp.asarray(
            np.random.default_rng(9).integers(0, cfg.vocab_size, (B, s)), jnp.int32
        )
        hidden, _ = tfm.forward(params, toks, cfg, remat=False)
        from repro.models.layers import lm_logits

        full_logits = np.asarray(
            jax.vmap(lambda h: lm_logits(params["embed"], h, cfg))(hidden),
            np.float32,
        )
        cache = tfm.init_decode_cache(B, cfg, s)
        outs = []
        for i in range(s):
            lg, cache = tfm.decode_step(
                params, toks[:, i : i + 1], cache, jnp.int32(i), cfg
            )
            outs.append(np.asarray(lg, np.float32))
        np.testing.assert_allclose(
            np.stack(outs, 1), full_logits, rtol=2e-2, atol=2e-2
        )

    def test_sliding_window_ring_buffer(self):
        """Windowed decode with a ring cache (cache_len == window) matches
        a full-cache decode beyond one wrap-around."""
        cfg = _fp32(dataclasses.replace(reduced_config(ARCHS["h2o-danube-1.8b"]),
                                        sliding_window=6))
        params = init_params(S.model_decls(cfg), KEY)
        s = 16  # > 2 windows
        toks = jnp.asarray(
            np.random.default_rng(2).integers(0, cfg.vocab_size, (B, s)), jnp.int32
        )
        ring = tfm.init_decode_cache(B, cfg, s)     # len = window = 6
        assert ring["cyc"]["0"]["k"].shape[-2] == 6
        big_cfg = dataclasses.replace(cfg, sliding_window=None)
        # full cache but explicit window mask path:
        full = tfm.init_decode_cache(B, big_cfg, s)
        out_r, out_f = [], []
        for i in range(s):
            lr, ring = tfm.decode_step(params, toks[:, i:i+1], ring, jnp.int32(i), cfg)
            out_r.append(np.asarray(lr, np.float32))
        # reference: forward with window mask
        hidden, _ = tfm.forward(params, toks, cfg, remat=False)
        from repro.models.layers import lm_logits
        ref = np.asarray(jax.vmap(lambda h: lm_logits(params["embed"], h, cfg))(hidden), np.float32)
        np.testing.assert_allclose(np.stack(out_r, 1), ref, rtol=2e-3, atol=2e-3)


class TestRecurrentEquivalence:
    def test_mlstm_chunk_sizes_agree(self):
        """Chunkwise-parallel mLSTM is chunk-size invariant (the carried
        (C, n, m) state is exact)."""
        from repro.models.ssm import mlstm_apply, mlstm_decls

        cfg = _fp32(reduced_config(ARCHS["xlstm-350m"]))
        p = init_params({"m": __import__("repro.models.ssm", fromlist=["x"]).mlstm_decls(cfg)}, KEY)["m"]
        x = jnp.asarray(
            np.random.default_rng(3).standard_normal((2, 16, cfg.d_model)) * 0.1,
            jnp.float32,
        )
        y4 = np.asarray(mlstm_apply(p, x, cfg, chunk=4))
        y8 = np.asarray(mlstm_apply(p, x, cfg, chunk=8))
        y16 = np.asarray(mlstm_apply(p, x, cfg, chunk=16))
        np.testing.assert_allclose(y4, y16, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(y8, y16, rtol=1e-4, atol=1e-4)

    def test_rglru_scan_equals_stepwise(self):
        from repro.models.rglru import (
            rglru_apply,
            rglru_decls,
            rglru_decode,
            rglru_init_state,
        )

        cfg = _fp32(reduced_config(ARCHS["recurrentgemma-2b"]))
        p = init_params({"r": rglru_decls(cfg)}, KEY)["r"]
        x = jnp.asarray(
            np.random.default_rng(4).standard_normal((2, 10, cfg.d_model)) * 0.1,
            jnp.float32,
        )
        y_full = np.asarray(rglru_apply(p, x, cfg))
        st = rglru_init_state(2, cfg)
        outs = []
        for i in range(10):
            y, st = rglru_decode(p, x[:, i : i + 1], st, cfg)
            outs.append(np.asarray(y))
        np.testing.assert_allclose(
            np.concatenate(outs, 1), y_full, rtol=1e-4, atol=1e-4
        )

    def test_mlstm_parallel_equals_decode(self):
        from repro.models.ssm import (
            mlstm_apply,
            mlstm_decode,
            mlstm_decls,
            mlstm_init_state,
        )

        cfg = _fp32(reduced_config(ARCHS["xlstm-350m"]))
        p = init_params({"m": mlstm_decls(cfg)}, KEY)["m"]
        x = jnp.asarray(
            np.random.default_rng(5).standard_normal((2, 8, cfg.d_model)) * 0.1,
            jnp.float32,
        )
        y_full = np.asarray(mlstm_apply(p, x, cfg, chunk=8))
        st = mlstm_init_state(2, cfg)
        outs = []
        for i in range(8):
            y, st = mlstm_decode(p, x[:, i : i + 1], st, cfg)
            outs.append(np.asarray(y))
        np.testing.assert_allclose(
            np.concatenate(outs, 1), y_full, rtol=1e-4, atol=1e-4
        )


class TestMoE:
    def test_moe_routes_and_balances(self):
        from repro.models.moe import moe_apply, moe_decls

        cfg = _fp32(reduced_config(ARCHS["qwen2-moe-a2.7b"]))
        p = init_params({"moe": moe_decls(cfg)}, KEY)["moe"]
        x = jnp.asarray(
            np.random.default_rng(6).standard_normal((2, 64, cfg.d_model)) * 0.5,
            jnp.float32,
        )
        y, aux = moe_apply(p, x, cfg)
        assert y.shape == x.shape
        assert np.isfinite(np.asarray(y)).all()
        # aux loss ~1 for balanced routing; must be finite and positive.
        assert 0 < float(aux) < 10

    def test_moe_capacity_drop_is_graceful(self):
        """With capacity_factor ~0, (nearly) all tokens drop -> output ~ 0
        from routed experts (shared expert still contributes)."""
        import dataclasses as dc

        from repro.models.moe import moe_apply, moe_decls

        cfg = dc.replace(
            _fp32(reduced_config(ARCHS["phi3.5-moe-42b-a6.6b"])),
            capacity_factor=0.01,
        )
        p = init_params({"moe": moe_decls(cfg)}, KEY)["moe"]
        x = jnp.asarray(
            np.random.default_rng(7).standard_normal((1, 64, cfg.d_model)),
            jnp.float32,
        )
        y, _ = moe_apply(p, x, cfg)
        assert np.isfinite(np.asarray(y)).all()


def test_mrope_text_equals_rope():
    """With all three position streams equal, M-RoPE == plain RoPE."""
    from repro.models.layers import mrope, rope

    x = jnp.asarray(np.random.default_rng(8).standard_normal((2, 6, 4, 16)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(6), (2, 6))
    pos3 = jnp.broadcast_to(pos, (3, 2, 6))
    a = np.asarray(rope(x, pos, 10_000.0))
    b = np.asarray(mrope(x, pos3, 10_000.0, (2, 3, 3)))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
