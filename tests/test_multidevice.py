"""Multi-(virtual-)device integration: the real sharded train/serve steps
running with actual data movement on an 8-device CPU mesh (subprocess —
device count must be set before jax initializes)."""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, timeout=540):
    return subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd=REPO, timeout=timeout,
        env={**os.environ, "PYTHONPATH": "src"},
    )


@pytest.mark.slow
def test_sharded_train_step_8dev():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding
from repro.configs import ARCHS, TrainConfig, reduced_config
from repro.launch import specs as S
from repro.launch.train import synthetic_lm_batch
from repro.models.base import init_params, pspec_tree
from repro.train.train_step import init_train_state, make_train_step

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = reduced_config(ARCHS["h2o-danube-1.8b"])
tcfg = TrainConfig(microbatches=2, total_steps=4, warmup_steps=1)
with mesh:
    params = init_params(S.model_decls(cfg), jax.random.PRNGKey(0))
    pspecs = pspec_tree(S.model_decls(cfg), mesh)
    params = jax.tree.map(
        lambda a, s: jax.device_put(a, NamedSharding(mesh, s)), params, pspecs,
        is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, dict),
    )
    state = init_train_state(params, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg, mesh=mesh), donate_argnums=(0,))
    losses = []
    for i in range(4):
        state, m = step(state, synthetic_lm_batch(cfg, 8, 64, i))
        losses.append(float(m["loss"]))
assert all(np.isfinite(losses)), losses
print("OK", losses)
"""
    r = _run(code)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


@pytest.mark.slow
def test_sharded_decode_8dev():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.configs import ARCHS, reduced_config
from repro.launch import specs as S
from repro.models import transformer as tfm
from repro.models.base import init_params
from repro.sharding.partition import set_profile

set_profile("serve_tp")
mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = reduced_config(ARCHS["recurrentgemma-2b"])
with mesh:
    params = init_params(S.model_decls(cfg), jax.random.PRNGKey(0))
    cache = tfm.init_decode_cache(8, cfg, 32)
    dec = jax.jit(lambda p, t, c, po: tfm.decode_step(p, t, c, po, cfg, mesh=mesh))
    toks = jnp.zeros((8, 1), jnp.int32)
    for i in range(4):
        logits, cache = dec(params, toks, cache, jnp.int32(i))
        toks = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
assert np.isfinite(np.asarray(logits, np.float32)).all()
print("OK")
"""
    r = _run(code)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


@pytest.mark.slow
def test_tm_engine_data_parallel_8dev():
    """TrainerEngine with a mesh: per-device delta sums combined by the
    shard_map psum must give a model bit-identical to the unmeshed run."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.core.cotm import CoTMConfig
from repro.core.patches import PatchSpec
from repro.train.tm_engine import TrainerEngine

spec = PatchSpec(image_x=8, image_y=8, window_x=3, window_y=3)
cfg = CoTMConfig(n_clauses=16, n_classes=3, patch=spec, T=15, s=3.0)
rng = np.random.default_rng(0)
x = (rng.random((64, 8, 8)) > 0.5).astype(np.uint8)
y = rng.integers(0, 3, 64).astype(np.int32)
key = jax.random.PRNGKey(2)

plain = TrainerEngine(cfg, batch_size=16)
ds = plain.prepare(x, y, booleanize_method="none")
m1 = plain.init_model(key)
_, m1, _, _ = plain.fit(key, m1, ds, epochs=2)

mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
meshed = TrainerEngine(cfg, batch_size=16, mesh=mesh)
ds2 = meshed.prepare(x, y, booleanize_method="none")
m2 = meshed.init_model(key)
_, m2, _, _ = meshed.fit(key, m2, ds2, epochs=2)

np.testing.assert_array_equal(np.asarray(m1.ta_state), np.asarray(m2.ta_state))
np.testing.assert_array_equal(np.asarray(m1.weights), np.asarray(m2.weights))
print("OK")
"""
    r = _run(code)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


@pytest.mark.slow
def test_grad_compression_train_step_runs():
    """EF-int8 gradient compression wired into the real train step."""
    code = """
import sys; sys.path.insert(0, "src")
import jax, numpy as np
from repro.configs import ARCHS, TrainConfig, reduced_config
from repro.launch import specs as S
from repro.launch.train import synthetic_lm_batch
from repro.models.base import init_params
from repro.train.train_step import init_train_state, make_train_step

cfg = reduced_config(ARCHS["h2o-danube-1.8b"])
tcfg = TrainConfig(microbatches=1, total_steps=4, warmup_steps=1, grad_compression=True)
params = init_params(S.model_decls(cfg), jax.random.PRNGKey(0))
state = init_train_state(params, tcfg)
step = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
for i in range(3):
    state, m = step(state, synthetic_lm_batch(cfg, 4, 32, i))
assert np.isfinite(float(m["loss"]))
assert "residual_norm" in m and np.isfinite(float(m["residual_norm"]))
print("OK", float(m["loss"]), float(m["residual_norm"]))
"""
    r = _run(code)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
