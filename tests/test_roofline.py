"""Roofline machinery: HLO collective parsing + analytic FLOPs validation
against an unrolled lowering (where XLA's cost analysis is exact)."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduced_config
from repro.configs.base import ShapeConfig
from repro.roofline.analysis import parse_collective_bytes, roofline_terms
from repro.roofline.flops import flops_estimate

SAMPLE_HLO = """
HloModule test
fused_computation {
  %p0 = f32[128,256]{1,0} parameter(0)
}
ENTRY main {
  %arg0 = bf16[64,1024]{1,0} parameter(0)
  %ag = bf16[1024,1024]{1,0} all-gather(%arg0), dimensions={0}
  %ar = f32[512]{0} all-reduce(%c), to_apply=%add
  %c = f32[512]{0} constant(0)
  %rs = f32[32]{0} reduce-scatter(%ar), dimensions={0}
  %cp = bf16[64,1024]{1,0} collective-permute(%arg0), source_target_pairs={{0,1}}
}
"""


class TestCollectiveParser:
    def test_counts_and_bytes(self):
        out = parse_collective_bytes(SAMPLE_HLO)
        assert out["all-gather"]["count"] == 1
        # operand of all-gather is arg0: 64*1024*2 bytes
        assert out["all-gather"]["operand_bytes"] == 64 * 1024 * 2
        assert out["all-reduce"]["count"] == 1
        assert out["all-reduce"]["operand_bytes"] == 512 * 4
        # wire factor 2x for all-reduce
        assert out["all-reduce"]["wire_bytes"] == 2 * 512 * 4
        assert out["reduce-scatter"]["count"] == 1
        assert out["collective-permute"]["count"] == 1
        assert out["all-to-all"]["count"] == 0

    def test_roofline_terms_dominance(self):
        cost = {"flops": 197e12 * 0.5, "bytes accessed": 819e9 * 0.1}
        terms = roofline_terms(cost, SAMPLE_HLO, chips=256)
        assert terms["dominant"] == "compute"
        assert terms["compute_s"] == pytest.approx(0.5)
        assert terms["roofline_fraction"] == pytest.approx(1.0)


class TestAnalyticFlops:
    """flops_estimate must match XLA's cost analysis on an UNROLLED tiny
    lowering (no scans: trip-1 loops inline, attention single-chunk)."""

    def _hlo_flops(self, cfg, b, s):
        from repro.launch import specs as S
        from repro.models import transformer as tfm
        from repro.models.base import abstract_params

        params = abstract_params(S.model_decls(cfg))
        toks = jax.ShapeDtypeStruct((b, s), jnp.int32)

        def fwd(p, t):
            # forward + full-vocab head, no remat, single attention chunk
            h, _ = tfm.forward(p, t, cfg, remat=False)
            from repro.models.layers import lm_logits

            return lm_logits(p["embed"], h, cfg)

        lowered = jax.jit(fwd).lower(params, toks)
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        return float(cost["flops"])

    @pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "codeqwen1.5-7b"])
    def test_dense_forward_flops_within_15pct(self, arch):
        cfg = reduced_config(ARCHS[arch])
        # one unrolled cycle: n_layers == 1, no window (tiny seq), fp32 off
        cfg = dataclasses.replace(
            cfg, n_layers=1, sliding_window=None, vocab_size=1024,
        )
        b, s = 2, 256
        hlo = self._hlo_flops(cfg, b, s)
        shape = ShapeConfig("t", s, b, "train")
        analytic = flops_estimate(cfg, shape) / 3.0  # forward only
        assert hlo > 0
        ratio = analytic / hlo
        assert 0.85 < ratio < 1.15, f"analytic/HLO = {ratio} ({analytic} vs {hlo})"

    def test_moe_flops_scaling(self):
        """MoE flops scale with active (top-k) experts, not total."""
        cfg = ARCHS["qwen2-moe-a2.7b"]
        shape = ShapeConfig("t", 1024, 8, "train")
        f_moe = flops_estimate(cfg, shape)
        dense_equiv = dataclasses.replace(
            cfg, n_experts=0, n_experts_per_token=0, n_shared_experts=0,
            d_ff=cfg.d_ff * cfg.n_experts,       # all experts dense
        )
        f_dense = flops_estimate(dense_equiv, shape)
        assert f_moe < f_dense / 4

    def test_window_reduces_decode_flops(self):
        """Windowed archs decode against a ring cache of window length —
        executed decode flops must drop vs a full cache.  (Prefill executed
        flops do NOT drop: the chunked kernel computes-then-masks; the
        block-skip optimization is tracked in §Perf.)"""
        cfg = ARCHS["h2o-danube-1.8b"]
        full = dataclasses.replace(cfg, sliding_window=None)
        shape = ShapeConfig("d", 32768, 128, "decode")
        assert flops_estimate(cfg, shape) < flops_estimate(full, shape) / 2

    def test_decode_flops_tiny_vs_prefill(self):
        cfg = ARCHS["mistral-nemo-12b"]
        d = flops_estimate(cfg, ShapeConfig("d", 32768, 128, "decode"))
        p = flops_estimate(cfg, ShapeConfig("p", 32768, 32, "prefill"))
        assert d < p / 100
