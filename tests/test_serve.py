"""Serving subsystem: path registry, ServableModel freeze-once contract,
batch bucketing, multi-dataset engine."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cotm import CoTMConfig, infer, init_boundary_model
from repro.core.patches import PatchSpec
from repro.serve import (
    ServingEngine,
    available_paths,
    freeze,
    get_path,
    register_path,
    run_path,
)

# Edge geometry: B/P/C deliberately not multiples of the kernel block
# sizes (block_b=8, block_c=128, block_p=64): P = 7*7 = 49, C = 37.
EDGE_SPEC = PatchSpec(image_x=11, image_y=11, window_x=5, window_y=5)
EDGE_CFG = CoTMConfig(n_clauses=37, n_classes=10, patch=EDGE_SPEC)
PAPER_CFG = CoTMConfig(n_clauses=64)   # paper geometry, smaller clause pool


def _model(cfg, seed=0):
    return init_boundary_model(jax.random.PRNGKey(seed), cfg)


def _images(cfg, b, seed=0):
    key = jax.random.PRNGKey(seed + 100)
    side = cfg.patch.image_y
    return (jax.random.uniform(key, (b, side, side)) > 0.6).astype(jnp.uint8)


class TestPathRegistry:
    def test_builtin_paths_registered(self):
        assert {"dense", "bitpacked", "matmul", "kernel", "fused"} <= set(
            available_paths()
        )

    def test_unknown_path_raises(self):
        with pytest.raises(KeyError, match="registered"):
            get_path("no-such-path")

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            register_path("dense", "dense")(lambda *a: None)

    @pytest.mark.parametrize("cfg", [PAPER_CFG, EDGE_CFG], ids=["paper", "edge"])
    @pytest.mark.parametrize("batch", [1, 3])
    def test_all_paths_identical(self, cfg, batch):
        """Every registered path gives identical predictions and class sums
        (the multi-path equivalence contract, incl. padding-edge shapes)."""
        model = _model(cfg, seed=batch)
        imgs = _images(cfg, batch, seed=batch)
        want_p = want_v = None
        for name in available_paths():
            c = dataclasses.replace(cfg, eval_path=name)
            p, v = infer(model, imgs, c)
            p, v = np.asarray(p), np.asarray(v)
            if want_v is None:
                want_p, want_v = p, v
            np.testing.assert_array_equal(want_v, v, err_msg=f"path {name}")
            np.testing.assert_array_equal(want_p, p, err_msg=f"path {name}")

    def test_run_path_matches_infer(self):
        from repro.core.patches import extract_patch_features, make_literals, pack_bits

        model = _model(EDGE_CFG)
        imgs = _images(EDGE_CFG, 4)
        sm = freeze(model, EDGE_CFG)
        lits = make_literals(extract_patch_features(imgs, EDGE_CFG.patch))
        want = np.asarray(infer(model, imgs, EDGE_CFG)[1])
        for name in available_paths():
            path = get_path(name)
            arg = pack_bits(lits) if path.input_form == "packed" else lits
            v = np.asarray(run_path(path, sm, arg))
            np.testing.assert_array_equal(want, v, err_msg=f"path {name}")


class TestServableModel:
    def test_freeze_fields(self):
        model = _model(PAPER_CFG)
        sm = freeze(model, PAPER_CFG)
        np.testing.assert_array_equal(
            np.asarray(sm.include), np.asarray(model.include)
        )
        assert sm.include_packed.dtype == jnp.uint32
        assert sm.weights.dtype == jnp.int8
        assert sm.nonempty.shape == (PAPER_CFG.n_clauses,)
        assert sm.config is PAPER_CFG

    def test_freeze_clamps_weights(self):
        model = _model(PAPER_CFG)
        model.weights = model.weights.at[0, 0].set(300)
        sm = freeze(model, PAPER_CFG)
        assert int(sm.weights[0, 0]) == 127

    def test_servable_is_pytree(self):
        sm = freeze(_model(PAPER_CFG), PAPER_CFG)
        leaves = jax.tree.leaves(sm)
        assert len(leaves) == 4          # config is static metadata
        sm2 = jax.tree.map(lambda x: x, sm)
        assert sm2.config is PAPER_CFG


class TestEngine:
    def _engine(self, cfg=EDGE_CFG, path=None, max_batch=16, seed=0):
        engine = ServingEngine(max_batch=max_batch)
        model = _model(cfg, seed)
        engine.register(
            "glyphs", model, cfg, booleanize_method="none", path=path
        )
        return engine, model

    def test_bucket_for(self):
        engine = ServingEngine(max_batch=16)
        assert [engine.bucket_for(n) for n in (1, 2, 3, 5, 8, 9, 16, 40)] == [
            1, 2, 4, 8, 8, 16, 16, 16
        ]

    def test_padded_bucket_matches_direct_infer(self):
        engine, model = self._engine()
        imgs = _images(EDGE_CFG, 5)      # bucket 8 -> 3 padding rows
        res = engine.classify("glyphs", np.asarray(imgs))
        assert res.bucket == 8
        want_p, want_v = infer(model, imgs, EDGE_CFG)
        np.testing.assert_array_equal(res.predictions, np.asarray(want_p))
        np.testing.assert_array_equal(res.class_sums, np.asarray(want_v))

    def test_oversized_request_is_sliced(self):
        engine, model = self._engine(max_batch=8)
        imgs = _images(EDGE_CFG, 19)     # 8 + 8 + 3
        res = engine.classify("glyphs", np.asarray(imgs))
        assert res.predictions.shape == (19,)
        want_p, _ = infer(model, imgs, EDGE_CFG)
        np.testing.assert_array_equal(res.predictions, np.asarray(want_p))

    def test_bounded_recompiles(self):
        from repro.serve import engine as engine_mod
        from tools.recompile_guard import no_recompiles

        engine, _ = self._engine()
        sizes = [1, 2, 3, 3, 5, 7, 8, 9, 13, 16, 2, 5]
        buckets = sorted({1 << (n - 1).bit_length() for n in sizes})
        for n in buckets:    # warm every pow2 bucket this traffic can hit
            engine.classify("glyphs", np.asarray(_images(EDGE_CFG, n, seed=n)))
        # every pow2 bucket is now compiled; the mixed-size traffic below
        # must hit those caches only (tools/recompile_guard)
        with no_recompiles(engine_mod.classify_step):
            for n in sizes:
                engine.classify(
                    "glyphs", np.asarray(_images(EDGE_CFG, n, seed=n))
                )
        st = engine.stats("glyphs")
        assert st.requests == 12 + len(set(st.compiled_buckets))
        assert st.images >= 74
        # mixed sizes, but only the pow2 buckets ever compiled.
        assert set(st.compiled_buckets) <= {1, 2, 4, 8, 16}
        assert st.classifications_per_s > 0

    def test_freeze_happens_once_per_model(self, monkeypatch):
        """The pack-once contract: include packing runs at registration,
        never per classify call; the cached ServableModel arrays are
        reused identically across engine calls."""
        import repro.serve.servable as servable_mod

        calls = {"n": 0}
        real = servable_mod.pack_bits

        def counting(*a, **kw):
            calls["n"] += 1
            return real(*a, **kw)

        monkeypatch.setattr(servable_mod, "pack_bits", counting)
        engine, _ = self._engine()
        assert calls["n"] == 1           # one freeze at register time
        sm0 = engine.servable("glyphs")
        for n in (3, 5, 8, 5):
            engine.classify("glyphs", np.asarray(_images(EDGE_CFG, n, seed=n)))
        assert calls["n"] == 1           # no re-freeze on the serve path
        sm1 = engine.servable("glyphs")
        assert sm1 is sm0
        assert sm1.include_packed is sm0.include_packed

    def test_multi_dataset_registry(self):
        engine = ServingEngine(max_batch=8)
        for i, name in enumerate(["mnist", "fmnist", "kmnist"]):
            engine.register(
                name, _model(EDGE_CFG, seed=i), EDGE_CFG, booleanize_method="none"
            )
        assert engine.models() == ("fmnist", "kmnist", "mnist")
        imgs = np.asarray(_images(EDGE_CFG, 4))
        preds = {n: engine.classify(n, imgs).predictions for n in engine.models()}
        # different models -> independent stats
        assert all(engine.stats(n).requests == 1 for n in engine.models())
        assert preds["mnist"].shape == (4,)

    def test_empty_request_rejected(self):
        engine, _ = self._engine()
        with pytest.raises(ValueError, match="empty request"):
            engine.classify("glyphs", np.zeros((0, 11, 11), np.uint8))
        assert engine.stats("glyphs").requests == 0   # stats untouched

    def test_warmup_compiles_without_request_stats(self):
        engine, _ = self._engine(max_batch=8)
        compiled = engine.warmup("glyphs")
        assert compiled == (1, 2, 4, 8)
        st = engine.stats("glyphs")
        assert set(st.compiled_buckets) == {1, 2, 4, 8}
        assert st.requests == 0 and st.total_latency_s == 0.0
        assert st.bucket_hits == {}
        # idempotent: already-compiled buckets are skipped
        assert engine.warmup("glyphs") == ()
        with pytest.raises(ValueError, match="max_batch"):
            engine.warmup("glyphs", buckets=[16])

    def test_warmup_normalizes_nonpow2_buckets(self):
        engine, _ = self._engine(max_batch=16)
        assert engine.warmup("glyphs", buckets=[10]) == (16,)
        st = engine.stats("glyphs")
        assert st.compiled_buckets == (16,) and st.bucket_hits == {}
        # converged: the normalized bucket is now compiled
        assert engine.warmup("glyphs", buckets=[10]) == ()

    def test_unknown_eval_path_fails_at_register(self):
        engine = ServingEngine()
        with pytest.raises(KeyError):
            engine.register(
                "x", _model(EDGE_CFG), EDGE_CFG, path="not-a-path"
            )

    def test_load_checkpoint_roundtrip(self, tmp_path):
        from repro.checkpoint.checkpointer import save_pytree

        model = _model(EDGE_CFG, seed=3)
        save_pytree(model, str(tmp_path), step=1)
        engine = ServingEngine(max_batch=8)
        engine.load_checkpoint(
            "glyphs", str(tmp_path), EDGE_CFG, booleanize_method="none"
        )
        imgs = _images(EDGE_CFG, 4, seed=9)
        res = engine.classify("glyphs", np.asarray(imgs))
        want_p, _ = infer(model, imgs, EDGE_CFG)
        np.testing.assert_array_equal(res.predictions, np.asarray(want_p))

    def test_preprocessed_literals_accepted_when_well_formed(self):
        """preprocessed=True with literals in the path's input form matches
        the raw-image ingress exactly (dense and packed paths)."""
        from repro.data.pipeline import preprocess_for_serving

        for path in ("matmul", "bitpacked"):
            engine, model = self._engine(path=path)
            imgs = np.asarray(_images(EDGE_CFG, 4))
            want = engine.classify("glyphs", imgs)
            lits = preprocess_for_serving(
                imgs, EDGE_CFG.patch, method="none",
                packed=get_path(path).input_form == "packed",
            )
            got = engine.classify("glyphs", lits, preprocessed=True)
            np.testing.assert_array_equal(want.class_sums, got.class_sums)

    def test_preprocessed_wrong_form_rejected(self):
        """Dense literals into a packed path (and vice versa) used to
        silently produce garbage predictions; now they raise."""
        from repro.data.pipeline import preprocess_for_serving

        imgs = np.asarray(_images(EDGE_CFG, 3))
        dense = preprocess_for_serving(imgs, EDGE_CFG.patch, method="none", packed=False)
        packed = preprocess_for_serving(imgs, EDGE_CFG.patch, method="none", packed=True)

        engine_packed, _ = self._engine(path="bitpacked")
        with pytest.raises(ValueError, match="packed uint32"):
            engine_packed.classify("glyphs", dense, preprocessed=True)

        engine_dense, _ = self._engine(path="matmul")
        with pytest.raises(ValueError, match="dense uint8"):
            engine_dense.classify("glyphs", packed, preprocessed=True)

    def test_preprocessed_wrong_shape_or_dtype_rejected(self):
        engine, _ = self._engine(path="matmul")
        spec = EDGE_CFG.patch
        good = np.zeros((2, spec.n_patches, spec.n_literals), np.uint8)
        # wrong trailing dim
        with pytest.raises(ValueError, match="preprocessed literals"):
            engine.classify("glyphs", good[:, :, :-1], preprocessed=True)
        # wrong rank (raw images passed with preprocessed=True)
        with pytest.raises(ValueError, match="preprocessed literals"):
            engine.classify(
                "glyphs", np.zeros((2, 11, 11), np.uint8), preprocessed=True
            )
        # wrong dtype
        with pytest.raises(ValueError, match="preprocessed literals"):
            engine.classify(
                "glyphs", good.astype(np.int32), preprocessed=True
            )
        # stats untouched by rejected requests
        assert engine.stats("glyphs").requests == 0

    def test_booleanize_method_applied(self):
        """Raw uint8 images with a 'threshold' entry match manually
        booleanized inputs through a 'none' entry."""
        from repro.data import booleanize_split

        cfg = EDGE_CFG
        engine = ServingEngine(max_batch=8)
        model = _model(cfg)
        engine.register("raw", model, cfg, booleanize_method="threshold")
        engine.register("pre", model, cfg, booleanize_method="none")
        rng = np.random.default_rng(2)
        raw = rng.integers(0, 256, (4, 11, 11)).astype(np.uint8)
        r1 = engine.classify("raw", raw)
        r2 = engine.classify("pre", booleanize_split(raw, "threshold"))
        np.testing.assert_array_equal(r1.class_sums, r2.class_sums)


class TestCotmDispatch:
    def test_cotm_has_no_eval_path_chain(self):
        """core/cotm.py must resolve paths via the registry, not if/elif."""
        import inspect

        import repro.core.cotm as cotm

        src = inspect.getsource(cotm)
        assert 'eval_path == "' not in src and "eval_path == '" not in src
        assert "get_path" in src

    def test_infer_rejects_unknown_path(self):
        cfg = dataclasses.replace(EDGE_CFG, eval_path="bogus")
        with pytest.raises(KeyError):
            infer(_model(EDGE_CFG), _images(EDGE_CFG, 1), cfg)

    def test_make_tm_serve_fn(self):
        """The serve-step building block matches infer()."""
        from repro.core.patches import extract_patch_features, make_literals, pack_bits
        from repro.train.serve_step import make_tm_serve_fn

        model = _model(EDGE_CFG)
        sm = freeze(model, EDGE_CFG)
        classify = make_tm_serve_fn(sm, path="bitpacked")
        imgs = _images(EDGE_CFG, 3)
        lp = pack_bits(make_literals(extract_patch_features(imgs, EDGE_CFG.patch)))
        p, v = classify(lp)
        want_p, want_v = infer(model, imgs, EDGE_CFG)
        np.testing.assert_array_equal(np.asarray(v), np.asarray(want_v))
        np.testing.assert_array_equal(np.asarray(p), np.asarray(want_p))
