"""Sharded serving across a device mesh (serve/mesh.py).

The acceptance contract: predictions AND class sums from a meshed engine
are bit-identical to the single-device engine — for raw, host-ingress and
preprocessed request forms, replicated and clause-sharded placements, and
under ``ServingService`` concurrent load.

Single-device-mesh cases run everywhere (tier-1).  Multi-device cases
need virtual CPU devices: they skip unless the process was started with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the dedicated CI
multidevice job does exactly that); ``test_sharded_serve_8dev_subprocess``
additionally covers the 1/2/8-device sweep from a plain tier-1 run via a
subprocess, marked slow.
"""

import asyncio
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core.cotm import CoTMConfig, init_boundary_model
from repro.core.patches import PatchSpec
from repro.serve import (
    ServeMesh,
    ServiceConfig,
    ServingEngine,
    ServingService,
    make_serve_mesh,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# n_clauses divisible by 2/4/8 so every clause-sharded mesh splits evenly.
SPEC = PatchSpec(image_x=11, image_y=11, window_x=5, window_y=5)
CFG = CoTMConfig(n_clauses=40, n_classes=10, patch=SPEC)


def _model(seed=0):
    return init_boundary_model(jax.random.PRNGKey(seed), CFG)


def _images(n, seed=0):
    rng = np.random.default_rng(seed)
    side = CFG.patch.image_y
    return rng.integers(0, 256, (n, side, side)).astype(np.uint8)


def _reference(max_batch=32):
    engine = ServingEngine(max_batch=max_batch)
    engine.register("m", _model(), CFG)
    return engine


def _meshed(data, model=1, *, shard_clauses=None, max_batch=32):
    smesh = make_serve_mesh(data, model, shard_clauses=shard_clauses)
    engine = ServingEngine(max_batch=max_batch, mesh=smesh)
    engine.register("m", _model(), CFG)
    return engine, smesh


def _need_devices(n):
    if jax.device_count() < n:
        pytest.skip(
            f"needs {n} devices; run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=8"
        )


def _assert_identical(meshed: ServingEngine, ref: ServingEngine, n=13, seed=3):
    """All three request forms bit-identical between two engines."""
    imgs = _images(n, seed=seed)
    want = ref.classify("m", imgs)
    for kw in (dict(), dict(ingress="host")):
        got = meshed.classify("m", imgs, **kw)
        np.testing.assert_array_equal(want.predictions, got.predictions)
        np.testing.assert_array_equal(want.class_sums, got.class_sums)
    lits = meshed.preprocess("m", imgs)
    got = meshed.classify("m", lits, preprocessed=True)
    np.testing.assert_array_equal(want.predictions, got.predictions)
    np.testing.assert_array_equal(want.class_sums, got.class_sums)


class TestServeMeshPlacement:
    def test_requires_data_axis(self):
        from jax.sharding import Mesh

        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("a", "b"))
        with pytest.raises(ValueError, match='"data" axis'):
            ServeMesh(mesh)

    def test_clause_sharding_requires_model_axis(self):
        from jax.sharding import Mesh

        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        with pytest.raises(ValueError, match='"model" axis'):
            ServeMesh(mesh, shard_clauses=True)

    def test_clause_count_must_divide(self):
        _need_devices(2)
        smesh = make_serve_mesh(1, 2, shard_clauses=True)
        cfg = CoTMConfig(n_clauses=7, n_classes=3, patch=SPEC)  # 7 % 2 != 0
        with pytest.raises(ValueError, match="does not divide"):
            ServingEngine(max_batch=8, mesh=smesh).register(
                "m", init_boundary_model(jax.random.PRNGKey(0), cfg), cfg
            )

    def test_data_axis_must_be_pow2_and_fit_max_batch(self):
        from jax.sharding import Mesh

        smesh = make_serve_mesh(1, 1)
        ServingEngine(max_batch=1, mesh=smesh)  # 1 divides everything
        _need_devices(3)
        mesh3 = Mesh(np.array(jax.devices()[:3]).reshape(3, 1), ("data", "model"))
        with pytest.raises(ValueError, match="power of two"):
            ServingEngine(max_batch=8, mesh=ServeMesh(mesh3))
        with pytest.raises(ValueError, match="exceeds max_batch"):
            ServingEngine(max_batch=1, mesh=make_serve_mesh(2, 1))

    def test_bucket_clamped_to_data_shards(self):
        engine, smesh = _meshed(1)
        assert engine.bucket_for(1) == 1
        assert engine.bucket_for(3) == 4
        _need_devices(4)
        engine, smesh = _meshed(4)
        assert engine.data_shards == 4
        assert engine.bucket_for(1) == 4     # smallest shardable bucket
        assert engine.bucket_for(3) == 4
        assert engine.bucket_for(5) == 8

    def test_batch_placed_across_all_devices(self):
        """The dispatched buffer's rows really land on every mesh device
        (the 'batch work placed across all devices' acceptance check)."""
        _need_devices(8)
        engine, smesh = _meshed(8)
        x = smesh.place_batch(_images(16))
        devices_used = {s.device for s in x.addressable_shards}
        assert len(devices_used) == 8
        assert all(s.data.shape[0] == 2 for s in x.addressable_shards)

    def test_stats_carry_mesh_geometry(self):
        engine, _ = _meshed(1)
        engine.classify("m", _images(5))
        st = engine.stats("m")
        assert st.devices == 1 and st.data_shards == 1
        d = st.as_dict()
        assert d["devices"] == 1
        assert d["per_device_bucket_hits"] == {8: 1}

    def test_per_device_bucket_accounting(self):
        _need_devices(8)
        engine, _ = _meshed(8, max_batch=64)
        engine.classify("m", _images(16))
        engine.classify("m", _images(3))     # bucket 4 -> clamped to 8
        st = engine.stats("m")
        assert st.devices == 8
        assert st.bucket_hits == {16: 1, 8: 1}
        assert st.per_device_bucket_hits == {2: 1, 1: 1}


class TestShardedBitIdentity:
    """Predictions/class sums identical across device counts and forms."""

    def test_single_device_mesh_replicated(self):
        engine, _ = _meshed(1)
        _assert_identical(engine, _reference())

    def test_single_device_mesh_clause_sharded(self):
        # n_model == 1 still exercises the full shard_map + psum path.
        engine, _ = _meshed(1, 1, shard_clauses=True)
        _assert_identical(engine, _reference())

    def test_two_device_data_parallel(self):
        _need_devices(2)
        engine, _ = _meshed(2)
        _assert_identical(engine, _reference())

    def test_eight_device_data_parallel(self):
        _need_devices(8)
        engine, _ = _meshed(8)
        _assert_identical(engine, _reference())

    def test_clause_sharded_four_way(self):
        _need_devices(4)
        engine, _ = _meshed(1, 4)
        _assert_identical(engine, _reference())

    def test_data_and_clause_sharded(self):
        _need_devices(8)
        engine, _ = _meshed(2, 4)
        _assert_identical(engine, _reference())

    def test_warmup_compiles_both_forms_meshed(self):
        engine, _ = _meshed(1)
        compiled = engine.warmup("m", buckets=[2, 8])
        assert compiled == (2, 8)
        st = engine.stats("m")
        assert st.requests == 0              # warmup never pollutes stats

    @pytest.mark.parametrize("path", ["dense", "bitpacked", "matmul"])
    def test_clause_sharded_across_paths(self, path):
        """The shard_map program wraps every registered eval path."""
        ref = ServingEngine(max_batch=32)
        ref.register("m", _model(), CFG, path=path)
        smesh = make_serve_mesh(1, 1, shard_clauses=True)
        eng = ServingEngine(max_batch=32, mesh=smesh)
        eng.register("m", _model(), CFG, path=path)
        imgs = _images(9, seed=7)
        want = ref.classify("m", imgs)
        got = eng.classify("m", imgs)
        np.testing.assert_array_equal(want.predictions, got.predictions)
        np.testing.assert_array_equal(want.class_sums, got.class_sums)

    @pytest.mark.parametrize("path", ["sparse", "fused_sparse", "matmul_sparse"])
    @pytest.mark.parametrize(
        "geometry", [(1, 1, False), (2, 1, False), (1, 2, True), (2, 2, True)],
        ids=["replicated", "data2", "clause2", "data2xclause2"],
    )
    def test_sparse_paths_on_mesh(self, path, geometry):
        """Sparse paths stay bit-identical under ServeMesh sharding:
        replicated placement serves the real sparse kernels (the analysis
        replicates with the model), clause-sharded placement drops the
        analysis and resolves to the dense fallback inside the shard_map
        — either way results equal the unmeshed dense engine."""
        data, model_ax, shard = geometry
        _need_devices(data * model_ax)
        ref = ServingEngine(max_batch=32)
        ref.register("m", _model(), CFG, path="dense")
        eng2 = ServingEngine(max_batch=32, mesh=make_serve_mesh(
            data, model_ax, shard_clauses=shard))
        eng2.register("m", _model(), CFG, path=path)
        assert (eng2.servable("m").sparsity is None) == shard
        for n in (1, 5, 9):
            imgs = _images(n, seed=n)
            want = ref.classify("m", imgs)
            for kw in ({"ingress": "device"}, {"ingress": "host"}):
                got = eng2.classify("m", imgs, **kw)
                np.testing.assert_array_equal(want.predictions, got.predictions)
                np.testing.assert_array_equal(want.class_sums, got.class_sums)


class TestServiceOnMesh:
    def _run_service_load(self, engine, ref, max_coalesce=None):
        service = ServingService(
            engine,
            ServiceConfig(max_delay_us=500.0, max_coalesce=max_coalesce),
        )

        async def run():
            await service.start()
            sizes = [1, 3, 7, 2, 5, 1, 4, 6, 2, 1]
            batches = [_images(n, seed=10 + i) for i, n in enumerate(sizes)]

            async def one(b, i):
                await asyncio.sleep(0.0005 * (i % 3))
                return await service.submit("m", b)

            results = await asyncio.gather(
                *(one(b, i) for i, b in enumerate(batches))
            )
            await service.stop(drain=True)
            return batches, results

        batches, results = asyncio.run(run())
        for b, r in zip(batches, results):
            want = ref.classify("m", b)
            np.testing.assert_array_equal(r.predictions, want.predictions)
            np.testing.assert_array_equal(r.class_sums, want.class_sums)

    def test_service_bit_identical_single_device_mesh(self):
        engine, _ = _meshed(1)
        self._run_service_load(engine, _reference())

    def test_service_bit_identical_multidevice(self):
        _need_devices(8)
        engine, _ = _meshed(8)
        self._run_service_load(engine, _reference())

    def test_service_bit_identical_clause_sharded(self):
        _need_devices(4)
        engine, _ = _meshed(2, 2)
        self._run_service_load(engine, _reference())

    def test_max_coalesce_scales_with_data_shards(self):
        _need_devices(4)
        engine, _ = _meshed(4)
        service = ServingService(engine, ServiceConfig(max_coalesce=8))
        assert service._sched.max_coalesce == 32   # 8 images per shard
        plain = ServingService(_reference(), ServiceConfig(max_coalesce=8))
        assert plain._sched.max_coalesce == 8

    def test_max_coalesce_scaling_clamped_to_max_batch(self):
        """The scaled window never exceeds the largest bucket: one
        microbatch must stay one dispatch, not a chain of max_batch
        slices."""
        _need_devices(8)
        engine, _ = _meshed(8, max_batch=32)
        service = ServingService(engine, ServiceConfig(max_coalesce=8))
        assert service._sched.max_coalesce == 32   # min(64, max_batch)
        # unmeshed legacy behavior: an explicit oversized window survives
        big = ServingService(
            _reference(max_batch=16), ServiceConfig(max_coalesce=64)
        )
        assert big._sched.max_coalesce == 64


@pytest.mark.slow
def test_sharded_serve_8dev_subprocess():
    """The full 1/2/8-device bit-identity sweep from a plain run: the
    device count must be set before jax initializes, so it runs in a
    subprocess (covers tier-1 environments with a single device)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, numpy as np
from repro.core.cotm import CoTMConfig, init_boundary_model
from repro.core.patches import PatchSpec
from repro.serve import ServingEngine, make_serve_mesh

spec = PatchSpec(image_x=11, image_y=11, window_x=5, window_y=5)
cfg = CoTMConfig(n_clauses=40, n_classes=10, patch=spec)
model = init_boundary_model(jax.random.PRNGKey(0), cfg)
imgs = np.random.default_rng(0).integers(0, 256, (13, 11, 11)).astype(np.uint8)

ref = ServingEngine(max_batch=32)
ref.register("m", model, cfg)
want = ref.classify("m", imgs)

for data, mdl, sc in ((1, 1, False), (2, 1, False), (8, 1, False),
                      (1, 4, True), (2, 4, True)):
    eng = ServingEngine(
        max_batch=32, mesh=make_serve_mesh(data, mdl, shard_clauses=sc)
    )
    eng.register("m", model, cfg)
    for kw in ({}, {"ingress": "host"}):
        got = eng.classify("m", imgs, **kw)
        np.testing.assert_array_equal(want.predictions, got.predictions)
        np.testing.assert_array_equal(want.class_sums, got.class_sums)
    lits = eng.preprocess("m", imgs)
    got = eng.classify("m", lits, preprocessed=True)
    np.testing.assert_array_equal(want.class_sums, got.class_sums)
print("OK")
"""
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd=REPO, timeout=540, env={**os.environ, "PYTHONPATH": "src"},
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
