"""Async serving service: microbatch scheduler policy (fake clock),
asyncio service lifecycle, bit-identical-to-engine results under
concurrent load, backpressure, round-robin fairness, graceful drain."""

import asyncio

import jax
import numpy as np
import pytest

from repro.core.cotm import CoTMConfig, init_boundary_model
from repro.core.patches import PatchSpec
from repro.serve import (
    MicrobatchScheduler,
    PendingRequest,
    QueueFull,
    SchedulerConfig,
    ServiceConfig,
    ServiceOverloaded,
    ServiceStopped,
    ServingEngine,
    ServingService,
)

EDGE_SPEC = PatchSpec(image_x=11, image_y=11, window_x=5, window_y=5)
EDGE_CFG = CoTMConfig(n_clauses=37, n_classes=10, patch=EDGE_SPEC)


def _model(cfg=EDGE_CFG, seed=0):
    return init_boundary_model(jax.random.PRNGKey(seed), cfg)


def _images(n, seed=0):
    key = jax.random.PRNGKey(seed + 100)
    side = EDGE_CFG.patch.image_y
    return np.asarray(
        (jax.random.uniform(key, (n, side, side)) > 0.6)
    ).astype(np.uint8)


def _req(model="m", n=1, t=0.0):
    return PendingRequest(
        model=model, literals=np.zeros((n, 1), np.uint8), n=n, enqueue_t=t
    )


class TestSchedulerPolicy:
    """Pure state-machine tests: all time passed in, no event loop."""

    def test_config_validation(self):
        with pytest.raises(ValueError, match="max_delay_us"):
            SchedulerConfig(max_delay_us=-1)
        with pytest.raises(ValueError, match="high_water"):
            SchedulerConfig(high_water=0)
        with pytest.raises(ValueError, match="max_coalesce"):
            MicrobatchScheduler(max_coalesce=0)

    def test_admission_high_water(self):
        s = MicrobatchScheduler(
            SchedulerConfig(high_water=8), max_coalesce=16
        )
        s.submit(_req(n=5))
        s.submit(_req(n=3))          # exactly at high water: admitted
        assert s.depth("m") == 8
        with pytest.raises(QueueFull) as e:
            s.submit(_req(n=1))
        assert e.value.depth == 8 and e.value.high_water == 8

    def test_oversized_request_admitted_when_queue_empty(self):
        """A single request above high_water must still be servable (the
        engine slices it); it is only rejected behind existing work."""
        s = MicrobatchScheduler(SchedulerConfig(high_water=4), max_coalesce=8)
        s.submit(_req(n=100))        # empty queue: admitted
        assert s.depth("m") == 100
        with pytest.raises(QueueFull):
            s.submit(_req(n=1))

    def test_deadline_dispatch(self):
        s = MicrobatchScheduler(
            SchedulerConfig(max_delay_us=100.0), max_coalesce=8
        )
        s.submit(_req(n=1, t=1.0))
        assert s.next_ready(1.0) is None             # window still open
        assert s.next_ready(1.0 + 99e-6) is None
        assert s.next_ready(1.0 + 100e-6) == "m"     # deadline hit
        assert s.earliest_deadline() == pytest.approx(1.0 + 100e-6)

    def test_full_window_dispatches_immediately(self):
        s = MicrobatchScheduler(
            SchedulerConfig(max_delay_us=1e6), max_coalesce=4
        )
        for _ in range(3):
            s.submit(_req(n=1, t=0.0))
        assert s.next_ready(0.0) is None             # 3 < 4, far deadline
        s.submit(_req(n=1, t=0.0))
        assert s.next_ready(0.0) == "m"              # window full

    def test_force_ignores_deadline(self):
        s = MicrobatchScheduler(
            SchedulerConfig(max_delay_us=1e6), max_coalesce=8
        )
        s.submit(_req(n=1, t=0.0))
        assert s.next_ready(0.0) is None
        assert s.next_ready(0.0, force=True) == "m"  # drain mode

    def test_pop_batch_fifo_and_cap(self):
        s = MicrobatchScheduler(max_coalesce=8)
        for i, n in enumerate([3, 3, 3, 2]):
            r = _req(n=n, t=float(i))
            r.payload = i
            s.submit(r)
        batch = s.pop_batch("m")                     # 3 + 3, next 3 > 8
        assert [r.payload for r in batch] == [0, 1]
        assert s.depth("m") == 5
        batch = s.pop_batch("m")
        assert [r.payload for r in batch] == [2, 3]
        assert s.depth("m") == 0
        with pytest.raises(ValueError, match="no pending"):
            s.pop_batch("m")

    def test_pop_batch_takes_oversized_single(self):
        s = MicrobatchScheduler(max_coalesce=4)
        s.submit(_req(n=100))
        assert [r.n for r in s.pop_batch("m")] == [100]

    def test_round_robin_across_models(self):
        """The hot tenant cannot starve the others: after serving a model
        the cursor moves past it."""
        s = MicrobatchScheduler(
            SchedulerConfig(max_delay_us=0.0), max_coalesce=4
        )
        for _ in range(3):
            s.submit(_req(model="a", n=1))
        s.submit(_req(model="b", n=1))
        s.submit(_req(model="c", n=1))
        order = []
        while s.total_depth():
            m = s.next_ready(now=1e9)
            order.append(m)
            s.pop_batch(m)
        # a's 3 requests coalesce into one batch (cap 4): each tenant
        # gets exactly one dispatch, in rotation order.
        assert sorted(order) == ["a", "b", "c"]
        # With coalescing capped to 1, a is revisited only after b and c.
        s2 = MicrobatchScheduler(
            SchedulerConfig(max_delay_us=0.0), max_coalesce=1
        )
        for m in ["a", "a", "b", "c"]:
            s2.submit(_req(model=m, n=1))
        order2 = []
        while s2.total_depth():
            m = s2.next_ready(now=1e9)
            order2.append(m)
            s2.pop_batch(m)
        assert order2 == ["a", "b", "c", "a"]

    def test_drain_all_clears_queues(self):
        s = MicrobatchScheduler(max_coalesce=4)
        for m in ["a", "b", "a"]:
            s.submit(_req(model=m, n=2))
        dropped = s.drain_all()
        assert len(dropped) == 3 and s.total_depth() == 0
        assert s.next_ready(1e9, force=True) is None


def _serving_pair(max_batch=16, path=None, seed=0):
    """A service-backed engine and an independent reference engine over
    the same model — reference results never touch the service."""
    model = _model(seed=seed)
    engine = ServingEngine(max_batch=max_batch)
    engine.register("glyphs", model, EDGE_CFG, booleanize_method="none", path=path)
    ref = ServingEngine(max_batch=max_batch)
    ref.register("glyphs", model, EDGE_CFG, booleanize_method="none", path=path)
    return engine, ref


class TestServingService:
    def test_bit_identical_under_concurrent_load(self):
        """The acceptance contract: results equal direct engine.classify
        no matter how the microbatcher coalesced the requests."""
        engine, ref = _serving_pair()
        service = ServingService(engine, ServiceConfig(max_delay_us=500.0))

        async def run():
            await service.start()
            sizes = [1, 3, 7, 2, 5, 1, 4, 6, 2, 1]
            batches = [_images(n, seed=i) for i, n in enumerate(sizes)]

            async def one(b, i):
                # stagger submitters so coalescing patterns vary
                await asyncio.sleep(0.0005 * (i % 3))
                return await service.submit("glyphs", b)

            results = await asyncio.gather(
                *(one(b, i) for i, b in enumerate(batches))
            )
            await service.stop(drain=True)
            return batches, results

        batches, results = asyncio.run(run())
        coalesced = 0
        for b, r in zip(batches, results):
            want = ref.classify("glyphs", b)
            np.testing.assert_array_equal(r.predictions, want.predictions)
            np.testing.assert_array_equal(r.class_sums, want.class_sums)
            coalesced = max(coalesced, r.batch_requests)
        st = service.stats("glyphs")
        assert st.completed == len(batches)
        assert st.images == sum(len(b) for b in batches)

    def test_requests_coalesce_into_one_bucket(self):
        """Back-to-back submissions under an open deadline ride one
        microbatch — and still match the reference bit for bit."""
        engine, ref = _serving_pair()
        service = ServingService(engine, ServiceConfig(max_delay_us=50_000.0))

        async def run():
            await service.start()
            futs = [
                service.submit_nowait("glyphs", _images(2, seed=i))
                for i in range(4)
            ]
            out = await asyncio.gather(*futs)
            await service.stop(drain=True)
            return out

        results = asyncio.run(run())
        assert all(r.batch_requests == 4 for r in results)
        assert all(r.batch_images == 8 for r in results)
        assert all(r.bucket == 8 for r in results)
        for i, r in enumerate(results):
            want = ref.classify("glyphs", _images(2, seed=i))
            np.testing.assert_array_equal(r.predictions, want.predictions)
        st = service.stats("glyphs")
        assert st.batches == 1
        assert st.occupancy_hist == {8: {"batches": 1, "images": 8}}
        assert st.mean_occupancy == 1.0

    def test_zero_delay_serves_lone_request_immediately(self):
        engine, _ = _serving_pair()
        service = ServingService(engine, ServiceConfig(max_delay_us=0.0))

        async def run():
            await service.start()
            r = await service.submit("glyphs", _images(1))
            await service.stop()
            return r

        r = asyncio.run(run())
        assert r.batch_requests == 1 and r.bucket == 1

    def test_backpressure_rejects_past_high_water(self):
        """With the dispatcher held off by a long deadline the queue
        fills to high_water, further submissions get ServiceOverloaded
        with a retry hint, and drain still answers everyone admitted."""
        engine, ref = _serving_pair()
        service = ServingService(
            engine, ServiceConfig(max_delay_us=10e6, high_water=6)
        )

        async def run():
            await service.start()
            futs, errors = [], []
            for i in range(10):
                try:
                    futs.append(
                        service.submit_nowait("glyphs", _images(2, seed=i))
                    )
                except ServiceOverloaded as e:
                    errors.append(e)
            results = await asyncio.gather(*futs)
            await service.stop(drain=True)
            return futs, errors, results

        futs, errors, results = asyncio.run(run())
        assert len(futs) == 3 and len(errors) == 7    # 2+2+2 <= 6, then full
        assert all(e.retry_after_s > 0 for e in errors)
        assert all(e.model == "glyphs" for e in errors)
        for i, r in enumerate(results):
            want = ref.classify("glyphs", _images(2, seed=i))
            np.testing.assert_array_equal(r.predictions, want.predictions)
        st = service.stats("glyphs")
        assert st.submitted == 10 and st.rejected == 7 and st.completed == 3
        assert st.queue_depth == 0

    def test_graceful_drain_under_load(self):
        """stop(drain=True) mid-stream: every admitted request resolves
        with correct results; later submissions are refused."""
        engine, ref = _serving_pair()
        service = ServingService(engine, ServiceConfig(max_delay_us=2000.0))

        async def run():
            await service.start()
            futs = []
            for i in range(12):
                futs.append(service.submit_nowait("glyphs", _images(3, seed=i)))
                if i % 4 == 3:
                    await asyncio.sleep(0.001)   # let some batches dispatch
            await service.stop(drain=True)       # flushes the rest
            results = await asyncio.gather(*futs)
            with pytest.raises(ServiceStopped):
                service.submit_nowait("glyphs", _images(1))
            return results

        results = asyncio.run(run())
        assert len(results) == 12
        for i, r in enumerate(results):
            want = ref.classify("glyphs", _images(3, seed=i))
            np.testing.assert_array_equal(r.predictions, want.predictions)
            np.testing.assert_array_equal(r.class_sums, want.class_sums)

    def test_stop_joins_executors_off_loop(self, monkeypatch):
        """Regression pin for the tmlint TM301 fix: stop() used to call
        executor.shutdown(wait=True) directly in the async def, joining
        worker threads ON the event loop.  The joins must run off-loop
        (asyncio.to_thread) while still waiting for in-flight work."""
        import threading
        from concurrent.futures import ThreadPoolExecutor

        engine, _ = _serving_pair()
        service = ServingService(engine, ServiceConfig(max_delay_us=100.0))
        calls = []
        real = ThreadPoolExecutor.shutdown

        def recording(self, wait=True, **kw):
            calls.append((threading.current_thread(), wait))
            return real(self, wait, **kw)

        monkeypatch.setattr(ThreadPoolExecutor, "shutdown", recording)

        async def run():
            await service.start()
            fut = service.submit_nowait("glyphs", _images(2))
            await fut
            await service.stop(drain=True)
            return threading.current_thread()

        loop_thread = asyncio.run(run())
        # dispatch, completion and ingress pools all joined (wait=True)...
        joins = [t for t, w in calls if w]
        assert len(joins) >= 3
        # ...and never from the event-loop thread itself.  (asyncio.run's
        # own loop.close() fires a wait=False shutdown on the main thread
        # after the loop exits; only the blocking joins matter here.)
        assert all(t is not loop_thread for t in joins), (
            "executor.shutdown(wait=True) ran on the event-loop thread"
        )

    def test_hard_stop_fails_queued_requests(self):
        engine, _ = _serving_pair()
        service = ServingService(engine, ServiceConfig(max_delay_us=10e6))

        async def run():
            await service.start()
            futs = [
                service.submit_nowait("glyphs", _images(1, seed=i))
                for i in range(3)
            ]
            await service.stop(drain=False)
            return await asyncio.gather(*futs, return_exceptions=True)

        out = asyncio.run(run())
        assert all(isinstance(r, ServiceStopped) for r in out)

    def test_multi_model_tenancy_and_stats_isolation(self):
        model_a, model_b = _model(seed=1), _model(seed=2)
        engine = ServingEngine(max_batch=8)
        engine.register("a", model_a, EDGE_CFG, booleanize_method="none")
        engine.register("b", model_b, EDGE_CFG, booleanize_method="none")
        ref = ServingEngine(max_batch=8)
        ref.register("a", model_a, EDGE_CFG, booleanize_method="none")
        ref.register("b", model_b, EDGE_CFG, booleanize_method="none")
        service = ServingService(engine, ServiceConfig(max_delay_us=1000.0))

        async def run():
            await service.start()
            imgs = _images(2, seed=7)
            futs = [
                service.submit_nowait(name, imgs)
                for name in ("a", "b", "a", "b")
            ]
            results = await asyncio.gather(*futs)
            await service.stop(drain=True)
            return imgs, results

        imgs, results = asyncio.run(run())
        np.testing.assert_array_equal(
            results[0].predictions, ref.classify("a", imgs).predictions
        )
        np.testing.assert_array_equal(
            results[1].predictions, ref.classify("b", imgs).predictions
        )
        # same inputs, different models -> independently computed
        np.testing.assert_array_equal(
            results[0].predictions, results[2].predictions
        )
        sa, sb = service.stats("a"), service.stats("b")
        assert sa.completed == 2 and sb.completed == 2
        assert sa.images == 4 and sb.images == 4

    def test_validation_errors_propagate_without_enqueue(self):
        engine, _ = _serving_pair()
        service = ServingService(engine)

        async def run():
            await service.start()
            with pytest.raises(KeyError):
                service.submit_nowait("nope", _images(1))
            with pytest.raises(ValueError, match="empty request"):
                service.submit_nowait(
                    "glyphs", np.zeros((0, 11, 11), np.uint8)
                )
            with pytest.raises(ValueError, match="preprocessed literals"):
                service.submit_nowait(
                    "glyphs", np.zeros((2, 3), np.uint8), preprocessed=True
                )
            await service.stop()

        asyncio.run(run())
        assert service.stats("glyphs").submitted == 0

    def test_restart_after_stop(self):
        engine, ref = _serving_pair()
        service = ServingService(engine, ServiceConfig(max_delay_us=0.0))

        async def run():
            await service.start()
            await service.submit("glyphs", _images(1))
            await service.stop()
            assert not service.running
            await service.start()        # a stopped service can restart
            r = await service.submit("glyphs", _images(2, seed=5))
            await service.stop()
            return r

        r = asyncio.run(run())
        want = ref.classify("glyphs", _images(2, seed=5))
        np.testing.assert_array_equal(r.predictions, want.predictions)

    def test_oversized_request_occupancy_accounting(self):
        """A request above max_batch executes as several engine slices;
        the occupancy histogram must reflect those buckets (occupancy
        stays a <= 1 fraction), while batches counts one dispatch."""
        engine, ref = _serving_pair(max_batch=8)
        service = ServingService(engine, ServiceConfig(max_delay_us=0.0))

        async def run():
            await service.start()
            r = await service.submit("glyphs", _images(19, seed=3))  # 8+8+3
            await service.stop()
            return r

        r = asyncio.run(run())
        want = ref.classify("glyphs", _images(19, seed=3))
        np.testing.assert_array_equal(r.predictions, want.predictions)
        st = service.stats("glyphs")
        assert st.batches == 1 and st.images == 19
        assert st.occupancy_hist == {
            4: {"batches": 1, "images": 3},
            8: {"batches": 2, "images": 16},
        }
        assert 0.0 < st.mean_occupancy <= 1.0

    def test_submit_requires_running_service(self):
        engine, _ = _serving_pair()
        service = ServingService(engine)
        with pytest.raises(ServiceStopped):
            service.submit_nowait("glyphs", _images(1))

    def test_stats_unknown_model_raises(self):
        engine, _ = _serving_pair()
        service = ServingService(engine)
        with pytest.raises(KeyError):
            service.stats("no-such-model")
        st = service.stats("glyphs")     # registered, no traffic: zeros
        assert st.completed == 0 and st.queue_depth == 0

    def test_service_config_validation(self):
        with pytest.raises(ValueError, match="max_coalesce"):
            ServiceConfig(max_coalesce=0)
        with pytest.raises(ValueError, match="latency_window"):
            ServiceConfig(latency_window=0)

    def test_double_start_rejected(self):
        engine, _ = _serving_pair()
        service = ServingService(engine)

        async def run():
            await service.start()
            with pytest.raises(RuntimeError, match="already started"):
                await service.start()
            await service.stop()

        asyncio.run(run())
