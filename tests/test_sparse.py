"""Clause-sparsity fast path: freeze-time analysis, sparse eval paths'
bit-identity against the reference kernels, fallback resolution, and
degenerate servables (ARCHITECTURE.md §Sparsity)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cotm import TA_HALF, CoTMConfig, infer, init_boundary_model
from repro.core.patches import (
    PatchSpec,
    extract_patch_features,
    make_literals,
    pack_bits,
)
from repro.serve import (
    ServingEngine,
    analyze_sparsity,
    freeze,
    get_path,
    resolve_path,
    run_path,
)

# Edge geometry: B/P/C deliberately not multiples of the kernel block
# sizes; C = 37 also exercises the packed-word padding of exclude masks.
EDGE_SPEC = PatchSpec(image_x=11, image_y=11, window_x=5, window_y=5)
EDGE_CFG = CoTMConfig(n_clauses=37, n_classes=10, patch=EDGE_SPEC)
PAPER_CFG = CoTMConfig(n_clauses=64)

SPARSE_PATHS = ("sparse", "fused_sparse", "matmul_sparse")


def _model(cfg, seed=0):
    return init_boundary_model(jax.random.PRNGKey(seed), cfg)


def _model_n_active(cfg, n_active, seed=0):
    """A model whose trailing clauses are forced empty (zeroed TA rows =>
    every literal excluded => the Sec. IV-D empty-clause rule drops them)
    and whose leading ``n_active`` clauses provably include something."""
    model = _model(cfg, seed)
    ta = np.asarray(model.ta_state).copy()
    ta[n_active:] = 0
    if n_active:
        ta[:n_active, 0] = np.maximum(ta[:n_active, 0], TA_HALF)
    return dataclasses.replace(model, ta_state=jnp.asarray(ta))


def _images(cfg, b, seed=0):
    key = jax.random.PRNGKey(seed + 100)
    side = cfg.patch.image_y
    return (jax.random.uniform(key, (b, side, side)) > 0.6).astype(jnp.uint8)


def _lits(cfg, imgs):
    return make_literals(extract_patch_features(imgs, cfg.patch))


def _path_arg(path, lits):
    return pack_bits(lits) if path.input_form == "packed" else lits


class TestAnalyzeSparsity:
    def test_active_set_matches_nonempty(self):
        sm = analyze_sparsity(freeze(_model(EDGE_CFG), EDGE_CFG))
        sp = sm.sparsity
        assert sp.n_active == int(np.asarray(sm.nonempty).sum())
        np.testing.assert_array_equal(
            np.asarray(sp.active_idx), np.flatnonzero(np.asarray(sm.nonempty))
        )
        assert 0.0 <= sp.include_density <= 1.0

    def test_idempotent(self):
        sm = analyze_sparsity(freeze(_model(EDGE_CFG), EDGE_CFG))
        assert analyze_sparsity(sm) is sm

    def test_exclude_is_complement_with_pad_bits_set(self):
        """exclude_packed == ~include_packed with every pad bit forced 1,
        so a padded literal word can never violate a clause."""
        sm = analyze_sparsity(freeze(_model(EDGE_CFG), EDGE_CFG))
        sp = sm.sparsity
        n_lit = EDGE_CFG.n_literals
        inc = np.asarray(sp.include_packed)
        exc = np.asarray(sp.exclude_packed)
        np.testing.assert_array_equal(exc & inc, np.zeros_like(inc))
        # Pad bits: set in exclude for every active clause.
        exc_bits = np.unpackbits(
            exc.view(np.uint8).reshape(exc.shape[0], -1), axis=1,
            bitorder="little",
        )
        assert exc_bits[:, n_lit:].all()

    def test_all_empty_model(self):
        sm = analyze_sparsity(freeze(_model_n_active(EDGE_CFG, 0), EDGE_CFG))
        assert sm.sparsity.n_active == 0
        assert sm.sparsity.include_density == 0.0


class TestSparseBitIdentity:
    @pytest.mark.parametrize("cfg", [PAPER_CFG, EDGE_CFG], ids=["paper", "edge"])
    @pytest.mark.parametrize("batch", [1, 2, 5, 16])
    @pytest.mark.parametrize("name", SPARSE_PATHS)
    def test_matches_dense_reference(self, cfg, batch, name):
        """Sparse paths == the dense reference path, bit for bit, across
        bucket-ish batch sizes and both geometries."""
        model = _model(cfg, seed=batch)
        sm = analyze_sparsity(freeze(model, cfg))
        lits = _lits(cfg, _images(cfg, batch, seed=batch))
        want = np.asarray(run_path(get_path("dense"), sm, lits))
        path = get_path(name)
        got = np.asarray(run_path(path, sm, _path_arg(path, lits)))
        np.testing.assert_array_equal(want, got, err_msg=f"path {name}")

    @pytest.mark.parametrize("n_active", [1, 19])
    @pytest.mark.parametrize("name", SPARSE_PATHS)
    def test_partial_active_identity(self, n_active, name):
        """Models with empty clauses (single active clause, half-empty
        pool): the active-set evaluation equals the full evaluation."""
        cfg = EDGE_CFG
        sm = analyze_sparsity(freeze(_model_n_active(cfg, n_active), cfg))
        assert sm.sparsity.n_active == n_active
        lits = _lits(cfg, _images(cfg, 3))
        want = np.asarray(run_path(get_path("dense"), sm, lits))
        path = get_path(name)
        got = np.asarray(run_path(path, sm, _path_arg(path, lits)))
        np.testing.assert_array_equal(want, got)

    @pytest.mark.parametrize("name", SPARSE_PATHS)
    def test_all_clauses_empty(self, name):
        """The fully-degenerate servable (every clause empty): class sums
        are identically zero on every path, sparse included."""
        cfg = EDGE_CFG
        sm = analyze_sparsity(freeze(_model_n_active(cfg, 0), cfg))
        lits = _lits(cfg, _images(cfg, 2))
        path = get_path(name)
        got = np.asarray(run_path(path, sm, _path_arg(path, lits)))
        np.testing.assert_array_equal(got, np.zeros_like(got))
        want = np.asarray(run_path(get_path("dense"), sm, lits))
        np.testing.assert_array_equal(want, got)

    @pytest.mark.parametrize("name", SPARSE_PATHS)
    def test_infer_eval_path(self, name):
        """The sparse names also work as ``CoTMConfig.eval_path`` through
        the top-level ``infer`` (which analyzes sparsity on the fly)."""
        cfg = dataclasses.replace(EDGE_CFG, eval_path=name)
        model = _model(EDGE_CFG)
        imgs = _images(EDGE_CFG, 3)
        want_p, want_v = infer(model, imgs, EDGE_CFG)
        got_p, got_v = infer(model, imgs, cfg)
        np.testing.assert_array_equal(np.asarray(want_v), np.asarray(got_v))
        np.testing.assert_array_equal(np.asarray(want_p), np.asarray(got_p))


class TestFallbackResolution:
    @pytest.mark.parametrize("name", SPARSE_PATHS)
    def test_no_sparsity_falls_back(self, name):
        """Without an attached analysis a sparse path resolves to its
        same-form dense fallback — and still returns identical sums."""
        sm = freeze(_model(EDGE_CFG), EDGE_CFG)      # sparsity=None
        assert sm.sparsity is None
        path = get_path(name)
        assert resolve_path(path, sm).name == path.fallback
        lits = _lits(EDGE_CFG, _images(EDGE_CFG, 2))
        got = np.asarray(run_path(path, sm, _path_arg(path, lits)))
        want = np.asarray(run_path(get_path("dense"), sm, lits))
        np.testing.assert_array_equal(want, got)

    def test_fallback_shares_input_form(self):
        for name in SPARSE_PATHS:
            path = get_path(name)
            assert path.fallback is not None
            assert get_path(path.fallback).input_form == path.input_form


class TestEngineSparseForms:
    @pytest.mark.parametrize("name", ["fused_sparse", "sparse", "matmul_sparse"])
    def test_all_request_forms_match_dense_engine(self, name):
        """A sparse-path engine serves raw / host / preprocessed requests
        bit-identically to the dense-path engine, across buckets."""
        cfg = EDGE_CFG
        model = _model(cfg)
        ref = ServingEngine(max_batch=8)
        ref.register("m", model, cfg, path="dense")
        eng = ServingEngine(max_batch=8)
        eng.register("m", model, cfg, path=name)
        rng = np.random.default_rng(0)
        side = cfg.patch.image_y
        for n in (1, 3, 8):
            imgs = rng.integers(0, 256, (n, side, side)).astype(np.uint8)
            want = ref.classify("m", imgs)
            for kw in (
                {"ingress": "device"},
                {"ingress": "host"},
            ):
                got = eng.classify("m", imgs, **kw)
                np.testing.assert_array_equal(want.class_sums, got.class_sums)
                np.testing.assert_array_equal(want.predictions, got.predictions)
            lits = eng.preprocess("m", imgs)
            got = eng.classify("m", lits, preprocessed=True)
            np.testing.assert_array_equal(want.class_sums, got.class_sums)

    def test_register_attaches_sparsity(self):
        eng = ServingEngine(max_batch=4)
        eng.register("m", _model(EDGE_CFG), EDGE_CFG, path="fused_sparse")
        sp = eng.servable("m").sparsity
        assert sp is not None and sp.n_active > 0
