"""Launch-layer metadata: input specs, cache specs, shape applicability —
the contracts the 512-device dry-run relies on, tested without any mesh."""

import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SHAPES, TrainConfig, applicable_shapes, get_config
from repro.launch import specs as S


class TestApplicability:
    def test_forty_assigned_cells(self):
        """10 archs x 4 shapes = 40 assigned cells; 34 applicable (6
        long_500k cells are full-attention-family skips, ARCHITECTURE.md §Substrate)."""
        total = sum(len(applicable_shapes(c)) for c in ARCHS.values())
        assert len(ARCHS) == 10
        assert total == 34
        skipped = {
            name for name, c in ARCHS.items()
            if "long_500k" not in applicable_shapes(c)
        }
        assert skipped == {
            "mistral-nemo-12b", "codeqwen1.5-7b", "qwen2-moe-a2.7b",
            "phi3.5-moe-42b-a6.6b", "seamless-m4t-large-v2", "qwen2-vl-7b",
        }

    def test_long_context_archs_have_bounded_caches(self):
        from repro.models.attention import cache_len

        for name in ("h2o-danube-1.8b", "h2o-danube-3-4b"):
            cfg = ARCHS[name]
            assert cache_len(cfg, 524_288) == cfg.sliding_window
        assert ARCHS["recurrentgemma-2b"].local_window == 2048


class TestBatchSpecs:
    @pytest.mark.parametrize("arch", sorted(ARCHS))
    @pytest.mark.parametrize("shape_name", ["train_4k", "prefill_32k"])
    def test_batch_shapes_and_dtypes(self, arch, shape_name):
        cfg = get_config(arch)
        shape = SHAPES[shape_name]
        batch = S.batch_specs(cfg, shape)
        total_seq = 0
        for k, v in batch.items():
            assert v.shape[0] == shape.global_batch
            if k == "tokens":
                assert v.dtype == jnp.int32
                total_seq += v.shape[1]
            elif k == "dec_tokens":
                assert v.dtype == jnp.int32
            elif k == "frontend_embeds":
                assert v.shape[-1] == cfg.d_model
                if not cfg.is_encoder_decoder:
                    total_seq += v.shape[1]
        if not cfg.is_encoder_decoder:
            assert total_seq == shape.seq_len  # cells cover the full seq

    def test_encdec_encoder_gets_full_sequence(self):
        cfg = get_config("seamless-m4t-large-v2")
        b = S.batch_specs(cfg, SHAPES["train_4k"])
        assert b["frontend_embeds"].shape == (256, 4096, 1024)
        assert b["dec_tokens"].shape == (256, 1024)


class TestCacheSpecs:
    def test_dense_cache_layout(self):
        cfg = get_config("mistral-nemo-12b")
        cache = S.cache_specs(cfg, SHAPES["decode_32k"])
        k = cache["cyc"]["0"]["k"]
        assert k.shape == (40, 128, 8, 32768, 128)     # L, B, KV, S, hd

    def test_swa_cache_is_ring(self):
        cfg = get_config("h2o-danube-1.8b")
        cache = S.cache_specs(cfg, SHAPES["long_500k"])
        k = cache["cyc"]["0"]["k"]
        assert k.shape[-2] == cfg.sliding_window        # not 524288

    def test_recurrent_cache_is_o1(self):
        cfg = get_config("xlstm-350m")
        cache = S.cache_specs(cfg, SHAPES["long_500k"])
        # mLSTM state: [n_cycles, B, H, hd, hd] — no sequence dimension.
        c = cache["cyc"]["0"]["C"]
        assert c.shape == (3, 1, 4, 512, 512)

    def test_hybrid_cache_mixes_kinds(self):
        cfg = get_config("recurrentgemma-2b")
        cache = S.cache_specs(cfg, SHAPES["decode_32k"])
        assert set(cache["cyc"]["0"].keys()) == {"h", "conv"}   # rglru
        assert set(cache["cyc"]["2"].keys()) == {"k", "v"}      # local attn
        assert cache["cyc"]["2"]["k"].shape[-2] == cfg.local_window
        # 26 layers = 8 full (r,r,a) cycles + (r,r) tail
        assert set(cache["tail"].keys()) == {"0", "1"}


class TestTrainStateSpecs:
    def test_state_covers_opt_and_residual(self):
        cfg = get_config("h2o-danube-1.8b")
        tcfg = TrainConfig(grad_compression=True)
        st = S.abstract_train_state(cfg, tcfg)
        assert set(st.keys()) == {"params", "opt", "residual"}
        assert set(st["opt"].keys()) == {"step", "m", "v", "master"}
        # moments are fp32 regardless of param dtype
        import jax

        for leaf in jax.tree.leaves(st["opt"]["m"]):
            assert leaf.dtype == jnp.float32

    def test_param_counts_sane(self):
        expected = {
            "xlstm-350m": (0.2e9, 0.6e9),
            "h2o-danube-1.8b": (1.5e9, 2.2e9),
            "mistral-nemo-12b": (11e9, 14e9),
            "phi3.5-moe-42b-a6.6b": (38e9, 46e9),
            "qwen2-moe-a2.7b": (12e9, 16e9),   # total (active 2.7B)
        }
        for name, (lo, hi) in expected.items():
            n = ARCHS[name].param_count()
            assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B"
        a = ARCHS["qwen2-moe-a2.7b"].active_param_count()
        assert 2.0e9 <= a <= 4.5e9

    def test_microbatch_heuristic_divides(self):
        from repro.sharding.partition import single_device_mesh

        mesh = single_device_mesh()
        for arch in ARCHS.values():
            for sn in applicable_shapes(arch):
                k = S.microbatches_for(arch, SHAPES[sn], mesh)
                assert SHAPES[sn].global_batch % k == 0
