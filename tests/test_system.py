"""End-to-end behaviour tests for the paper's system (the ConvCoTM
accelerator reproduced in JAX) + the launcher drivers."""

import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.convcotm import BOOLEANIZE_METHOD, COTM_CONFIGS
from repro.core import (
    CoTMConfig,
    infer,
    infer_packed,
    init_model,
    pack_model,
    unpack_model,
    update_batch,
)
from repro.core.patches import PatchSpec, extract_patch_features, make_literals, pack_bits
from repro.data import (
    DoubleBufferedLoader,
    PipelineState,
    batches,
    booleanize_split,
    noisy_xor_2d,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class TestPaperConfiguration:
    def test_registry_has_paper_configs(self):
        assert set(COTM_CONFIGS) == {
            "convcotm-mnist", "convcotm-fmnist", "convcotm-kmnist"
        }
        cfg = COTM_CONFIGS["convcotm-mnist"]
        assert cfg.n_clauses == 128 and cfg.n_classes == 10
        assert cfg.patch.n_patches == 361 and cfg.n_literals == 272
        assert BOOLEANIZE_METHOD["convcotm-mnist"] == "threshold"
        assert BOOLEANIZE_METHOD["convcotm-fmnist"] == "adaptive"

    def test_full_inference_path_paper_scale(self):
        """Booleanize -> patches -> 128 clauses -> class sums -> argmax,
        at the exact paper dimensions, via all three eval paths."""
        cfg = COTM_CONFIGS["convcotm-mnist"]
        key = jax.random.PRNGKey(1)
        model = init_model(key, cfg)
        model.ta_state = jax.random.randint(
            key, model.ta_state.shape, 120, 136
        ).astype(jnp.uint8)
        raw = jax.random.randint(key, (16, 28, 28), 0, 256).astype(jnp.uint8)
        imgs = jnp.asarray(booleanize_split(np.asarray(raw), "threshold"))
        preds = {}
        for path in ("dense", "bitpacked", "matmul"):
            c = dataclasses.replace(cfg, eval_path=path)
            p, v = infer(model, imgs, c)
            preds[path] = (np.asarray(p), np.asarray(v))
        np.testing.assert_array_equal(preds["dense"][1], preds["bitpacked"][1])
        np.testing.assert_array_equal(preds["dense"][1], preds["matmul"][1])

    def test_serving_fast_path_packed_literals(self):
        """Host-packed literals (the AXI-stream analogue) give identical
        predictions to the image path."""
        cfg = CoTMConfig(n_clauses=32)
        key = jax.random.PRNGKey(2)
        model = init_model(key, cfg)
        model.ta_state = jax.random.randint(
            key, model.ta_state.shape, 120, 136
        ).astype(jnp.uint8)
        imgs = (jax.random.uniform(key, (4, 28, 28)) > 0.6).astype(jnp.uint8)
        p1, v1 = infer(model, imgs, cfg)
        feats = extract_patch_features(imgs, cfg.patch)
        lp = pack_bits(make_literals(feats))
        p2, v2 = infer_packed(model, lp, cfg)
        np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))

    def test_register_image_roundtrip_is_deployable(self):
        """Train -> pack to the register image -> unpack -> identical
        inference (the load-model flow of Sec. IV-A/B)."""
        tx, ty, vx, vy = noisy_xor_2d(n_train=600, n_test=100, seed=3)
        tx, vx = booleanize_split(tx), booleanize_split(vx)
        spec = PatchSpec(image_x=4, image_y=4, window_x=2, window_y=2)
        cfg = CoTMConfig(n_clauses=16, n_classes=2, patch=spec, T=15, s=3.0)
        key = jax.random.PRNGKey(4)
        model = init_model(key, cfg)
        txj, tyj = jnp.asarray(tx), jnp.asarray(ty.astype(np.int32))
        for i in range(0, 600, 100):
            key, k = jax.random.split(key)
            model = update_batch(k, model, txj[i:i+100], tyj[i:i+100], cfg)
        blob = pack_model(model, cfg)
        model2 = unpack_model(blob, cfg)
        vxj = jnp.asarray(vx)
        p1, _ = infer(model, vxj, cfg)
        p2, _ = infer(model2, vxj, cfg)
        np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))


class TestPipeline:
    def test_double_buffered_loader_order(self):
        x = np.arange(40).reshape(10, 2, 2)
        y = np.arange(10)
        it = batches(x, y, batch_size=2, state=PipelineState(seed=1))
        loader = DoubleBufferedLoader(it)
        seen = [int(np.asarray(yb)[0]) for _, yb, _ in loader]
        assert len(seen) == 5

    def test_pipeline_resume_mid_epoch(self):
        x = np.arange(80).reshape(20, 2, 2)
        y = np.arange(20)
        full = [st for _, _, st in batches(x, y, 4, PipelineState(seed=7))]
        resumed = list(batches(x, y, 4, full[1]))
        assert len(resumed) == 3
        tail = list(batches(x, y, 4, PipelineState(seed=7)))[2:]
        for (xa, _, _), (xb, _, _) in zip(resumed, tail):
            np.testing.assert_array_equal(xa, xb)

    def test_pipeline_resume_across_epoch_boundary(self):
        """The cursor yielded with an epoch's final batch must roll over to
        (epoch+1, step=0): resuming from it starts the next epoch instead
        of yielding an empty iterator forever (the old step==n_steps bug)."""
        x = np.arange(48).reshape(12, 2, 2)
        y = np.arange(12)
        states = [st for _, _, st in batches(x, y, 4, PipelineState(seed=3))]
        final = states[-1]
        assert final.epoch == 1 and final.step == 0
        resumed = list(batches(x, y, 4, final))
        assert len(resumed) == 3          # a full next epoch, not empty
        # and it is exactly epoch 1's shuffle
        fresh = list(batches(x, y, 4, PipelineState(epoch=1, seed=3)))
        for (xa, _, _), (xb, _, _) in zip(resumed, fresh):
            np.testing.assert_array_equal(xa, xb)

    def test_pipeline_stale_exhausted_cursor_rolls_forward(self):
        """A pre-fix cursor stuck at step == n_steps (or one saved under a
        larger n_steps) must start the next epoch, not yield nothing."""
        x = np.arange(48).reshape(12, 2, 2)
        y = np.arange(12)
        stale = PipelineState(epoch=0, step=3, seed=3)   # n_steps == 3
        resumed = list(batches(x, y, 4, stale))
        assert len(resumed) == 3
        fresh = list(batches(x, y, 4, PipelineState(epoch=1, seed=3)))
        np.testing.assert_array_equal(resumed[0][0], fresh[0][0])

    def test_epoch_permutations_do_not_collide(self):
        """default_rng(seed + epoch) used to replay the same permutation
        for (seed=3, epoch=0) and (seed=2, epoch=1); the SeedSequence pair
        seeding keeps distinct (seed, epoch) streams distinct."""
        from repro.data import epoch_permutation

        n = 64
        a = epoch_permutation(3, 0, n)
        b = epoch_permutation(2, 1, n)
        assert not np.array_equal(a, b)
        # successive epochs under one seed differ too
        assert not np.array_equal(epoch_permutation(3, 0, n), epoch_permutation(3, 1, n))
        # and the stream is deterministic
        np.testing.assert_array_equal(a, epoch_permutation(3, 0, n))

    def test_composite_inference(self):
        from repro.core.composites import (
            CompositeConfig,
            CompositeModel,
            composite_infer,
        )

        spec = PatchSpec(image_x=8, image_y=8, window_x=3, window_y=3)
        cfg = CoTMConfig(n_clauses=8, n_classes=3, patch=spec)
        comp = CompositeConfig(specialists=(cfg, cfg))
        key = jax.random.PRNGKey(5)
        m = CompositeModel(members=(init_model(key, cfg), init_model(key, cfg)))
        views = [
            (jax.random.uniform(key, (4, 8, 8)) > 0.5).astype(jnp.uint8)
        ] * 2
        pred, v = composite_infer(m, views, comp)
        assert pred.shape == (4,) and v.shape == (4, 3)


class TestDrivers:
    @pytest.mark.slow
    def test_train_driver_runs_and_checkpoints(self, tmp_path):
        r = subprocess.run(
            [
                sys.executable, "-m", "repro.launch.train",
                "--arch", "h2o-danube-1.8b", "--reduced",
                "--steps", "4", "--batch", "4", "--seq", "32",
                "--ckpt-dir", str(tmp_path), "--microbatches", "2",
            ],
            capture_output=True, text=True, timeout=540,
            env={**os.environ, "PYTHONPATH": "src"}, cwd=REPO,
        )
        assert r.returncode == 0, r.stderr[-3000:]
        assert "step" in r.stdout
        from repro.checkpoint.checkpointer import latest_step

        assert latest_step(str(tmp_path)) == 4

    @pytest.mark.slow
    def test_serve_driver_generates(self):
        r = subprocess.run(
            [
                sys.executable, "-m", "repro.launch.serve",
                "--arch", "xlstm-350m", "--reduced",
                "--batch", "2", "--prompt-len", "4", "--gen", "4",
            ],
            capture_output=True, text=True, timeout=540,
            env={**os.environ, "PYTHONPATH": "src"}, cwd=REPO,
        )
        assert r.returncode == 0, r.stderr[-3000:]
        assert "generated" in r.stdout
