"""TrainerEngine: jitted-epoch training vs the naive per-batch loop.

The contract under test: with the same starting key, cursor and batch
order, ``TrainerEngine`` (literals frozen once, one lax.scan per epoch,
donated model buffers, matmul training eval) produces the *bit-identical*
model to a hand-written ``update_batch`` python loop over ``batches()`` —
so "same accuracy as the naive epoch loop" holds by construction and is
asserted directly on the glyphs example config.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cotm import CoTMConfig, init_model
from repro.core.patches import PatchSpec
from repro.core.train import update_batch
from repro.data import PipelineState, batches, booleanize_split, synthetic_glyphs
from repro.train.tm_engine import TMDataset, TrainerEngine

SPEC_SMALL = PatchSpec(image_x=8, image_y=8, window_x=3, window_y=3)


def _small_cfg(**kw):
    base = dict(n_clauses=16, n_classes=3, patch=SPEC_SMALL, T=15, s=3.0)
    base.update(kw)
    return CoTMConfig(**base)


def _small_data(n=64, seed=0):
    rng = np.random.default_rng(seed)
    x = (rng.random((n, 8, 8)) > 0.5).astype(np.uint8)
    y = rng.integers(0, 3, n).astype(np.int32)
    return x, y


def _naive_loop(cfg, key, x, y, batch, epochs, mode="batch", seed=0):
    """The hand-written epoch loop the engine must reproduce bit-exactly."""
    model = init_model(key, cfg)
    state = PipelineState(seed=seed)
    for _ in range(epochs):
        for xb, yb, state in batches(x, y, batch, state):
            key, k = jax.random.split(key)
            model = update_batch(
                k, model, jnp.asarray(xb), jnp.asarray(yb), cfg, mode=mode
            )
    return model, state


class TestEngineEquivalence:
    @pytest.mark.parametrize("mode", ["batch", "scan"])
    def test_engine_matches_naive_loop_bitexact(self, mode):
        cfg = _small_cfg()
        x, y = _small_data()
        key = jax.random.PRNGKey(3)
        want, want_state = _naive_loop(cfg, key, x, y, batch=16, epochs=2, mode=mode)

        engine = TrainerEngine(cfg, batch_size=16, mode=mode)
        ds = engine.prepare(x, y, booleanize_method="none")
        model = engine.init_model(key)
        _, model, state, _ = engine.fit(key, model, ds, epochs=2)
        np.testing.assert_array_equal(
            np.asarray(want.ta_state), np.asarray(model.ta_state)
        )
        np.testing.assert_array_equal(
            np.asarray(want.weights), np.asarray(model.weights)
        )
        assert state == want_state

    def test_resume_mid_epoch_matches_full_epoch(self):
        """run_epoch from a mid-epoch cursor trains exactly the remaining
        steps of that epoch's permutation."""
        cfg = _small_cfg()
        x, y = _small_data()
        key = jax.random.PRNGKey(1)
        engine = TrainerEngine(cfg, batch_size=16)
        ds = engine.prepare(x, y, booleanize_method="none")

        model = engine.init_model(key)
        key_a, model_full, state_full, n_full = engine.run_epoch(
            key, model, ds, PipelineState(seed=5)
        )
        assert n_full == 64 and state_full == PipelineState(1, 0, 5)

        # Same epoch in two halves: 2 steps, then resume from the cursor.
        model2 = engine.init_model(key)
        k = key
        state = PipelineState(seed=5)
        from repro.data import epoch_permutation

        perm = epoch_permutation(5, 0, 64)
        for step in range(2):
            k, kk = jax.random.split(k)
            idx = perm[step * 16 : (step + 1) * 16]
            model2 = update_batch(
                kk, model2, jnp.asarray(x[idx]), jnp.asarray(y[idx]), cfg
            )
        key_b, model2, state2, n2 = engine.run_epoch(
            k, model2, ds, PipelineState(epoch=0, step=2, seed=5)
        )
        assert n2 == 32 and state2 == PipelineState(1, 0, 5)
        np.testing.assert_array_equal(
            np.asarray(model_full.ta_state), np.asarray(model2.ta_state)
        )
        np.testing.assert_array_equal(
            np.asarray(model_full.weights), np.asarray(model2.weights)
        )
        np.testing.assert_array_equal(np.asarray(key_a), np.asarray(key_b))

    def test_exhausted_cursor_rolls_over_and_trains(self):
        """A cursor exhausted on entry (step == n_steps, e.g. a pre-fix
        checkpoint) rolls forward and trains the next epoch — bit-identical
        to what the naive batches() loop does with the same stale cursor."""
        cfg = _small_cfg()
        x, y = _small_data()
        key = jax.random.PRNGKey(0)
        engine = TrainerEngine(cfg, batch_size=16)
        ds = engine.prepare(x, y, booleanize_method="none")
        model = engine.init_model(key)
        stale = PipelineState(epoch=2, step=4, seed=0)
        key_e, model_e, state, n = engine.run_epoch(key, model, ds, stale)
        assert n == 64 and state == PipelineState(4, 0, 0)

        model_n = engine.init_model(key)
        k = key
        st = stale
        for xb, yb, st in batches(x, y, 16, stale):
            k, kk = jax.random.split(k)
            model_n = update_batch(kk, model_n, jnp.asarray(xb), jnp.asarray(yb), cfg)
        assert st == state
        np.testing.assert_array_equal(
            np.asarray(model_n.ta_state), np.asarray(model_e.ta_state)
        )
        np.testing.assert_array_equal(np.asarray(k), np.asarray(key_e))

    @pytest.mark.slow
    def test_glyphs_engine_accuracy_matches_naive(self):
        """The glyphs example config (paper geometry, 128 clauses): the
        engine reaches exactly the naive loop's accuracy — the models are
        bit-identical — and actually learns."""
        tx, ty, vx, vy = synthetic_glyphs(n_train=1000, n_test=300, seed=1)
        txb = booleanize_split(tx, "threshold")
        vxb = booleanize_split(vx, "threshold")
        cfg = CoTMConfig(n_clauses=128, n_classes=10, T=100, s=5.0)
        key = jax.random.PRNGKey(0)

        engine = TrainerEngine(cfg, batch_size=100)
        train_ds = engine.prepare(txb, ty, booleanize_method="none")
        eval_ds = engine.prepare(vxb, vy, booleanize_method="none")
        model_e = engine.init_model(key)
        _, model_e, _, reports = engine.fit(
            key, model_e, train_ds, epochs=5, eval_ds=eval_ds
        )
        acc_engine = reports[-1].accuracy

        model_n, _ = _naive_loop(
            cfg, key, txb, ty.astype(np.int32), batch=100, epochs=5
        )
        np.testing.assert_array_equal(
            np.asarray(model_n.ta_state), np.asarray(model_e.ta_state)
        )
        acc_naive = engine.evaluate(model_n, eval_ds)
        assert acc_engine == acc_naive
        assert acc_engine >= 0.75, f"glyph accuracy {acc_engine}"


class TestEngineAPI:
    def test_prepare_runs_shared_ingress(self):
        """prepare() must produce exactly the pipeline ingress literals."""
        from repro.data.pipeline import preprocess_for_serving

        cfg = _small_cfg()
        x, y = _small_data(n=8)
        engine = TrainerEngine(cfg, batch_size=4)
        ds = engine.prepare(x, y, booleanize_method="none")
        want = preprocess_for_serving(x, cfg.patch, method="none", packed=False)
        assert isinstance(ds, TMDataset)
        assert ds.n == 8
        np.testing.assert_array_equal(np.asarray(ds.literals), want)
        np.testing.assert_array_equal(np.asarray(ds.labels), y)

    def test_evaluate_matches_accuracy(self):
        from repro.core.train import accuracy

        cfg = _small_cfg()
        x, y = _small_data(n=16, seed=4)
        # eval_batch=5 forces the chunked path incl. a remainder chunk
        engine = TrainerEngine(cfg, batch_size=4, eval_batch=5)
        ds = engine.prepare(x, y, booleanize_method="none")
        model = engine.init_model(jax.random.PRNGKey(2))
        want = float(accuracy(model, jnp.asarray(x), jnp.asarray(y), cfg))
        assert engine.evaluate(model, ds) == want

    def test_dataset_smaller_than_batch_rejected(self):
        """A dataset with fewer samples than batch_size must raise, not
        silently train 0 samples while advancing the epoch cursor."""
        cfg = _small_cfg()
        x, y = _small_data(n=8)
        engine = TrainerEngine(cfg, batch_size=16)
        ds = engine.prepare(x, y, booleanize_method="none")
        model = engine.init_model(jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="batch_size"):
            engine.run_epoch(jax.random.PRNGKey(0), model, ds)

    def test_invalid_modes_rejected(self):
        cfg = _small_cfg()
        with pytest.raises(ValueError, match="mode"):
            TrainerEngine(cfg, mode="nope")
        with pytest.raises(ValueError, match="batch_size"):
            TrainerEngine(cfg, batch_size=0)

    def test_scan_mode_with_mesh_rejected(self):
        from jax.sharding import Mesh

        cfg = _small_cfg()
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        with pytest.raises(ValueError, match="sequential"):
            TrainerEngine(cfg, mode="scan", mesh=mesh)

    def test_single_device_mesh_matches_unmeshed(self):
        """The shard_map psum path on a 1-device mesh is bit-identical to
        the plain sum (the multi-device contract, minus the devices — the
        8-device version runs in the slow suite)."""
        from jax.sharding import Mesh

        cfg = _small_cfg()
        x, y = _small_data(n=32, seed=9)
        key = jax.random.PRNGKey(11)
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))

        plain = TrainerEngine(cfg, batch_size=16)
        meshed = TrainerEngine(cfg, batch_size=16, mesh=mesh)
        ds_a = plain.prepare(x, y, booleanize_method="none")
        ds_b = meshed.prepare(x, y, booleanize_method="none")
        m_a = plain.init_model(key)
        m_b = meshed.init_model(key)
        _, m_a, _, _ = plain.fit(key, m_a, ds_a, epochs=1)
        _, m_b, _, _ = meshed.fit(key, m_b, ds_b, epochs=1)
        np.testing.assert_array_equal(
            np.asarray(m_a.ta_state), np.asarray(m_b.ta_state)
        )
        np.testing.assert_array_equal(
            np.asarray(m_a.weights), np.asarray(m_b.weights)
        )


class TestEvaluateHostSyncContract:
    """Regression pins for the tmlint TM103 fix: evaluate() used to
    int() every chunk inside the dispatch loop, serializing chunk k+1's
    dispatch behind chunk k's compute."""

    def _engine_and_ds(self, n=23, eval_batch=7):
        cfg = _small_cfg()
        x, y = _small_data(n=n)
        engine = TrainerEngine(cfg, batch_size=8, eval_batch=eval_batch)
        ds = engine.prepare(x, y, booleanize_method="none")
        return engine, ds

    def test_single_host_conversion_per_evaluate(self):
        """Exactly ONE int() conversion for the whole split, however many
        chunks it evaluates (here ceil(23/7) = 4 chunks)."""
        engine, ds = self._engine_and_ds()
        model = engine.init_model(jax.random.PRNGKey(0))
        conversions = {"n": 0}
        real_eval = engine._eval_fn

        class Spy:
            def __init__(self, v):
                self.v = v

            def __add__(self, other):
                return Spy(self.v + (other.v if isinstance(other, Spy) else other))

            __radd__ = __add__

            def __int__(self):
                conversions["n"] += 1
                return int(self.v)

        engine._eval_fn = lambda *a: Spy(real_eval(*a))
        acc = engine.evaluate(model, ds)
        assert conversions["n"] == 1
        assert 0.0 <= acc <= 1.0

    def test_chunked_evaluate_bitexact_vs_single_dispatch(self):
        """Chunking (and the deferred conversion) never changes the
        result: same accuracy as one whole-dataset dispatch."""
        engine, ds = self._engine_and_ds()
        model = engine.init_model(jax.random.PRNGKey(1))
        acc = engine.evaluate(model, ds)
        whole = int(engine._eval_fn(model, ds.literals, ds.labels))
        assert acc == whole / ds.n


class TestTrainerNoRecompile:
    def test_steady_state_epochs_do_not_recompile(self):
        """After the first epoch + evaluate compile, further same-shape
        epochs and evals reuse the caches (tools/recompile_guard)."""
        from tools.recompile_guard import no_recompiles

        cfg = _small_cfg()
        x, y = _small_data(n=64)
        engine = TrainerEngine(cfg, batch_size=16, eval_batch=32)
        ds = engine.prepare(x, y, booleanize_method="none")
        key = jax.random.PRNGKey(5)
        model = engine.init_model(key)
        # warm both executables: one epoch + one eval (full chunk shape)
        key, model, state, _ = engine.run_epoch(key, model, ds)
        engine.evaluate(model, ds)
        with no_recompiles((engine, "_epoch_fn"), (engine, "_eval_fn")):
            for _ in range(2):
                key, model, state, _ = engine.run_epoch(key, model, ds, state)
                engine.evaluate(model, ds)
